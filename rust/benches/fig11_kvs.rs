//! `cargo bench --bench fig11_kvs` — regenerates Fig 11(c)(d)(e) (KV stores vs models, single core).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    let mut backend = exp::ModelBackend::auto();
    eprintln!("model backend: {}", backend.name());
    for r in exp::fig11_kvs(&mut backend, fast) { r.print(); }
    eprintln!("[fig11_kvs] regenerated in {:.1?}", t0.elapsed());
}
