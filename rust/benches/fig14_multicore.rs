//! `cargo bench --bench fig14_multicore` — regenerates Fig 14 (multicore scaling).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    for r in exp::fig14(fast) { r.print(); }
    eprintln!("[fig14_multicore] regenerated in {:.1?}", t0.elapsed());
}
