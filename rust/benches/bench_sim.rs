//! `cargo bench --bench bench_sim` — wall-clock of the fixed fast-mode
//! sweep (plain main; no criterion in the offline image). Writes
//! `BENCH_sim.json` (points/sec, total wall seconds, simulated ops per wall
//! second) at the workspace root so successive commits can compare the
//! simulator's host-side cost. `CXLKVS_FAST=1` shrinks the windows for the
//! CI smoke run.

use cxlkvs::coordinator::bench::run_fixed_sweep;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let window_ms = if fast_mode() { 5.0 } else { 20.0 };
    println!("== bench_sim == (window {window_ms} ms/point)");
    let r = run_fixed_sweep(window_ms);
    print!("{}", r.to_json());
    match r.write_json() {
        Ok(p) => eprintln!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write BENCH_sim.json: {e}"),
    }
}
