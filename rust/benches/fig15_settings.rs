//! `cargo bench --bench fig15_settings` — regenerates Fig 15 (Table 5 setting variations).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    exp::fig15(fast).print();
    eprintln!("[fig15_settings] regenerated in {:.1?}", t0.elapsed());
}
