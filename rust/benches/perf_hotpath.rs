//! `cargo bench --bench perf_hotpath` — microbenchmarks of the stack's hot
//! paths (the in-repo replacement for criterion, which is unavailable in the
//! offline image):
//!
//! - simulator event-loop throughput (suboperation slices per second),
//! - KV store slice throughput per design,
//! - compressed-class slice throughput (the inline decompress charge),
//! - PJRT artifact evaluation latency (batch of 64),
//! - native model evaluation latency.
//!
//! Results feed EXPERIMENTS.md §Perf.

use cxlkvs::microbench::{Microbench, MicrobenchConfig};
use cxlkvs::model::{theta_prob_recip, OpParams, SysParams};
use cxlkvs::runtime::{BaseIn, ModelEvaluator};
use cxlkvs::sim::{Dur, Machine, MachineConfig, MemConfig, Rng};
use std::time::Instant;

/// Run `f` a few times; `f` returns (elapsed, work) and the best-rate rep wins.
fn best_of<F: FnMut() -> (std::time::Duration, u64)>(
    reps: usize,
    mut f: F,
) -> (std::time::Duration, u64) {
    let mut best: Option<(std::time::Duration, u64)> = None;
    for _ in 0..reps {
        let (dt, work) = f();
        let better = match &best {
            Some((bd, bw)) => {
                (work as f64 / dt.as_secs_f64()) > (*bw as f64 / bd.as_secs_f64())
            }
            None => true,
        };
        if better {
            best = Some((dt, work));
        }
    }
    best.unwrap()
}

fn sim_event_loop() {
    // 1 simulated core, 64 threads, M=10+IO at 5 µs: measure simulated
    // suboperations (slices) per wall second.
    let (dt, subops) = best_of(3, || {
        let mut rng = Rng::new(1);
        let mb = Microbench::new(MicrobenchConfig::default(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 64,
                mem: MemConfig::fpga(Dur::us(5.0)),
                ..Default::default()
            },
            mb,
        );
        let t = Instant::now();
        let st = m.run(Dur::ms(2.0), Dur::ms(150.0));
        (t.elapsed(), st.ops * 12) // M + IO subops per op
    });
    println!(
        "sim_event_loop: {:>12.0} subops/sec  ({} subops in {:.1?})",
        subops as f64 / dt.as_secs_f64(),
        subops,
        dt
    );
}

fn kv_slice_throughput() {
    use cxlkvs::kvs::{CacheKv, CacheKvConfig, LsmKv, LsmKvConfig, TreeKv, TreeKvConfig};
    let mcfg = || MachineConfig {
        threads_per_core: 64,
        n_locks: 64,
        mem: MemConfig::fpga(Dur::us(5.0)),
        ..Default::default()
    };
    // Time the simulation only — store construction (population) is a
    // one-time load phase, not the hot path.
    let (dt, ops) = best_of(3, || {
        let mut rng = Rng::new(2);
        let kv = TreeKv::new(TreeKvConfig::default(), &mut rng);
        let mut m = Machine::new(mcfg(), kv);
        let t = Instant::now();
        let ops = m.run(Dur::ms(2.0), Dur::ms(60.0)).ops;
        (t.elapsed(), ops)
    });
    println!(
        "treekv_sim:     {:>12.0} sim-ops/wall-sec ({:.1?})",
        ops as f64 / dt.as_secs_f64(),
        dt
    );
    let (dt, ops) = best_of(3, || {
        let mut rng = Rng::new(3);
        let kv = LsmKv::new(LsmKvConfig::default(), &mut rng);
        let mut m = Machine::new(mcfg(), kv);
        let t = Instant::now();
        let ops = m.run(Dur::ms(2.0), Dur::ms(60.0)).ops;
        (t.elapsed(), ops)
    });
    println!(
        "lsmkv_sim:      {:>12.0} sim-ops/wall-sec ({:.1?})",
        ops as f64 / dt.as_secs_f64(),
        dt
    );
    let (dt, ops) = best_of(3, || {
        let mut rng = Rng::new(4);
        let kv = CacheKv::new(CacheKvConfig::default(), &mut rng);
        let mut m = Machine::new(mcfg(), kv);
        let t = Instant::now();
        let ops = m.run(Dur::ms(2.0), Dur::ms(60.0)).ops;
        (t.elapsed(), ops)
    });
    println!(
        "cachekv_sim:    {:>12.0} sim-ops/wall-sec ({:.1?})",
        ops as f64 / dt.as_secs_f64(),
        dt
    );
}

fn compressed_slice_throughput() {
    use cxlkvs::kvs::{CompressMode, Compression, LsmKv, LsmKvConfig, PlacementPolicy};
    // Same machine as kv_slice_throughput; unbounded budget so every
    // offloadable class is DRAM-resident, once plain and once forced
    // compressed — the delta is the host-side cost of the inline
    // decompress charge on the store hot path.
    let mcfg = || MachineConfig {
        threads_per_core: 64,
        n_locks: 64,
        mem: MemConfig::fpga(Dur::us(5.0)),
        ..Default::default()
    };
    let run = |mode: CompressMode| {
        best_of(3, move || {
            let mut rng = Rng::new(5);
            let kv = LsmKv::new(
                LsmKvConfig {
                    placement: PlacementPolicy::Budget {
                        dram_bytes: u64::MAX,
                    },
                    compression: mode,
                    ..Default::default()
                },
                &mut rng,
            );
            let mut m = Machine::new(mcfg(), kv);
            let t = Instant::now();
            let ops = m.run(Dur::ms(2.0), Dur::ms(60.0)).ops;
            (t.elapsed(), ops)
        })
    };
    let (dt, ops) = run(CompressMode::Off);
    println!(
        "lsmkv_plain:    {:>12.0} sim-ops/wall-sec ({:.1?})",
        ops as f64 / dt.as_secs_f64(),
        dt
    );
    let (dt, ops) = run(CompressMode::Forced(Compression::new(0.5, 0.12)));
    println!(
        "lsmkv_cpr:      {:>12.0} sim-ops/wall-sec ({:.1?})",
        ops as f64 / dt.as_secs_f64(),
        dt
    );
}

fn pjrt_eval() {
    let Ok(mut ev) = ModelEvaluator::load_default() else {
        println!("pjrt_eval:      skipped (run `make artifacts`)");
        return;
    };
    let inputs: Vec<BaseIn> = (0..64)
        .map(|i| BaseIn {
            m: 10.0,
            t_mem: 0.1,
            t_pre: 1.5,
            t_post: 0.2,
            l_mem: 0.1 + i as f32 * 0.15,
            t_sw: 0.05,
            p: 12.0,
            n: 1e6,
        })
        .collect();
    // Warm once (compile is already done at load; first exec touches buffers).
    let _ = ev.eval_base(&inputs).unwrap();
    let (dt, n) = best_of(5, || {
        let t = Instant::now();
        let mut cnt = 0;
        for _ in 0..20 {
            let out = ev.eval_base(&inputs).unwrap();
            cnt += out.len() as u64;
        }
        (t.elapsed(), cnt)
    });
    println!(
        "pjrt_eval:      {:>12.0} model-evals/sec (batch=64, {:.1?} per 20 batches)",
        n as f64 / dt.as_secs_f64(),
        dt
    );
}

fn native_eval() {
    let op = OpParams::table1_example();
    let sys = SysParams::table1_example();
    let (dt, n) = best_of(5, || {
        let t = Instant::now();
        let mut acc = 0.0;
        for i in 0..1280 {
            acc += theta_prob_recip(&op, 0.1 + (i % 64) as f64 * 0.15, &sys);
        }
        std::hint::black_box(acc);
        (t.elapsed(), 1280)
    });
    println!(
        "native_eval:    {:>12.0} model-evals/sec ({:.1?} per 1280)",
        n as f64 / dt.as_secs_f64(),
        dt
    );
}

fn main() {
    println!("== perf_hotpath ==");
    sim_event_loop();
    kv_slice_throughput();
    compressed_slice_throughput();
    pjrt_eval();
    native_eval();
}
