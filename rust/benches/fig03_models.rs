//! `cargo bench --bench fig03_models` — regenerates the paper's Fig 3 (model curves, Table 1 example values).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    let mut backend = exp::ModelBackend::auto();
    eprintln!("model backend: {}", backend.name());
    exp::fig03(&mut backend).print();
    let _ = fast;
    eprintln!("[fig03_models] regenerated in {:.1?}", t0.elapsed());
}
