//! `cargo bench --bench fig17_op_latency` — regenerates Fig 17 (KV operation latency).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    exp::fig17(fast).print();
    eprintln!("[fig17_op_latency] regenerated in {:.1?}", t0.elapsed());
}
