//! `cargo bench --bench val1404` — regenerates the 1,404-combination model-validation sweep (§4.1.2).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    let mut backend = exp::ModelBackend::auto();
    eprintln!("model backend: {}", backend.name());
    exp::val1404(&mut backend, fast).print();
    eprintln!("[val1404] regenerated in {:.1?}", t0.elapsed());
}
