//! `cargo bench --bench table6_cpr` — regenerates Table 6 (cost-performance ratios).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    exp::table6(fast).print();
    eprintln!("[table6_cpr] regenerated in {:.1?}", t0.elapsed());
}
