//! `cargo bench --bench ablation_extensions` — ablations of the paper's
//! §5.2 future-work directions, implemented as first-class features:
//!
//! 1. §5.2.3 index tiering: full offload vs random-fraction placement vs
//!    access-aware top-levels placement, at equal-ish DRAM budgets.
//! 2. §5.2.4 on-device cache: a flash-backed CXL device with a DRAM buffer
//!    serving 30%/60% of loads at 400 ns.
//!
//! Both report the Aerospike-like store's normalized throughput at 5 µs
//! (vs all-DRAM placement), the paper's headline metric.

use cxlkvs::coordinator::report::{f2, f3, Report};
use cxlkvs::coordinator::runner::{best_threads, run_tree_with, SweepCfg};
use cxlkvs::kvs::{PlacementPolicy, TreeKv, TreeKvConfig};
use cxlkvs::sim::{Dur, Machine, Rng};

fn dram_baseline(window: Dur) -> f64 {
    let sweep = SweepCfg {
        l_mem: Dur::us(0.1),
        window,
        thread_candidates: vec![32, 64],
        ..Default::default()
    };
    best_threads(&sweep.thread_candidates.clone(), |n| {
        run_tree_with(TreeKvConfig::default(), &sweep, n)
    })
    .1
    .ops_per_sec
}

fn run_tiering(policy: PlacementPolicy, window: Dur) -> (f64, f64, f64) {
    let cfg = TreeKvConfig {
        placement: policy,
        ..Default::default()
    };
    // Capacity-side DRAM fraction (what the operator pays for).
    let mut rng = Rng::new(0x7143);
    let probe = TreeKv::new(cfg.clone(), &mut rng);
    let cap_frac = probe.dram_entry_fraction();
    drop(probe);

    // 8 µs: past the full-offload knee, so the policies separate.
    let sweep = SweepCfg {
        l_mem: Dur::us(8.0),
        window,
        thread_candidates: vec![32, 64],
        ..Default::default()
    };
    let (_, st) = best_threads(&sweep.thread_candidates.clone(), |n| {
        run_tree_with(cfg.clone(), &sweep, n)
    });
    (st.ops_per_sec, cap_frac, st.mean_m)
}

fn main() {
    let fast = cxlkvs::coordinator::runner::fast_mode();
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(15.0) };
    let t0 = std::time::Instant::now();

    let dram = dram_baseline(window);

    // --- §5.2.3 tiering policies ------------------------------------------
    let mut r = Report::new(
        "Ablation §5.2.3 — index tiering policies (treekv @ 8us, vs all-DRAM)",
        &["policy", "DRAM capacity share", "measured M", "norm throughput"],
    );
    for (name, policy) in [
        ("full offload (rho=1)", PlacementPolicy::AllSecondary),
        ("random 2% in DRAM", PlacementPolicy::Random { dram_frac: 0.02 }),
        ("random 30% in DRAM", PlacementPolicy::Random { dram_frac: 0.30 }),
        ("top 4 levels in DRAM", PlacementPolicy::TopLevels { k: 4 }),
        ("top 7 levels in DRAM", PlacementPolicy::TopLevels { k: 7 }),
    ] {
        let (ops, cap, m) = run_tiering(policy, window);
        r.row(vec![
            name.into(),
            f3(cap),
            f2(m),
            f3(ops / dram),
        ]);
    }
    r.note("top-levels placement buys more latency-tolerance per DRAM byte");
    r.note("than the random placement Eq 15's rho-interpolation assumes");
    r.write_csv("ablation_tiering").ok();
    r.print();

    // --- §5.2.4 on-device cache -------------------------------------------
    let mut r = Report::new(
        "Ablation §5.2.4 — on-device cache (treekv @ 5us flash + tail)",
        &["device", "norm throughput"],
    );
    for (name, hit) in [
        ("no device cache", 0.0),
        ("30% hits @ 400ns", 0.3),
        ("60% hits @ 400ns", 0.6),
    ] {
        let sweep = SweepCfg {
            l_mem: Dur::us(5.0),
            tail: true,
            window,
            thread_candidates: vec![32, 64],
            ..Default::default()
        };
        let (_, st) = best_threads(&sweep.thread_candidates.clone(), |n| {
            let mut mcfg = sweep.machine(n);
            if hit > 0.0 {
                mcfg.mem = mcfg.mem.with_device_cache(hit, Dur::ns(400.0));
            }
            let mut rng = Rng::new(0xdc ^ n as u64);
            let kv = TreeKv::new(TreeKvConfig::default(), &mut rng);
            Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
        });
        r.row(vec![name.into(), f3(st.ops_per_sec / dram)]);
    }
    r.note("an on-device DRAM buffer recovers most of the tail-latency loss");
    r.write_csv("ablation_device_cache").ok();
    r.print();

    eprintln!("[ablation_extensions] regenerated in {:.1?}", t0.elapsed());
}
