//! `cargo bench --bench fig16_threads` — regenerates Fig 16 (throughput vs thread count).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    exp::fig16(fast).print();
    eprintln!("[fig16_threads] regenerated in {:.1?}", t0.elapsed());
}
