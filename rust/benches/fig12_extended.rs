//! `cargo bench --bench fig12_extended` — regenerates Fig 12 (extended-model scenarios).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    let mut backend = exp::ModelBackend::auto();
    eprintln!("model backend: {}", backend.name());
    for r in exp::fig12(&mut backend, fast) { r.print(); }
    eprintln!("[fig12_extended] regenerated in {:.1?}", t0.elapsed());
}
