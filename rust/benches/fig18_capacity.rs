//! `cargo bench --bench fig18_capacity` — regenerates Fig 18 (capacity-expansion scenarios).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    exp::fig18(fast).print();
    eprintln!("[fig18_capacity] regenerated in {:.1?}", t0.elapsed());
}
