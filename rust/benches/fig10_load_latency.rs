//! `cargo bench --bench fig10_load_latency` — regenerates Fig 10 (load-latency distributions + eviction ratio).
//! Respects CXLKVS_FAST=1 for a pruned smoke run.

use cxlkvs::coordinator::experiments as exp;
use cxlkvs::coordinator::runner::fast_mode;

fn main() {
    let fast = fast_mode();
    let t0 = std::time::Instant::now();
    for r in exp::fig10(fast) { r.print(); }
    eprintln!("[fig10_load_latency] regenerated in {:.1?}", t0.elapsed());
}
