//! Experiment implementations: one function per paper figure/table.
//!
//! Each returns `Report`s whose rows are the series the paper plots. Model
//! curves are evaluated through the AOT-compiled JAX+Pallas artifact via
//! PJRT when `artifacts/` is present (the production path), falling back to
//! the native Rust model otherwise (e.g. in unit tests before `make
//! artifacts`).

use super::report::{f1, f2, f3, Report};
use super::runner::{
    best_threads, best_threads_by, crash_recover_check, parallel_map, run_cache_with,
    run_lsm_interference, run_lsm_with, run_microbench, run_store, run_store_ycsb_adaptive,
    run_store_ycsb_compressed, run_store_ycsb_durable, run_store_ycsb_placed,
    run_store_ycsb_profiled, run_store_ycsb_snap, run_store_ycsb_tenants, run_tree_with,
    store_offload_bytes, AdaptiveCfg, DurableRun, InterferenceRun, MeasuredParams, StoreKind,
    SweepCfg,
};
use crate::kvs::{
    model_mix, CacheKv, CacheKvConfig, CompressMode, Compression, LsmKv, LsmKvConfig,
    PlacementPolicy, TreeKv, TreeKvConfig, WalConfig,
};
use crate::microbench::MicrobenchConfig;
use crate::model::{self, CprScenario, ExtParams, KindCost, OpParams, SysParams};
use crate::runtime::{BaseIn, ExtIn, ModelEvaluator};
use crate::sim::{BgShare, Dur, ErrorWindow, FaultPlan, RetryPolicy, Time};
use crate::workload::{
    KeyDist, OpMix, OpWeights, PhasedWorkload, ScanLen, TenantSet, TenantSpec, ValueSize,
    YcsbWorkload,
};

/// Model evaluation backend: PJRT artifact (preferred) or native fallback.
pub enum ModelBackend {
    Pjrt(Box<ModelEvaluator>),
    Native,
}

impl ModelBackend {
    /// Load the PJRT artifact if present.
    pub fn auto() -> ModelBackend {
        match ModelEvaluator::load_default() {
            Ok(ev) => ModelBackend::Pjrt(Box::new(ev)),
            Err(_) => ModelBackend::Native,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelBackend::Pjrt(_) => "pjrt(jax+pallas artifact)",
            ModelBackend::Native => "native(rust)",
        }
    }

    /// (mask_recip, prob_recip) for a parameter set at latency l (µs).
    pub fn mask_prob(&mut self, op: &OpParams, sys: &SysParams, l: f64) -> (f64, f64) {
        match self {
            ModelBackend::Pjrt(ev) => {
                let out = ev
                    .eval_base(&[BaseIn {
                        m: op.m as f32,
                        t_mem: op.t_mem as f32,
                        t_pre: op.t_pre as f32,
                        t_post: op.t_post as f32,
                        l_mem: l as f32,
                        t_sw: sys.t_sw as f32,
                        p: sys.p as f32,
                        n: sys.n as f32,
                    }])
                    .expect("pjrt eval");
                (out[0].mask as f64, out[0].prob as f64)
            }
            ModelBackend::Native => (
                model::theta_mask_recip(op, l, sys),
                model::theta_prob_recip(op, l, sys),
            ),
        }
    }

    /// Batched base-model curves over a latency grid.
    pub fn curves(
        &mut self,
        op: &OpParams,
        sys: &SysParams,
        grid: &[f64],
    ) -> Vec<(f64, f64, f64, f64, f64, f64)> {
        match self {
            ModelBackend::Pjrt(ev) => {
                let ins: Vec<BaseIn> = grid
                    .iter()
                    .map(|&l| BaseIn {
                        m: op.m as f32,
                        t_mem: op.t_mem as f32,
                        t_pre: op.t_pre as f32,
                        t_post: op.t_post as f32,
                        l_mem: l as f32,
                        t_sw: sys.t_sw as f32,
                        p: sys.p as f32,
                        n: sys.n as f32,
                    })
                    .collect();
                ev.eval_base(&ins)
                    .expect("pjrt eval")
                    .iter()
                    .map(|o| {
                        (
                            o.single as f64,
                            o.multi as f64,
                            o.mem as f64,
                            o.mask as f64,
                            o.best as f64,
                            o.prob as f64,
                        )
                    })
                    .collect()
            }
            ModelBackend::Native => grid
                .iter()
                .map(|&l| {
                    (
                        model::theta_single_recip(op.t_mem, l),
                        model::theta_multi_recip(op.t_mem, l, sys),
                        model::theta_mem_recip(op.t_mem, l, sys),
                        model::theta_mask_recip(op, l, sys),
                        model::theta_best_recip(op, l, sys),
                        model::theta_prob_recip(op, l, sys),
                    )
                })
                .collect(),
        }
    }

    /// Extended model reciprocal at latency l. The 16-column artifact
    /// interface carries aggregate device rates, so the array term enters
    /// the PJRT path as `n_ssd`-scaled `b_io`/`r_io` (identical algebra to
    /// the native Θ_ssd floors — the HLO signature stays stable).
    pub fn extended(&mut self, op: &OpParams, sys: &SysParams, ext: &ExtParams, l: f64) -> f64 {
        match self {
            ModelBackend::Pjrt(ev) => {
                let n_ssd = ext.n_ssd.max(1.0);
                let out = ev
                    .eval_extended(&[ExtIn {
                        m: op.m as f32,
                        t_mem: op.t_mem as f32,
                        t_pre: op.t_pre as f32,
                        t_post: op.t_post as f32,
                        l_mem: l as f32,
                        t_sw: sys.t_sw as f32,
                        p: sys.p as f32,
                        rho: ext.rho as f32,
                        eps: ext.eps as f32,
                        a_mem: ext.a_mem as f32,
                        b_mem: ext.b_mem as f32,
                        l_dram: ext.l_dram as f32,
                        a_io: ext.a_io as f32,
                        b_io: (ext.b_io * n_ssd) as f32,
                        r_io: (ext.r_io * n_ssd) as f32,
                        s: ext.s as f32,
                    }])
                    .expect("pjrt eval");
                out[0].extended as f64
            }
            ModelBackend::Native => model::theta_extended_recip(op, l, ext, sys),
        }
    }
}

/// The measured testbed system parameters (§4.1.3: T_sw = 50 ns, P = 12).
pub fn sys_params() -> SysParams {
    SysParams::measured_testbed(1_000_000)
}

// ---------------------------------------------------------------------------
// Fig 3 — model curves with Table 1 example values.
// ---------------------------------------------------------------------------

pub fn fig03(backend: &mut ModelBackend) -> Report {
    let op = OpParams::table1_example();
    let sys = SysParams::table1_example();
    let grid: Vec<f64> = vec![0.1, 0.3, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 5.0, 6.0, 8.0, 10.0];
    let curves = backend.curves(&op, &sys, &grid);
    let base = &curves[0];

    let mut r = Report::new(
        "Fig 3 — normalized throughput vs memory latency (Table 1 example values)",
        &["L_mem(us)", "single", "multi", "mem-only(P)", "masking", "ours(prob)"],
    );
    for (l, c) in grid.iter().zip(curves.iter()) {
        r.row(vec![
            f1(*l),
            f3(base.0 / c.0),
            f3(base.1 / c.1),
            f3(base.2 / c.2),
            f3(base.3 / c.3),
            f3(base.5 / c.5),
        ]);
    }
    r.note(format!("model backend: {}", backend.name()));
    r.note("paper: masking-only predicts 29% degradation at 5us, ours 7%");
    r
}

// ---------------------------------------------------------------------------
// Fig 10 — load-latency distribution and premature eviction ratio ε.
// ---------------------------------------------------------------------------

pub fn fig10(fast: bool) -> Vec<Report> {
    let window = if fast { Dur::ms(8.0) } else { Dur::ms(30.0) };
    let mk = |cache_lines: u64, title: &str, name: &str| {
        let sweep = SweepCfg {
            l_mem: Dur::us(10.0),
            cache_lines,
            window,
            ..Default::default()
        };
        let mb = MicrobenchConfig::default();
        let mut rng = crate::sim::Rng::new(7);
        let service = crate::microbench::Microbench::new(mb, &mut rng);
        let mut machine = crate::sim::Machine::new(sweep.machine(64), service);
        machine.run(sweep.warmup, sweep.window);
        let mut r = Report::new(title, &["load_wait_us(bucket<=)", "count", "fraction"]);
        let hist = &machine.metrics.load_wait;
        let total = hist.total().max(1);
        for (edge, count) in hist.buckets() {
            r.row(vec![
                f2(edge.as_us()),
                count.to_string(),
                format!("{:.6}", count as f64 / total as f64),
            ]);
        }
        let eps = machine.metrics.evictions as f64 / machine.metrics.loads.max(1) as f64;
        r.note(format!("premature eviction ratio eps = {eps:.5}"));
        r.write_csv(name).ok();
        r
    };
    vec![
        mk(
            1_000_000,
            "Fig 10(a) — load latency distribution, 60MB-class cache, L=10us",
            "fig10a",
        ),
        mk(
            512,
            "Fig 10(b) — load latency distribution, reduced cache, L=10us",
            "fig10b",
        ),
    ]
}

// ---------------------------------------------------------------------------
// Fig 11(a)(b) — microbenchmark vs models.
// ---------------------------------------------------------------------------

pub fn fig11_micro(backend: &mut ModelBackend, fast: bool) -> Vec<Report> {
    let grid = if fast {
        SweepCfg::latency_grid_fast()
    } else {
        SweepCfg::latency_grid()
    };
    let combos = [
        (
            "Fig 11(a) — microbench M=10 T_mem=0.10 T_pre=1.5 T_post=0.2",
            "fig11a",
            MicrobenchConfig::default(),
            OpParams {
                m: 10.0,
                t_mem: 0.1,
                t_pre: 1.5,
                t_post: 0.2,
            },
        ),
        (
            "Fig 11(b) — microbench M=10 T_mem=0.10 T_pre=3.5 T_post=2.2",
            "fig11b",
            MicrobenchConfig {
                extra_pre: Dur::us(2.0),
                extra_post: Dur::us(2.0),
                ..MicrobenchConfig::default()
            },
            OpParams {
                m: 10.0,
                t_mem: 0.1,
                t_pre: 3.5,
                t_post: 2.2,
            },
        ),
    ];
    let sys = sys_params();
    let mut out = Vec::new();
    for (title, name, mb, op) in combos {
        let window = if fast { Dur::ms(10.0) } else { Dur::ms(25.0) };
        // Measured points in parallel over the latency grid.
        let jobs: Vec<_> = grid
            .iter()
            .map(|&l| {
                let mb = mb.clone();
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    window,
                    ..Default::default()
                };
                move || {
                    best_threads(&sweep.thread_candidates.clone(), |n| {
                        run_microbench(&mb, &sweep, n)
                    })
                    .1
                    .ops_per_sec
                }
            })
            .collect();
        let measured = parallel_map(jobs);
        let dram_measured = measured[0];

        let mut r = Report::new(
            title,
            &["L_mem(us)", "measured", "masking", "ours(prob)"],
        );
        let (mask0, prob0) = backend.mask_prob(&op, &sys, grid[0]);
        for (i, &l) in grid.iter().enumerate() {
            let (mask, prob) = backend.mask_prob(&op, &sys, l);
            r.row(vec![
                f1(l),
                f3(measured[i] / dram_measured),
                f3(mask0 / mask),
                f3(prob0 / prob),
            ]);
        }
        r.note(format!("model backend: {}", backend.name()));
        r.write_csv(name).ok();
        out.push(r);
    }
    out
}

// ---------------------------------------------------------------------------
// §4.1.2 — the 1,404-combination validation sweep.
// ---------------------------------------------------------------------------

pub fn val1404(backend: &mut ModelBackend, fast: bool) -> Report {
    let ms = if fast { vec![1u32, 10] } else { vec![1, 5, 10, 15] };
    let tmems = if fast { vec![0.10] } else { vec![0.10, 0.12, 0.14] };
    let tpres = if fast { vec![1.5, 3.5] } else { vec![1.5, 2.5, 3.5] };
    let tposts = if fast { vec![0.2, 2.2] } else { vec![0.2, 1.2, 2.2] };
    let grid = if fast {
        vec![0.1, 1.0, 3.0, 5.0, 10.0]
    } else {
        SweepCfg::latency_grid()
    };
    let window = if fast { Dur::ms(8.0) } else { Dur::ms(15.0) };

    struct Combo {
        m: u32,
        t_mem: f64,
        t_pre: f64,
        t_post: f64,
    }
    let mut combos = Vec::new();
    for &m in &ms {
        for &t_mem in &tmems {
            for &t_pre in &tpres {
                for &t_post in &tposts {
                    combos.push(Combo {
                        m,
                        t_mem,
                        t_pre,
                        t_post,
                    });
                }
            }
        }
    }

    let sys = sys_params();
    let mut n_points = 0usize;
    let mut mask_underest_max = 0.0f64; // max (measured-mask)/measured
    let mut prob_err_lo = 0.0f64;
    let mut prob_err_hi = 0.0f64;
    let mut prob_abs_sum = 0.0f64;
    let mut errs: Vec<f64> = Vec::new();

    for c in &combos {
        let mb = MicrobenchConfig {
            m: c.m,
            t_mem: Dur::us(c.t_mem),
            extra_pre: Dur::us(c.t_pre - 1.5),
            extra_post: Dur::us(c.t_post - 0.2),
            ..MicrobenchConfig::default()
        };
        let op = OpParams {
            m: c.m as f64,
            t_mem: c.t_mem,
            t_pre: c.t_pre,
            t_post: c.t_post,
        };
        let jobs: Vec<_> = grid
            .iter()
            .map(|&l| {
                let mb = mb.clone();
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    window,
                    thread_candidates: vec![32, 64, 128],
                    ..Default::default()
                };
                move || {
                    best_threads(&sweep.thread_candidates.clone(), |n| {
                        run_microbench(&mb, &sweep, n)
                    })
                    .1
                    .ops_per_sec
                }
            })
            .collect();
        let measured = parallel_map(jobs);
        let dram = measured[0];
        let (mask0, prob0) = backend.mask_prob(&op, &sys, grid[0]);
        for (i, &l) in grid.iter().enumerate() {
            let (mask, prob) = backend.mask_prob(&op, &sys, l);
            let nm = measured[i] / dram;
            let nmask = mask0 / mask;
            let nprob = prob0 / prob;
            mask_underest_max = mask_underest_max.max((nm - nmask) / nm);
            let err = (nprob - nm) / nm;
            prob_err_lo = prob_err_lo.min(err);
            prob_err_hi = prob_err_hi.max(err);
            prob_abs_sum += err.abs();
            errs.push(err);
            n_points += 1;
        }
    }

    let mut r = Report::new(
        "§4.1.2 — model validation over the microbenchmark parameter sweep",
        &["metric", "value"],
    );
    r.row(vec!["points".into(), n_points.to_string()]);
    r.row(vec![
        "masking max underestimate".into(),
        format!("{:.1}%", 100.0 * mask_underest_max),
    ]);
    r.row(vec![
        "prob model error range".into(),
        format!("[{:.1}%, {:+.1}%]", 100.0 * prob_err_lo, 100.0 * prob_err_hi),
    ]);
    r.row(vec![
        "prob model mean |error|".into(),
        format!("{:.1}%", 100.0 * prob_abs_sum / n_points as f64),
    ]);
    errs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| errs[((p * (errs.len() - 1) as f64) as usize).min(errs.len() - 1)];
    r.row(vec![
        "prob model error p5..p95".into(),
        format!("[{:.1}%, {:+.1}%]", 100.0 * q(0.05), 100.0 * q(0.95)),
    ]);
    r.row(vec![
        "prob model |error| p90".into(),
        format!("{:.1}%", 100.0 * q(0.90).abs().max(q(0.10).abs())),
    ]);
    r.note("paper: masking underestimates by up to 32.7%; ours within [-5.0%, +6.8%]");
    r.note("tail errors concentrate at heavy-post-IO combos where the sim's");
    r.note("queued-prefetch discipline waits more than the model's window bound");
    r.write_csv("val1404").ok();
    r
}

// ---------------------------------------------------------------------------
// Fig 11(c)(d)(e) — the three KV stores, single core, vs models.
// ---------------------------------------------------------------------------

/// Per-store per-IO CPU suboperation times (device base + store extras,
/// which are configured constants — see each store's Io steps).
fn store_io_times(kind: StoreKind) -> (f64, f64) {
    match kind {
        StoreKind::Tree => (1.5 + 2.0, 0.2 + 2.3),
        StoreKind::Lsm => (1.5 + 1.5, 0.2 + 3.0),
        StoreKind::Cache => (1.5 + 1.0, 0.2 + 2.0),
    }
}

pub fn fig11_kvs(backend: &mut ModelBackend, fast: bool) -> Vec<Report> {
    let grid = if fast {
        vec![0.1, 1.0, 3.0, 5.0, 8.0, 10.0]
    } else {
        SweepCfg::latency_grid()
    };
    let window = if fast { Dur::ms(8.0) } else { Dur::ms(20.0) };
    let sys = sys_params();
    let mut out = Vec::new();

    for (kind, fig, name) in [
        (StoreKind::Tree, "Fig 11(c) — Aerospike-like treekv", "fig11c"),
        (StoreKind::Lsm, "Fig 11(d) — RocksDB-like lsmkv", "fig11d"),
        (StoreKind::Cache, "Fig 11(e) — CacheLib-like cachekv", "fig11e"),
    ] {
        let jobs: Vec<_> = grid
            .iter()
            .map(|&l| {
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    window,
                    ..Default::default()
                };
                move || {
                    best_threads(&sweep.thread_candidates.clone(), |n| {
                        run_store(kind, &sweep, n)
                    })
                    .1
                }
            })
            .collect();
        let stats = parallel_map(jobs);
        let dram = &stats[0];

        // Measured model parameters from the DRAM-placement run.
        let (t_pre, t_post) = store_io_times(kind);
        let mp = MeasuredParams::from_stats(dram, t_pre, t_post);
        let op = OpParams {
            m: mp.m_per_io(),
            t_mem: mp.t_mem,
            t_pre,
            t_post,
        };

        let mut r = Report::new(
            &format!(
                "{fig} (measured M={:.1} S={:.2} T_mem={:.3} T_pre={:.1} T_post={:.1})",
                mp.m, mp.s, mp.t_mem, t_pre, t_post
            ),
            &["L_mem(us)", "measured", "masking", "ours(prob)", "ops/sec"],
        );
        let (mask0, prob0) = backend.mask_prob(&op, &sys, grid[0]);
        for (i, &l) in grid.iter().enumerate() {
            let (mask, prob) = backend.mask_prob(&op, &sys, l);
            r.row(vec![
                f1(l),
                f3(stats[i].ops_per_sec / dram.ops_per_sec),
                f3(mask0 / mask),
                f3(prob0 / prob),
                format!("{:.0}", stats[i].ops_per_sec),
            ]);
        }
        r.note(format!("model backend: {}", backend.name()));
        r.write_csv(name).ok();
        out.push(r);
    }
    out
}

// ---------------------------------------------------------------------------
// Fig 12 — extended-model scenarios.
// ---------------------------------------------------------------------------

pub fn fig12(backend: &mut ModelBackend, fast: bool) -> Vec<Report> {
    let grid = if fast {
        vec![0.1, 1.0, 3.0, 5.0, 10.0]
    } else {
        SweepCfg::latency_grid()
    };
    let window = if fast { Dur::ms(8.0) } else { Dur::ms(20.0) };
    let sys = sys_params();
    let op = OpParams {
        m: 10.0,
        t_mem: 0.1,
        t_pre: 1.5,
        t_post: 0.2,
    };
    let base_ext = ExtParams {
        rho: 1.0,
        l_dram: 0.09,
        eps: 0.0,
        a_mem: 64.0,
        b_mem: 1e9,
        a_io: 1536.0,
        b_io: 10_000.0,
        r_io: 2.2,
        s: 1.0,
        n_ssd: 1.0,
        w_log: 0.0,
        s_log: 0.0,
        retry_factor: 1.0,
    };
    let mut out = Vec::new();

    // Each scenario: (title, csv, microbench+machine mutation, ExtParams).
    type Mut = Box<dyn Fn(&mut MicrobenchConfig, &mut SweepCfg) + Sync>;
    let scenarios: Vec<(&str, &str, Mut, ExtParams)> = vec![
        (
            "Fig 12(a) — SSD bandwidth-limited (A_IO=128kB, one SSD)",
            "fig12a",
            Box::new(|mb: &mut MicrobenchConfig, _s: &mut SweepCfg| {
                mb.io_bytes = 128 * 1024;
            }),
            ExtParams {
                a_io: 131_072.0,
                b_io: 2_500.0,
                ..base_ext
            },
        ),
        (
            "Fig 12(b) — SSD IOPS-limited (slow SATA SSD)",
            "fig12b",
            Box::new(|_mb, _s| {}),
            ExtParams {
                r_io: 0.075,
                ..base_ext
            },
        ),
        (
            "Fig 12(c) — memory bandwidth-throttled (B_mem=200MB/s)",
            "fig12c",
            Box::new(|_mb, s: &mut SweepCfg| {
                s.mem_bandwidth = 200e6;
            }),
            ExtParams {
                b_mem: 200.0,
                ..base_ext
            },
        ),
        (
            "Fig 12(d) — CPU cache size-limited",
            "fig12d",
            Box::new(|_mb, s: &mut SweepCfg| {
                // Calibrated so ε ≈ 5% at the 64-thread operating point
                // (the paper reduces the L3 to 4 MB via resctrl).
                s.cache_lines = 512;
            }),
            ExtParams {
                eps: 0.05,
                ..base_ext
            },
        ),
        (
            "Fig 12(e) — tiering rho=0.7 (30% of accesses on DRAM)",
            "fig12e",
            Box::new(|_mb, s: &mut SweepCfg| {
                // ρ is modeled as a latency mixture on the memory device.
                s.seed ^= 1; // distinct stream
            }),
            ExtParams {
                rho: 0.7,
                ..base_ext
            },
        ),
    ];

    for (title, name, mutate, ext) in scenarios {
        let rho = ext.rho;
        let jobs: Vec<_> = grid
            .iter()
            .map(|&l| {
                let mutate = &mutate;
                move || {
                    let mut mb = MicrobenchConfig::default();
                    let mut sweep = SweepCfg {
                        l_mem: Dur::us(l),
                        window,
                        ..Default::default()
                    };
                    mutate(&mut mb, &mut sweep);
                    if name_is_12a(title) {
                        // one SSD: swap device config
                    }
                    let mut mcfg = sweep.machine(64);
                    if title.contains("one SSD") {
                        mcfg.ssd = crate::sim::SsdConfig::optane_single();
                    }
                    if title.contains("SATA") {
                        mcfg.ssd = crate::sim::SsdConfig::sata_slow();
                    }
                    if rho < 1.0 {
                        // mixture: (1-ρ) of lines at DRAM latency
                        mcfg.mem.tail = Some(crate::sim::TailProfile {
                            entries: vec![(Dur::ns(90.0), 1.0 - rho)],
                        });
                    }
                    // The cache-limited scenario pins the paper's 64-thread
                    // operating point (thread-count search would sidestep
                    // the small cache by shrinking concurrency).
                    let cands: &[usize] = if rho < 1.0 || sweep.cache_lines < 1024 {
                        &[64]
                    } else {
                        &[32, 64, 128]
                    };
                    let (_, st) = best_threads(cands, |n| {
                        let mut mc = mcfg.clone();
                        mc.threads_per_core = n;
                        let mut rng = crate::sim::Rng::new(0xf12 ^ n as u64);
                        let svc = crate::microbench::Microbench::new(mb.clone(), &mut rng);
                        crate::sim::Machine::new(mc, svc).run(sweep.warmup, sweep.window)
                    });
                    (st.ops_per_sec, st.eviction_ratio)
                }
            })
            .collect();
        let measured = parallel_map(jobs);

        let mut r = Report::new(
            title,
            &["L_mem(us)", "measured_kops", "extended_model_kops", "eps_measured"],
        );
        for (i, &l) in grid.iter().enumerate() {
            // The cache-limited scenario feeds the *measured* ε back into the
            // model, as the paper does (ε is a measured system parameter).
            let ext_pt = if ext.eps > 0.0 {
                ExtParams {
                    eps: measured[i].1,
                    ..ext
                }
            } else {
                ext
            };
            let recip = backend.extended(&op, &sys, &ext_pt, l);
            let model_ops = 1e6 / recip; // µs/op → ops/sec
            r.row(vec![
                f1(l),
                f1(measured[i].0 / 1e3),
                f1(model_ops / 1e3),
                format!("{:.4}", measured[i].1),
            ]);
        }
        r.note(format!("model backend: {}", backend.name()));
        r.write_csv(name).ok();
        out.push(r);
    }
    out
}

fn name_is_12a(title: &str) -> bool {
    title.contains("12(a)")
}

// ---------------------------------------------------------------------------
// Fig 14 — multicore scaling.
// ---------------------------------------------------------------------------

pub fn fig14(fast: bool) -> Vec<Report> {
    let cores_list = if fast {
        vec![1usize, 2, 4]
    } else {
        vec![1, 2, 4, 8, 16]
    };
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(10.0) };
    let mut out = Vec::new();

    // (a) scaling with cores at L = 5 µs.
    let mut ra = Report::new(
        "Fig 14(a) — multicore throughput at L_mem=5us",
        &["store", "cores", "ops/sec", "vs 1-core"],
    );
    for kind in StoreKind::ALL {
        let jobs: Vec<_> = cores_list
            .iter()
            .map(|&c| {
                let sweep = SweepCfg {
                    cores: c,
                    window,
                    thread_candidates: vec![32, 64],
                    ..Default::default()
                };
                move || {
                    best_threads(&sweep.thread_candidates.clone(), |n| {
                        run_store(kind, &sweep, n)
                    })
                    .1
                    .ops_per_sec
                }
            })
            .collect();
        let ops = parallel_map(jobs);
        for (i, &c) in cores_list.iter().enumerate() {
            ra.row(vec![
                kind.name().into(),
                c.to_string(),
                format!("{:.0}", ops[i]),
                f2(ops[i] / ops[0]),
            ]);
        }
    }
    ra.note("paper: 1.8-1.9x per core-count doubling (sublinear from contention)");
    ra.write_csv("fig14a").ok();
    out.push(ra);

    // (b) latency sweep at the largest core count.
    let max_cores = *cores_list.last().unwrap();
    let grid = if fast {
        vec![0.1, 1.0, 5.0, 10.0]
    } else {
        vec![0.1, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0]
    };
    let mut rb = Report::new(
        &format!("Fig 14(b) — normalized throughput vs latency at {max_cores} cores"),
        &["L_mem(us)", "treekv", "lsmkv", "cachekv"],
    );
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for kind in StoreKind::ALL {
        let jobs: Vec<_> = grid
            .iter()
            .map(|&l| {
                let sweep = SweepCfg {
                    cores: max_cores,
                    l_mem: Dur::us(l),
                    window,
                    thread_candidates: vec![32, 64],
                    ..Default::default()
                };
                move || {
                    best_threads(&sweep.thread_candidates.clone(), |n| {
                        run_store(kind, &sweep, n)
                    })
                    .1
                    .ops_per_sec
                }
            })
            .collect();
        let ops = parallel_map(jobs);
        cols.push(ops.iter().map(|o| o / ops[0]).collect());
    }
    for (i, &l) in grid.iter().enumerate() {
        rb.row(vec![f1(l), f3(cols[0][i]), f3(cols[1][i]), f3(cols[2][i])]);
    }
    rb.note("paper: <2% degradation up to 5us for Aerospike/CacheLib at 16 cores");
    rb.write_csv("fig14b").ok();
    out.push(rb);
    out
}

// ---------------------------------------------------------------------------
// Fig 15 — settings variations (Table 5).
// ---------------------------------------------------------------------------

pub fn fig15(fast: bool) -> Report {
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(15.0) };
    let at = move |l: f64| SweepCfg {
        l_mem: Dur::us(l),
        window,
        thread_candidates: vec![32, 64],
        ..Default::default()
    };

    // Each variation: name + closure running (latency) -> ops/sec.
    type RunFn = Box<dyn Fn(f64) -> f64 + Sync + Send>;
    let mut variations: Vec<(String, RunFn)> = Vec::new();

    // treekv: value sizes, distributions, write mixes.
    let tree_cases: Vec<(&str, TreeKvConfig)> = vec![
        ("treekv value=1k", TreeKvConfig { value_size: ValueSize::Fixed(1000), ..Default::default() }),
        ("treekv value=2-2.5k", TreeKvConfig { value_size: ValueSize::Range(2000, 2500), ..Default::default() }),
        ("treekv zipf1.1", TreeKvConfig { key_dist: KeyDist::Zipf { s: 1.1, scrambled: true }, ..Default::default() }),
        ("treekv rw2:1", TreeKvConfig { mix: OpMix::ratio(2, 1), ..Default::default() }),
        ("treekv rw1:1", TreeKvConfig { mix: OpMix::ratio(1, 1), ..Default::default() }),
    ];
    for (name, cfg) in tree_cases {
        let at = at.clone();
        variations.push((
            name.to_string(),
            Box::new(move |l| {
                let sweep = at(l);
                best_threads(&sweep.thread_candidates.clone(), |n| {
                    run_tree_with(cfg.clone(), &sweep, n)
                })
                .1
                .ops_per_sec
            }),
        ));
    }
    // lsmkv: key sizes (block fanout), distribution, write mixes.
    let lsm_cases: Vec<(&str, LsmKvConfig)> = vec![
        ("lsmkv value=200", LsmKvConfig { value_size: ValueSize::Fixed(200), keys_per_block: 16, ..Default::default() }),
        ("lsmkv value=800", LsmKvConfig { value_size: ValueSize::Fixed(800), keys_per_block: 4, ..Default::default() }),
        ("lsmkv zipf0.8", LsmKvConfig { key_dist: KeyDist::Zipf { s: 0.8, scrambled: true }, ..Default::default() }),
        ("lsmkv rw2:1", LsmKvConfig { mix: OpMix::ratio(2, 1), ..Default::default() }),
        ("lsmkv rw1:1", LsmKvConfig { mix: OpMix::ratio(1, 1), ..Default::default() }),
    ];
    for (name, cfg) in lsm_cases {
        let at = at.clone();
        variations.push((
            name.to_string(),
            Box::new(move |l| {
                let sweep = at(l);
                best_threads(&sweep.thread_candidates.clone(), |n| {
                    run_lsm_with(cfg.clone(), &sweep, n)
                })
                .1
                .ops_per_sec
            }),
        ));
    }
    // cachekv: value sizes, distribution, mixes.
    let cache_cases: Vec<(&str, CacheKvConfig)> = vec![
        ("cachekv value=100-150", CacheKvConfig { value_size: ValueSize::Range(100, 150), ..Default::default() }),
        ("cachekv value=300-450", CacheKvConfig { value_size: ValueSize::Range(300, 450), ..Default::default() }),
        ("cachekv hotset(graph-leader)", CacheKvConfig { key_dist: KeyDist::HotSet { hot_frac: 0.08, hot_weight: 0.85 }, ..Default::default() }),
        ("cachekv rw1:1", CacheKvConfig { mix: OpMix::ratio(1, 1), ..Default::default() }),
    ];
    for (name, cfg) in cache_cases {
        let at = at.clone();
        variations.push((
            name.to_string(),
            Box::new(move |l| {
                let sweep = at(l);
                best_threads(&sweep.thread_candidates.clone(), |n| {
                    run_cache_with(cfg.clone(), &sweep, n)
                })
                .1
                .ops_per_sec
            }),
        ));
    }

    let names: Vec<String> = variations.iter().map(|(n, _)| n.clone()).collect();
    let jobs: Vec<_> = variations
        .into_iter()
        .map(|(_, f)| {
            move || {
                let dram = f(0.1);
                let two = f(2.0);
                let five = f(5.0);
                (two / dram, five / dram)
            }
        })
        .collect();
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "Fig 15 — latency-tolerance across KV store settings (Table 5 variations)",
        &["setting", "norm@2us", "norm@5us"],
    );
    let mut geo = 0.0;
    for (name, (n2, n5)) in names.iter().zip(results.iter()) {
        r.row(vec![name.clone(), f3(*n2), f3(*n5)]);
        geo += n5.ln();
    }
    let geomean = (geo / results.len() as f64).exp();
    r.note(format!(
        "geomean degradation at 5us = {:.1}% (paper: 8%)",
        100.0 * (1.0 - geomean)
    ));
    r.write_csv("fig15").ok();
    r
}

// ---------------------------------------------------------------------------
// Fig 16 — throughput vs number of threads.
// ---------------------------------------------------------------------------

pub fn fig16(fast: bool) -> Report {
    let threads = if fast {
        vec![8usize, 32, 96, 192]
    } else {
        vec![4, 8, 16, 32, 48, 64, 96, 128, 192, 256]
    };
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(15.0) };
    let mut r = Report::new(
        "Fig 16 — throughput vs user-level threads per core (L_mem=5us)",
        &["threads", "treekv", "lsmkv", "cachekv"],
    );
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for kind in StoreKind::ALL {
        let jobs: Vec<_> = threads
            .iter()
            .map(|&n| {
                let sweep = SweepCfg {
                    window,
                    ..Default::default()
                };
                move || run_store(kind, &sweep, n).ops_per_sec
            })
            .collect();
        cols.push(parallel_map(jobs));
    }
    for (i, &n) in threads.iter().enumerate() {
        r.row(vec![
            n.to_string(),
            format!("{:.0}", cols[0][i]),
            format!("{:.0}", cols[1][i]),
            format!("{:.0}", cols[2][i]),
        ]);
    }
    r.note("paper: peak throughput stable across a wide range of thread counts");
    r.write_csv("fig16").ok();
    r
}

// ---------------------------------------------------------------------------
// Fig 17 — KV operation latency.
// ---------------------------------------------------------------------------

pub fn fig17(fast: bool) -> Report {
    let grid = if fast {
        vec![0.1, 1.0, 5.0, 10.0]
    } else {
        vec![0.1, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0]
    };
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(15.0) };
    let mut r = Report::new(
        "Fig 17 — KV operation latency vs memory latency (single core)",
        &["L_mem(us)", "store", "mean(us)", "p50(us)", "p99(us)"],
    );
    for kind in StoreKind::ALL {
        let jobs: Vec<_> = grid
            .iter()
            .map(|&l| {
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    window,
                    thread_candidates: vec![64],
                    ..Default::default()
                };
                move || run_store(kind, &sweep, 64)
            })
            .collect();
        let stats = parallel_map(jobs);
        for (i, &l) in grid.iter().enumerate() {
            r.row(vec![
                f1(l),
                kind.name().into(),
                f1(stats[i].op_latency_mean.as_us()),
                f1(stats[i].op_latency_p50.as_us()),
                f1(stats[i].op_latency_p99.as_us()),
            ]);
        }
    }
    r.note("paper: longer memory latency lengthens op latency, but impact is limited");
    r.write_csv("fig17").ok();
    r
}

// ---------------------------------------------------------------------------
// Fig 18 — capacity-expansion scenarios.
// ---------------------------------------------------------------------------

pub fn fig18(fast: bool) -> Report {
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(15.0) };
    let mut r = Report::new(
        "Fig 18 — 32GB DRAM vs 128GB CXL (scaled 1000x): capacity & throughput",
        &["store", "config", "items", "ops/sec", "notes"],
    );

    // treekv: DRAM budget fits 500k 64-byte entries (scaled 32 MB); CXL 4x
    // budget fits 1.9M. The DRAM-only system cannot hold 1.9M -> OOM.
    let dram_capacity_items = 500_000u64;
    let big_items = 1_900_000u64;
    r.row(vec![
        "treekv".into(),
        "(a) DRAM 32MB-eq".into(),
        big_items.to_string(),
        "OOM".into(),
        format!("index needs {}MB > budget", big_items * 64 / 1_000_000),
    ]);
    let sweep5 = SweepCfg {
        l_mem: Dur::us(5.0),
        tail: true,
        window,
        thread_candidates: vec![32, 64],
        ..Default::default()
    };
    let tree_big = best_threads(&sweep5.thread_candidates.clone(), |n| {
        run_tree_with(
            TreeKvConfig {
                n_items: if fast { 600_000 } else { big_items },
                sprigs: 2048,
                ..Default::default()
            },
            &sweep5,
            n,
        )
    })
    .1;
    r.row(vec![
        "treekv".into(),
        "(b) CXL 128MB-eq @5us+tail".into(),
        big_items.to_string(),
        format!("{:.0}", tree_big.ops_per_sec),
        format!("fits ({}MB of CXL)", big_items * 64 / 1_000_000),
    ]);
    let _ = dram_capacity_items;

    // lsmkv: 4x block cache at Zipf 0.7 → paper sees +32%.
    let lsm_small = LsmKvConfig {
        key_dist: KeyDist::Zipf {
            s: 0.7,
            scrambled: true,
        },
        cache_blocks: 3_000,
        ..Default::default()
    };
    let lsm_large = LsmKvConfig {
        cache_blocks: 12_000,
        ..lsm_small.clone()
    };
    let dram_sweep = SweepCfg {
        l_mem: Dur::us(0.1),
        window,
        thread_candidates: vec![32, 64],
        ..Default::default()
    };
    let small = best_threads(&dram_sweep.thread_candidates.clone(), |n| {
        run_lsm_with(lsm_small.clone(), &dram_sweep, n)
    })
    .1;
    let large = best_threads(&sweep5.thread_candidates.clone(), |n| {
        run_lsm_with(lsm_large.clone(), &sweep5, n)
    })
    .1;
    r.row(vec![
        "lsmkv".into(),
        "(a) DRAM cache 3k blocks".into(),
        "1M".into(),
        format!("{:.0}", small.ops_per_sec),
        "zipf 0.7".into(),
    ]);
    r.row(vec![
        "lsmkv".into(),
        "(b) CXL cache 12k blocks @5us+tail".into(),
        "1M".into(),
        format!("{:.0}", large.ops_per_sec),
        format!("{:+.0}% vs (a); paper +32%",
            100.0 * (large.ops_per_sec / small.ops_per_sec - 1.0)),
    ]);

    // cachekv: 4x tier-1 (and bigger tier-2) → paper sees +25%.
    let cache_small = CacheKvConfig::default();
    let cache_large = CacheKvConfig {
        t1_items: cache_small.t1_items * 4,
        t2_items: cache_small.t2_items * 2,
        ..cache_small.clone()
    };
    let csmall = best_threads(&dram_sweep.thread_candidates.clone(), |n| {
        run_cache_with(cache_small.clone(), &dram_sweep, n)
    })
    .1;
    let clarge = best_threads(&sweep5.thread_candidates.clone(), |n| {
        run_cache_with(cache_large.clone(), &sweep5, n)
    })
    .1;
    r.row(vec![
        "cachekv".into(),
        "(a) DRAM tier1 12k items".into(),
        "100k".into(),
        format!("{:.0}", csmall.ops_per_sec),
        "".into(),
    ]);
    r.row(vec![
        "cachekv".into(),
        "(b) CXL tier1 48k items @5us+tail".into(),
        "100k".into(),
        format!("{:.0}", clarge.ops_per_sec),
        format!("{:+.0}% vs (a); paper +25%",
            100.0 * (clarge.ops_per_sec / csmall.ops_per_sec - 1.0)),
    ]);

    r.note("capacities scaled 1000x down from the paper's GB figures");
    r.write_csv("fig18").ok();
    r
}

// ---------------------------------------------------------------------------
// modelcheck — Θ_scan model-vs-simulator validation sweep.
// ---------------------------------------------------------------------------

/// Documented tolerance bands for the Θ_scan-extended model: relative error
/// of the **normalized** predicted throughput against the simulator, per
/// workload class.
///
/// - B/C/D (point reads, ≤5% updates): tight — the per-kind model has no
///   unmodeled mechanisms here.
/// - A/F (write-heavy): looser — the stores hold sprig/shard locks across
///   long-latency locked descents and run background defrag/flush threads,
///   neither of which Eq 14 models.
/// - E (scan-heavy): loosest, by design — the Θ_scan vector approximates
///   the walk length, block span, and batch count of a scan-length
///   *distribution* by their means.
///
/// Enforced by `tests/model_vs_sim.rs` and the CI `modelcheck --fast` step.
pub fn modelcheck_tolerance(wl: YcsbWorkload) -> f64 {
    match wl {
        YcsbWorkload::B | YcsbWorkload::C | YcsbWorkload::D => 0.20,
        YcsbWorkload::A | YcsbWorkload::F => 0.30,
        YcsbWorkload::E => 0.40,
    }
}

/// Predicted normalized throughput at `l` for a snapshot `mix` normalized
/// at the DRAM point `l0`, plus its relative error against the simulated
/// normalization. The single implementation shared by `modelcheck`,
/// `ycsb_sweep`, and `tests/model_vs_sim.rs`, so the CI gate and the
/// reports can never disagree on the same data.
pub fn model_norm_err(
    mix: &[(f64, KindCost)],
    l0: f64,
    l: f64,
    sim_norm: f64,
    ext: &ExtParams,
    sys: &SysParams,
) -> (f64, f64) {
    let recip0 = model::theta_mix_recip(mix, l0, ext, sys);
    let recip = model::theta_mix_recip(mix, l, ext, sys);
    let model_norm = if recip > 0.0 { recip0 / recip } else { 1.0 };
    let err = (model_norm - sim_norm) / sim_norm.max(1e-9);
    (model_norm, err)
}

/// Aggregate M/S of a `(fraction, KindCost)` mix (for the report columns).
fn mix_m_s(mix: &[(f64, KindCost)]) -> (f64, f64) {
    let total: f64 = mix.iter().map(|(f, _)| f).sum();
    if total <= 0.0 {
        return (0.0, 0.0);
    }
    (
        mix.iter().map(|(f, c)| f * c.m).sum::<f64>() / total,
        mix.iter().map(|(f, c)| f * c.s).sum::<f64>() / total,
    )
}

/// Sweep L_mem × workload A–F × store and report the Θ_scan-extended
/// model's prediction against the simulator: per point, the normalized
/// throughput from both sides and their relative error against the
/// documented tolerance. The model mix is snapshotted from the DRAM-point
/// run (`model_params(op_kind)` per store — geometry plus measured hit
/// ratios, the paper's treatment of measured system parameters) and the
/// whole curve is predicted from that single snapshot.
///
/// Returns `(report, all_points_within_tolerance)`; the CLI exits non-zero
/// on drift so CI can gate on it.
pub fn modelcheck(fast: bool) -> (Report, bool) {
    let grid: Vec<f64> = if fast {
        vec![0.1, 5.0]
    } else {
        vec![0.1, 1.0, 5.0]
    };
    // The multi-SSD axis rides only the slow sweep (PR 3 follow-up): the
    // same tolerance bands are enforced on the n_ssd = 4 points, whose
    // model side uses the aggregate Θ_ssd = n_ssd·R_IO / n_ssd·B_IO floors.
    let n_axis: Vec<u32> = if fast { vec![1] } else { vec![1, 4] };
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(12.0) };
    let sys = sys_params();

    // One flat job list (store × workload × array size × latency).
    let mut jobs = Vec::new();
    for wl in YcsbWorkload::ALL {
        for kind in StoreKind::ALL {
            for &n in &n_axis {
                for &l in &grid {
                    jobs.push(move || {
                        let sweep = SweepCfg {
                            l_mem: Dur::us(l),
                            window,
                            thread_candidates: vec![32],
                            n_ssd: n,
                            ..Default::default()
                        };
                        run_store_ycsb_snap(kind, wl, &sweep, 32)
                    });
                }
            }
        }
    }
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "modelcheck — Θ_scan-extended model vs simulator (normalized throughput)",
        &[
            "workload",
            "store",
            "n_ssd",
            "L_mem(us)",
            "ops/sec",
            "sim_norm",
            "model_norm",
            "err%",
            "tol%",
            "M_sim",
            "M_model",
            "S_sim",
            "S_model",
        ],
    );
    let mut all_ok = true;
    let mut worst = 0.0f64;
    let mut idx = 0usize;
    for wl in YcsbWorkload::ALL {
        let tol = modelcheck_tolerance(wl);
        for kind in StoreKind::ALL {
            for &n in &n_axis {
                let ext = SweepCfg::default().at_n_ssd(n).ext_params();
                let group = &results[idx..idx + grid.len()];
                idx += grid.len();
                let (dram_stats, mix) = &group[0];
                let (m_model, s_model) = mix_m_s(mix);
                for (i, &l) in grid.iter().enumerate() {
                    let st = &group[i].0;
                    let sim_norm = st.ops_per_sec / dram_stats.ops_per_sec.max(1e-9);
                    let (model_norm, err) = model_norm_err(mix, grid[0], l, sim_norm, &ext, &sys);
                    worst = worst.max(err.abs());
                    if err.abs() > tol {
                        all_ok = false;
                    }
                    r.row(vec![
                        wl.tag().into(),
                        kind.name().into(),
                        n.to_string(),
                        f1(l),
                        format!("{:.0}", st.ops_per_sec),
                        f3(sim_norm),
                        f3(model_norm),
                        format!("{:+.1}", 100.0 * err),
                        f1(100.0 * tol),
                        f2(st.mean_m),
                        f2(m_model),
                        f2(st.mean_s),
                        f2(s_model),
                    ]);
                }
            }
        }
    }
    r.note("model mix snapshotted from the DRAM-point run (geometry + measured");
    r.note("hit ratios); the whole latency curve is predicted from that snapshot");
    r.note("E's Θ_scan: m_scan = descend+len, S = E[ceil(len/batch)] from the");
    r.note("length distribution's two moments, batch bytes against n_ssd·B_IO");
    r.note("n_ssd=4 points (slow mode) validate the aggregate Θ_ssd floors");
    r.note(format!(
        "worst |err| = {:.1}% — {}",
        100.0 * worst,
        if all_ok {
            "all points within the documented tolerance"
        } else {
            "TOLERANCE EXCEEDED"
        }
    ));
    r.write_csv("modelcheck").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// placement — the DRAM-budget axis (kvs::placement) across stores.
// ---------------------------------------------------------------------------

/// Map a DRAM budget fraction to a placement policy over a store's total
/// offloadable footprint (0 → all-secondary, 1 → all-DRAM).
fn placement_of(frac: f64, total_bytes: u64) -> PlacementPolicy {
    if frac <= 0.0 {
        PlacementPolicy::AllSecondary
    } else if frac >= 1.0 {
        PlacementPolicy::AllDram
    } else {
        PlacementPolicy::Budget {
            dram_bytes: (frac * total_bytes as f64) as u64,
        }
    }
}

/// Sweep DRAM budget × L_mem × store under YCSB C (point reads isolate the
/// placement signal; write-heavy mixes inherit model coverage from
/// `modelcheck`) and validate the split-hop Θ (`kvs::placement` module
/// docs) against the simulator:
///
/// - throughput at the slowest grid memory (8 µs — past the full-offload
///   knee, where the prefetch-queue wall `P/L` binds and a DRAM residue
///   genuinely buys throughput) must be **monotone non-decreasing** in the
///   DRAM budget, within a 10% slack. The slack is physical, not just
///   noise: once latency is fully thread-hidden, a secondary hop costs
///   `T_mem + T_sw` of busy time against an inline hop's
///   `T_mem + L_DRAM`, so near the all-DRAM end the hybrid can
///   legitimately edge out `AllDram` by a few percent (the paper's
///   small-residue sweet spot). A mis-tiered hop path shifts throughput
///   far beyond the slack, which is what the gate is for;
/// - reported simulated DRAM bytes must be exactly monotone in the budget;
/// - predicted-vs-simulated error must stay within the documented
///   `modelcheck` tolerance band.
///
/// Returns `(report, all_gates_passed)`; the CLI exits non-zero on a gate
/// failure so CI can gate on `placement --fast`.
pub fn placement(fast: bool) -> (Report, bool) {
    let grid: Vec<f64> = if fast {
        vec![0.1, 8.0]
    } else {
        vec![0.1, 2.0, 8.0]
    };
    let fracs: Vec<f64> = if fast {
        vec![0.0, 0.1, 1.0]
    } else {
        vec![0.0, 0.02, 0.1, 0.5, 1.0]
    };
    let wls = [YcsbWorkload::C];
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(12.0) };
    let sys = sys_params();
    let ext = SweepCfg::default().ext_params();
    let base_seed = SweepCfg::default().seed;

    // Budget fractions resolve against each store's AllDram footprint.
    let mut totals = Vec::new();
    for &wl in &wls {
        for kind in StoreKind::ALL {
            totals.push(store_offload_bytes(kind, wl, base_seed));
        }
    }

    // Flat job list: workload × store × budget × latency.
    let mut jobs = Vec::new();
    let mut ti = 0usize;
    for &wl in &wls {
        for kind in StoreKind::ALL {
            let total = totals[ti];
            ti += 1;
            for &frac in &fracs {
                let policy = placement_of(frac, total);
                for &l in &grid {
                    jobs.push(move || {
                        let sweep = SweepCfg {
                            l_mem: Dur::us(l),
                            window,
                            thread_candidates: vec![32],
                            placement: policy,
                            ..Default::default()
                        };
                        run_store_ycsb_placed(kind, wl, &sweep, 32)
                    });
                }
            }
        }
    }
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "placement — hybrid DRAM/µs-memory index placement (DRAM budget axis)",
        &[
            "workload",
            "store",
            "dram_frac",
            "dram_MB",
            "L_mem(us)",
            "ops/sec",
            "sim_norm",
            "model_norm",
            "err%",
            "tol%",
            "M_sec",
            "M_dram",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let l_slow = *grid.last().unwrap();
    let mut idx = 0usize;
    for &wl in &wls {
        let tol = modelcheck_tolerance(wl);
        for kind in StoreKind::ALL {
            // ops at the slowest latency and dram bytes, per budget point,
            // for the monotonicity gates.
            let mut slow_ops: Vec<f64> = Vec::new();
            let mut dram_bytes: Vec<u64> = Vec::new();
            for &frac in &fracs {
                let group = &results[idx..idx + grid.len()];
                idx += grid.len();
                let (dram_stats, mix, bytes) = &group[0];
                dram_bytes.push(*bytes);
                for (i, &l) in grid.iter().enumerate() {
                    let st = &group[i].0;
                    let sim_norm = st.ops_per_sec / dram_stats.ops_per_sec.max(1e-9);
                    let (model_norm, err) = model_norm_err(mix, grid[0], l, sim_norm, &ext, &sys);
                    if err.abs() > tol {
                        all_ok = false;
                        failures.push(format!(
                            "{}/{} frac={frac} L={l}: err {:+.1}% > tol {:.0}%",
                            wl.tag(),
                            kind.name(),
                            100.0 * err,
                            100.0 * tol
                        ));
                    }
                    if (l - l_slow).abs() < 1e-9 {
                        slow_ops.push(st.ops_per_sec);
                    }
                    r.row(vec![
                        wl.tag().into(),
                        kind.name().into(),
                        f2(frac),
                        f2(*bytes as f64 / 1e6),
                        f1(l),
                        format!("{:.0}", st.ops_per_sec),
                        f3(sim_norm),
                        f3(model_norm),
                        format!("{:+.1}", 100.0 * err),
                        f1(100.0 * tol),
                        f2(st.mean_m),
                        f2(st.mean_m_dram),
                    ]);
                }
            }
            // Gate: throughput monotone non-decreasing in the DRAM budget
            // at the slowest memory. 10% slack: the near-AllDram plateau can
            // legitimately invert by a few percent (hidden secondary hops
            // cost T_mem+T_sw busy vs inline T_mem+L_DRAM — see fn docs)
            // and the short windows add noise; wiring bugs blow far past it.
            for w in slow_ops.windows(2) {
                if w[1] < w[0] * 0.90 {
                    all_ok = false;
                    failures.push(format!(
                        "{}/{}: throughput fell with a larger DRAM budget at \
                         L={l_slow}us: {:.0} -> {:.0}",
                        wl.tag(),
                        kind.name(),
                        w[0],
                        w[1]
                    ));
                }
            }
            // Gate: reported DRAM bytes exactly monotone in the budget.
            for w in dram_bytes.windows(2) {
                if w[1] < w[0] {
                    all_ok = false;
                    failures.push(format!(
                        "{}/{}: dram bytes fell with a larger budget: {} -> {}",
                        wl.tag(),
                        kind.name(),
                        w[0],
                        w[1]
                    ));
                }
            }
        }
    }
    r.note("dram_frac: share of the store's offloadable footprint placed in");
    r.note("DRAM (0 = full offload, 1 = all-DRAM baseline); placement is");
    r.note("class-granular — hottest structures first (kvs::placement)");
    r.note("sim_norm/model_norm: vs the same budget's DRAM-latency point;");
    r.note("the split-hop model prices M_sec on the prefetch path and M_dram");
    r.note("inline at T_mem + L_DRAM (Eq 14 split, kvs::placement docs)");
    r.note("headline: past the full-offload knee (8 µs, P/L wall binding) a");
    r.note("small DRAM residue (top index levels / hot handles) recovers");
    r.note("most — sometimes slightly more than all — of the all-DRAM");
    r.note("throughput: hidden secondary hops cost T_mem+T_sw of busy time");
    r.note("vs an inline hop's T_mem+L_DRAM, so the hybrid is the sweet spot");
    if failures.is_empty() {
        r.note("all placement gates passed (monotone throughput, monotone");
        r.note("DRAM bytes, model within tolerance)");
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("placement").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// planner — measured access-frequency placement vs the static prior.
// ---------------------------------------------------------------------------

/// Documented slack for the planner's equal-budget gate: the measured plan
/// must achieve at least `1 - PLANNER_SLACK` of the static plan's
/// throughput at every point. The slack absorbs short-window noise between
/// two runs whose placements genuinely differ (where the plans coincide the
/// arms are bit-identical and the ratio is exactly 1); a mis-ranked
/// placement that demotes a genuinely hot class blows far past it.
pub const PLANNER_SLACK: f64 = 0.08;

/// Sweep store × workload × DRAM budget × L_mem through the two-phase
/// **profile → replan → measure** path (`run_store_ycsb_profiled`) and
/// compare measured-ranking placement against the static hotness prior at
/// equal DRAM budget. Three gates, **exit non-zero** on violation:
///
/// 1. at every point the measured plan's throughput is ≥ the static
///    plan's minus [`PLANNER_SLACK`];
/// 2. the measured ranking actually *differs* from the static prior on at
///    least one of the designed discriminator points — lsmkv-E (scans
///    never touch the restart arrays, so the static handles ≻ restarts ≻
///    data order is provably wrong) or cachekv-A (the write-heavy mix's
///    LRU traffic out-accesses the hash chains per byte) — otherwise the
///    experiment validated nothing;
/// 3. the **measured** arm's split-hop model prediction stays inside the
///    same `modelcheck` tolerance bands as the static sweeps (the
///    `KindCost` `m`/`m_dram` snapshots are derived from the *replanned*
///    plan, so this extends model validation to replanned placements).
///    The band gate applies on the latency range the bands are calibrated
///    for (the `modelcheck` grid, ≤ 5 µs): the 8 µs point — needed past
///    the full-offload knee, where the placement signal actually
///    separates — reports its error but does not gate, since the A/F
///    bands (unmodeled lock-hold time across locked descents) were never
///    documented there.
///
/// The byte columns are the honest accounting: policy-placed bytes plus
/// the pinned residual (lsmkv memtable, cachekv directory + SOC index).
pub fn planner(fast: bool) -> (Report, bool) {
    let grid: Vec<f64> = if fast {
        vec![0.1, 5.0, 8.0]
    } else {
        vec![0.1, 2.0, 5.0, 8.0]
    };
    // The model-band gate's calibrated latency ceiling (the modelcheck
    // grid's maximum).
    const MODEL_GATE_L_MAX: f64 = 5.0;
    // Budget fractions of each store's offloadable footprint. 0.5 is the
    // discriminator point: for cachekv it fits exactly one of the two
    // equal-byte tier-1 classes, so the static and measured plans place
    // *different* structures at identical cost.
    let fracs: Vec<f64> = if fast { vec![0.5] } else { vec![0.1, 0.5] };
    let wls: Vec<YcsbWorkload> = if fast {
        vec![YcsbWorkload::A, YcsbWorkload::C, YcsbWorkload::E]
    } else {
        YcsbWorkload::ALL.to_vec()
    };
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(12.0) };
    let sys = sys_params();
    let ext = SweepCfg::default().ext_params();
    let base_seed = SweepCfg::default().seed;

    let mut totals = Vec::new();
    for &wl in &wls {
        for kind in StoreKind::ALL {
            totals.push(store_offload_bytes(kind, wl, base_seed));
        }
    }

    // Flat job list: workload × store × budget × latency; each job runs
    // both arms (the static arm doubles as the profiling run).
    let mut jobs = Vec::new();
    let mut ti = 0usize;
    for &wl in &wls {
        for kind in StoreKind::ALL {
            let total = totals[ti];
            ti += 1;
            for &frac in &fracs {
                let budget = (frac * total as f64) as u64;
                for &l in &grid {
                    jobs.push(move || {
                        let sweep = SweepCfg {
                            l_mem: Dur::us(l),
                            window,
                            thread_candidates: vec![32],
                            placement: PlacementPolicy::Budget { dram_bytes: budget },
                            ..Default::default()
                        };
                        run_store_ycsb_profiled(kind, wl, &sweep, 32)
                    });
                }
            }
        }
    }
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "planner — measured access-frequency placement vs the static prior",
        &[
            "workload",
            "store",
            "dram_frac",
            "L_mem(us)",
            "static_ops",
            "measured_ops",
            "meas/static",
            "static_MB",
            "measured_MB",
            "rank",
            "model_norm",
            "err%",
            "tol%",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let mut discriminator_differed = false;
    let mut idx = 0usize;
    for &wl in &wls {
        let tol = modelcheck_tolerance(wl);
        for kind in StoreKind::ALL {
            for &frac in &fracs {
                let group = &results[idx..idx + grid.len()];
                idx += grid.len();
                // Model validation of the measured arm: normalized against
                // its own DRAM point, mix from that point's replanned plan.
                // Each latency point replans from its own profile; the
                // normalized curve is one placement only when every point
                // resolved the same ranking. A near-tie density that flips
                // across latencies would make sim_norm a cross-placement
                // ratio, so the band gate is skipped (reported, not
                // failed) for such a group.
                let dram_meas = &group[0].measured_arm;
                let rankings_agree = group
                    .iter()
                    .all(|g| g.measured_ranking == group[0].measured_ranking);
                for (i, &l) in grid.iter().enumerate() {
                    let run = &group[i];
                    let s_ops = run.static_arm.stats.ops_per_sec;
                    let m_ops = run.measured_arm.stats.ops_per_sec;
                    let ratio = m_ops / s_ops.max(1e-9);
                    if ratio < 1.0 - PLANNER_SLACK {
                        all_ok = false;
                        failures.push(format!(
                            "{}/{} frac={frac} L={l}: measured placement lost \
                             {:.1}% > {:.0}% slack ({s_ops:.0} -> {m_ops:.0})",
                            wl.tag(),
                            kind.name(),
                            100.0 * (1.0 - ratio),
                            100.0 * PLANNER_SLACK
                        ));
                    }
                    let is_discriminator = (kind == StoreKind::Lsm && wl == YcsbWorkload::E)
                        || (kind == StoreKind::Cache && wl == YcsbWorkload::A);
                    if is_discriminator && run.rank_differs {
                        discriminator_differed = true;
                    }
                    let sim_norm =
                        m_ops / dram_meas.stats.ops_per_sec.max(1e-9);
                    let (model_norm, err) =
                        model_norm_err(&dram_meas.mix, grid[0], l, sim_norm, &ext, &sys);
                    if rankings_agree && l <= MODEL_GATE_L_MAX && err.abs() > tol {
                        all_ok = false;
                        failures.push(format!(
                            "{}/{} frac={frac} L={l}: replanned model err \
                             {:+.1}% > tol {:.0}%",
                            wl.tag(),
                            kind.name(),
                            100.0 * err,
                            100.0 * tol
                        ));
                    }
                    r.row(vec![
                        wl.tag().into(),
                        kind.name().into(),
                        f2(frac),
                        f1(l),
                        format!("{s_ops:.0}"),
                        format!("{m_ops:.0}"),
                        f3(ratio),
                        f2(run.static_arm.dram_bytes as f64 / 1e6),
                        f2(run.measured_arm.dram_bytes as f64 / 1e6),
                        if run.rank_differs {
                            "measured".into()
                        } else {
                            "=static".into()
                        },
                        f3(model_norm),
                        format!("{:+.1}", 100.0 * err),
                        f1(100.0 * tol),
                    ]);
                }
            }
        }
    }
    if !discriminator_differed {
        all_ok = false;
        failures.push(
            "no discriminator point (lsmkv-E / cachekv-A) produced a measured \
             ranking different from the static prior"
                .to_string(),
        );
    }
    r.note("two-phase path: run static (collect per-class AccessProfile) ->");
    r.note("replan by measured accesses-per-byte -> rerun at the same budget;");
    r.note("where the rankings coincide the arms are bit-identical (ratio 1)");
    r.note("byte columns are honest: policy-placed + pinned residual (lsmkv");
    r.note("memtable, cachekv bucket directory + SOC index)");
    r.note("headline: lsmkv-E demotes the scan-untouched restart arrays;");
    r.note("cachekv-A promotes the LRU lists over the hash chains at equal");
    r.note("bytes once the write mix's eviction walks dominate the profile");
    r.note("model band gated at L <= 5us (the modelcheck-calibrated grid);");
    r.note("the 8us knee point reports err% ungated; a group whose");
    r.note("per-latency replans resolved different rankings also reports");
    r.note("ungated (its normalized curve would span two placements)");
    if failures.is_empty() {
        r.note(format!(
            "all planner gates passed (measured >= static - {:.0}% at equal \
             budget; ranking differs on a discriminator; replanned model \
             within bands)",
            100.0 * PLANNER_SLACK
        ));
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("planner").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// adaptive — online replanning under drifting (phased) workloads.
// ---------------------------------------------------------------------------

/// Documented slack for the adaptive gate: after the first workload turn
/// the online arm must score at least `1 - ADAPTIVE_SLACK` of the **best**
/// frozen arm (static or offline-replanned). Where the online planner never
/// fires the arms are bit-identical and the ratio is exactly 1; once a
/// migration fires the arms' event streams diverge, so genuinely-different
/// runs carry short-window noise the slack absorbs. A planner that
/// thrashes — or mis-times its migrations into measured windows — blows
/// far past it, because every migration is charged as simulated work.
pub const ADAPTIVE_SLACK: f64 = 0.10;

/// Drifting-workload experiment: store × phase scenario × DRAM budget ×
/// L_mem through [`run_store_ycsb_adaptive`], racing three arms from the
/// same seed:
///
/// - **static**: the initial plan, frozen for the whole schedule;
/// - **offline**: one replan from the whole-schedule aggregate profile
///   (the hindsight placement), then frozen;
/// - **online**: decaying-window profile + hysteresis replanning, with
///   every migration charged (`Machine::charge_migration`).
///
/// Two gates, **exit non-zero** on violation:
///
/// 1. on every *designed* cell the online arm's window-weighted post-turn
///    throughput is ≥ the best frozen arm's minus [`ADAPTIVE_SLACK`];
/// 2. the designed adapting cell — cachekv × diurnal at the one-class
///    budget, where the night-write phase genuinely flips the
///    LRU-vs-chains density ordering — must actually replan online
///    (`replans ≥ 1` with lines migrated), otherwise every arm was
///    identical and the gate validated nothing.
///
/// The designed cells pair each store with the scenario that stresses its
/// own ordering: cachekv × diurnal (ordering flips → adapt), lsmkv ×
/// scan-swing (restart-array density collapses but the freed bytes cannot
/// admit the data blocks → hysteresis correctly declines), treekv ×
/// hotspot-shift (level reach stays monotone → ranking is drift-stable).
/// Full mode adds exploratory cells that report ungated.
pub fn adaptive(fast: bool) -> (Report, bool) {
    type Ctor = fn(Dur) -> PhasedWorkload;
    let designed: [(StoreKind, Ctor); 3] = [
        (StoreKind::Cache, PhasedWorkload::diurnal),
        (StoreKind::Lsm, PhasedWorkload::scan_swing),
        (StoreKind::Tree, PhasedWorkload::hotspot_shift),
    ];
    let exploratory: [(StoreKind, Ctor); 3] = [
        (StoreKind::Cache, PhasedWorkload::zipf_drift),
        (StoreKind::Lsm, PhasedWorkload::diurnal),
        (StoreKind::Tree, PhasedWorkload::zipf_drift),
    ];
    let mut cells: Vec<(StoreKind, Ctor, bool)> =
        designed.iter().map(|&(k, c)| (k, c, true)).collect();
    if !fast {
        cells.extend(exploratory.iter().map(|&(k, c)| (k, c, false)));
    }
    let grid: Vec<f64> = if fast { vec![2.0] } else { vec![2.0, 5.0] };
    // Budget fractions of each store's offloadable footprint; 0.5 is the
    // discriminator (for cachekv it fits exactly one of the two equal-byte
    // tier-1 classes, so a replan swaps whole structures at equal cost).
    let fracs: Vec<f64> = if fast { vec![0.5] } else { vec![0.25, 0.5] };
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(8.0) };
    let base_seed = SweepCfg::default().seed;

    let mut jobs = Vec::new();
    for &(kind, ctor, _) in &cells {
        let scenario = ctor(window);
        let total = store_offload_bytes(kind, scenario.base, base_seed);
        for &frac in &fracs {
            let budget = (frac * total as f64) as u64;
            for &l in &grid {
                let scenario = scenario.clone();
                jobs.push(move || {
                    let sweep = SweepCfg {
                        l_mem: Dur::us(l),
                        thread_candidates: vec![32],
                        placement: PlacementPolicy::Budget { dram_bytes: budget },
                        ..Default::default()
                    };
                    run_store_ycsb_adaptive(kind, &scenario, &sweep, &AdaptiveCfg::default(), 32)
                });
            }
        }
    }
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "adaptive — online replanning vs frozen placements under drift",
        &[
            "scenario",
            "store",
            "dram_frac",
            "L_mem(us)",
            "phase",
            "static_ops",
            "offline_ops",
            "online_ops",
            "on/best",
            "p50(us)",
            "p99(us)",
            "replans",
            "lines",
            "refill_rd",
            "stall(us)",
            "gate",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let mut discriminator_adapted = false;
    let mut idx = 0usize;
    for &(kind, ctor, gated) in &cells {
        let scenario = ctor(window);
        for &frac in &fracs {
            for &l in &grid {
                let run = &results[idx];
                idx += 1;
                let on = &run.online_arm;
                for (i, ps) in on.phases.iter().enumerate() {
                    let s_ops = run.static_arm.phases[i].stats.ops_per_sec;
                    let f_ops = run.offline_arm.phases[i].stats.ops_per_sec;
                    let o_ops = ps.stats.ops_per_sec;
                    r.row(vec![
                        scenario.tag.into(),
                        kind.name().into(),
                        f2(frac),
                        f1(l),
                        ps.phase.into(),
                        format!("{s_ops:.0}"),
                        format!("{f_ops:.0}"),
                        format!("{o_ops:.0}"),
                        f3(o_ops / s_ops.max(f_ops).max(1e-9)),
                        f2(ps.stats.op_latency_p50.as_us()),
                        f2(ps.stats.op_latency_p99.as_us()),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                }
                let s_post = run.static_arm.ops_per_sec_from(1);
                let f_post = run.offline_arm.ops_per_sec_from(1);
                let o_post = on.ops_per_sec_from(1);
                let best = s_post.max(f_post);
                let ratio = o_post / best.max(1e-9);
                let pass = !gated || ratio >= 1.0 - ADAPTIVE_SLACK;
                if !pass {
                    all_ok = false;
                    failures.push(format!(
                        "{}/{} frac={frac} L={l}: online lost {:.1}% > {:.0}% slack \
                         post-turn (best frozen {best:.0} -> online {o_post:.0})",
                        scenario.tag,
                        kind.name(),
                        100.0 * (1.0 - ratio),
                        100.0 * ADAPTIVE_SLACK
                    ));
                }
                if kind == StoreKind::Cache
                    && scenario.tag == "diurnal"
                    && on.replans >= 1
                    && on.migrated_lines > 0
                {
                    discriminator_adapted = true;
                }
                r.row(vec![
                    scenario.tag.into(),
                    kind.name().into(),
                    f2(frac),
                    f1(l),
                    "post-turn".into(),
                    format!("{s_post:.0}"),
                    format!("{f_post:.0}"),
                    format!("{o_post:.0}"),
                    f3(ratio),
                    "-".into(),
                    "-".into(),
                    on.replans.to_string(),
                    on.migrated_lines.to_string(),
                    on.migration_reads.to_string(),
                    format!("{:.1}", on.migration_stall.as_us()),
                    if !gated {
                        "report".into()
                    } else if pass {
                        "ok".into()
                    } else {
                        "FAIL".into()
                    },
                ]);
            }
        }
    }
    if !discriminator_adapted {
        all_ok = false;
        failures.push(
            "the designed adapting cell (cachekv x diurnal) never replanned \
             online (replans = 0 or nothing migrated) — the gate compared \
             three identical arms and validated nothing"
                .to_string(),
        );
    }
    r.note("three arms per point, same seed: static (initial plan frozen),");
    r.note("offline (one replan from the whole-schedule profile, then");
    r.note("frozen), online (decaying EWMA profile + hysteresis margin,");
    r.note("migrations charged as MemAccess line traffic + SSD refills via");
    r.note("Machine::charge_migration — thrash is visible in throughput)");
    r.note("score = window-weighted ops/s over post-turn phases (the first");
    r.note("phase is excluded: all three arms still agree there)");
    r.note("headline: cachekv x diurnal — night-write flips the LRU-vs-");
    r.note("chains density ordering; online migrates inside the settle");
    r.note("slack and holds the best frozen arm's throughput after the turn");
    r.note("lsmkv x scan-swing: hysteresis correctly declines to act (the");
    r.note("restart arrays' density collapses, but evicting them frees too");
    r.note("few bytes to admit the data blocks at this budget)");
    r.note("treekv: per-level reach stays monotone under drift, so the");
    r.note("ranking is stable and online == static bit-for-bit");
    r.note("exploratory cells (full mode) report ungated");
    if failures.is_empty() {
        r.note(format!(
            "all adaptive gates passed (online >= best frozen - {:.0}% \
             post-turn on designed cells; discriminator cell adapted)",
            100.0 * ADAPTIVE_SLACK
        ));
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("adaptive").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// Multi-tenant serving — noisy-neighbor isolation on per-tenant tail latency.
// ---------------------------------------------------------------------------

/// Noisy-neighbor isolation band: the point-read tenant's shared-arm p99 must
/// stay within `band * solo_p99 + floor` at every swept L_mem.
///
/// Derivation: the two tenants issue ops in an exact 1:1 interleave (SWRR),
/// and a YCSB-E scan costs ~`len/batch` SSD reads plus ~`len` extra memory
/// hops versus a single point read, so the mixed mean service time is bounded
/// by roughly `0.5 * 1 + 0.5 * scan_cost ≈ 3x` the solo mean. Queueing at the
/// shared cores inflates the p99 by at most that mix ratio times a small
/// burst factor, so 5x is a generous ceiling; starvation or priority
/// inversion shows up as 10-100x and still trips the gate. v1 value — to be
/// tightened from CI history like `WAL_OVERHEAD_BAND`.
pub const TENANT_ISOLATION_BAND: f64 = 5.0;

/// Absolute slack added to the isolation bound (µs). At DRAM-class L_mem the
/// solo p99 is tiny and a pure ratio gate would amplify scheduling noise;
/// the floor keeps the bound meaningful at small absolute latencies.
pub const TENANT_P99_FLOOR_US: f64 = 50.0;

/// Completed-ops fair-share tolerance. SWRR makes the *issued* stream match
/// the weight ratio exactly; completed counts inside a finite window differ
/// only by the in-flight ops straddling the window edges (<= threads ops per
/// tenant), so the observed share may drift from the weight share by about
/// `threads / window_ops`. 0.10 covers the shortest fast-mode windows.
pub const TENANT_FAIR_SHARE_TOL: f64 = 0.10;

/// Multi-tenant serving: two tenants share one store, one SSD, and one
/// planner DRAM budget. Tenant `point` runs YCSB B point reads on the lower
/// half of the keyspace; tenant `noisy` runs scan-heavy YCSB E on the upper
/// half at equal weight. Per-tenant p50/p99/p999 come from the per-tenant
/// latency histograms (interpolated quantiles). Gated:
///
/// 1. isolation — shared-arm point p99 within
///    `TENANT_ISOLATION_BAND * solo p99 + TENANT_P99_FLOOR_US` per cell;
/// 2. lanes — every tenant lane has ops > 0 and p50 <= p99 <= p999;
/// 3. fair share — completed-ops split within `TENANT_FAIR_SHARE_TOL` of the
///    1:1 weight ratio (SWRR flow conservation).
///
/// Cachekv is excluded: its tenant routing ignores scans (no scan support),
/// so a noisy neighbor there is not scan-heavy and probes nothing new.
pub fn tenants(fast: bool) -> (Report, bool) {
    let stores = [StoreKind::Tree, StoreKind::Lsm];
    let lats = [0.1, 1.0, 5.0];
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(10.0) };
    let base = YcsbWorkload::B;
    let base_seed = SweepCfg::default().seed;
    let threads = 32usize;
    let point = || TenantSpec::ycsb("point", YcsbWorkload::B, 1, 0.0, 0.5);
    let noisy = || TenantSpec::ycsb("noisy", YcsbWorkload::E, 1, 0.5, 1.0);

    let mut jobs = Vec::new();
    for &kind in &stores {
        // One shared budget per store: 25% of its offloadable bytes, split
        // across tenants implicitly by the combined access profile.
        let budget = (0.25 * store_offload_bytes(kind, base, base_seed) as f64) as u64;
        for &l in &lats {
            jobs.push(move || {
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    thread_candidates: vec![threads],
                    window,
                    placement: PlacementPolicy::Budget { dram_bytes: budget },
                    ..Default::default()
                };
                let solo_set = TenantSet::solo(point());
                let shared_set = TenantSet::new(vec![point(), noisy()]);
                let solo = run_store_ycsb_tenants(kind, base, &solo_set, &sweep, threads, true);
                let shared = run_store_ycsb_tenants(kind, base, &shared_set, &sweep, threads, true);
                (kind, l, solo, shared)
            });
        }
    }
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "Multi-tenant serving — per-tenant tail latency under a noisy neighbor",
        &[
            "store",
            "L_mem(us)",
            "arm",
            "tenant",
            "ops/s",
            "share",
            "p50(us)",
            "p99(us)",
            "p999(us)",
            "p99/solo",
            "absorb",
            "dram_MB",
            "gate",
        ],
    );
    let mut failures: Vec<String> = Vec::new();
    for (kind, l, solo, shared) in &results {
        let cell = format!("{kind:?} L={l}us");
        if solo.stats.tenants.len() != 1 || shared.stats.tenants.len() != 2 {
            failures.push(format!("{cell}: missing tenant lanes"));
            continue;
        }
        let sp = &solo.stats.tenants[0];
        let pt = &shared.stats.tenants[0];
        let nn = &shared.stats.tenants[1];

        let bound_us = sp.p99.as_us() * TENANT_ISOLATION_BAND + TENANT_P99_FLOOR_US;
        let iso_ok = pt.p99.as_us() <= bound_us;
        if !iso_ok {
            failures.push(format!(
                "{cell}: point p99 {:.1}us > bound {:.1}us (solo {:.1}us)",
                pt.p99.as_us(),
                bound_us,
                sp.p99.as_us()
            ));
        }
        let lanes_ok = [sp, pt, nn]
            .iter()
            .all(|t| t.ops > 0 && t.p50 <= t.p99 && t.p99 <= t.p999 && t.p999 > Dur::ZERO);
        if !lanes_ok {
            failures.push(format!("{cell}: empty or non-monotone tenant lane"));
        }
        let share = pt.ops as f64 / (pt.ops + nn.ops).max(1) as f64;
        let share_ok = (share - 0.5).abs() <= TENANT_FAIR_SHARE_TOL;
        if !share_ok {
            failures.push(format!("{cell}: point completed-ops share {share:.3} vs 0.5"));
        }

        let gate = if iso_ok && lanes_ok && share_ok {
            "ok"
        } else {
            "FAIL"
        };
        r.row(vec![
            format!("{kind:?}"),
            f1(*l),
            "solo".into(),
            "point".into(),
            f1(sp.ops_per_sec),
            f3(1.0),
            f1(sp.p50.as_us()),
            f1(sp.p99.as_us()),
            f1(sp.p999.as_us()),
            f2(1.0),
            f3(solo.absorbed_frac),
            f1(solo.dram_bytes as f64 / (1 << 20) as f64),
            "-".into(),
        ]);
        r.row(vec![
            format!("{kind:?}"),
            f1(*l),
            "shared".into(),
            "point".into(),
            f1(pt.ops_per_sec),
            f3(share),
            f1(pt.p50.as_us()),
            f1(pt.p99.as_us()),
            f1(pt.p999.as_us()),
            f2(pt.p99.as_us() / sp.p99.as_us().max(1e-9)),
            f3(shared.absorbed_frac),
            f1(shared.dram_bytes as f64 / (1 << 20) as f64),
            gate.into(),
        ]);
        r.row(vec![
            format!("{kind:?}"),
            f1(*l),
            "shared".into(),
            "noisy".into(),
            f1(nn.ops_per_sec),
            f3(1.0 - share),
            f1(nn.p50.as_us()),
            f1(nn.p99.as_us()),
            f1(nn.p999.as_us()),
            "-".into(),
            f3(shared.absorbed_frac),
            f1(shared.dram_bytes as f64 / (1 << 20) as f64),
            "-".into(),
        ]);
    }

    let all_ok = failures.is_empty();
    r.note("two tenants share the store, the SSD, and one planner DRAM");
    r.note("budget; SWRR multiplexing issues ops in an exact 1:1 interleave");
    r.note("point = YCSB B on keys [0, 0.5), noisy = scan-heavy YCSB E on");
    r.note("[0.5, 1.0); solo arm = point tenant alone, same budget and seed");
    r.note("per-tenant quantiles use the interpolated histogram (p999 is a");
    r.note("real intra-bucket estimate, not a bucket-edge overstatement)");
    r.note(format!(
        "isolation gate: shared point p99 <= {TENANT_ISOLATION_BAND:.0}x \
         solo p99 + {TENANT_P99_FLOOR_US:.0}us (v1 band, see const docs)"
    ));
    r.note(format!(
        "fair-share gate: completed-ops split within {TENANT_FAIR_SHARE_TOL:.2} \
         of the 1:1 weight ratio"
    ));
    if all_ok {
        r.note("all tenant gates passed at every swept L_mem");
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("tenants").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// YCSB sweep — full-operation-surface workloads A–F across all stores.
// ---------------------------------------------------------------------------

/// Sweep L_mem × YCSB workload × store, reporting throughput-vs-latency
/// degradation per workload. Workloads E (scan-heavy) and F (RMW) change
/// both M (accesses per op) and the IO:compute ratio, probing the model's
/// IO-amortization term across the whole operation surface.
pub fn ycsb_sweep(fast: bool) -> Report {
    let grid: Vec<f64> = if fast {
        vec![0.1, 2.0, 10.0]
    } else {
        // DRAM-class baseline, then 1/2/5/10 µs.
        vec![0.1, 1.0, 2.0, 5.0, 10.0]
    };
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(12.0) };

    let mut r = Report::new(
        "YCSB sweep — normalized throughput vs memory latency per workload/store",
        &[
            "workload",
            "store",
            "L_mem(us)",
            "ops/sec",
            "norm",
            "model_norm",
            "err%",
            "M",
            "S",
        ],
    );
    let sys = sys_params();
    let ext = SweepCfg::default().ext_params();
    for wl in YcsbWorkload::ALL {
        for kind in StoreKind::ALL {
            // Each job returns the best-threads stats plus the winning
            // run's model snapshot — the predicted column reuses the DRAM
            // point's run instead of paying for a separate one.
            let jobs: Vec<_> = grid
                .iter()
                .map(|&l| {
                    let sweep = SweepCfg {
                        l_mem: Dur::us(l),
                        window,
                        thread_candidates: vec![32, 64],
                        ..Default::default()
                    };
                    move || {
                        best_threads_by(
                            &sweep.thread_candidates.clone(),
                            |n| run_store_ycsb_snap(kind, wl, &sweep, n),
                            |(st, _)| st.ops_per_sec,
                        )
                        .1
                    }
                })
                .collect();
            let results = parallel_map(jobs);
            let stats: Vec<_> = results.iter().map(|(st, _)| st).collect();
            let mix = &results[0].1;
            let dram = stats[0].ops_per_sec;
            for (i, &l) in grid.iter().enumerate() {
                let norm = stats[i].ops_per_sec / dram;
                let (model_norm, err) = model_norm_err(mix, grid[0], l, norm, &ext, &sys);
                r.row(vec![
                    wl.name().into(),
                    kind.name().into(),
                    f1(l),
                    format!("{:.0}", stats[i].ops_per_sec),
                    f3(norm),
                    f3(model_norm),
                    format!("{:+.1}", 100.0 * err),
                    f2(stats[i].mean_m),
                    f2(stats[i].mean_s),
                ]);
            }
        }
    }
    r.note("E multiplies M and S per op (index walk + batched value reads),");
    r.note("F roughly doubles both (read path + write path per op) — the");
    r.note("IO-amortization term keeps degradation bounded in both cases");
    r.note("model_norm: Θ_scan-extended per-kind mix (model/extended.rs),");
    r.note("snapshotted from each store's geometry at the DRAM point");
    r.note("cachekv under E is degenerate: scans are a documented no-op");
    r.note("(hash layout has no ordered iteration), so its E row measures");
    r.note("the API-call floor, not range-scan service");
    r.write_csv("ycsb_sweep").ok();
    r
}

// ---------------------------------------------------------------------------
// Multi-SSD scaling — the sharded-array scale axis (ROADMAP open item).
// ---------------------------------------------------------------------------

/// Sweep the SSD array size `n_ssd ∈ {1,2,4,8}` at two operating points:
///
/// - **ssd-bound**: low `L_mem`, IO-heavy mix on per-device-limited drives —
///   throughput must track the aggregate ceiling `Θ_ssd = n_ssd·R_IO`
///   (~linear scaling) until the CPU term takes over;
/// - **latency-bound**: the classic 5 µs memory-bound point on unsaturated
///   drives — the array must be invisible (<2% movement).
///
/// The overlayed model curve is Eq 14 with the Θ_ssd floors (per core).
pub fn ssd_scaling(backend: &mut ModelBackend, fast: bool) -> Report {
    let n_grid: [u32; 4] = [1, 2, 4, 8];
    let window = if fast { Dur::ms(8.0) } else { Dur::ms(20.0) };
    let sys = sys_params();

    // Per-device drive small enough that one device saturates under the
    // IO-heavy mix (40 KIOPS ≪ the CPU ceiling of ~417 kops/s at M=4).
    let ssd_bound_dev = crate::sim::SsdConfig {
        iops: 40e3,
        bandwidth_bps: 1e9,
        queue_depth: 64,
        ..crate::sim::SsdConfig::optane_array()
    };

    struct Regime {
        name: &'static str,
        l_us: f64,
        mb: MicrobenchConfig,
        dev: crate::sim::SsdConfig,
        op: OpParams,
        ext: ExtParams,
    }
    let base_ext = ExtParams::table2_example();
    let regimes = [
        Regime {
            name: "ssd-bound",
            l_us: 0.5,
            mb: MicrobenchConfig {
                m: 4,
                io_bytes: 4096,
                ..MicrobenchConfig::default()
            },
            dev: ssd_bound_dev,
            op: OpParams {
                m: 4.0,
                t_mem: 0.1,
                t_pre: 1.5,
                t_post: 0.2,
            },
            ext: ExtParams {
                a_io: 4096.0,
                b_io: 1_000.0, // 1 GB/s per device
                r_io: 0.04,    // 40 KIOPS per device
                b_mem: 1e9,
                ..base_ext
            },
        },
        Regime {
            name: "latency-bound",
            l_us: 5.0,
            mb: MicrobenchConfig::default(),
            dev: crate::sim::SsdConfig::optane_array(),
            op: OpParams {
                m: 10.0,
                t_mem: 0.1,
                t_pre: 1.5,
                t_post: 0.2,
            },
            ext: ExtParams {
                b_mem: 1e9,
                ..base_ext
            },
        },
    ];

    let mut r = Report::new(
        "Multi-SSD scaling — sharded array, per-shard queues (n_ssd axis)",
        &[
            "regime",
            "n_ssd",
            "L_mem(us)",
            "ops/sec",
            "vs n_ssd=1",
            "model_kops",
            "dev_imbalance",
        ],
    );
    for regime in &regimes {
        let jobs: Vec<_> = n_grid
            .iter()
            .map(|&n| {
                let mb = regime.mb.clone();
                let sweep = SweepCfg {
                    l_mem: Dur::us(regime.l_us),
                    window,
                    ssd: regime.dev.clone(),
                    n_ssd: n,
                    ..Default::default()
                };
                move || {
                    let mcfg = sweep.machine(64);
                    // Same service seed at every n: identical chain and op
                    // stream, so the array size is the only moving part.
                    let mut rng = crate::sim::Rng::new(0x55d);
                    let svc = crate::microbench::Microbench::new(mb, &mut rng);
                    let mut machine = crate::sim::Machine::new(mcfg, svc);
                    let st = machine.run(sweep.warmup, sweep.window);
                    (st.ops_per_sec, machine.ssd.per_device_ios())
                }
            })
            .collect();
        let measured = parallel_map(jobs);
        let base_ops = measured[0].0;
        for (i, &n) in n_grid.iter().enumerate() {
            let ops = measured[i].0;
            let per_dev = &measured[i].1;
            let recip = backend.extended(
                &regime.op,
                &sys,
                &ExtParams {
                    n_ssd: n as f64,
                    ..regime.ext
                },
                regime.l_us,
            );
            let total: u64 = per_dev.iter().sum::<u64>().max(1);
            let mean = total as f64 / per_dev.len() as f64;
            let imbalance = per_dev.iter().copied().max().unwrap_or(0) as f64 / mean;
            r.row(vec![
                regime.name.into(),
                n.to_string(),
                f1(regime.l_us),
                format!("{ops:.0}"),
                f2(ops / base_ops),
                f1(1e6 / recip / 1e3),
                f2(imbalance),
            ]);
        }
    }
    // Regime 3 — scan-bound: treekv workload E's batched value reads
    // against the aggregate bandwidth ceiling n_ssd·B_IO. Each scan of 16
    // records issues 2 batch IOs of ~12 kB, so a 400 MB/s device saturates
    // far below the CPU ceiling and throughput must scale with the array
    // until the Θ_scan CPU term takes over. The model column is the
    // per-kind mix (`model_params`) through `theta_mix_recip`.
    let scan_dev = crate::sim::SsdConfig {
        bandwidth_bps: 4e8,
        iops: 1e6,
        queue_depth: 256,
        ..crate::sim::SsdConfig::optane_array()
    };
    let scan_window = if fast { Dur::ms(10.0) } else { Dur::ms(25.0) };
    let scan_jobs: Vec<_> = n_grid
        .iter()
        .map(|&n| {
            let dev = scan_dev.clone();
            move || {
                let sweep = SweepCfg {
                    l_mem: Dur::us(0.5),
                    window: scan_window,
                    ssd: dev,
                    n_ssd: n,
                    ..Default::default()
                };
                let mcfg = sweep.machine(64);
                let mut rng = crate::sim::Rng::new(0x5ca9);
                let cfg = TreeKvConfig {
                    n_items: 60_000,
                    sprigs: 64,
                    ops: Some(YcsbWorkload::E.weights()),
                    key_dist: YcsbWorkload::E.key_dist(),
                    scan_len: ScanLen::Fixed(16),
                    ..Default::default()
                };
                let kv = TreeKv::new(cfg, &mut rng).with_background(mcfg.cores, 64);
                let mut machine = crate::sim::Machine::new(mcfg, kv);
                let st = machine.run(sweep.warmup, sweep.window);
                let mix = model_mix(&machine.service, &YcsbWorkload::E.weights());
                let recip = model::theta_mix_recip(&mix, 0.5, &sweep.ext_params(), &sys);
                (st.ops_per_sec, machine.ssd.per_device_ios(), recip)
            }
        })
        .collect();
    let scan_measured = parallel_map(scan_jobs);
    let scan_base = scan_measured[0].0;
    for (i, &n) in n_grid.iter().enumerate() {
        let (ops, per_dev, recip) = &scan_measured[i];
        let total: u64 = per_dev.iter().sum::<u64>().max(1);
        let mean = total as f64 / per_dev.len() as f64;
        let imbalance = per_dev.iter().copied().max().unwrap_or(0) as f64 / mean;
        r.row(vec![
            "scan-bound(treekv-E)".into(),
            n.to_string(),
            f1(0.5),
            format!("{ops:.0}"),
            f2(ops / scan_base),
            f1(1e6 / recip / 1e3),
            f2(imbalance),
        ]);
    }

    r.note("ssd-bound: throughput tracks Theta_ssd = n_ssd*R_IO until the CPU");
    r.note("term takes over; latency-bound: unsaturated devices, array invisible");
    r.note("scan-bound: treekv workload-E batch transfers against n_ssd*B_IO —");
    r.note("the Theta_scan bandwidth floor lifts linearly with the array");
    r.note(format!("model backend: {}", backend.name()));
    r.write_csv("ssd_scaling").ok();
    r
}

// ---------------------------------------------------------------------------
// Table 6 — cost-performance ratios with measured degradation.
// ---------------------------------------------------------------------------

pub fn table6(fast: bool) -> Report {
    let window = if fast { Dur::ms(6.0) } else { Dur::ms(15.0) };
    // Measure degradation d at 5 µs + tail profile (flash) and at 0.8 µs
    // (compressed-DRAM-class latency) for each store.
    let measure_d = |l: f64, tail: bool| -> Vec<f64> {
        let jobs: Vec<_> = StoreKind::ALL
            .iter()
            .map(|&kind| {
                let sweep_d = SweepCfg {
                    l_mem: Dur::us(0.1),
                    window,
                    thread_candidates: vec![32, 64],
                    ..Default::default()
                };
                let sweep_l = SweepCfg {
                    l_mem: Dur::us(l),
                    tail,
                    window,
                    thread_candidates: vec![32, 64],
                    ..Default::default()
                };
                move || {
                    let dram = best_threads(&sweep_d.thread_candidates.clone(), |n| {
                        run_store(kind, &sweep_d, n)
                    })
                    .1
                    .ops_per_sec;
                    let slow = best_threads(&sweep_l.thread_candidates.clone(), |n| {
                        run_store(kind, &sweep_l, n)
                    })
                    .1
                    .ops_per_sec;
                    1.0 - slow / dram
                }
            })
            .collect();
        parallel_map(jobs)
    };

    let d_flash = measure_d(5.0, true);
    let d_cdram = measure_d(0.8, false);
    let d_flash_max = d_flash.iter().cloned().fold(0.0, f64::max).max(0.0);
    let d_flash_min = d_flash.iter().cloned().fold(1.0, f64::min).max(0.0);
    let d_cdram_max = d_cdram.iter().cloned().fold(0.0, f64::max).max(0.0);

    let c = CprScenario::paper_c();
    let mut r = Report::new(
        "Table 6 — cost-performance ratio r = (1-d)/(cb+(1-c)), c=0.4",
        &["memory medium", "bit cost b", "degradation d", "CPR r"],
    );
    for (b, d) in [
        (1.0 / 3.0, 0.0f64.max(d_cdram_max * 0.5)),
        (0.5, d_cdram_max),
    ] {
        let s = CprScenario { c, b, d };
        r.row(vec![
            "compressed DRAM".into(),
            f2(b),
            format!("{:.1}%", 100.0 * d),
            f2(model::cpr(&s)),
        ]);
    }
    for (b, d) in [(0.15, d_flash_min), (0.2, d_flash_max)] {
        let s = CprScenario { c, b, d };
        r.row(vec![
            "low-latency flash".into(),
            f2(b),
            format!("{:.1}%", 100.0 * d),
            f2(model::cpr(&s)),
        ]);
    }
    r.note(format!(
        "measured d: flash(5us+tail) per store = {:?}, cdram(0.8us) = {:?}",
        d_flash
            .iter()
            .map(|d| format!("{:.1}%", 100.0 * d))
            .collect::<Vec<_>>(),
        d_cdram
            .iter()
            .map(|d| format!("{:.1}%", 100.0 * d))
            .collect::<Vec<_>>()
    ));
    r.note("paper: compressed DRAM r = 1.23-1.36; flash r = 1.19-1.50; d 2-19% w/ tail");
    r.write_csv("table6").ok();
    r
}

// ---------------------------------------------------------------------------
// Durability — WAL group commit, crash recovery, and SSD fault injection.
// ---------------------------------------------------------------------------

/// Absolute tolerance on |WAL overhead(sim) − WAL overhead(model)|, where
/// overhead = Θ⁻¹_WAL / Θ⁻¹_noWAL − 1 (reciprocal throughputs, so larger
/// is slower). This is the v1 calibration band: the model carries Eq 14's
/// log-traffic sharing floors, the serialized-flush floor, and the additive
/// append/poll CPU (see `kvs::wal` module docs), but no queueing inside the
/// log device and no WAL↔lock-path interaction.
const WAL_OVERHEAD_BAND: f64 = 0.30;
/// Group commit must beat per-op commit by at least this factor at equal
/// durability — it amortizes the serialized log-device flush over a batch.
const GROUP_COMMIT_EDGE: f64 = 1.05;
/// Queueing slack (µs) on the fault-window p99 bound beyond one full retry
/// ladder (`RetryPolicy::total_backoff`).
const FAULT_P99_SLACK_US: f64 = 200.0;

/// `cxlkvs run durability` — the durability & fault-injection gate:
///
/// 1. **crash**: crash–recovery drills per store × crash point
///    ([`crash_recover_check`]): acked-durable, no delete resurrection, no
///    torn unacked effects, idempotent replay.
/// 2. **sweep**: store × {no-WAL, WAL} × L_mem on YCSB A: the WAL arm must
///    keep every acked LSN durable, and its measured throughput overhead
///    must match the extended model (Eq 14 + `ExtParams::with_log_traffic`
///    + serialized-flush floor + append/poll CPU) within
///    [`WAL_OVERHEAD_BAND`].
/// 3. **commit**: group vs per-op commit at equal durability
///    ([`GROUP_COMMIT_EDGE`], flush amortization ≥ 2×).
/// 4. **faults**: a transient-error window (50% failures over the first
///    half of the measured window, single device): the default
///    retry/backoff policy must keep goodput > 0 with bounded p99, while
///    the no-retry control visibly errors out.
pub fn durability(fast: bool) -> (Report, bool) {
    #[derive(Clone, Copy, PartialEq, Eq)]
    enum Arm {
        NoWal,
        Wal,
        WalPerOp,
        WalFaults,
        WalFaultsNoRetry,
    }
    impl Arm {
        fn label(self) -> &'static str {
            match self {
                Arm::NoWal => "no-wal",
                Arm::Wal => "wal",
                Arm::WalPerOp => "wal-perop",
                Arm::WalFaults => "wal+faults",
                Arm::WalFaultsNoRetry => "wal+faults-noretry",
            }
        }
    }

    let grid: Vec<f64> = if fast { vec![2.0] } else { vec![1.0, 5.0] };
    let crash_points: Vec<f64> = if fast {
        vec![1.0, 4.0]
    } else {
        vec![1.0, 4.0, 8.0]
    };
    let window = if fast { Dur::ms(4.0) } else { Dur::ms(10.0) };
    let warmup = if fast { Dur::ms(1.0) } else { Dur::ms(2.0) };
    // YCSB A (50/50 read/update): the write path — the one the WAL taxes —
    // carries half the mix.
    let wl = YcsbWorkload::A;
    let sys = sys_params();
    let stores = [StoreKind::Tree, StoreKind::Lsm, StoreKind::Cache];
    // A mutation mix with deletes for the crash drills, so the recovery
    // oracle exercises both the must-be-present and must-stay-dead sides.
    let drill_ops = || Some(OpWeights::new(0.3, 0.4, 0.3, 0.0, 0.0));

    // --- Section 1: crash–recovery drills ---------------------------------
    let mut crash_descr = Vec::new();
    let mut crash_jobs = Vec::new();
    for &kind in &stores {
        for &ms in &crash_points {
            crash_descr.push((kind, ms));
            crash_jobs.push(move || {
                let mcfg = SweepCfg {
                    l_mem: Dur::us(2.0),
                    ..Default::default()
                }
                .machine(32);
                let seed = 0xd00d ^ (ms as u64);
                match kind {
                    StoreKind::Tree => crash_recover_check(
                        |rng| {
                            let cfg = TreeKvConfig {
                                ops: drill_ops(),
                                wal: WalConfig::on(),
                                ..Default::default()
                            };
                            TreeKv::new(cfg, rng).with_background(1, 32)
                        },
                        mcfg,
                        seed,
                        Dur::ms(ms),
                    ),
                    StoreKind::Lsm => crash_recover_check(
                        |rng| {
                            let cfg = LsmKvConfig {
                                ops: drill_ops(),
                                wal: WalConfig::on(),
                                ..Default::default()
                            };
                            LsmKv::new(cfg, rng).with_background(32)
                        },
                        mcfg,
                        seed,
                        Dur::ms(ms),
                    ),
                    StoreKind::Cache => crash_recover_check(
                        |rng| {
                            let cfg = CacheKvConfig {
                                ops: drill_ops(),
                                wal: WalConfig::on(),
                                ..Default::default()
                            };
                            CacheKv::new(cfg, rng)
                        },
                        mcfg,
                        seed,
                        Dur::ms(ms),
                    ),
                }
            });
        }
    }
    let crash_results = parallel_map(crash_jobs);

    // --- Sections 2–4: the measured arms ----------------------------------
    // One transient-error brown-out on the (single) device: 50% failures
    // over the first half of the measured window.
    let fault_from = Time(warmup.0);
    let fault_until = Time((warmup + Dur(window.0 / 2)).0);
    let mut descr: Vec<(StoreKind, f64, Arm)> = Vec::new();
    for &kind in &stores {
        for &l in &grid {
            descr.push((kind, l, Arm::NoWal));
            descr.push((kind, l, Arm::Wal));
            descr.push((kind, l, Arm::WalFaults));
            if l == grid[0] {
                descr.push((kind, l, Arm::WalFaultsNoRetry));
                if kind == StoreKind::Lsm {
                    descr.push((kind, l, Arm::WalPerOp));
                }
            }
        }
    }
    let mut jobs = Vec::new();
    for &(kind, l, arm) in &descr {
        jobs.push(move || {
            let mut sweep = SweepCfg {
                l_mem: Dur::us(l),
                window,
                warmup,
                ..Default::default()
            };
            if matches!(arm, Arm::WalFaults | Arm::WalFaultsNoRetry) {
                let plan = FaultPlan {
                    error_windows: vec![ErrorWindow {
                        from: fault_from,
                        until: fault_until,
                        prob: 0.5,
                    }],
                    ..FaultPlan::default()
                };
                sweep.ssd = sweep.ssd.clone().with_fault(0, plan);
                if arm == Arm::WalFaultsNoRetry {
                    sweep.retry = RetryPolicy::none();
                }
            }
            let wal = match arm {
                Arm::NoWal => WalConfig::default(),
                Arm::WalPerOp => WalConfig::per_op(),
                _ => WalConfig::on(),
            };
            run_store_ycsb_durable(kind, wl, &sweep, 32, wal)
        });
    }
    let results = parallel_map(jobs);
    let get = |kind: StoreKind, l: f64, arm: Arm| {
        let i = descr
            .iter()
            .position(|&(k, dl, a)| k == kind && dl == l && a == arm)
            .expect("durability arm not scheduled");
        &results[i]
    };

    let mut r = Report::new(
        "durability — WAL group commit, crash recovery, fault injection (YCSB A)",
        &[
            "section",
            "store",
            "arm",
            "L(us)",
            "ops/sec",
            "p99(us)",
            "appends",
            "flushes",
            "log_KB",
            "retries",
            "failed",
            "invariant",
            "ovh_sim",
            "ovh_model",
            "gate",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let mut gate = |pass: bool, msg: String| -> String {
        if pass {
            "ok".to_string()
        } else {
            all_ok = false;
            failures.push(msg);
            "FAIL".to_string()
        }
    };

    // Section 1 rows + gates.
    for ((kind, ms), c) in crash_descr.iter().zip(&crash_results) {
        let holds = if *kind == StoreKind::Cache {
            c.holds_for_cache()
        } else {
            c.holds_for_index_store()
        };
        // Later crash points must land mid-traffic, not before the first
        // group flush — otherwise the drill validates an empty log.
        let nonvacuous = *ms < 4.0 || c.durable_lsn > 0;
        let violations =
            c.missing_puts + c.resurrected_deletes + c.unacked_perturbed + c.second_replay;
        let pass_msg = format!(
            "crash {}@{ms}ms: missing_puts={} resurrected_deletes={} \
             unacked_perturbed={} replayed={}/{} second_replay={} (records={})",
            kind.name(),
            c.missing_puts,
            c.resurrected_deletes,
            c.unacked_perturbed,
            c.replayed,
            c.durable_lsn,
            c.second_replay,
            c.total_records
        );
        let g = gate(holds && nonvacuous, pass_msg);
        r.row(vec![
            "crash".into(),
            kind.name().into(),
            format!("crash@{ms}ms"),
            f1(2.0),
            "-".into(),
            "-".into(),
            c.durable_lsn.to_string(),
            c.total_records.to_string(),
            "-".into(),
            c.replayed.to_string(),
            violations.to_string(),
            if holds {
                "ok".into()
            } else {
                "VIOLATED".to_string()
            },
            "-".into(),
            "-".into(),
            g,
        ]);
    }

    // Section 2 rows + gates: no-WAL vs WAL throughput, model band.
    for &kind in &stores {
        for &l in &grid {
            let base: &DurableRun = get(kind, l, Arm::NoWal);
            let walr = get(kind, l, Arm::Wal);
            r.row(vec![
                "sweep".into(),
                kind.name().into(),
                Arm::NoWal.label().into(),
                f1(l),
                format!("{:.0}", base.stats.ops_per_sec),
                f2(base.stats.op_latency_p99.as_us()),
                "0".into(),
                "0".into(),
                "0.0".into(),
                base.stats.io_retries.to_string(),
                base.failed_ops.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            // Measured per-op log rates. WAL counters are cumulative over
            // warmup+window while `stats.ops` is window-only; scale by the
            // window's share of simulated time (logging is roughly uniform
            // in time under a steady workload).
            let scale = window.0 as f64 / (warmup + window).0 as f64;
            let ops = walr.stats.ops.max(1) as f64;
            let per_op = |x: u64| x as f64 * scale / ops;
            let s_log = per_op(walr.wal.flushes);
            let w_log = per_op(walr.wal.flush_bytes);
            let wal_cfg = WalConfig::on();
            let log_cpu = per_op(walr.wal.appends) * wal_cfg.append_cpu.as_us()
                + per_op(walr.wal.commit_polls) * sys.t_sw;
            let sweep = SweepCfg {
                l_mem: Dur::us(l),
                window,
                warmup,
                ..Default::default()
            };
            let ext = sweep.ext_params();
            // Group flushes serialize on the log device (one in flight), so
            // the measured flush rate is itself a throughput floor.
            let flush_floor = s_log * sweep.ssd.write_latency.as_us();
            let ext_wal = ext.with_log_traffic(w_log, s_log, 1.0);
            let recip_base = model::theta_mix_recip(&base.mix, l, &ext, &sys);
            let recip_mix = model::theta_mix_recip(&walr.mix, l, &ext_wal, &sys);
            let recip_wal = recip_mix.max(flush_floor) + log_cpu;
            let ovh_model = recip_wal / recip_base.max(1e-9) - 1.0;
            let ovh_sim = base.stats.ops_per_sec / walr.stats.ops_per_sec.max(1e-9) - 1.0;
            let acked = walr.acked_all_durable;
            let active = walr.wal.appends > 0 && walr.wal.flushes > 0;
            let in_band = (ovh_sim - ovh_model).abs() <= WAL_OVERHEAD_BAND;
            let g = gate(
                acked && active && in_band,
                format!(
                    "sweep {}@L={l}: acked_all_durable={acked} appends={} flushes={} \
                     ovh_sim={ovh_sim:.3} ovh_model={ovh_model:.3} band={WAL_OVERHEAD_BAND}",
                    kind.name(),
                    walr.wal.appends,
                    walr.wal.flushes
                ),
            );
            r.row(vec![
                "sweep".into(),
                kind.name().into(),
                Arm::Wal.label().into(),
                f1(l),
                format!("{:.0}", walr.stats.ops_per_sec),
                f2(walr.stats.op_latency_p99.as_us()),
                walr.wal.appends.to_string(),
                walr.wal.flushes.to_string(),
                f1(walr.wal.flush_bytes as f64 / 1024.0),
                walr.stats.io_retries.to_string(),
                walr.failed_ops.to_string(),
                if acked { "ok" } else { "VIOLATED" }.into(),
                f3(ovh_sim),
                f3(ovh_model),
                g,
            ]);
        }
    }

    // Section 3: group vs per-op commit at equal durability (lsmkv).
    {
        let l = grid[0];
        let group = get(StoreKind::Lsm, l, Arm::Wal);
        let perop = get(StoreKind::Lsm, l, Arm::WalPerOp);
        let thr_edge = group.stats.ops_per_sec >= GROUP_COMMIT_EDGE * perop.stats.ops_per_sec;
        let amortized = group.wal.flushes * 2 <= group.wal.appends;
        let acked = group.acked_all_durable && perop.acked_all_durable;
        let g = gate(
            thr_edge && amortized && acked,
            format!(
                "commit lsmkv@L={l}: group {:.0} ops/s vs per-op {:.0} \
                 (edge {GROUP_COMMIT_EDGE}), group flushes {} vs appends {} \
                 (need >=2x amortization), acked={acked}",
                group.stats.ops_per_sec,
                perop.stats.ops_per_sec,
                group.wal.flushes,
                group.wal.appends
            ),
        );
        r.row(vec![
            "commit".into(),
            StoreKind::Lsm.name().into(),
            Arm::WalPerOp.label().into(),
            f1(l),
            format!("{:.0}", perop.stats.ops_per_sec),
            f2(perop.stats.op_latency_p99.as_us()),
            perop.wal.appends.to_string(),
            perop.wal.flushes.to_string(),
            f1(perop.wal.flush_bytes as f64 / 1024.0),
            perop.stats.io_retries.to_string(),
            perop.failed_ops.to_string(),
            if perop.acked_all_durable {
                "ok".into()
            } else {
                "VIOLATED".to_string()
            },
            "-".into(),
            "-".into(),
            g,
        ]);
    }

    // Section 4: transient-error window — retry/backoff vs no-retry.
    let ladder_us = RetryPolicy::default().total_backoff().as_us();
    for &kind in &stores {
        for &l in &grid {
            let clean = get(kind, l, Arm::Wal);
            let faulty = get(kind, l, Arm::WalFaults);
            let p99_bound = clean.stats.op_latency_p99.as_us() + ladder_us + FAULT_P99_SLACK_US;
            let p99 = faulty.stats.op_latency_p99.as_us();
            let goodput = faulty.stats.ops_per_sec > 0.0;
            let retried = faulty.stats.io_retries > 0;
            let control = if l == grid[0] {
                let noretry = get(kind, l, Arm::WalFaultsNoRetry);
                // The control must visibly error out, and retries must
                // absorb most of what it surfaces.
                noretry.failed_ops > 0 && faulty.failed_ops < noretry.failed_ops
            } else {
                true
            };
            let pass =
                goodput && retried && faulty.acked_all_durable && p99 <= p99_bound && control;
            let g = gate(
                pass,
                format!(
                    "faults {}@L={l}: goodput={:.0} retries={} failed={} p99={p99:.1}us \
                     (bound {p99_bound:.1}us) acked={} control_ok={control}",
                    kind.name(),
                    faulty.stats.ops_per_sec,
                    faulty.stats.io_retries,
                    faulty.failed_ops,
                    faulty.acked_all_durable
                ),
            );
            r.row(vec![
                "faults".into(),
                kind.name().into(),
                Arm::WalFaults.label().into(),
                f1(l),
                format!("{:.0}", faulty.stats.ops_per_sec),
                f2(p99),
                faulty.wal.appends.to_string(),
                faulty.wal.flushes.to_string(),
                f1(faulty.wal.flush_bytes as f64 / 1024.0),
                faulty.stats.io_retries.to_string(),
                faulty.failed_ops.to_string(),
                if faulty.acked_all_durable {
                    "ok".into()
                } else {
                    "VIOLATED".to_string()
                },
                "-".into(),
                "-".into(),
                g,
            ]);
            if l == grid[0] {
                let noretry = get(kind, l, Arm::WalFaultsNoRetry);
                r.row(vec![
                    "faults".into(),
                    kind.name().into(),
                    Arm::WalFaultsNoRetry.label().into(),
                    f1(l),
                    format!("{:.0}", noretry.stats.ops_per_sec),
                    f2(noretry.stats.op_latency_p99.as_us()),
                    noretry.wal.appends.to_string(),
                    noretry.wal.flushes.to_string(),
                    f1(noretry.wal.flush_bytes as f64 / 1024.0),
                    noretry.stats.io_retries.to_string(),
                    noretry.failed_ops.to_string(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "control".into(),
                ]);
            }
        }
    }

    r.note("crash rows: appends=durable_lsn, flushes=records appended,");
    r.note("retries=records replayed, failed=invariant violations; drills");
    r.note("crash a WAL-on store mid-run (30/40/30 read/update/delete),");
    r.note("rebuild from the constructor seed, replay the durable prefix,");
    r.note("then audit against the log's own oracle + a second replay");
    r.note("sweep: YCSB A, 32 threads, single device shared by data + log;");
    r.note("ovh = thr(no-wal)/thr(wal) - 1, model = Eq 14 mix with measured");
    r.note("w_log/s_log sharing terms, serialized-flush floor, and");
    r.note("append/poll CPU; |sim-model| gated by the calibration band");
    r.note("faults: 50% transient-error probability on the device over the");
    r.note("first half of the window; retry ladder 6x 20us->640us backoff;");
    r.note("p99 bound = clean p99 + full ladder + queueing slack; the");
    r.note("no-retry control rows are ungated evidence (must error out)");
    if failures.is_empty() {
        r.note(format!(
            "all durability gates passed (crash invariants, acked-durable, \
             WAL overhead within {WAL_OVERHEAD_BAND} of model, group commit \
             >= {GROUP_COMMIT_EDGE}x per-op, faulted goodput with bounded p99)"
        ));
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("durability").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// ablation — random placement vs the ranked knapsack at equal DRAM bytes.
// ---------------------------------------------------------------------------

/// Documented slack for the ablation's equal-bytes gate: at every point the
/// ranked (Budget) arm must reach at least `1 - ABLATION_SLACK` of the
/// Random arm's throughput. Class-granular stores resolve `Random` to the
/// same hottest-first prefix at `frac · offloadable` bytes, so their arms
/// are bit-identical and the ratio is exactly 1; treekv's entry-granular
/// random bit genuinely scatters residency, and there the ranked arm must
/// *win* (see the discriminator gate) — the slack only absorbs short-window
/// noise on the class-granular ties.
pub const ABLATION_SLACK: f64 = 0.05;

/// Placement ablation (the paper's §5.2.3 motivation, isolated): **Random**
/// residency vs the hotness-ranked **Budget** knapsack at *equal DRAM
/// bytes*, across all three stores under YCSB C. `Budget` generalizes
/// `TopLevels` — its ranked prefix at treekv's class granularity *is* the
/// top-levels rule — so the two structured policies collapse into one arm.
///
/// Each row also carries an **Eq 15 overlay** column: the paper's blind
/// ρ-interpolation (every hop priced at `ρ·L_mem + (1-ρ)·L_DRAM`, with ρ
/// the access-weighted offloaded share from the measured mix) evaluated on
/// the same normalized curve, next to the split-hop model that prices
/// `M_sec` on the prefetch path and `M_dram`/`M_cpr` inline. The overlay is
/// report-only: it tracks the Random arm (uniform residency is exactly what
/// interpolation assumes) and misprices the ranked arm (hot-hop share ≠
/// byte share), which is the point of the column.
///
/// Gates (exit non-zero):
/// 1. ranked ≥ random − [`ABLATION_SLACK`] at every point;
/// 2. discriminator: on treekv at the slowest memory the ranked arm beats
///    random by ≥ 2% — entry-granular random leaves hot upper levels
///    offloaded, so a real knapsack must separate from it;
/// 3. equal-bytes fairness: the arms' simulated DRAM bytes agree within 5%
///    (treekv's random bit is a binomial draw, not an exact quota);
/// 4. the split-hop model stays within the `modelcheck` band on the
///    calibrated grid (L ≤ 5 µs); the Random arm gets +10% — its model
///    side splits hops by the *expected* entry fraction while the draw is
///    binomial per node.
pub fn ablation(fast: bool) -> (Report, bool) {
    let grid: Vec<f64> = if fast {
        vec![0.1, 5.0, 8.0]
    } else {
        vec![0.1, 2.0, 5.0, 8.0]
    };
    const MODEL_GATE_L_MAX: f64 = 5.0;
    // One budget point: 35% of each store's offloadable footprint — inside
    // the placement sweep's steep region, where *which* bytes stay
    // resident actually moves throughput.
    const FRAC: f64 = 0.35;
    let wl = YcsbWorkload::C;
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(12.0) };
    let sys = sys_params();
    let ext = SweepCfg::default().ext_params();
    let base_seed = SweepCfg::default().seed;

    let mut totals = Vec::new();
    for kind in StoreKind::ALL {
        totals.push(store_offload_bytes(kind, wl, base_seed));
    }

    // Flat job list: store × arm(random, ranked) × latency.
    let mut jobs = Vec::new();
    let mut ti = 0usize;
    for kind in StoreKind::ALL {
        let total = totals[ti];
        ti += 1;
        let arms = [
            PlacementPolicy::Random { dram_frac: FRAC },
            PlacementPolicy::Budget {
                dram_bytes: (FRAC * total as f64) as u64,
            },
        ];
        for policy in arms {
            for &l in &grid {
                jobs.push(move || {
                    let sweep = SweepCfg {
                        l_mem: Dur::us(l),
                        window,
                        thread_candidates: vec![32],
                        placement: policy,
                        ..Default::default()
                    };
                    run_store_ycsb_placed(kind, wl, &sweep, 32)
                });
            }
        }
    }
    let results = parallel_map(jobs);

    // Eq 15 overlay: collapse the split-hop mix into the paper's blind
    // ρ-interpolation — every hop "secondary" at the interpolated latency,
    // with ρ the access-weighted offloaded share of the measured mix.
    let eq15 = |mix: &[(f64, KindCost)], l: f64, sim_norm: f64| -> (f64, f64) {
        let sec: f64 = mix.iter().map(|(f, c)| f * c.m).sum();
        let all: f64 = mix
            .iter()
            .map(|(f, c)| f * (c.m + c.m_dram + c.m_cpr))
            .sum();
        let rho = if all > 0.0 { sec / all } else { 1.0 };
        let merged: Vec<(f64, KindCost)> = mix
            .iter()
            .map(|&(f, c)| {
                (
                    f,
                    KindCost {
                        m: c.m + c.m_dram + c.m_cpr,
                        m_dram: 0.0,
                        m_cpr: 0.0,
                        t_cpu: 0.0,
                        ..c
                    },
                )
            })
            .collect();
        let ext_rho = ExtParams { rho, ..ext };
        model_norm_err(&merged, grid[0], l, sim_norm, &ext_rho, &sys)
    };

    let mut r = Report::new(
        "ablation — random vs ranked placement at equal DRAM bytes (Eq 15 overlay)",
        &[
            "workload",
            "store",
            "arm",
            "dram_MB",
            "L_mem(us)",
            "ops/sec",
            "sim_norm",
            "model_norm",
            "err%",
            "eq15_norm",
            "eq15_err%",
            "M_sec",
            "M_dram",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let tol = modelcheck_tolerance(wl);
    let l_slow = *grid.last().unwrap();
    let mut idx = 0usize;
    for kind in StoreKind::ALL {
        let rand_group = &results[idx..idx + grid.len()];
        idx += grid.len();
        let rank_group = &results[idx..idx + grid.len()];
        idx += grid.len();
        for (arm, group) in [("random", rand_group), ("ranked", rank_group)] {
            let (dram_stats, mix, bytes) = &group[0];
            let band = if arm == "random" { tol + 0.10 } else { tol };
            for (i, &l) in grid.iter().enumerate() {
                let st = &group[i].0;
                let sim_norm = st.ops_per_sec / dram_stats.ops_per_sec.max(1e-9);
                let (model_norm, err) = model_norm_err(mix, grid[0], l, sim_norm, &ext, &sys);
                let (eq15_norm, eq15_err) = eq15(mix, l, sim_norm);
                if l <= MODEL_GATE_L_MAX && err.abs() > band {
                    all_ok = false;
                    failures.push(format!(
                        "{}/{arm} L={l}: split-hop err {:+.1}% > band {:.0}%",
                        kind.name(),
                        100.0 * err,
                        100.0 * band
                    ));
                }
                r.row(vec![
                    wl.tag().into(),
                    kind.name().into(),
                    arm.into(),
                    f2(*bytes as f64 / 1e6),
                    f1(l),
                    format!("{:.0}", st.ops_per_sec),
                    f3(sim_norm),
                    f3(model_norm),
                    format!("{:+.1}", 100.0 * err),
                    f3(eq15_norm),
                    format!("{:+.1}", 100.0 * eq15_err),
                    f2(st.mean_m),
                    f2(st.mean_m_dram),
                ]);
            }
        }
        // Gate: equal-bytes fairness between the arms.
        let (rb, kb) = (rand_group[0].2, rank_group[0].2);
        if (rb as f64 - kb as f64).abs() > 0.05 * (kb.max(1)) as f64 {
            all_ok = false;
            failures.push(format!(
                "{}: arms not byte-comparable: random {rb} vs ranked {kb}",
                kind.name()
            ));
        }
        // Gate: ranked >= random - slack at every latency; discriminator
        // win on treekv at the slowest memory.
        for (i, &l) in grid.iter().enumerate() {
            let r_ops = rand_group[i].0.ops_per_sec;
            let k_ops = rank_group[i].0.ops_per_sec;
            if k_ops < r_ops * (1.0 - ABLATION_SLACK) {
                all_ok = false;
                failures.push(format!(
                    "{} L={l}: ranked placement lost to random at equal bytes \
                     ({r_ops:.0} -> {k_ops:.0})",
                    kind.name()
                ));
            }
            if kind == StoreKind::Tree && (l - l_slow).abs() < 1e-9 && k_ops < r_ops * 1.02 {
                all_ok = false;
                failures.push(format!(
                    "tree L={l}: ranked arm failed to beat entry-granular \
                     random by 2% ({r_ops:.0} vs {k_ops:.0}) — the knapsack \
                     validated nothing"
                ));
            }
        }
    }
    r.note("both arms hold the same DRAM byte allowance (35% of the");
    r.note("offloadable footprint); 'ranked' is the hottest-first knapsack,");
    r.note("'random' scatters residency (per node on treekv; class-granular");
    r.note("stores resolve it to the same ranked prefix, arms bit-identical)");
    r.note("eq15 columns: the paper's blind rho-interpolation re-prediction");
    r.note("of the same curve — it tracks random residency and misprices the");
    r.note("ranked arm, which is why the split-hop model exists; report-only");
    r.note("model band gated at L <= 5us (the modelcheck-calibrated grid);");
    r.note("random arm gets +10% (binomial residency vs expected-fraction");
    r.note("model split)");
    if failures.is_empty() {
        r.note(format!(
            "all ablation gates passed (ranked >= random - {:.0}% at equal \
             bytes everywhere, treekv discriminator win, bytes comparable, \
             model within bands)",
            100.0 * ABLATION_SLACK
        ));
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("ablation_placement").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// compress — the joint placement×compression planner's CPU-for-bytes trade.
// ---------------------------------------------------------------------------

/// Documented slack for the compression crossover gates: the winning arm of
/// each predicted-crossover cell may fall short of the losing arm by at
/// most this fraction. Two runs whose plans differ diverge event-by-event,
/// so short windows carry real noise; a mispriced decompress charge or a
/// broken knapsack variant blows far past it. v1 band, pending CI
/// calibration on the recorded sweeps.
pub const COMPRESS_WIN_SLACK: f64 = 0.05;

/// Documented tolerance for the t_cpu-extended Eq 14 against the simulator
/// on compressed arms (the `modelcheck` C-band plus headroom for the
/// decompress-CPU term, whose inline charge interleaves with lock holds
/// that Eq 14 does not model). v1 band, pending CI calibration.
pub const COMPRESS_MODEL_BAND: f64 = 0.35;

/// Decompress CPU charged per compressed hop in the experiment's spec (µs)
/// — LZ4-class block decompression over the ~64–128 B touched per hop.
const COMPRESS_T_CPU_US: f64 = 0.12;

/// The tight-budget fraction per store: chosen so the *uncompressed* plan
/// is forced to leave a genuinely hot slab offloaded while the compressed
/// variant pulls it (or a deeper prefix) into DRAM — the cell where the
/// CPU-for-bytes trade has something to buy.
///
/// - treekv: 6% — covers all but the last ~4 levels uncompressed vs all
///   but ~3 at ratio ½ (level bytes are geometric, so every halving of the
///   residual budget costs one level);
/// - lsmkv/cachekv: 52% — just over half the footprint, so the dominant
///   class (lsmkv's block-cache data slabs; one of cachekv's two
///   equal-byte tier-1 classes) fits compressed-at-½ but not plain.
fn compress_tight_frac(kind: StoreKind) -> f64 {
    match kind {
        StoreKind::Tree => 0.06,
        StoreKind::Lsm | StoreKind::Cache => 0.52,
    }
}

/// Sweep budget × L_mem × compression ratio across all three stores under
/// YCSB C and gate on the crossover the t_cpu-extended model predicts
/// (`kvs/placement.rs` module docs): a compressed-in-DRAM hop costs
/// `T_mem + L_DRAM + t_cpu` of busy time, an offloaded hop costs
/// `T_mem + T_sw` busy but holds a prefetch slot for `L_mem` (the `P/L`
/// wall). Compression therefore wins exactly where the wall binds — tight
/// budgets at long L_mem — and only burns CPU where it doesn't.
///
/// Arms per (store, budget, L): `off` (plain two-state knapsack), `joint`
/// (the planner chooses per class), `forced` (every placed class stays
/// compressed — isolates the decompress cost). Gates, exit non-zero:
///
/// 1. **tight/slow win**: at the tight budget and slowest memory, the
///    joint and forced arms reach at least `1 - COMPRESS_WIN_SLACK` of the
///    uncompressed throughput, and at least one such cell shows a strict
///    ≥ 2% compressed win;
/// 2. **loose loss**: at the loose budget (1.1× offloadable) the forced
///    arm never *beats* `off` by more than the slack, and at DRAM-like
///    memory `off` strictly wins by ≥ 2% — compression with nothing to buy
///    is pure CPU;
/// 3. **joint folds to off when loose**: the upgrade pass lifts every
///    class to plain DRAM, so the joint arm's op count is bit-equal to
///    `off` at the loose budget;
/// 4. **model band**: every arm's normalized curve stays within
///    [`COMPRESS_MODEL_BAND`] (compressed arms) / the `modelcheck` band
///    (`off`) of the t_cpu-extended Eq 14 on the calibrated grid
///    (L ≤ 5 µs), with mixes snapshotted from the live plan;
/// 5. **ratio-1.0 passthrough**: a `Joint` spec at ratio 1.0 normalizes to
///    no compression, and its run is bit-equal (op count) to `off` at the
///    same cell.
pub fn compress(fast: bool) -> (Report, bool) {
    let grid: Vec<f64> = if fast {
        vec![0.1, 5.0, 8.0]
    } else {
        vec![0.1, 2.0, 5.0, 8.0]
    };
    const MODEL_GATE_L_MAX: f64 = 5.0;
    // Canonical spec first: the crossover gates anchor on ratio ½; the
    // extra slow-mode ratios map the trade's sensitivity, report-only.
    let ratios: Vec<f64> = if fast {
        vec![0.5]
    } else {
        vec![0.5, 0.3, 0.8]
    };
    let wl = YcsbWorkload::C;
    let window = if fast { Dur::ms(5.0) } else { Dur::ms(12.0) };
    let sys = sys_params();
    let ext = SweepCfg::default().ext_params();
    let base_seed = SweepCfg::default().seed;

    let mut totals = Vec::new();
    for kind in StoreKind::ALL {
        totals.push(store_offload_bytes(kind, wl, base_seed));
    }

    // Flat descriptor list per store × budget: an `off` row group over the
    // grid, then per ratio a `joint` and a `forced` group; after both
    // budgets, one ratio-1.0 passthrough cell at (tight, slowest L), which
    // must be bit-identical to the tight `off` arm there (the spec
    // normalizes away at plan resolution). One closure site keeps the job
    // list a single type for `parallel_map`.
    let mut descr: Vec<(StoreKind, u64, CompressMode, f64)> = Vec::new();
    let mut ti = 0usize;
    for kind in StoreKind::ALL {
        let total = totals[ti];
        ti += 1;
        for tight in [true, false] {
            let frac = if tight {
                compress_tight_frac(kind)
            } else {
                1.10
            };
            let budget = (frac * total as f64) as u64;
            for &l in &grid {
                descr.push((kind, budget, CompressMode::Off, l));
            }
            for &q in &ratios {
                let spec = Compression::new(q, COMPRESS_T_CPU_US);
                for &l in &grid {
                    descr.push((kind, budget, CompressMode::Joint(spec), l));
                }
                for &l in &grid {
                    descr.push((kind, budget, CompressMode::Forced(spec), l));
                }
            }
        }
        descr.push((
            kind,
            (compress_tight_frac(kind) * total as f64) as u64,
            CompressMode::Joint(Compression::new(1.0, COMPRESS_T_CPU_US)),
            *grid.last().unwrap(),
        ));
    }
    let jobs: Vec<_> = descr
        .into_iter()
        .map(|(kind, budget, mode, l)| {
            move || {
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    window,
                    thread_candidates: vec![32],
                    placement: PlacementPolicy::Budget { dram_bytes: budget },
                    ..Default::default()
                };
                run_store_ycsb_compressed(kind, wl, &sweep, 32, mode)
            }
        })
        .collect();
    let results = parallel_map(jobs);

    let mut r = Report::new(
        "compress — joint placement×compression: CPU for µs-memory bytes",
        &[
            "workload",
            "store",
            "budget",
            "dram_MB",
            "arm",
            "ratio",
            "L_mem(us)",
            "ops/sec",
            "vs_off",
            "M_sec",
            "M_cpr",
            "sim_norm",
            "model_norm",
            "err%",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let mut tight_win = false;
    let mut loose_loss = false;
    let tol = modelcheck_tolerance(wl);
    let l_slow = *grid.last().unwrap();
    let mut idx = 0usize;
    for kind in StoreKind::ALL {
        for tight in [true, false] {
            let budget_tag = if tight { "tight" } else { "loose" };
            let off_group = &results[idx..idx + grid.len()];
            idx += grid.len();
            // Per-ratio arm groups, in push order: joint then forced.
            let mut arm_groups: Vec<(f64, &str, &[_])> = Vec::new();
            for &q in &ratios {
                arm_groups.push((q, "joint", &results[idx..idx + grid.len()]));
                idx += grid.len();
                arm_groups.push((q, "forced", &results[idx..idx + grid.len()]));
                idx += grid.len();
            }
            let mut emit = |arm: &str, ratio: Option<f64>, group: &[_], band: f64| {
                let (dram_stats, mix, bytes) = &group[0];
                let m_cpr: f64 = mix.iter().map(|(f, c)| f * c.m_cpr).sum();
                for (i, &l) in grid.iter().enumerate() {
                    let st = &group[i].0;
                    let off_ops = off_group[i].0.ops_per_sec;
                    let sim_norm = st.ops_per_sec / dram_stats.ops_per_sec.max(1e-9);
                    let (model_norm, err) = model_norm_err(mix, grid[0], l, sim_norm, &ext, &sys);
                    if l <= MODEL_GATE_L_MAX && err.abs() > band {
                        all_ok = false;
                        failures.push(format!(
                            "{}/{budget_tag}/{arm} L={l}: t_cpu-extended model \
                             err {:+.1}% > band {:.0}%",
                            kind.name(),
                            100.0 * err,
                            100.0 * band
                        ));
                    }
                    r.row(vec![
                        wl.tag().into(),
                        kind.name().into(),
                        budget_tag.into(),
                        f2(*bytes as f64 / 1e6),
                        arm.into(),
                        ratio.map(f2).unwrap_or_else(|| "-".into()),
                        f1(l),
                        format!("{:.0}", st.ops_per_sec),
                        f3(st.ops_per_sec / off_ops.max(1e-9)),
                        f2(st.mean_m),
                        f2(m_cpr),
                        f3(sim_norm),
                        f3(model_norm),
                        format!("{:+.1}", 100.0 * err),
                    ]);
                }
            };
            emit("off", None, off_group, tol);
            for &(q, arm, group) in &arm_groups {
                emit(arm, Some(q), group, COMPRESS_MODEL_BAND);
            }
            drop(emit);
            // Crossover gates anchor on the canonical ratio (ratios[0]).
            let joint = arm_groups[0].2;
            let forced = arm_groups[1].2;
            for (i, &l) in grid.iter().enumerate() {
                let off_ops = off_group[i].0.ops_per_sec;
                let j_ops = joint[i].0.ops_per_sec;
                let f_ops = forced[i].0.ops_per_sec;
                if tight && (l - l_slow).abs() < 1e-9 {
                    // Gate 1: compression must win (within slack) where the
                    // P/L wall binds and bytes are scarce.
                    for (arm, ops) in [("joint", j_ops), ("forced", f_ops)] {
                        if ops < off_ops * (1.0 - COMPRESS_WIN_SLACK) {
                            all_ok = false;
                            failures.push(format!(
                                "{}/tight L={l}: {arm} lost to uncompressed \
                                 ({off_ops:.0} -> {ops:.0}) where the model \
                                 predicts a compression win",
                                kind.name()
                            ));
                        }
                    }
                    if j_ops >= off_ops * 1.02 {
                        tight_win = true;
                    }
                }
                if !tight {
                    // Gate 2: with nothing to buy, forced compression may
                    // only lose.
                    if f_ops > off_ops * (1.0 + COMPRESS_WIN_SLACK) {
                        all_ok = false;
                        failures.push(format!(
                            "{}/loose L={l}: forced compression beat \
                             uncompressed ({off_ops:.0} -> {f_ops:.0}) with \
                             nothing offloaded to save",
                            kind.name()
                        ));
                    }
                    if (l - grid[0]).abs() < 1e-9 && off_ops >= f_ops * 1.02 {
                        loose_loss = true;
                    }
                    // Gate 3: the upgrade pass must fold joint into off
                    // bit-for-bit at a loose budget.
                    if joint[i].0.ops != off_group[i].0.ops {
                        all_ok = false;
                        failures.push(format!(
                            "{}/loose L={l}: joint arm diverged from off \
                             ({} vs {} ops) — the upgrade pass failed to \
                             lift every class to plain DRAM",
                            kind.name(),
                            joint[i].0.ops,
                            off_group[i].0.ops
                        ));
                    }
                }
            }
        }
        // Gate 5: ratio-1.0 passthrough, bit-equal to tight `off` at the
        // slowest memory. The tight off group for this store sits two
        // budget blocks back from `idx`.
        let per_budget = grid.len() * (1 + 2 * ratios.len());
        let tight_off_slow = &results[idx - 2 * per_budget + grid.len() - 1];
        let pass = &results[idx];
        idx += 1;
        if pass.0.ops != tight_off_slow.0.ops {
            all_ok = false;
            failures.push(format!(
                "{}: ratio-1.0 passthrough not bit-identical to off \
                 ({} vs {} ops)",
                kind.name(),
                pass.0.ops,
                tight_off_slow.0.ops
            ));
        }
        r.row(vec![
            wl.tag().into(),
            kind.name().into(),
            "tight".into(),
            f2(pass.2 as f64 / 1e6),
            "pass(q=1)".into(),
            f2(1.0),
            f1(l_slow),
            format!("{:.0}", pass.0.ops_per_sec),
            f3(pass.0.ops_per_sec / tight_off_slow.0.ops_per_sec.max(1e-9)),
            f2(pass.0.mean_m),
            "0.00".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    if !tight_win {
        all_ok = false;
        failures.push(
            "no tight-budget/slow-memory cell showed a strict >=2% compressed \
             win — the crossover never materialized"
                .to_string(),
        );
    }
    if !loose_loss {
        all_ok = false;
        failures.push(
            "no loose-budget/DRAM-like cell showed uncompressed strictly \
             beating forced compression — the CPU cost never materialized"
                .to_string(),
        );
    }
    r.note("arms: off = two-state knapsack; joint = planner picks Dram /");
    r.note("Compressed / Secondary per class; forced = every placed class");
    r.note("stays compressed (isolates the decompress CPU)");
    r.note("crossover (kvs/placement.rs docs): a compressed hop costs");
    r.note("T_mem+L_DRAM+t_cpu busy; an offloaded hop costs T_mem+T_sw busy");
    r.note("but holds a prefetch slot for L_mem — compression wins once the");
    r.note("P/L wall it relieves exceeds the CPU it adds (tight budget, long");
    r.note("L); at loose budgets it is pure CPU and must lose");
    r.note("tight budgets: tree 6%, lsm/cache 52% of the offloadable");
    r.note("footprint — each forces the uncompressed plan to strand a hot");
    r.note("slab that the ratio-1/2 variant can afford to keep resident");
    r.note("model bands: off gated at the modelcheck C band, compressed arms");
    r.note(format!(
        "at {:.0}% (t_cpu-extended Eq 14, v1 pending CI calibration), both",
        100.0 * COMPRESS_MODEL_BAND
    ));
    r.note("on the calibrated grid (L <= 5us); mixes snapshot the live plan");
    if failures.is_empty() {
        r.note(format!(
            "all compression gates passed (tight/slow compressed win within \
             {:.0}% slack with a strict win cell, loose forced loss, joint \
             folds to off bit-identically when loose, ratio-1.0 passthrough \
             bit-identical, model within bands)",
            100.0 * COMPRESS_WIN_SLACK
        ));
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("compression").ok();
    (r, all_ok)
}

// ---------------------------------------------------------------------------
// interference — compaction storms vs foreground traffic under the
// fg/bg bandwidth-sharing policies, with the Eq 14 interference term.
// ---------------------------------------------------------------------------

/// Minimum fractional foreground-throughput depression a compaction storm
/// must inflict under `BgShare::None` at the fastest memory point. The
/// storm arm saturates the background thread with back-to-back 32 KiB IOs
/// on the shared servers, so the bite should be well clear of this; v1,
/// pending CI calibration.
pub const STORM_BITE_MIN: f64 = 0.02;

/// The storm must inflate the foreground IO p99 by at least this many µs
/// under `BgShare::None` (fastest memory point) before the cap-recovery
/// gate is meaningful — shared-FIFO queueing behind bulk 32 KiB transfers
/// is the whole mechanism under test.
pub const STORM_P99_INFLATION_MIN_US: f64 = 1.0;

/// Fraction of the storm-induced foreground IO-p99 inflation that
/// `Cap{0.5}` must claw back: `p99(none) − p99(cap)` must be at least this
/// share of `p99(none) − p99(idle)`. The cap isolates foreground queueing
/// from the storm entirely but serves it at half rate, so the documented
/// floor is conservative; v1, pending CI calibration.
pub const CAP_RECOVERY_FRAC: f64 = 0.10;

/// Slack on the cap-monotonicity gate: foreground throughput under
/// `Cap{0.25}` (background capped harder) may fall short of `Cap{0.5}` by
/// at most this fraction. Completion-order ripples through the thread
/// scheduler make the *system-level* property approximate; the
/// device-level property is strict and pinned in
/// `tests/prop_interference.rs`.
pub const CAP_MONO_SLACK: f64 = 0.02;

/// |ovh_sim − ovh_model| band for the Eq 14 interference term on the
/// shared-policy storm arms. The model folds background traffic into the
/// rate ceilings (`model/extended.rs`), so it underestimates contention
/// that queues without saturating a server; v1, pending CI calibration.
pub const INTERFERENCE_MODEL_BAND: f64 = 0.40;

/// Memtable cap for the storm arms: rotate every 64 updates, so under
/// YCSB A the flush backlog never drains and the background thread issues
/// flush/compaction IO back-to-back for the whole window.
const STORM_MEMTABLE_CAP: u32 = 64;

/// Memtable cap for the idle arms: never reached inside a run, so the
/// memtable never rotates and the background thread only ever parks. The
/// cap only feeds rotation checks and byte accounting — nothing is
/// allocated at this size.
const IDLE_MEMTABLE_CAP: u32 = u32::MAX;

pub fn interference(fast: bool) -> (Report, bool) {
    #[derive(Clone, Copy, PartialEq)]
    enum Arm {
        Idle,
        StormNone,
        StormCap25,
        StormCap50,
        StormWeighted,
    }
    impl Arm {
        fn label(self) -> &'static str {
            match self {
                Arm::Idle => "idle",
                Arm::StormNone => "storm/none",
                Arm::StormCap25 => "storm/cap25",
                Arm::StormCap50 => "storm/cap50",
                Arm::StormWeighted => "storm/w3:1",
            }
        }
        fn share(self) -> BgShare {
            match self {
                Arm::Idle | Arm::StormNone => BgShare::None,
                Arm::StormCap25 => BgShare::Cap { frac: 0.25 },
                Arm::StormCap50 => BgShare::Cap { frac: 0.5 },
                Arm::StormWeighted => BgShare::Weighted { fg_w: 3, bg_w: 1 },
            }
        }
        /// The `bg_share` the Eq 14 term models this arm with (Weighted is
        /// modeled as shared — the pacer keeps the servers work-conserving).
        fn model_share(self) -> f64 {
            match self {
                Arm::StormCap25 => 0.25,
                Arm::StormCap50 => 0.5,
                _ => 0.0,
            }
        }
    }

    let grid: Vec<f64> = if fast { vec![2.0] } else { vec![1.0, 5.0] };
    let window = if fast { Dur::ms(4.0) } else { Dur::ms(12.0) };
    let warmup = if fast { Dur::ms(1.0) } else { Dur::ms(2.0) };
    // YCSB A: the 50% update stream is the churn that fills the memtable.
    let wl = YcsbWorkload::A;
    let sys = sys_params();

    let mut arms = vec![Arm::Idle, Arm::StormNone, Arm::StormCap25, Arm::StormCap50];
    if !fast {
        arms.push(Arm::StormWeighted);
    }
    let mut descr: Vec<(f64, Arm)> = Vec::new();
    for &l in &grid {
        for &arm in &arms {
            descr.push((l, arm));
        }
    }
    let jobs: Vec<_> = descr
        .iter()
        .map(|&(l, arm)| {
            move || {
                let sweep = SweepCfg {
                    l_mem: Dur::us(l),
                    window,
                    warmup,
                    ..Default::default()
                };
                let cap = match arm {
                    Arm::Idle => IDLE_MEMTABLE_CAP,
                    _ => STORM_MEMTABLE_CAP,
                };
                run_lsm_interference(wl, &sweep, 32, Some(cap), arm.share())
            }
        })
        .collect();
    let results = parallel_map(jobs);
    let get = |l: f64, arm: Arm| -> &InterferenceRun {
        let i = descr
            .iter()
            .position(|&(dl, a)| dl == l && a == arm)
            .expect("interference arm not scheduled");
        &results[i]
    };
    // Background lane totals: (ios, bytes, io-weighted mean queue wait µs)
    // summed over the four background lanes (compaction/flush/defrag/wal).
    let bg = |r: &InterferenceRun| {
        let (mut ios, mut bytes, mut wait) = (0u64, 0u64, 0.0f64);
        for c in r.stats.io_classes.iter().skip(1) {
            ios += c.ios;
            bytes += c.bytes;
            wait += c.queue_wait_mean.as_us() * c.ios as f64;
        }
        (ios, bytes, if ios > 0 { wait / ios as f64 } else { 0.0 })
    };

    let mut r = Report::new(
        "interference — compaction storms vs foreground under fg/bg sharing (lsmkv, YCSB A)",
        &[
            "arm",
            "L(us)",
            "ops/sec",
            "op_p99(us)",
            "fg_iop99(us)",
            "bg_ios",
            "lane_MB",
            "ledger_MB",
            "bg_wait(us)",
            "wamp",
            "ovh_sim",
            "ovh_model",
            "gate",
        ],
    );
    let mut all_ok = true;
    let mut failures: Vec<String> = Vec::new();
    let mut gate = |pass: bool, msg: String| -> String {
        if pass {
            "ok".to_string()
        } else {
            all_ok = false;
            failures.push(msg);
            "FAIL".to_string()
        }
    };

    let l_gate = grid[0];
    for &l in &grid {
        let idle = get(l, Arm::Idle);
        let none = get(l, Arm::StormNone);
        let ext = SweepCfg {
            l_mem: Dur::us(l),
            window,
            warmup,
            ..Default::default()
        }
        .ext_params();
        let recip_idle = model::theta_mix_recip(&idle.mix, l, &ext, &sys);
        let p99_idle = idle.stats.io_classes[0].io_p99.as_us();
        let p99_none = none.stats.io_classes[0].io_p99.as_us();

        for &arm in &arms {
            let run = get(l, arm);
            let fgc = &run.stats.io_classes[0];
            let (bg_ios, bg_bytes, bg_wait) = bg(run);
            let lane_bytes =
                run.stats.io_classes[1].bytes + run.stats.io_classes[2].bytes;
            let ledger_bytes =
                run.flush_write_bytes + run.compact_read_bytes + run.compact_write_bytes;
            let wamp = if run.flush_write_bytes > 0 {
                f2(ledger_bytes as f64 / run.flush_write_bytes as f64)
            } else {
                "-".into()
            };

            // Flow + tagging gate, every arm: the compaction and flush
            // lanes must equal the store's own byte ledger exactly (same
            // events, both window-only, fault-free ⇒ no retry inflation),
            // and lsmkv must put nothing in the defrag or WAL lanes.
            let ledger_ok = run.stats.io_classes[1].bytes
                == run.compact_read_bytes + run.compact_write_bytes
                && run.stats.io_classes[2].bytes == run.flush_write_bytes
                && run.stats.io_classes[3].ios == 0
                && run.stats.io_classes[4].ios == 0;
            let mut pass = ledger_ok;
            let mut why = format!(
                "{}@L={l}: lanes [cmpct {} B, flush {} B] vs ledger \
                 [cmpct {} B, flush {} B]",
                arm.label(),
                run.stats.io_classes[1].bytes,
                run.stats.io_classes[2].bytes,
                run.compact_read_bytes + run.compact_write_bytes,
                run.flush_write_bytes
            );

            let (mut ovh_sim, mut ovh_model) = (None, None);
            match arm {
                Arm::Idle => {
                    // Idle gate: a never-rotating memtable must produce a
                    // background-free device — all bg lanes exactly zero.
                    pass = pass && bg_ios == 0 && bg_bytes == 0;
                    if bg_ios != 0 || bg_bytes != 0 {
                        why = format!(
                            "idle@L={l}: background lanes not empty \
                             ({bg_ios} IOs, {bg_bytes} B)"
                        );
                    }
                }
                _ => {
                    // Every storm arm must actually storm.
                    if bg_ios == 0 {
                        pass = false;
                        why = format!(
                            "{}@L={l}: storm arm produced no background IO",
                            arm.label()
                        );
                    }
                    let ops = run.stats.ops.max(1) as f64;
                    let ext_bg = ext.with_bg_traffic(
                        bg_bytes as f64 / ops,
                        bg_ios as f64 / ops,
                        arm.model_share(),
                    );
                    let recip = model::theta_mix_recip(&run.mix, l, &ext_bg, &sys);
                    let m = recip / recip_idle.max(1e-9) - 1.0;
                    let s = idle.stats.ops_per_sec / run.stats.ops_per_sec.max(1e-9) - 1.0;
                    ovh_sim = Some(s);
                    ovh_model = Some(m);
                    if arm == Arm::StormNone {
                        // Bite gate (fastest memory): the storm depresses
                        // foreground throughput on the shared servers.
                        if (l - l_gate).abs() < 1e-9 {
                            let bit = s >= STORM_BITE_MIN;
                            let inflated = p99_none >= p99_idle + STORM_P99_INFLATION_MIN_US;
                            if !(bit && inflated) {
                                pass = false;
                                why = format!(
                                    "storm/none@L={l}: bite={s:.3} (need \
                                     >={STORM_BITE_MIN}), fg io_p99 \
                                     {p99_none:.1}us vs idle {p99_idle:.1}us \
                                     (need +{STORM_P99_INFLATION_MIN_US}us)"
                                );
                            }
                        }
                        // Model gate, every L: Eq 14 with the measured
                        // per-op background traffic holds the v1 band.
                        if (s - m).abs() > INTERFERENCE_MODEL_BAND {
                            pass = false;
                            why = format!(
                                "storm/none@L={l}: ovh_sim={s:.3} vs \
                                 ovh_model={m:.3} outside band \
                                 {INTERFERENCE_MODEL_BAND}"
                            );
                        }
                    }
                }
            }
            let g = gate(pass, why);
            r.row(vec![
                arm.label().into(),
                f1(l),
                format!("{:.0}", run.stats.ops_per_sec),
                f2(run.stats.op_latency_p99.as_us()),
                f2(fgc.io_p99.as_us()),
                bg_ios.to_string(),
                f2(lane_bytes as f64 / 1e6),
                f2(ledger_bytes as f64 / 1e6),
                f2(bg_wait),
                wamp,
                ovh_sim.map(f3).unwrap_or_else(|| "-".into()),
                ovh_model.map(f3).unwrap_or_else(|| "-".into()),
                g,
            ]);
        }

        // Cap-recovery gate (fastest memory): Cap{0.5} claws back a
        // documented fraction of the storm's fg IO-p99 inflation.
        if (l - l_gate).abs() < 1e-9 {
            let cap50 = get(l, Arm::StormCap50);
            let p99_cap = cap50.stats.io_classes[0].io_p99.as_us();
            let inflation = p99_none - p99_idle;
            let recovered = p99_none - p99_cap;
            let g = recovered >= CAP_RECOVERY_FRAC * inflation;
            gate(
                g,
                format!(
                    "cap50@L={l}: recovered {recovered:.1}us of \
                     {inflation:.1}us fg io_p99 inflation (need \
                     >={CAP_RECOVERY_FRAC} of it)"
                ),
            );
        }
        // Cap monotonicity: a harder background cap never hurts
        // foreground throughput (within scheduler-ripple slack).
        let cap25 = get(l, Arm::StormCap25);
        let cap50 = get(l, Arm::StormCap50);
        let mono = cap25.stats.ops_per_sec
            >= cap50.stats.ops_per_sec * (1.0 - CAP_MONO_SLACK);
        gate(
            mono,
            format!(
                "cap-monotone@L={l}: {:.0} ops/s at cap25 < {:.0} at cap50 \
                 (slack {CAP_MONO_SLACK})",
                cap25.stats.ops_per_sec,
                cap50.stats.ops_per_sec
            ),
        );
    }

    r.note("arms: idle = memtable never rotates (no background IO);");
    r.note("storm = rotate every 64 updates, saturating the flush/compaction");
    r.note("path; none/cap25/cap50/w3:1 = BgShare policy on the device");
    r.note("lane_MB = device compaction+flush lanes; ledger_MB = the store's");
    r.note("own flush/compaction byte counters (window-only) — gated equal;");
    r.note("wamp = ledger bytes over memtable-flush bytes (8 IOs per flush");
    r.note("cycle: 1 flush write + 3 compaction writes + 4 compaction reads)");
    r.note("ovh = thr(idle)/thr(arm) − 1; model = Eq 14 mix with measured");
    r.note("per-op bg bytes/IOs in the interference term (model/extended.rs),");
    r.note("Cap arms via the max(fg/(1−f), bg/f) partition ceilings;");
    r.note("bite/inflation/recovery gates anchor at the fastest memory point");
    if failures.is_empty() {
        r.note(format!(
            "all interference gates passed (storm bite >= {STORM_BITE_MIN}, \
             lanes == ledger, idle bg-free, cap50 recovers \
             >= {CAP_RECOVERY_FRAC} of fg io_p99 inflation, cap monotone, \
             Eq 14 within {INTERFERENCE_MODEL_BAND})"
        ));
    } else {
        for f in &failures {
            r.note(format!("GATE FAILED: {f}"));
        }
    }
    r.write_csv("interference").ok();
    (r, all_ok)
}
