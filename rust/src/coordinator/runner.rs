//! Sweep execution: build a machine for one (store, latency, threads, cores)
//! point, run it, and search thread counts for the best throughput — the
//! paper's per-point optimization ("for each latency, we optimize the number
//! of threads"). Points run in parallel across host threads.

use crate::kvs::{
    model_mix, should_replan, AccessProfile, CacheKv, CacheKvConfig, CompressMode, DriveCounts,
    Durable, LsmKv, LsmKvConfig, Plan, PlacementPolicy, TreeKv, TreeKvConfig, WalConfig,
    WalKind, WalStats,
};
use crate::microbench::{Microbench, MicrobenchConfig};
use crate::model::{ExtParams, KindCost};
use crate::sim::{
    BgShare, Dur, Machine, MachineConfig, MemConfig, RetryPolicy, Rng, RunStats, Service,
    SsdConfig, TailProfile,
};
use crate::workload::{PhasedWorkload, TenantSet, YcsbWorkload};

/// Which KV store design a sweep drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Tree,
    Lsm,
    Cache,
}

impl StoreKind {
    pub const ALL: [StoreKind; 3] = [StoreKind::Tree, StoreKind::Lsm, StoreKind::Cache];

    pub fn name(&self) -> &'static str {
        match self {
            StoreKind::Tree => "treekv(aerospike)",
            StoreKind::Lsm => "lsmkv(rocksdb)",
            StoreKind::Cache => "cachekv(cachelib)",
        }
    }
}

/// Common sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepCfg {
    pub cores: usize,
    /// Thread counts to try per point (best wins).
    pub thread_candidates: Vec<usize>,
    pub warmup: Dur,
    pub window: Dur,
    /// Secondary memory latency.
    pub l_mem: Dur,
    /// Inject the §5.1 tail-latency profile.
    pub tail: bool,
    /// Memory bandwidth (bytes/sec; INFINITY = unlimited).
    pub mem_bandwidth: f64,
    /// CPU cache capacity in lines.
    pub cache_lines: u64,
    /// Per-device SSD configuration (`n_ssd` below overrides its array size).
    pub ssd: SsdConfig,
    /// SSD array size — the multi-SSD scale axis (1 = the classic sweeps).
    pub n_ssd: u32,
    /// Index/cache tier placement — the DRAM-budget axis (`kvs::placement`;
    /// `AllSecondary` = the classic full-offload sweeps).
    pub placement: PlacementPolicy,
    /// Transient-IO-error retry policy (the durability sweeps' no-retry
    /// control sets `max_retries: 0`; inert on a fault-free array).
    pub retry: RetryPolicy,
    pub seed: u64,
}

impl Default for SweepCfg {
    fn default() -> Self {
        SweepCfg {
            cores: 1,
            thread_candidates: vec![16, 32, 64, 96],
            warmup: Dur::ms(3.0),
            window: Dur::ms(20.0),
            l_mem: Dur::us(5.0),
            tail: false,
            mem_bandwidth: f64::INFINITY,
            cache_lines: 1_000_000,
            ssd: SsdConfig::optane_array(),
            n_ssd: 1,
            placement: PlacementPolicy::AllSecondary,
            retry: RetryPolicy::default(),
            seed: 0x5eed,
        }
    }
}

impl SweepCfg {
    /// Machine config for one point at `threads` threads/core.
    pub fn machine(&self, threads: usize) -> MachineConfig {
        let mut mem = MemConfig::fpga(self.l_mem).with_bandwidth(self.mem_bandwidth);
        if self.tail {
            mem = mem.with_tail(TailProfile::paper_flash());
        }
        MachineConfig {
            cores: self.cores,
            threads_per_core: threads,
            cache_lines: self.cache_lines,
            mem,
            ssd: SsdConfig {
                n_ssd: self.n_ssd.max(1),
                ..self.ssd.clone()
            },
            n_locks: 64,
            contention_factor: 0.025,
            retry: self.retry,
            seed: self.seed,
            ..MachineConfig::default()
        }
    }

    /// The same sweep at a different array size.
    pub fn at_n_ssd(&self, n: u32) -> SweepCfg {
        SweepCfg {
            n_ssd: n.max(1),
            ..self.clone()
        }
    }

    pub fn at_latency(&self, l: Dur) -> SweepCfg {
        SweepCfg {
            l_mem: l,
            ..self.clone()
        }
    }

    /// The same sweep under a different tier-placement policy.
    pub fn at_placement(&self, p: PlacementPolicy) -> SweepCfg {
        SweepCfg {
            placement: p,
            ..self.clone()
        }
    }

    /// The extended-model parameters matching this sweep's machine: device
    /// rates converted to the model's per-µs units, the array size, and the
    /// memory-bandwidth cap when one is set. `a_io`/`s` are per-kind in the
    /// Θ_scan model, so the defaults here are placeholders overridden by
    /// each `KindCost`.
    pub fn ext_params(&self) -> ExtParams {
        ExtParams {
            rho: 1.0,
            l_dram: 0.09,
            eps: 0.0,
            a_mem: 64.0,
            b_mem: if self.mem_bandwidth.is_finite() {
                self.mem_bandwidth / 1e6
            } else {
                1e12
            },
            a_io: 1536.0,
            b_io: self.ssd.bandwidth_bps / 1e6,
            r_io: self.ssd.iops / 1e6,
            s: 1.0,
            n_ssd: self.n_ssd.max(1) as f64,
            // Durability terms default off; `ExtParams::with_log_traffic`
            // attaches measured WAL/retry rates where a run logs.
            w_log: 0.0,
            s_log: 0.0,
            retry_factor: 1.0,
            // Interference terms default off; `ExtParams::with_bg_traffic`
            // attaches measured per-class lane rates where a run compacts.
            w_bg: 0.0,
            s_bg: 0.0,
            bg_share: 0.0,
        }
    }

    /// The paper's latency grid (§4.1.2), DRAM first for normalization.
    pub fn latency_grid() -> Vec<f64> {
        vec![0.1, 0.3, 0.5, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]
    }

    /// A pruned grid for quick runs (CXLKVS_FAST=1).
    pub fn latency_grid_fast() -> Vec<f64> {
        vec![0.1, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0]
    }
}

/// True when CXLKVS_FAST=1: benches prune grids to smoke-test duration.
pub fn fast_mode() -> bool {
    std::env::var("CXLKVS_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Run one store at one point (default store configs; the sweep's
/// placement axis is threaded into them).
pub fn run_store(kind: StoreKind, sweep: &SweepCfg, threads: usize) -> RunStats {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed);
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                ..Default::default()
            };
            let kv = TreeKv::new(cfg, &mut rng).with_background(mcfg.cores, threads);
            Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                ..Default::default()
            };
            let kv = LsmKv::new(cfg, &mut rng).with_background(threads);
            Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                ..Default::default()
            };
            let kv = CacheKv::new(cfg, &mut rng);
            Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
        }
    }
}

/// Store configs for one YCSB preset. The sweep uses them as-is; the golden
/// determinism tests derive from them (overriding only the store *sizes*
/// via struct update), so the workload-facing fields — op weights, key
/// distribution, scan lengths — are measured from one definition.
pub fn ycsb_tree_cfg(wl: YcsbWorkload) -> TreeKvConfig {
    TreeKvConfig {
        ops: Some(wl.weights()),
        key_dist: wl.key_dist(),
        scan_len: wl.scan_len(),
        ..Default::default()
    }
}

pub fn ycsb_lsm_cfg(wl: YcsbWorkload) -> LsmKvConfig {
    LsmKvConfig {
        ops: Some(wl.weights()),
        key_dist: wl.key_dist(),
        scan_len: wl.scan_len(),
        ..Default::default()
    }
}

pub fn ycsb_cache_cfg(wl: YcsbWorkload) -> CacheKvConfig {
    CacheKvConfig {
        ops: Some(wl.weights()),
        key_dist: wl.key_dist(),
        ..Default::default()
    }
}

/// Run one store under one YCSB preset at one sweep point. Delegates to
/// [`run_store_ycsb_snap`] (same seeds, same stores) and drops the model
/// snapshot — the two must never drift apart.
pub fn run_store_ycsb(
    kind: StoreKind,
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
) -> RunStats {
    run_store_ycsb_snap(kind, wl, sweep, threads).0
}

/// Run one store under one YCSB preset and additionally return the store's
/// **post-run** per-kind model snapshot: `(workload fraction, KindCost)`
/// pairs ready for `model::theta_mix_recip`. Snapshotting after the run
/// lets hit-ratio-dependent kinds use measured counters (the paper's
/// treatment of measured system parameters like ε). Delegates to
/// [`run_store_ycsb_placed`] and drops the DRAM-byte accounting.
pub fn run_store_ycsb_snap(
    kind: StoreKind,
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
) -> (RunStats, Vec<(f64, KindCost)>) {
    let (st, mix, _) = run_store_ycsb_placed(kind, wl, sweep, threads);
    (st, mix)
}

/// [`run_store_ycsb_snap`] plus the store's post-run simulated DRAM byte
/// accounting under the sweep's placement policy (the `placement`
/// experiment's third column). One store-construction path for all three
/// callers — the gate, the reports, and the placement sweep cannot drift.
pub fn run_store_ycsb_placed(
    kind: StoreKind,
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
) -> (RunStats, Vec<(f64, KindCost)>, u64) {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed ^ wl.tag().as_bytes()[0] as u64);
    let w = wl.weights();
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                ..ycsb_tree_cfg(wl)
            };
            let kv = TreeKv::new(cfg, &mut rng).with_background(mcfg.cores, threads);
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let bytes = m.service.dram_bytes();
            (st, model_mix(&m.service, &w), bytes)
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                ..ycsb_lsm_cfg(wl)
            };
            let kv = LsmKv::new(cfg, &mut rng).with_background(threads);
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let bytes = m.service.dram_bytes();
            (st, model_mix(&m.service, &w), bytes)
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                ..ycsb_cache_cfg(wl)
            };
            let kv = CacheKv::new(cfg, &mut rng);
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let bytes = m.service.dram_bytes();
            (st, model_mix(&m.service, &w), bytes)
        }
    }
}

/// [`run_store_ycsb_placed`] with an explicit per-class [`CompressMode`] —
/// the `compress` experiment's off/joint/forced arms. Same seeds and store
/// construction as the placed path, so a `CompressMode::Off` arm is
/// bit-identical to it (pinned by `compressed_run_off_matches_placed_path`).
/// The returned byte accounting is the honest post-run total: compressed
/// classes count their *scaled* resident bytes plus the pinned residual.
pub fn run_store_ycsb_compressed(
    kind: StoreKind,
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
    compress: CompressMode,
) -> (RunStats, Vec<(f64, KindCost)>, u64) {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed ^ wl.tag().as_bytes()[0] as u64);
    let w = wl.weights();
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                compression: compress,
                ..ycsb_tree_cfg(wl)
            };
            let kv = TreeKv::new(cfg, &mut rng).with_background(mcfg.cores, threads);
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let bytes = m.service.dram_bytes();
            (st, model_mix(&m.service, &w), bytes)
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                compression: compress,
                ..ycsb_lsm_cfg(wl)
            };
            let kv = LsmKv::new(cfg, &mut rng).with_background(threads);
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let bytes = m.service.dram_bytes();
            (st, model_mix(&m.service, &w), bytes)
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                compression: compress,
                ..ycsb_cache_cfg(wl)
            };
            let kv = CacheKv::new(cfg, &mut rng);
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let bytes = m.service.dram_bytes();
            (st, model_mix(&m.service, &w), bytes)
        }
    }
}

/// Result of one interference arm ([`run_lsm_interference`]): the window
/// stats (with per-traffic-class IO lanes) plus the store's **window-only**
/// flush/compaction byte ledger — the side the write-amplification gate
/// cross-checks against the device lanes, which also cover the window only.
pub struct InterferenceRun {
    pub stats: RunStats,
    /// Memtable-flush bytes written during the window (store ledger).
    pub flush_write_bytes: u64,
    /// Compaction bytes read during the window (store ledger).
    pub compact_read_bytes: u64,
    /// Compaction bytes written during the window (store ledger).
    pub compact_write_bytes: u64,
    /// Post-run per-kind model snapshot for `model::theta_mix_recip`.
    pub mix: Vec<(f64, KindCost)>,
}

/// Run lsmkv under one YCSB preset with the interference knobs: an optional
/// `memtable_cap` override (a huge cap never rotates the memtable, so no
/// flush/compaction fires inside the window — the idle arm) and a
/// [`BgShare`] policy on every device of the array. Same seeds and store
/// construction as [`run_store_ycsb_placed`]'s lsmkv arm, so
/// `(None, BgShare::None)` is bit-identical to that path (pinned by
/// `tests/prop_interference.rs`).
pub fn run_lsm_interference(
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
    memtable_cap: Option<u32>,
    share: BgShare,
) -> InterferenceRun {
    let mut mcfg = sweep.machine(threads);
    mcfg.ssd.bg_share = share;
    let mut rng = Rng::new(sweep.seed ^ 0xfeed ^ wl.tag().as_bytes()[0] as u64);
    let w = wl.weights();
    let base = ycsb_lsm_cfg(wl);
    let cfg = LsmKvConfig {
        placement: sweep.placement,
        memtable_cap: memtable_cap.unwrap_or(base.memtable_cap),
        ..base
    };
    let kv = LsmKv::new(cfg, &mut rng).with_background(threads);
    let mut m = Machine::new(mcfg, kv);
    // Slice the measurement by hand — the same warmup / start_window /
    // run_until sequence as `Machine::run`, so the slicing is bit-identical
    // to it — purely so the store's byte ledger can be snapshotted at the
    // instant the device lane counters reset. Both sides of the
    // write-amplification gate then cover exactly the measured window.
    let t0 = m.now();
    m.run_until(t0 + sweep.warmup);
    m.start_window(sweep.window);
    let w_end = m.now() + sweep.window;
    let ledger0 = m.service.stats.clone();
    m.run_until(w_end);
    let stats = m.window_stats(sweep.window);
    let ledger = &m.service.stats;
    let mix = model_mix(&m.service, &w);
    InterferenceRun {
        stats,
        flush_write_bytes: ledger.flush_write_bytes - ledger0.flush_write_bytes,
        compact_read_bytes: ledger.compact_read_bytes - ledger0.compact_read_bytes,
        compact_write_bytes: ledger.compact_write_bytes - ledger0.compact_write_bytes,
        mix,
    }
}

/// Result of one durability arm ([`run_store_ycsb_durable`]): the window
/// stats plus the post-run WAL/robustness counters the `durability`
/// experiment gates on.
pub struct DurableRun {
    pub stats: RunStats,
    /// Post-run WAL counters (appends/flushes/bytes — the measured
    /// `s_log`/`w_log` inputs of the extended model's sharing terms).
    pub wal: WalStats,
    /// The acked-durable invariant: every acked LSN was durable at ack time.
    pub acked_all_durable: bool,
    /// `Service::io_failed` deliveries (store-level view of fault injection).
    pub io_errors: u64,
    /// Operations that finished with an error instead of a result.
    pub failed_ops: u64,
    /// Post-run per-kind model snapshot for `model::theta_mix_recip`.
    pub mix: Vec<(f64, KindCost)>,
}

/// Run one store under one YCSB preset with an explicit [`WalConfig`] —
/// the durability sweep's store×{no-WAL, WAL, WAL+faults} arms. Fault
/// injection and the retry policy ride the sweep itself (`sweep.ssd.faults`
/// via `SsdConfig::with_fault`, `sweep.retry`); this helper only threads
/// the WAL knob into the store config and extracts the post-run counters.
/// Same seeds and store construction as [`run_store_ycsb_placed`], so a
/// `WalConfig::default()` (disabled) arm is bit-identical to that path.
pub fn run_store_ycsb_durable(
    kind: StoreKind,
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
    wal: WalConfig,
) -> DurableRun {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed ^ wl.tag().as_bytes()[0] as u64);
    let w = wl.weights();
    macro_rules! arm {
        ($kv:expr) => {{
            let mut m = Machine::new(mcfg, $kv);
            let stats = m.run(sweep.warmup, sweep.window);
            DurableRun {
                acked_all_durable: m.service.wal.acked_all_durable(),
                wal: m.service.wal.stats.clone(),
                io_errors: m.service.stats.io_errors,
                failed_ops: m.service.stats.failed_ops,
                mix: model_mix(&m.service, &w),
                stats,
            }
        }};
    }
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                wal,
                ..ycsb_tree_cfg(wl)
            };
            let cores = mcfg.cores;
            arm!(TreeKv::new(cfg, &mut rng).with_background(cores, threads))
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                wal,
                ..ycsb_lsm_cfg(wl)
            };
            arm!(LsmKv::new(cfg, &mut rng).with_background(threads))
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                wal,
                ..ycsb_cache_cfg(wl)
            };
            arm!(CacheKv::new(cfg, &mut rng))
        }
    }
}

/// Verdict of one crash–recovery drill ([`crash_recover_check`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashCheck {
    /// Records the dead store had made durable by the crash.
    pub durable_lsn: u64,
    /// All records appended (durable or not) by the crash.
    pub total_records: u64,
    /// Durable-final-Put keys absent after replay. Must be zero for the
    /// index stores; the cache contract allows capacity eviction, so the
    /// cache gate only scores `resurrected_deletes`.
    pub missing_puts: u64,
    /// Durable-final-Delete keys present after replay — forbidden
    /// everywhere (an acked delete must never resurrect).
    pub resurrected_deletes: u64,
    /// Keys whose **only** records were unacked at the crash and whose
    /// presence changed across recovery — a torn partial effect; must be 0.
    pub unacked_perturbed: u64,
    /// Records applied by the first replay (== `durable_lsn` on success).
    pub replayed: u64,
    /// Records applied by a second, idempotence-probing replay (must be 0).
    pub second_replay: u64,
}

impl CrashCheck {
    /// The invariants every store must satisfy (the cache's weaker put
    /// contract is the caller's extra allowance, not a weaker baseline).
    pub fn holds_for_index_store(&self) -> bool {
        self.missing_puts == 0 && self.holds_for_cache()
    }

    pub fn holds_for_cache(&self) -> bool {
        self.resurrected_deletes == 0
            && self.unacked_perturbed == 0
            && self.replayed == self.durable_lsn
            && self.second_replay == 0
    }
}

/// One crash–recovery drill: build a WAL-enabled store, run it to
/// `crash_at` of simulated time, then "crash" — drop the machine mid-flight
/// and keep only what a real recovery would have: the durable WAL prefix
/// and the (deterministically reconstructible) initial disk image. A fresh
/// store built from the same constructor seed replays the log and the
/// recovered state is audited against the WAL's own oracle
/// (`Wal::durable_last_kind`):
///
/// - acked-durable: every durable-final Put present, Delete absent;
/// - unacked-atomic: keys only touched after the durable horizon keep
///   their pre-crash-run state;
/// - idempotence: a second replay applies nothing (the `applied_lsn`
///   watermark), leaving state identical.
///
/// `build` must construct the store with its WAL enabled and must be
/// deterministic in the `Rng` it is handed (both invocations get
/// `Rng::new(seed)`).
pub fn crash_recover_check<S, F>(
    build: F,
    mcfg: MachineConfig,
    seed: u64,
    crash_at: Dur,
) -> CrashCheck
where
    S: Service + Durable,
    F: Fn(&mut Rng) -> S,
{
    // Run to the crash point and stop: in-memory state dies, the log lives.
    let mut rng = Rng::new(seed);
    let kv = build(&mut rng);
    let mut m = Machine::new(mcfg, kv);
    let t0 = m.now();
    m.run_until(t0 + crash_at);
    let dead = m.service;
    assert!(dead.wal().enabled(), "crash drill needs a WAL-enabled store");

    let oracle = dead.wal().durable_last_kind();
    let durable = dead.wal().durable_lsn();
    // Keys only touched beyond the durable horizon: recovery must leave
    // them exactly as a never-crashed rebuild would (no torn effects).
    let unacked_keys: Vec<u64> = dead.wal().records()[durable as usize..]
        .iter()
        .map(|r| r.key)
        .filter(|k| !oracle.contains_key(k))
        .collect();

    // Recovery: same constructor seed → same preloaded disk image.
    let mut rng = Rng::new(seed);
    let mut fresh = build(&mut rng);
    let before: Vec<bool> = unacked_keys.iter().map(|&k| fresh.wal_present(k)).collect();
    let mut replay_rng = Rng::new(seed ^ 0x4ec0_4ec0);
    let replayed = fresh.wal_replay(dead.wal(), &mut replay_rng);
    let second_replay = fresh.wal_replay(dead.wal(), &mut replay_rng);

    let mut missing_puts = 0;
    let mut resurrected_deletes = 0;
    for (k, kind) in &oracle {
        match kind {
            WalKind::Put => {
                if !fresh.wal_present(*k) {
                    missing_puts += 1;
                }
            }
            WalKind::Delete => {
                if fresh.wal_present(*k) {
                    resurrected_deletes += 1;
                }
            }
        }
    }
    let unacked_perturbed = unacked_keys
        .iter()
        .zip(&before)
        .filter(|(k, was)| fresh.wal_present(**k) != **was)
        .count() as u64;
    CrashCheck {
        durable_lsn: durable,
        total_records: dead.wal().records().len() as u64,
        missing_puts,
        resurrected_deletes,
        unacked_perturbed,
        replayed,
        second_replay,
    }
}

/// One arm of a profiled (two-phase) run: the stats, the post-run model
/// snapshot, and the honest simulated DRAM bytes (policy-placed plus the
/// pinned residual).
pub struct PlannedArm {
    pub stats: RunStats,
    pub mix: Vec<(f64, KindCost)>,
    pub dram_bytes: u64,
}

/// Result of [`run_store_ycsb_profiled`]: the static arm (which doubles as
/// the profiling run), the measured arm (same store, same seeds, placement
/// re-resolved over the static arm's `AccessProfile`), and whether the
/// measured accesses-per-byte ranking differs from the static prior.
pub struct ProfiledRun {
    pub static_arm: PlannedArm,
    pub measured_arm: PlannedArm,
    pub rank_differs: bool,
    /// The measured arm's resolved ranking (offloadable class ids,
    /// hottest-first). Callers comparing several profiled runs (e.g. a
    /// normalized latency curve) can check the rankings agree before
    /// treating the points as one placement.
    pub measured_ranking: Vec<usize>,
}

/// The two-phase **profile → replan → measure** path of the measured
/// placement planner (`kvs::placement` module docs, "Measured re-ranking"):
///
/// 1. run the store under the sweep's policy with the *static* hotness
///    ranking, collecting the per-class [`crate::kvs::AccessProfile`]
///    (access counts are placement-independent, so the static arm is a
///    valid profiling run *and* the comparison baseline);
/// 2. rebuild the identical store (same seeds, same structure), `replan`
///    its placement over the measured profile, and run the same window.
///
/// Both arms spend the same DRAM budget, so the comparison isolates the
/// ranking: measured-vs-static at equal bytes. The measured arm's model
/// snapshot derives `m`/`m_dram` from the **replanned** plan, which is what
/// `cxlkvs run planner` validates against the modelcheck bands.
pub fn run_store_ycsb_profiled(
    kind: StoreKind,
    wl: YcsbWorkload,
    sweep: &SweepCfg,
    threads: usize,
) -> ProfiledRun {
    let mcfg = sweep.machine(threads);
    let seed = sweep.seed ^ 0xfeed ^ wl.tag().as_bytes()[0] as u64;
    let w = wl.weights();
    macro_rules! two_phase {
        ($new:expr, $bg:expr) => {{
            // Phase 1: static placement — the profiling run and baseline.
            let mut rng = Rng::new(seed);
            let kv = $bg($new(&mut rng));
            let mut m = Machine::new(mcfg.clone(), kv);
            let st = m.run(sweep.warmup, sweep.window);
            let static_arm = PlannedArm {
                mix: model_mix(&m.service, &w),
                dram_bytes: m.service.dram_bytes(),
                stats: st,
            };
            let profile = m.service.profile.clone();
            let static_rank = m.service.plan().ranking().to_vec();
            // Phase 2: identical store, measured re-ranking.
            let mut rng = Rng::new(seed);
            let mut kv = $bg($new(&mut rng));
            kv.replan(&profile);
            let rank_differs = kv.plan().ranking() != static_rank.as_slice();
            let measured_ranking = kv.plan().ranking().to_vec();
            let mut m = Machine::new(mcfg, kv);
            let st = m.run(sweep.warmup, sweep.window);
            let measured_arm = PlannedArm {
                mix: model_mix(&m.service, &w),
                dram_bytes: m.service.dram_bytes(),
                stats: st,
            };
            ProfiledRun {
                static_arm,
                measured_arm,
                rank_differs,
                measured_ranking,
            }
        }};
    }
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                ..ycsb_tree_cfg(wl)
            };
            let cores = mcfg.cores;
            two_phase!(
                |rng: &mut Rng| TreeKv::new(cfg.clone(), rng),
                |kv: TreeKv| kv.with_background(cores, threads)
            )
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                ..ycsb_lsm_cfg(wl)
            };
            two_phase!(
                |rng: &mut Rng| LsmKv::new(cfg.clone(), rng),
                |kv: LsmKv| kv.with_background(threads)
            )
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                ..ycsb_cache_cfg(wl)
            };
            two_phase!(|rng: &mut Rng| CacheKv::new(cfg.clone(), rng), |kv: CacheKv| kv)
        }
    }
}

/// Result of one multi-tenant arm ([`run_store_ycsb_tenants`]): the window
/// stats (whose `tenants` lanes the `tenants` experiment gates on) plus the
/// shared-budget accounting.
pub struct TenantRun {
    pub stats: RunStats,
    /// Share of the run's measured offloadable accesses the shared DRAM
    /// budget absorbed ([`Plan::absorbed_fraction`] over the *combined*
    /// multi-tenant profile — the implicit cross-tenant budget split).
    pub absorbed_frac: f64,
    /// Simulated DRAM bytes the placement consumed.
    pub dram_bytes: u64,
}

/// Run one store under a multi-tenant workload ([`crate::workload::tenants`])
/// at one sweep point. `base` supplies the store sizing/seed identity (same
/// formula as [`run_store_ycsb`], so a solo full-slice tenant whose spec
/// matches `base` is bit-identical to that path — `tests/tenants.rs` pins
/// it); the tenant set supplies the per-op behaviour.
///
/// With `replan`, the [`run_store_ycsb_profiled`] two-phase macro applies:
/// phase 1 profiles the *combined* tenant access stream under the static
/// ranking, phase 2 rebuilds the identical store and replans the one shared
/// budget over that profile — the planner's cross-tenant budget split.
pub fn run_store_ycsb_tenants(
    kind: StoreKind,
    base: YcsbWorkload,
    tenants: &TenantSet,
    sweep: &SweepCfg,
    threads: usize,
    replan: bool,
) -> TenantRun {
    let mcfg = sweep.machine(threads);
    let seed = sweep.seed ^ 0xfeed ^ base.tag().as_bytes()[0] as u64;
    macro_rules! tenant_run {
        ($new:expr, $bg:expr) => {{
            let mut rng = Rng::new(seed);
            let kv = $bg($new(&mut rng));
            let mut m = Machine::new(mcfg.clone(), kv);
            let mut stats = m.run(sweep.warmup, sweep.window);
            if replan {
                // Rebuild the identical store, replan the shared budget
                // over the combined profile, re-measure.
                let profile = m.service.profile.clone();
                let mut rng = Rng::new(seed);
                let mut kv = $bg($new(&mut rng));
                kv.replan(&profile);
                m = Machine::new(mcfg, kv);
                stats = m.run(sweep.warmup, sweep.window);
            }
            TenantRun {
                absorbed_frac: m.service.plan().absorbed_fraction(&m.service.profile),
                dram_bytes: m.service.dram_bytes(),
                stats,
            }
        }};
    }
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                tenants: Some(tenants.clone()),
                ..ycsb_tree_cfg(base)
            };
            let cores = mcfg.cores;
            tenant_run!(
                |rng: &mut Rng| TreeKv::new(cfg.clone(), rng),
                |kv: TreeKv| kv.with_background(cores, threads)
            )
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                tenants: Some(tenants.clone()),
                ..ycsb_lsm_cfg(base)
            };
            tenant_run!(
                |rng: &mut Rng| LsmKv::new(cfg.clone(), rng),
                |kv: LsmKv| kv.with_background(threads)
            )
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                tenants: Some(tenants.clone()),
                ..ycsb_cache_cfg(base)
            };
            tenant_run!(|rng: &mut Rng| CacheKv::new(cfg.clone(), rng), |kv: CacheKv| kv)
        }
    }
}

/// Knobs of the online adaptive replanner (`kvs::placement` module docs,
/// "Online replanning": decay, hysteresis, migration cost).
#[derive(Debug, Clone)]
pub struct AdaptiveCfg {
    /// Replan-evaluation period: at every simulated-time epoch boundary the
    /// profile decays and the hysteresis trigger is evaluated.
    pub epoch: Dur,
    /// Unmeasured grace after each workload turn before the phase's
    /// measured window opens. The online arm adapts here, so per-phase
    /// columns compare steady-state throughput rather than the adaptation
    /// transient (the transient's cost still shows up as the migration
    /// stall and in any replans that fire inside a measured window).
    pub settle: Dur,
    /// Hysteresis margin: replan only when the candidate plan would absorb
    /// more than `(1 + margin)×` the incumbent's access mass. `0.0`
    /// thrashes on any measured gain; `f64::INFINITY` never replans.
    pub margin: f64,
    /// Per-epoch EWMA retain fraction `decay_num / decay_den`.
    pub decay_num: u32,
    pub decay_den: u32,
}

impl Default for AdaptiveCfg {
    fn default() -> Self {
        AdaptiveCfg {
            epoch: Dur::ms(1.0),
            // Four epochs of grace: with retain 1/2 the stale phase's share
            // of the profile is < 10% by the window opening, so a genuine
            // turn's replan fires (and its migration is charged) inside the
            // grace, not inside the measured window.
            settle: Dur::ms(4.0),
            // A noise flip between near-equal-density classes moves
            // `absorbed` by their density gap — a few percent — while a
            // genuine workload turn roughly doubles the candidate's mass;
            // 0.25 sits between the two regimes.
            margin: 0.25,
            decay_num: 1,
            decay_den: 2,
        }
    }
}

impl AdaptiveCfg {
    /// The non-adaptive control: never replan, never decay — the final
    /// cumulative profile then doubles as the offline arm's whole-schedule
    /// aggregate.
    fn frozen(&self) -> AdaptiveCfg {
        AdaptiveCfg {
            margin: f64::INFINITY,
            decay_num: 1,
            decay_den: 1,
            ..self.clone()
        }
    }
}

/// One measured phase of an adaptive arm.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub phase: &'static str,
    pub window: Dur,
    pub stats: RunStats,
}

/// One arm of [`run_store_ycsb_adaptive`], with its migration bill.
#[derive(Debug, Clone)]
pub struct AdaptiveArm {
    pub phases: Vec<PhaseStats>,
    /// Times the hysteresis trigger fired.
    pub replans: u32,
    /// 64-byte line touches charged for migrations (dram + secondary).
    pub migrated_lines: u64,
    /// SSD refill reads charged for migrations.
    pub migration_reads: u64,
    /// Simulated time the migrations stalled every core.
    pub migration_stall: Dur,
    /// Final honest DRAM footprint (policy-placed + pinned residual).
    pub dram_bytes: u64,
}

impl AdaptiveArm {
    fn new() -> AdaptiveArm {
        AdaptiveArm {
            phases: Vec::new(),
            replans: 0,
            migrated_lines: 0,
            migration_reads: 0,
            migration_stall: Dur::ZERO,
            dram_bytes: 0,
        }
    }

    /// Window-weighted mean throughput over phases `skip..`. `skip = 1`
    /// drops the pre-turn phase (the one the static prior was tuned for) —
    /// the quantity the `cxlkvs run adaptive` gate scores.
    pub fn ops_per_sec_from(&self, skip: usize) -> f64 {
        let (num, den) = self
            .phases
            .iter()
            .skip(skip)
            .fold((0.0, 0u64), |(n, d), p| {
                (n + p.stats.ops_per_sec * p.window.0 as f64, d + p.window.0)
            });
        if den == 0 {
            0.0
        } else {
            num / den as f64
        }
    }
}

/// Result of [`run_store_ycsb_adaptive`]: one drifting schedule over the
/// same seeds under three placement regimes.
pub struct AdaptiveRun {
    /// Static prior placement, never replanned (doubles as the offline
    /// arm's profiling run).
    pub static_arm: AdaptiveArm,
    /// One hindsight replan over the whole schedule's aggregate profile,
    /// then fixed for the run.
    pub offline_arm: AdaptiveArm,
    /// Online: per-epoch EWMA decay + hysteresis replanning, migrations
    /// charged as simulated work.
    pub online_arm: AdaptiveArm,
}

/// Run one store through a phased (drifting) schedule three ways — static,
/// offline-replanned, online-adaptive — on identical seeds and machine
/// configs (`kvs::placement` module docs, "Online replanning").
///
/// Per phase: swap the workload (`set_workload` — no RNG draws), run an
/// unmeasured settle grace, then measure `phase.window` via
/// `Machine::start_window`/`window_stats`. Throughout, at every
/// `acfg.epoch` boundary, the store's [`AccessProfile`] decays by
/// `decay_num/decay_den` and a candidate replan is evaluated against the
/// hysteresis margin; a fired replan migrates entries via the store's
/// `replan_migrate` and charges the traffic to the machine clock via
/// `charge_migration` — thrash is visible in measured throughput. With
/// `margin = ∞` the decay/candidate bookkeeping is pure observation (no
/// simulated effect), which is why the static arm is bit-identical to an
/// online arm that never triggers — `tests/adaptive.rs` pins this.
pub fn run_store_ycsb_adaptive(
    kind: StoreKind,
    scenario: &PhasedWorkload,
    sweep: &SweepCfg,
    acfg: &AdaptiveCfg,
    threads: usize,
) -> AdaptiveRun {
    assert!(acfg.epoch > Dur::ZERO, "epoch must be positive");
    assert!(!scenario.phases.is_empty(), "a schedule needs phases");
    let mcfg = sweep.machine(threads);
    let seed = sweep.seed ^ 0xfeed ^ scenario.tag.as_bytes()[0] as u64;
    macro_rules! run_arm {
        ($new:expr, $bg:expr, $io:expr, $cfg:expr, $preplan:expr) => {{
            let a: &AdaptiveCfg = $cfg;
            let mut rng = Rng::new(seed);
            let mut kv = $bg($new(&mut rng));
            if let Some(p) = $preplan {
                kv.replan(p);
            }
            let mut m = Machine::new(mcfg.clone(), kv);
            let mut arm = AdaptiveArm::new();
            for (i, phase) in scenario.phases.iter().enumerate() {
                m.service.set_workload(Some(phase.ops), phase.key_dist);
                let settle = if i == 0 { sweep.warmup + a.settle } else { a.settle };
                for measured in [false, true] {
                    let span = if measured { phase.window } else { settle };
                    if measured {
                        m.start_window(span);
                    }
                    let mut left = span;
                    while left > Dur::ZERO {
                        let step = if left < a.epoch { left } else { a.epoch };
                        m.run_until(m.now() + step);
                        left -= step;
                        // Epoch boundary: age the profile, evaluate the
                        // hysteresis trigger (pure observation unless it
                        // fires).
                        m.service.profile.decay(a.decay_num, a.decay_den);
                        let profile = m.service.profile.clone();
                        let candidate = Plan::replan(
                            m.service.cfg.placement,
                            m.service.plan().classes().to_vec(),
                            &profile,
                        );
                        if should_replan(m.service.plan(), &candidate, &profile, a.margin) {
                            let mig = m.service.replan_migrate(&profile);
                            arm.replans += 1;
                            if mig != DriveCounts::default() {
                                let io_bytes = $io(&m.service);
                                let stall = m.charge_migration(
                                    mig.dram,
                                    mig.secondary,
                                    mig.reads,
                                    io_bytes,
                                );
                                arm.migrated_lines += mig.dram as u64 + mig.secondary as u64;
                                arm.migration_reads += mig.reads as u64;
                                arm.migration_stall += stall;
                            }
                        }
                    }
                    if measured {
                        arm.phases.push(PhaseStats {
                            phase: phase.name,
                            window: span,
                            stats: m.window_stats(span),
                        });
                    }
                }
            }
            arm.dram_bytes = m.service.dram_bytes();
            (arm, m.service.profile.clone())
        }};
    }
    macro_rules! arms {
        ($new:expr, $bg:expr, $io:expr) => {{
            let frozen = acfg.frozen();
            let (static_arm, aggregate) = run_arm!($new, $bg, $io, &frozen, None::<&AccessProfile>);
            let (offline_arm, _) = run_arm!($new, $bg, $io, &frozen, Some(&aggregate));
            let (online_arm, _) = run_arm!($new, $bg, $io, acfg, None::<&AccessProfile>);
            AdaptiveRun {
                static_arm,
                offline_arm,
                online_arm,
            }
        }};
    }
    match kind {
        StoreKind::Tree => {
            let cfg = TreeKvConfig {
                placement: sweep.placement,
                ..ycsb_tree_cfg(scenario.base)
            };
            let cores = mcfg.cores;
            arms!(
                |rng: &mut Rng| TreeKv::new(cfg.clone(), rng),
                |kv: TreeKv| kv.with_background(cores, threads),
                |_kv: &TreeKv| 0u32
            )
        }
        StoreKind::Lsm => {
            let cfg = LsmKvConfig {
                placement: sweep.placement,
                ..ycsb_lsm_cfg(scenario.base)
            };
            arms!(
                |rng: &mut Rng| LsmKv::new(cfg.clone(), rng),
                |kv: LsmKv| kv.with_background(threads),
                |kv: &LsmKv| kv.block_bytes()
            )
        }
        StoreKind::Cache => {
            let cfg = CacheKvConfig {
                placement: sweep.placement,
                ..ycsb_cache_cfg(scenario.base)
            };
            arms!(
                |rng: &mut Rng| CacheKv::new(cfg.clone(), rng),
                |kv: CacheKv| kv,
                |_kv: &CacheKv| 0u32
            )
        }
    }
}

/// Total offloadable bytes of one store kind under a YCSB preset's default
/// sizes (the `AllDram` footprint): the denominator turning the placement
/// experiment's budget fractions into `PlacementPolicy::Budget` bytes.
pub fn store_offload_bytes(kind: StoreKind, wl: YcsbWorkload, seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    match kind {
        StoreKind::Tree => TreeKv::new(ycsb_tree_cfg(wl), &mut rng).offload_bytes_total(),
        StoreKind::Lsm => LsmKv::new(ycsb_lsm_cfg(wl), &mut rng).offload_bytes_total(),
        StoreKind::Cache => CacheKv::new(ycsb_cache_cfg(wl), &mut rng).offload_bytes_total(),
    }
}

/// Run a store with custom KV configs (the Fig 15 / Fig 18 variations).
pub fn run_tree_with(cfg: TreeKvConfig, sweep: &SweepCfg, threads: usize) -> RunStats {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed);
    let kv = TreeKv::new(cfg, &mut rng).with_background(mcfg.cores, threads);
    Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
}

pub fn run_lsm_with(cfg: LsmKvConfig, sweep: &SweepCfg, threads: usize) -> RunStats {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed);
    let kv = LsmKv::new(cfg, &mut rng).with_background(threads);
    Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
}

pub fn run_cache_with(cfg: CacheKvConfig, sweep: &SweepCfg, threads: usize) -> RunStats {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xfeed);
    let kv = CacheKv::new(cfg, &mut rng);
    Machine::new(mcfg, kv).run(sweep.warmup, sweep.window)
}

/// Run the microbenchmark at one point.
pub fn run_microbench(cfg: &MicrobenchConfig, sweep: &SweepCfg, threads: usize) -> RunStats {
    let mcfg = sweep.machine(threads);
    let mut rng = Rng::new(sweep.seed ^ 0xbead);
    let mb = Microbench::new(cfg.clone(), &mut rng);
    Machine::new(mcfg, mb).run(sweep.warmup, sweep.window)
}

/// Try all thread candidates with an arbitrary per-run result, returning
/// the first maximum of `score` (ties keep the earlier candidate). The one
/// selection rule every sweep shares — generic so callers that carry extra
/// payload (e.g. model snapshots) cannot drift from [`best_threads`].
pub fn best_threads_by<T, F, S>(candidates: &[usize], mut run: F, score: S) -> (usize, T)
where
    F: FnMut(usize) -> T,
    S: Fn(&T) -> f64,
{
    let mut best: Option<(usize, T)> = None;
    for &n in candidates {
        let r = run(n);
        match &best {
            Some((_, b)) if score(b) >= score(&r) => {}
            _ => best = Some((n, r)),
        }
    }
    best.expect("no thread candidates")
}

/// Try all thread candidates, return (best_threads, best_stats).
pub fn best_threads<F>(candidates: &[usize], run: F) -> (usize, RunStats)
where
    F: FnMut(usize) -> RunStats,
{
    best_threads_by(candidates, run, |st| st.ops_per_sec)
}

/// Run `jobs` closures in parallel on host threads (sweep points are
/// independent simulations), preserving output order.
///
/// Work-stealing scheduling: a fixed pool of host threads pulls the next
/// job index off a shared atomic counter as each finishes. The former
/// chunk-barrier version stalled a whole chunk on its slowest point (a
/// 16-core fig14 point can run 10× longer than a 1-core one), leaving most
/// host threads idle at every chunk boundary.
pub fn parallel_map<T, F>(jobs: Vec<F>) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    // Per-slot mutexes (not one big lock): each slot is touched by exactly
    // one worker, the lock only pacifies the borrow checker across threads.
    let job_slots: Vec<Mutex<Option<F>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let out_slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let f = job_slots[i].lock().unwrap().take().unwrap();
                let r = f();
                *out_slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out_slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("sweep worker panicked"))
        .collect()
}

/// Measured model parameters extracted from a (DRAM-placement) run.
#[derive(Debug, Clone, Copy)]
pub struct MeasuredParams {
    pub m: f64,
    pub s: f64,
    /// Per-access compute (µs).
    pub t_mem: f64,
    /// Per-IO pre/post CPU suboperation times (µs).
    pub t_pre: f64,
    pub t_post: f64,
}

impl MeasuredParams {
    /// Derive from run stats given the store's per-IO CPU suboperation times
    /// (device base + the store's extra, which is configured and therefore
    /// known — the paper instead instruments timestamps around yields).
    pub fn from_stats(st: &RunStats, t_pre: f64, t_post: f64) -> MeasuredParams {
        let m = st.mean_m.max(0.01);
        let s = st.mean_s;
        let compute_us = st.mean_compute.as_us();
        let t_mem = ((compute_us - s * (t_pre + t_post)) / m).max(0.01);
        MeasuredParams {
            m,
            s,
            t_mem,
            t_pre,
            t_post,
        }
    }

    /// Per-IO split (Sec 3.2.3): M per IO for the model when S ≠ 1.
    pub fn m_per_io(&self) -> f64 {
        if self.s > 0.0 {
            self.m / self.s
        } else {
            self.m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_threads_picks_max() {
        let table = [(8usize, 100.0), (16, 300.0), (32, 200.0)];
        let (n, st) = best_threads(&[8, 16, 32], |t| {
            let ops = table.iter().find(|(c, _)| *c == t).unwrap().1;
            fake_stats(ops)
        });
        assert_eq!(n, 16);
        assert_eq!(st.ops_per_sec, 300.0);
    }

    fn fake_stats(ops: f64) -> RunStats {
        RunStats {
            ops_per_sec: ops,
            ops: ops as u64,
            op_latency_mean: Dur::ZERO,
            op_latency_p50: Dur::ZERO,
            op_latency_p99: Dur::ZERO,
            op_latency_p999: Dur::ZERO,
            mean_m: 10.0,
            mean_m_dram: 0.0,
            mean_s: 1.0,
            mean_compute: Dur::us(2.0),
            eviction_ratio: 0.0,
            load_wait_mean: Dur::ZERO,
            load_wait_p99: Dur::ZERO,
            io_reads: 0,
            io_writes: 0,
            io_bytes: 0,
            io_retries: 0,
            io_errors: 0,
            lock_contention: 0.0,
            tenants: Vec::new(),
            io_classes: Vec::new(),
        }
    }

    #[test]
    fn parallel_map_preserves_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20usize)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let out = parallel_map(jobs);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_handles_empty_and_skewed_durations() {
        let out: Vec<u32> = parallel_map(Vec::<Box<dyn FnOnce() -> u32 + Send>>::new());
        assert!(out.is_empty());
        // One slow job among many fast ones: with work-stealing this
        // completes in ~slowest + fast work, not chunks × slowest. Assert
        // correctness here (wall-clock is covered by the bench harness).
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..40usize)
            .map(|i| {
                Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(30));
                    }
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        assert_eq!(parallel_map(jobs), (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn sweep_placement_axis_reaches_the_stores() {
        use crate::workload::YcsbWorkload;
        // AllDram placement through the sweep: no secondary accesses and a
        // full DRAM footprint; AllSecondary reports zero bytes.
        let sweep = SweepCfg {
            window: Dur::ms(4.0),
            warmup: Dur::ms(1.0),
            l_mem: Dur::us(2.0),
            ..Default::default()
        }
        .at_placement(PlacementPolicy::AllDram);
        let (st, _, bytes) = run_store_ycsb_placed(StoreKind::Tree, YcsbWorkload::C, &sweep, 16);
        assert_eq!(st.mean_m, 0.0, "AllDram leaves no secondary hops");
        assert!(st.mean_m_dram > 1.0, "descent hops moved inline");
        assert!(bytes > 0, "AllDram must account its footprint");
        let base = SweepCfg {
            window: Dur::ms(4.0),
            warmup: Dur::ms(1.0),
            l_mem: Dur::us(2.0),
            ..Default::default()
        };
        let (_, _, b0) = run_store_ycsb_placed(StoreKind::Tree, YcsbWorkload::C, &base, 16);
        assert_eq!(b0, 0, "AllSecondary consumes no DRAM");
        // Budget fractions resolve against the store's total footprint.
        let total = store_offload_bytes(StoreKind::Tree, YcsbWorkload::C, base.seed);
        assert!(total > 0);
    }

    #[test]
    fn sweep_n_ssd_axis_reaches_the_machine() {
        let sweep = SweepCfg::default().at_n_ssd(4);
        let mcfg = sweep.machine(8);
        assert_eq!(mcfg.ssd.n_ssd, 4);
        // Per-device knobs come from the sweep's device config.
        assert_eq!(mcfg.ssd.queue_depth, sweep.ssd.queue_depth);
        assert_eq!(SweepCfg::default().machine(8).ssd.n_ssd, 1);
    }

    #[test]
    fn measured_params_algebra() {
        let st = fake_stats(1000.0); // mean_compute 2us, m=10, s=1
        let p = MeasuredParams::from_stats(&st, 0.5, 0.3);
        // t_mem = (2 - 1*(0.8)) / 10 = 0.12
        assert!((p.t_mem - 0.12).abs() < 1e-9, "t_mem={}", p.t_mem);
        assert!((p.m_per_io() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn durable_run_disabled_wal_matches_placed_path() {
        use crate::workload::YcsbWorkload;
        // WAL off: the durable helper is the placed path plus zeroed WAL
        // counters — same seeds, same store, bit-identical stats.
        let sweep = SweepCfg {
            window: Dur::ms(4.0),
            warmup: Dur::ms(1.0),
            l_mem: Dur::us(2.0),
            ..Default::default()
        };
        let d = run_store_ycsb_durable(
            StoreKind::Lsm,
            YcsbWorkload::A,
            &sweep,
            16,
            WalConfig::default(),
        );
        let (st, _, _) = run_store_ycsb_placed(StoreKind::Lsm, YcsbWorkload::A, &sweep, 16);
        assert_eq!(d.stats.ops, st.ops);
        assert_eq!(d.stats.io_writes, st.io_writes);
        assert_eq!(d.wal, WalStats::default());
        assert!(d.acked_all_durable, "vacuously true with no acks");
        assert_eq!((d.io_errors, d.failed_ops), (0, 0));
        // WAL on: same workload now carries log flushes and extra writes.
        let w = run_store_ycsb_durable(
            StoreKind::Lsm,
            YcsbWorkload::A,
            &sweep,
            16,
            WalConfig::on(),
        );
        assert!(w.wal.appends > 0 && w.wal.flushes > 0);
        assert!(w.acked_all_durable);
        assert!(w.stats.io_writes > d.stats.io_writes, "log writes are real IO");
    }

    #[test]
    fn compressed_run_off_matches_placed_path() {
        use crate::kvs::Compression;
        use crate::workload::YcsbWorkload;
        // CompressMode::Off: the compressed helper is the placed path —
        // same seeds, same store, bit-identical stats and byte accounting.
        let sweep = SweepCfg {
            window: Dur::ms(4.0),
            warmup: Dur::ms(1.0),
            l_mem: Dur::us(2.0),
            ..Default::default()
        };
        let (st0, _, b0) = run_store_ycsb_placed(StoreKind::Lsm, YcsbWorkload::C, &sweep, 16);
        let (st1, _, b1) = run_store_ycsb_compressed(
            StoreKind::Lsm,
            YcsbWorkload::C,
            &sweep,
            16,
            CompressMode::Off,
        );
        assert_eq!(st0.ops, st1.ops);
        assert_eq!(st0.io_reads, st1.io_reads);
        assert_eq!(st0.io_writes, st1.io_writes);
        assert_eq!(b0, b1);
        // A ratio-1.0 spec normalizes away: still bit-identical.
        let (st2, _, b2) = run_store_ycsb_compressed(
            StoreKind::Lsm,
            YcsbWorkload::C,
            &sweep,
            16,
            CompressMode::Joint(Compression::new(1.0, 0.5)),
        );
        assert_eq!(st0.ops, st2.ops);
        assert_eq!(b0, b2);
    }

    #[test]
    fn crash_drill_holds_on_a_quiet_and_busy_store() {
        use crate::workload::OpMix;
        let build = |rng: &mut Rng| {
            LsmKv::new(
                LsmKvConfig {
                    mix: OpMix::ratio(1, 3),
                    wal: WalConfig::on(),
                    ..Default::default()
                },
                rng,
            )
        };
        let mcfg = MachineConfig {
            threads_per_core: 32,
            n_locks: 64,
            ..MachineConfig::default()
        };
        for crash_ms in [0.5, 4.0] {
            let c = crash_recover_check(build, mcfg.clone(), 0xc4a5, Dur::ms(crash_ms));
            assert!(
                c.holds_for_index_store(),
                "crash at {crash_ms}ms violated recovery invariants: {c:?}"
            );
            if crash_ms > 1.0 {
                assert!(c.durable_lsn > 0, "a busy run must have durable records");
            }
        }
    }

    #[test]
    fn store_kinds_run_quickly() {
        // Smoke: every store produces sensible throughput on a short window.
        let sweep = SweepCfg {
            window: Dur::ms(5.0),
            warmup: Dur::ms(2.0),
            l_mem: Dur::us(1.0),
            ..Default::default()
        };
        for kind in StoreKind::ALL {
            let st = run_store(kind, &sweep, 32);
            assert!(
                st.ops_per_sec > 10_000.0,
                "{}: {}",
                kind.name(),
                st.ops_per_sec
            );
        }
    }
}
