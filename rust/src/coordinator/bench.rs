//! Wall-clock bench harness for the simulator hot path.
//!
//! [`run_fixed_sweep`] times a **fixed** sweep — the same points every run:
//! an 8-point latency grid × {1, 4}-device arrays on the default
//! microbenchmark — and reports host-side points/sec and simulated ops per
//! wall second. These are the numbers the hot-path work (cached next-core
//! scheduling in `run_until`, work-stealing `parallel_map`) must not
//! regress, and what the multi-SSD routing cost must stay inside.
//!
//! [`BenchResult::write_json`] emits `BENCH_sim.json` at the workspace root
//! (hand-rolled JSON; the offline image has no serde), starting the repo's
//! perf trajectory: CI runs the `bench_sim` bench in fast mode on every
//! push, and `tests/bench_smoke.rs` self-bootstraps the file on a plain
//! `cargo test` so a toolchain run always leaves a measurement behind.

use std::time::Instant;

use super::runner::{parallel_map, SweepCfg};
use crate::kvs::{CompressMode, Compression, LsmKv, LsmKvConfig, PlacementPolicy};
use crate::microbench::{Microbench, MicrobenchConfig};
use crate::sim::{Dur, Machine, Rng};

/// One timed sweep's summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Sweep points simulated.
    pub points: usize,
    /// Total wall-clock seconds for the sweep.
    pub wall_secs: f64,
    /// Host-side throughput: points per wall second.
    pub points_per_sec: f64,
    /// Simulated operations completed across all points.
    pub sim_ops: u64,
    /// Simulated ops per wall second (the hot-path figure of merit).
    pub sim_ops_per_wall_sec: f64,
    /// Simulated ops per wall second on the compressed-class slice points
    /// (lsmkv, every class forced compressed): the per-access decompress
    /// charge rides the store hot path, so its host-side cost is tracked
    /// as its own trajectory figure.
    pub compress: f64,
}

impl BenchResult {
    /// Hand-rolled JSON (no serde in the offline image).
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"points\": {},\n  \"wall_secs\": {:.3},\n  \"points_per_sec\": {:.2},\n  \"sim_ops\": {},\n  \"sim_ops_per_wall_sec\": {:.0},\n  \"compress\": {:.0}\n}}\n",
            self.points,
            self.wall_secs,
            self.points_per_sec,
            self.sim_ops,
            self.sim_ops_per_wall_sec,
            self.compress
        )
    }

    /// Where `BENCH_sim.json` lives: the workspace root (the parent of the
    /// crate, detected by its `Cargo.toml`), falling back to the current
    /// directory.
    pub fn default_path() -> std::path::PathBuf {
        if std::path::Path::new("../Cargo.toml").exists() {
            std::path::PathBuf::from("../BENCH_sim.json")
        } else {
            std::path::PathBuf::from("BENCH_sim.json")
        }
    }

    /// Write `BENCH_sim.json` at [`BenchResult::default_path`]. Returns the
    /// path written.
    pub fn write_json(&self) -> std::io::Result<std::path::PathBuf> {
        let path = Self::default_path();
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

/// Simulate the fixed sweep with `window_ms`-long measurement windows and
/// time it. All points run through [`parallel_map`], so the bench exercises
/// the work-stealing scheduler alongside the per-machine hot path.
pub fn run_fixed_sweep(window_ms: f64) -> BenchResult {
    let grid = [0.1, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0];
    let n_ssds = [1u32, 4];
    let mut jobs = Vec::new();
    for &n in &n_ssds {
        for &l in &grid {
            let sweep = SweepCfg {
                l_mem: Dur::us(l),
                warmup: Dur::ms(window_ms / 4.0),
                window: Dur::ms(window_ms),
                n_ssd: n,
                ..Default::default()
            };
            jobs.push(move || {
                let mut rng = Rng::new(0xbe7c);
                let svc = Microbench::new(MicrobenchConfig::default(), &mut rng);
                Machine::new(sweep.machine(64), svc)
                    .run(sweep.warmup, sweep.window)
                    .ops
            });
        }
    }
    let points = jobs.len();
    let t = Instant::now();
    let ops = parallel_map(jobs);
    let wall = t.elapsed().as_secs_f64().max(1e-9);
    let sim_ops: u64 = ops.iter().sum();

    // Compressed-class slice points (not counted in `points`: the fixed
    // 16-point contract predates them): lsmkv with an unbounded budget and
    // a *forced* spec, so every offloadable class stays compressed and
    // every cache hop runs the inline decompress charge.
    let mut cjobs = Vec::new();
    for &l in &[2.0, 8.0] {
        let window = Dur::ms(window_ms);
        let warmup = Dur::ms(window_ms / 4.0);
        cjobs.push(move || {
            let sweep = SweepCfg {
                l_mem: Dur::us(l),
                warmup,
                window,
                ..Default::default()
            };
            let mut rng = Rng::new(0xc0de);
            let kv = LsmKv::new(
                LsmKvConfig {
                    placement: PlacementPolicy::Budget {
                        dram_bytes: u64::MAX,
                    },
                    compression: CompressMode::Forced(Compression::new(0.5, 0.12)),
                    ..Default::default()
                },
                &mut rng,
            );
            Machine::new(sweep.machine(64), kv)
                .run(sweep.warmup, sweep.window)
                .ops
        });
    }
    let ct = Instant::now();
    let cops: u64 = parallel_map(cjobs).iter().sum();
    let cwall = ct.elapsed().as_secs_f64().max(1e-9);

    BenchResult {
        points,
        wall_secs: wall,
        points_per_sec: points as f64 / wall,
        sim_ops,
        sim_ops_per_wall_sec: sim_ops as f64 / wall,
        compress: cops as f64 / cwall,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_is_stable() {
        let r = BenchResult {
            points: 16,
            wall_secs: 1.25,
            points_per_sec: 12.8,
            sim_ops: 4_200,
            sim_ops_per_wall_sec: 3_360.0,
            compress: 2_900.0,
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        for key in [
            "\"points\"",
            "\"wall_secs\"",
            "\"points_per_sec\"",
            "\"sim_ops\"",
            "\"sim_ops_per_wall_sec\"",
            "\"compress\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
    }
}
