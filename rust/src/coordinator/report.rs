//! Tabular experiment reports: printed as aligned text (the "rows/series the
//! paper reports") and written as CSV under `reports/`.

use std::fmt::Write as _;
use std::io::Write as _;

/// One experiment's output table.
#[derive(Debug, Clone)]
pub struct Report {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(title: &str, headers: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        let _ = writeln!(out, "{}", hdr.join("  "));
        let _ = writeln!(out, "{}", "-".repeat(hdr.join("  ").len()));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", cells.join("  "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "note: {n}");
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write `reports/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("reports")?;
        let mut f = std::fs::File::create(format!("reports/{name}.csv"))?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format helpers used across experiments.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn kops(x: f64) -> String {
    format!("{:.1}k", x / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut r = Report::new("T", &["a", "bbbb"]);
        r.row(vec!["1".into(), "2".into()]);
        r.row(vec!["100".into(), "2000".into()]);
        r.note("hello");
        let s = r.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("note: hello"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row(vec!["1".into()]);
    }

    #[test]
    fn csv_written() {
        let mut r = Report::new("T", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        r.write_csv("_test_report").unwrap();
        let s = std::fs::read_to_string("reports/_test_report.csv").unwrap();
        assert_eq!(s, "a,b\n1,2\n");
        let _ = std::fs::remove_file("reports/_test_report.csv");
    }
}
