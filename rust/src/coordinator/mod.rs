//! The experiment coordinator: regenerates every figure and table of the
//! paper's evaluation (§4–§5) on the simulated testbed, overlaying the
//! analytic models evaluated through the AOT-compiled JAX+Pallas artifact
//! (falling back to the native Rust model when artifacts are absent).

pub mod bench;
pub mod experiments;
pub mod report;
pub mod runner;

pub use bench::BenchResult;
pub use report::Report;
pub use runner::{best_threads, StoreKind, SweepCfg};
