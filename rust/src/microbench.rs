//! The paper's §4.1 microbenchmark: each operation performs `M` dependent
//! pointer-chasing accesses on a permuted chain placed on (simulated)
//! secondary memory, then issues one SSD IO (Fig 9). Each memory suboperation
//! costs `T_mem` of compute (the paper generates variations with `pause`
//! spin loops); the IO suboperation times are the SSD's `t_pre`/`t_post` plus
//! configurable extras (the paper's +1/+2 µs variations).
//!
//! Setting `io: false` gives the memory-only benchmark used to estimate `P`
//! and `T_sw` via Eq 3; `m: 0` gives the IO-only benchmark used to estimate
//! `T_IO^pre`/`T_IO^post`.

use crate::sim::{Dur, IoKind, Rng, Service, Step, Tier, TrafficClass};

/// Microbenchmark parameters (one §4.1.2 combination).
#[derive(Debug, Clone)]
pub struct MicrobenchConfig {
    /// Memory accesses per operation, M.
    pub m: u32,
    /// Compute per memory access, T_mem.
    pub t_mem: Dur,
    /// Extra CPU time added to IO submission (T_IO^pre − base submit cost).
    pub extra_pre: Dur,
    /// Extra CPU time added to IO completion handling.
    pub extra_post: Dur,
    /// Whether each op ends with an IO.
    pub io: bool,
    /// IO transfer size (paper: raw block reads; A_IO in Table 2).
    pub io_bytes: u32,
    /// Fraction of IOs that are writes (paper reports read results; writes
    /// behaved the same).
    pub write_ratio: f64,
    /// Pointer-chain length in cachelines (paper: 1G × 64 B = 64 GB; we scale
    /// down — the chain length only affects locality, which is deliberately
    /// destroyed by permutation anyway).
    pub chain_len: u32,
}

impl Default for MicrobenchConfig {
    fn default() -> Self {
        MicrobenchConfig {
            m: 10,
            t_mem: Dur::ns(100.0),
            extra_pre: Dur::ZERO,
            extra_post: Dur::ZERO,
            io: true,
            io_bytes: 1536,
            write_ratio: 0.0,
            chain_len: 1 << 20,
        }
    }
}

/// The microbenchmark service: owns the real pointer chain.
pub struct Microbench {
    pub cfg: MicrobenchConfig,
    chain: Vec<u32>,
    /// Sum of visited chain values (prevents the chase from being optimized
    /// away and doubles as a determinism check).
    pub checksum: u64,
}

#[derive(Debug)]
pub struct MbOp {
    cur: u32,
    left: u32,
    phase: Phase,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Compute,
    Access,
    Io,
    Done,
}

impl Microbench {
    pub fn new(cfg: MicrobenchConfig, rng: &mut Rng) -> Microbench {
        // Sattolo's algorithm: a single-cycle permutation, so any M-hop walk
        // from any start visits M distinct lines with no short cycles.
        let n = cfg.chain_len as usize;
        let mut chain: Vec<u32> = (0..n as u32).collect();
        for i in (1..n).rev() {
            let j = rng.below(i as u64) as usize;
            chain.swap(i, j);
        }
        Microbench {
            cfg,
            chain,
            checksum: 0,
        }
    }
}

impl Service for Microbench {
    type Op = MbOp;

    fn next_op(&mut self, _tid: usize, rng: &mut Rng) -> MbOp {
        let start = rng.below(self.cfg.chain_len as u64) as u32;
        MbOp {
            cur: start,
            left: self.cfg.m,
            phase: if self.cfg.m > 0 {
                Phase::Compute
            } else if self.cfg.io {
                Phase::Io
            } else {
                Phase::Done
            },
        }
    }

    fn step(&mut self, _tid: usize, op: &mut MbOp, rng: &mut Rng) -> Step {
        match op.phase {
            Phase::Compute => {
                op.phase = Phase::Access;
                Step::Compute(self.cfg.t_mem)
            }
            Phase::Access => {
                // The real dependent load: follow the chain.
                op.cur = self.chain[op.cur as usize];
                self.checksum = self.checksum.wrapping_add(op.cur as u64);
                op.left -= 1;
                op.phase = if op.left > 0 {
                    Phase::Compute
                } else if self.cfg.io {
                    Phase::Io
                } else {
                    Phase::Done
                };
                Step::MemAccess(Tier::Secondary)
            }
            Phase::Io => {
                op.phase = Phase::Done;
                let kind = if self.cfg.write_ratio > 0.0 && rng.chance(self.cfg.write_ratio) {
                    IoKind::Write
                } else {
                    IoKind::Read
                };
                Step::Io {
                    kind,
                    bytes: self.cfg.io_bytes,
                    extra_pre: self.cfg.extra_pre,
                    extra_post: self.cfg.extra_post,
                    class: TrafficClass::Foreground,
                    // The op's chain position doubles as its block address:
                    // uniform across the array, no extra RNG draw.
                    shard: op.cur as u64,
                }
            }
            Phase::Done => Step::Done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, MachineConfig, MemConfig};

    #[test]
    fn chain_is_single_cycle() {
        let mut rng = Rng::new(3);
        let mb = Microbench::new(
            MicrobenchConfig {
                chain_len: 1024,
                ..Default::default()
            },
            &mut rng,
        );
        let mut seen = vec![false; 1024];
        let mut cur = 0u32;
        for _ in 0..1024 {
            assert!(!seen[cur as usize], "short cycle at {cur}");
            seen[cur as usize] = true;
            cur = mb.chain[cur as usize];
        }
        assert_eq!(cur, 0, "walk should return to start after n hops");
    }

    #[test]
    fn ops_have_m_accesses_and_one_io() {
        let mut rng = Rng::new(4);
        let mut mb = Microbench::new(
            MicrobenchConfig {
                m: 5,
                chain_len: 4096,
                ..Default::default()
            },
            &mut rng,
        );
        let mut op = mb.next_op(0, &mut rng);
        let (mut mems, mut ios, mut computes) = (0, 0, 0);
        loop {
            match mb.step(0, &mut op, &mut rng) {
                Step::MemAccess(_) => mems += 1,
                Step::Io { .. } => ios += 1,
                Step::Compute(_) => computes += 1,
                Step::Done => break,
                _ => unreachable!(),
            }
        }
        assert_eq!(mems, 5);
        assert_eq!(ios, 1);
        assert_eq!(computes, 5);
    }

    #[test]
    fn memory_only_mode_has_no_io() {
        let mut rng = Rng::new(5);
        let mut mb = Microbench::new(
            MicrobenchConfig {
                m: 3,
                io: false,
                chain_len: 4096,
                ..Default::default()
            },
            &mut rng,
        );
        let mut op = mb.next_op(0, &mut rng);
        loop {
            match mb.step(0, &mut op, &mut rng) {
                Step::Io { .. } => panic!("io in memory-only mode"),
                Step::Done => break,
                _ => {}
            }
        }
    }

    #[test]
    fn end_to_end_throughput_sane() {
        let mut rng = Rng::new(6);
        let mb = Microbench::new(MicrobenchConfig::default(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 48,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            mb,
        );
        let st = m.run(Dur::ms(2.0), Dur::ms(20.0));
        // Floor: M(T_mem+T_sw)+E = 10*0.15 + 1.5+0.2+0.1 = 3.3 µs/op →
        // ~300k ops/s; with some waits it's below that but well above 150k.
        assert!(
            st.ops_per_sec > 150_000.0 && st.ops_per_sec < 320_000.0,
            "ops/sec = {}",
            st.ops_per_sec
        );
        assert!((st.mean_m - 10.0).abs() < 1e-9);
        assert!((st.mean_s - 1.0).abs() < 1e-9);
        assert!(m.service.checksum != 0);
    }

    #[test]
    fn write_mix_produces_writes() {
        let mut rng = Rng::new(7);
        let mb = Microbench::new(
            MicrobenchConfig {
                write_ratio: 0.5,
                ..Default::default()
            },
            &mut rng,
        );
        let mut m = Machine::new(MachineConfig::default(), mb);
        let st = m.run(Dur::ms(1.0), Dur::ms(5.0));
        assert!(st.io_writes > 0 && st.io_reads > 0);
        let frac = st.io_writes as f64 / (st.io_writes + st.io_reads) as f64;
        assert!((frac - 0.5).abs() < 0.1, "write frac {frac}");
    }
}
