//! Model-artifact runtime: load the AOT-compiled JAX+Pallas model artifacts
//! (`artifacts/*.hlo.txt`) and evaluate them in batch from Rust.
//!
//! Python runs only at `make artifacts` time. In the full deployment the
//! artifacts execute through PJRT (`PjRtClient::cpu()` → HLO text →
//! `compile` → `execute`); the offline build image has no XLA bindings, so
//! [`evaluator::ModelEvaluator`] validates the artifacts and evaluates the
//! identical equations through the native mirror in [`crate::model`]. The
//! artifact directory defaults to `artifacts/` and can be overridden with
//! the `CXLKVS_ARTIFACTS` environment variable.

pub mod evaluator;

pub use evaluator::{BaseIn, BaseOut, ExtIn, ExtOut, ModelEvaluator, RuntimeError};
