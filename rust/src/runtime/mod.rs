//! PJRT runtime: load the AOT-compiled JAX+Pallas model artifacts
//! (`artifacts/*.hlo.txt`) and evaluate them in batch from Rust.
//!
//! Python runs only at `make artifacts` time; this module is the whole
//! request-path story: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`. HLO *text* is the interchange format (the
//! image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id protos; the text
//! parser reassigns ids).

pub mod evaluator;

pub use evaluator::{BaseIn, BaseOut, ExtIn, ExtOut, ModelEvaluator};
