//! Batched model evaluation through PJRT.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Compiled-in batch size of the AOT artifacts (python/compile/model.py).
pub const BATCH: usize = 64;
/// Input columns of the base artifact.
pub const BASE_COLS: usize = 8;
/// Output columns of the base artifact.
pub const BASE_OUTS: usize = 6;
/// Input columns of the extended artifact.
pub const EXT_COLS: usize = 16;
/// Output columns of the extended artifact.
pub const EXT_OUTS: usize = 2;

/// One base-model parameter tuple (times in µs; mirrors Table 1).
#[derive(Debug, Clone, Copy)]
pub struct BaseIn {
    pub m: f32,
    pub t_mem: f32,
    pub t_pre: f32,
    pub t_post: f32,
    pub l_mem: f32,
    pub t_sw: f32,
    pub p: f32,
    pub n: f32,
}

/// Reciprocal throughputs (µs/op) of all §3 base models for one input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BaseOut {
    pub single: f32,
    pub multi: f32,
    pub mem: f32,
    pub mask: f32,
    pub best: f32,
    pub prob: f32,
}

/// One extended-model parameter tuple (Table 2; µs / bytes / bytes-per-µs /
/// IOs-per-µs).
#[derive(Debug, Clone, Copy)]
pub struct ExtIn {
    pub m: f32,
    pub t_mem: f32,
    pub t_pre: f32,
    pub t_post: f32,
    pub l_mem: f32,
    pub t_sw: f32,
    pub p: f32,
    pub rho: f32,
    pub eps: f32,
    pub a_mem: f32,
    pub b_mem: f32,
    pub l_dram: f32,
    pub a_io: f32,
    pub b_io: f32,
    pub r_io: f32,
    pub s: f32,
}

/// Reciprocal throughputs of the extended models for one input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExtOut {
    pub rev: f32,
    pub extended: f32,
}

impl BaseIn {
    fn row(&self) -> [f32; BASE_COLS] {
        [
            self.m, self.t_mem, self.t_pre, self.t_post, self.l_mem, self.t_sw, self.p,
            self.n,
        ]
    }
}

impl ExtIn {
    fn row(&self) -> [f32; EXT_COLS] {
        [
            self.m, self.t_mem, self.t_pre, self.t_post, self.l_mem, self.t_sw, self.p,
            self.rho, self.eps, self.a_mem, self.b_mem, self.l_dram, self.a_io, self.b_io,
            self.r_io, self.s,
        ]
    }
}

/// Owns the PJRT client and the two compiled model executables.
pub struct ModelEvaluator {
    client: xla::PjRtClient,
    base_exe: xla::PjRtLoadedExecutable,
    ext_exe: xla::PjRtLoadedExecutable,
    /// Number of PJRT executions performed (perf accounting).
    pub executions: u64,
}

impl ModelEvaluator {
    /// Load from an artifacts directory (default: `artifacts/` at the repo
    /// root, overridable with `CXLKVS_ARTIFACTS`).
    pub fn load_default() -> Result<ModelEvaluator> {
        let dir = std::env::var("CXLKVS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    pub fn load(dir: &Path) -> Result<ModelEvaluator> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let base = Self::compile(&client, &dir.join(format!("model_base_b{BATCH}.hlo.txt")))?;
        let ext = Self::compile(&client, &dir.join(format!("model_extended_b{BATCH}.hlo.txt")))?;
        Ok(ModelEvaluator {
            client,
            base_exe: base,
            ext_exe: ext,
            executions: 0,
        })
    }

    fn compile(client: &xla::PjRtClient, path: &PathBuf) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {path:?} (run `make artifacts`)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {path:?}"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Evaluate the base models for an arbitrary number of inputs; inputs are
    /// padded to the artifact's static batch internally.
    pub fn eval_base(&mut self, inputs: &[BaseIn]) -> Result<Vec<BaseOut>> {
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in inputs.chunks(BATCH) {
            let mut flat = vec![0f32; BATCH * BASE_COLS];
            for (i, inp) in chunk.iter().enumerate() {
                flat[i * BASE_COLS..(i + 1) * BASE_COLS].copy_from_slice(&inp.row());
            }
            // Pad with the last row (keeps every lane numerically benign).
            if let Some(last) = chunk.last() {
                for i in chunk.len()..BATCH {
                    flat[i * BASE_COLS..(i + 1) * BASE_COLS].copy_from_slice(&last.row());
                }
            }
            let lit = xla::Literal::vec1(&flat).reshape(&[BATCH as i64, BASE_COLS as i64])?;
            let res = self.base_exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            self.executions += 1;
            let tup = res.to_tuple1()?;
            let vals = tup.to_vec::<f32>()?;
            anyhow::ensure!(vals.len() == BATCH * BASE_OUTS, "bad output size");
            for (i, _) in chunk.iter().enumerate() {
                let r = &vals[i * BASE_OUTS..(i + 1) * BASE_OUTS];
                out.push(BaseOut {
                    single: r[0],
                    multi: r[1],
                    mem: r[2],
                    mask: r[3],
                    best: r[4],
                    prob: r[5],
                });
            }
        }
        Ok(out)
    }

    /// Evaluate the extended models (Eq 14–15) for arbitrary many inputs.
    pub fn eval_extended(&mut self, inputs: &[ExtIn]) -> Result<Vec<ExtOut>> {
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in inputs.chunks(BATCH) {
            let mut flat = vec![0f32; BATCH * EXT_COLS];
            for (i, inp) in chunk.iter().enumerate() {
                flat[i * EXT_COLS..(i + 1) * EXT_COLS].copy_from_slice(&inp.row());
            }
            if let Some(last) = chunk.last() {
                for i in chunk.len()..BATCH {
                    flat[i * EXT_COLS..(i + 1) * EXT_COLS].copy_from_slice(&last.row());
                }
            }
            let lit = xla::Literal::vec1(&flat).reshape(&[BATCH as i64, EXT_COLS as i64])?;
            let res = self.ext_exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            self.executions += 1;
            let tup = res.to_tuple1()?;
            let vals = tup.to_vec::<f32>()?;
            anyhow::ensure!(vals.len() == BATCH * EXT_OUTS, "bad output size");
            for (i, _) in chunk.iter().enumerate() {
                out.push(ExtOut {
                    rev: vals[i * EXT_OUTS],
                    extended: vals[i * EXT_OUTS + 1],
                });
            }
        }
        Ok(out)
    }
}
