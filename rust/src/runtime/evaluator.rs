//! Batched model evaluation against the AOT-compiled artifacts.
//!
//! The production path described in the paper-reproduction plan loads the
//! JAX+Pallas models compiled to HLO text (`artifacts/*.hlo.txt`) and runs
//! them through PJRT. The offline build image ships neither the `xla`
//! bindings nor a PJRT plugin, so this module provides the same interface
//! backed by an artifact-gated evaluator: construction fails exactly like
//! the PJRT loader when the artifacts are absent or malformed, and
//! evaluation computes the identical §3 equations through the native Rust
//! mirror ([`crate::model`]) — the same equations the artifact encodes,
//! which `python/tests/test_aot.py` cross-validates at artifact-build time.
//! Callers (the coordinator's `ModelBackend`, tests, benches) are agnostic
//! to which backend satisfied the call.
//!
//! Artifact location: `artifacts/` at the crate root, overridable with the
//! `CXLKVS_ARTIFACTS` environment variable.

use std::fmt;
use std::path::{Path, PathBuf};

use crate::model::{
    theta_best_recip, theta_extended_recip, theta_mask_recip, theta_mem_recip, theta_multi_recip,
    theta_prob_recip, theta_rev_recip, theta_single_recip, ExtParams, OpParams, SysParams,
};

/// Compiled-in batch size of the AOT artifacts (python/compile/model.py).
pub const BATCH: usize = 64;
/// Input columns of the base artifact.
pub const BASE_COLS: usize = 8;
/// Output columns of the base artifact.
pub const BASE_OUTS: usize = 6;
/// Input columns of the extended artifact.
pub const EXT_COLS: usize = 16;
/// Output columns of the extended artifact.
pub const EXT_OUTS: usize = 2;

/// Error raised by artifact loading / evaluation.
#[derive(Debug, Clone)]
pub struct RuntimeError {
    msg: String,
}

impl RuntimeError {
    fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError { msg: msg.into() }
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// One base-model parameter tuple (times in µs; mirrors Table 1).
#[derive(Debug, Clone, Copy)]
pub struct BaseIn {
    pub m: f32,
    pub t_mem: f32,
    pub t_pre: f32,
    pub t_post: f32,
    pub l_mem: f32,
    pub t_sw: f32,
    pub p: f32,
    pub n: f32,
}

/// Reciprocal throughputs (µs/op) of all §3 base models for one input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BaseOut {
    pub single: f32,
    pub multi: f32,
    pub mem: f32,
    pub mask: f32,
    pub best: f32,
    pub prob: f32,
}

/// One extended-model parameter tuple (Table 2; µs / bytes / bytes-per-µs /
/// IOs-per-µs).
#[derive(Debug, Clone, Copy)]
pub struct ExtIn {
    pub m: f32,
    pub t_mem: f32,
    pub t_pre: f32,
    pub t_post: f32,
    pub l_mem: f32,
    pub t_sw: f32,
    pub p: f32,
    pub rho: f32,
    pub eps: f32,
    pub a_mem: f32,
    pub b_mem: f32,
    pub l_dram: f32,
    pub a_io: f32,
    pub b_io: f32,
    pub r_io: f32,
    pub s: f32,
}

/// Reciprocal throughputs of the extended models for one input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExtOut {
    pub rev: f32,
    pub extended: f32,
}

impl BaseIn {
    fn op(&self) -> OpParams {
        OpParams {
            m: self.m as f64,
            t_mem: self.t_mem as f64,
            t_pre: self.t_pre as f64,
            t_post: self.t_post as f64,
        }
    }

    fn sys(&self) -> SysParams {
        SysParams {
            t_sw: self.t_sw as f64,
            p: (self.p as usize).max(1),
            n: (self.n as usize).max(1),
        }
    }
}

impl ExtIn {
    fn op(&self) -> OpParams {
        OpParams {
            m: self.m as f64,
            t_mem: self.t_mem as f64,
            t_pre: self.t_pre as f64,
            t_post: self.t_post as f64,
        }
    }

    fn sys(&self) -> SysParams {
        SysParams {
            t_sw: self.t_sw as f64,
            p: (self.p as usize).max(1),
            n: 1_000_000,
        }
    }

    fn ext(&self) -> ExtParams {
        ExtParams {
            rho: self.rho as f64,
            eps: self.eps as f64,
            a_mem: self.a_mem as f64,
            b_mem: self.b_mem as f64,
            l_dram: self.l_dram as f64,
            a_io: self.a_io as f64,
            b_io: self.b_io as f64,
            r_io: self.r_io as f64,
            s: self.s as f64,
            // The 16-column artifact interface carries *aggregate* device
            // rates; callers with an SSD array pre-scale b_io/r_io by n_ssd
            // (see `ModelBackend::extended`), keeping the HLO signature
            // stable across the multi-SSD extension. The same reasoning
            // keeps the WAL/retry terms out of the artifact: callers fold
            // log traffic into the native model, not the frozen HLO.
            n_ssd: 1.0,
            w_log: 0.0,
            s_log: 0.0,
            retry_factor: 1.0,
        }
    }
}

/// Owns the validated artifacts and evaluates model batches.
pub struct ModelEvaluator {
    /// Paths of the validated HLO-text artifacts (kept for diagnostics).
    pub base_artifact: PathBuf,
    pub ext_artifact: PathBuf,
    /// Number of batch executions performed (perf accounting).
    pub executions: u64,
}

impl ModelEvaluator {
    /// Load from an artifacts directory (default: `artifacts/` at the repo
    /// root, overridable with `CXLKVS_ARTIFACTS`).
    pub fn load_default() -> Result<ModelEvaluator> {
        let dir = std::env::var("CXLKVS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    pub fn load(dir: &Path) -> Result<ModelEvaluator> {
        let base = Self::validate(&dir.join(format!("model_base_b{BATCH}.hlo.txt")))?;
        let ext = Self::validate(&dir.join(format!("model_extended_b{BATCH}.hlo.txt")))?;
        Ok(ModelEvaluator {
            base_artifact: base,
            ext_artifact: ext,
            executions: 0,
        })
    }

    /// Read and sanity-check one HLO text artifact (the same gate the PJRT
    /// text parser applies before id reassignment).
    fn validate(path: &Path) -> Result<PathBuf> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            RuntimeError::new(format!(
                "read HLO text {path:?}: {e} (run `make artifacts`)"
            ))
        })?;
        if !text.trim_start().starts_with("HloModule") {
            return Err(RuntimeError::new(format!(
                "{path:?} is not HLO text (missing HloModule header)"
            )));
        }
        Ok(path.to_path_buf())
    }

    /// Backend identifier (mirrors PJRT's `platform_name`).
    pub fn platform(&self) -> String {
        "cpu-native-mirror".to_string()
    }

    /// Evaluate the base models for an arbitrary number of inputs; inputs
    /// are processed in artifact-sized batches for accounting parity.
    pub fn eval_base(&mut self, inputs: &[BaseIn]) -> Result<Vec<BaseOut>> {
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in inputs.chunks(BATCH) {
            for inp in chunk {
                let op = inp.op();
                let sys = inp.sys();
                let l = inp.l_mem as f64;
                out.push(BaseOut {
                    single: theta_single_recip(op.t_mem, l) as f32,
                    multi: theta_multi_recip(op.t_mem, l, &sys) as f32,
                    mem: theta_mem_recip(op.t_mem, l, &sys) as f32,
                    mask: theta_mask_recip(&op, l, &sys) as f32,
                    best: theta_best_recip(&op, l, &sys) as f32,
                    prob: theta_prob_recip(&op, l, &sys) as f32,
                });
            }
            self.executions += 1;
        }
        Ok(out)
    }

    /// Evaluate the extended models (Eq 14–15) for arbitrarily many inputs.
    pub fn eval_extended(&mut self, inputs: &[ExtIn]) -> Result<Vec<ExtOut>> {
        let mut out = Vec::with_capacity(inputs.len());
        for chunk in inputs.chunks(BATCH) {
            for inp in chunk {
                let op = inp.op();
                let sys = inp.sys();
                let ext = inp.ext();
                let l = inp.l_mem as f64;
                out.push(ExtOut {
                    rev: theta_rev_recip(&op, l, &ext, &sys) as f32,
                    extended: theta_extended_recip(&op, l, &ext, &sys) as f32,
                });
            }
            self.executions += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_without_artifacts() {
        let err = ModelEvaluator::load(Path::new("/nonexistent-artifacts-dir"));
        assert!(err.is_err());
        let msg = format!("{}", err.err().unwrap());
        assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    }

    #[test]
    fn validate_rejects_non_hlo_text() {
        let dir = std::env::temp_dir().join("cxlkvs_evaluator_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("model_base_b{BATCH}.hlo.txt"));
        std::fs::write(&p, "not an hlo module").unwrap();
        let err = ModelEvaluator::validate(&p);
        assert!(err.is_err());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn eval_without_artifacts_matches_native_model() {
        // The evaluator's numeric path is independent of artifact loading;
        // construct one directly to pin the mirror equations.
        let mut ev = ModelEvaluator {
            base_artifact: PathBuf::new(),
            ext_artifact: PathBuf::new(),
            executions: 0,
        };
        let inp = BaseIn {
            m: 10.0,
            t_mem: 0.1,
            t_pre: 4.0,
            t_post: 3.0,
            l_mem: 5.0,
            t_sw: 0.05,
            p: 10.0,
            n: 1e6,
        };
        let out = ev.eval_base(&[inp]).unwrap();
        assert_eq!(out.len(), 1);
        let op = crate::model::OpParams::table1_example();
        let sys = crate::model::SysParams::table1_example();
        let native = theta_prob_recip(&op, 5.0, &sys);
        assert!(
            ((out[0].prob as f64) - native).abs() / native < 1e-5,
            "prob {} vs native {native}",
            out[0].prob
        );
        assert_eq!(ev.executions, 1);
    }
}
