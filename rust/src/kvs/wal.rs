//! Write-ahead log with group commit — the durability layer shared by all
//! three stores.
//!
//! Real SSD-backed KV stores do not ack a write when the in-memory index
//! mutation lands: they first append a log record and make it durable with
//! an fsync-class device write. This module adds that path to the
//! simulator's stores while preserving the repo's two core invariants:
//! **off by default** (a disabled WAL adds zero steps, zero RNG draws, and
//! keeps every existing summary bit-identical) and **all costs simulated**
//! (appends are CPU `Step`s, commit waits are `Step::Yield` polls charged
//! at `T_sw`, and every flush is one `Step::Io` through the `SsdArray`, so
//! log traffic visibly steals `R_IO`/`B_IO` from foreground reads).
//!
//! ## Protocol
//!
//! A mutating op applies its in-memory effect, appends a record
//! ([`Wal::append`], one `append_cpu` compute step), and enters the commit
//! state. There it loops:
//!
//! 1. **Durable already?** (`is_durable`) — ack and finish.
//! 2. **No flush in flight?** ([`Wal::try_lead`]) — become the *leader*:
//!    seal every appended-but-unflushed record (group commit) or just its
//!    own (per-op commit) and issue one log write for the sealed bytes on
//!    the dedicated `log_shard` route.
//! 3. **Otherwise** — *follower*: `Step::Yield` (a commit-wait poll, cost
//!    `T_sw`) and re-check next slice.
//!
//! When the leader's IO completes, [`Wal::flush_done`] advances the durable
//! LSN and every parked follower acks on its next poll. If the log write
//! *fails* (fault injection), the leader aborts the flush
//! ([`Wal::flush_aborted`]) so another op can re-elect itself — a failed
//! log device degrades to per-op errors, never a wedged commit queue.
//!
//! ## Group-commit cost model (the Eq 14 extension)
//!
//! Let `w_rec` be the record size, `A_sec` the device sector size (a flush
//! is sector-rounded: one fsync-class write), and `G` the mean batch size
//! (records per flush, measured as `appends / flushes`). Per foreground op
//! the log adds
//!
//! ```text
//!   s_log = flushes / ops            log IOs per op      (= 1/G when every
//!                                                          op logs once)
//!   w_log = flush_bytes / ops        log bytes per op
//! ```
//!
//! and the Eq 14 device floors gain a foreground/background sharing term —
//! log writes and foreground value IOs drain the *same* per-device command
//! and byte servers:
//!
//! ```text
//!   Θ ≤ (R_IO · n_ssd) / (S·r_retry + s_log)      IOPS floor
//!   Θ ≤ (B_IO · n_ssd) / (S·A_IO   + w_log)      bandwidth floor
//! ```
//!
//! where `r_retry ≥ 1` inflates foreground IO slots by transient-error
//! resubmissions (`io_retries / ios`). See `model::extended::ExtParams`
//! {`s_log`, `w_log`, `retry_factor`}. Group commit's whole value is that
//! `s_log → 1/G`: at `G = 32` threads per batch the per-op IOPS tax is
//! 1/32nd of per-op commit's, while the byte tax only shrinks until the
//! batch outgrows one sector — exactly the fsync-amortization argument,
//! replayed with the paper's floor algebra.
//!
//! The commit path also adds per-op CPU/latency (not a floor, an additive
//! `t_fixed` term): `append_cpu` for the record, `polls/op × T_sw` of
//! commit-wait, and the leader's IO pre/post amortized over the batch —
//! `(T_IO_pre + T_IO_post)/G`. The durability experiment predicts WAL-on
//! throughput from these measured WAL counters and gates on the simulator
//! agreeing within a documented band.
//!
//! ## Crash–recovery
//!
//! [`Durable`] is the store-side surface: a crash at simulated time `t` is
//! modeled by *dropping the machine* (volatile index state is gone) and
//! constructing a fresh store from the same config + seed, then replaying
//! the crashed WAL's durable prefix ([`Durable::wal_replay`]). The replay
//! honors an applied-LSN watermark, so replaying twice is a no-op —
//! idempotence is by construction, and the property tests assert
//! bit-identical counters. Recovery invariants:
//!
//! - **acked-durable**: every op acked before the crash is in the durable
//!   prefix (`Wal::acked_all_durable`) and therefore present after replay;
//! - **unacked-atomic**: an op whose record missed the durable prefix has
//!   no visible effect after recovery (the fresh store never saw it).

use std::collections::HashMap;

use crate::sim::{Dur, Rng};

/// What a WAL record logs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WalKind {
    /// Upsert (put or the write half of an RMW); `vsize` is the value size.
    Put,
    /// Delete / tombstone.
    Delete,
}

/// One log record. `key` is the store's durable key encoding — treekv logs
/// the 64-bit digest it indexes by; lsmkv/cachekv log the key itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalRecord {
    pub kind: WalKind,
    pub key: u64,
    pub vsize: u32,
}

/// WAL configuration (a field of every store's config; disabled by default
/// so existing runs are bit-identical).
#[derive(Debug, Clone)]
pub struct WalConfig {
    pub enabled: bool,
    /// Group commit (true): the leader seals every unflushed record.
    /// False: per-op commit — each op flushes exactly its own record (the
    /// control arm group commit must beat at equal durability).
    pub group_commit: bool,
    /// On-log size of one record (header + key + value metadata).
    pub record_bytes: u32,
    /// Sector granularity of a flush: the log write is rounded up (an
    /// fsync-class write always pays at least one sector).
    pub sector_bytes: u32,
    /// CPU cost of formatting + buffering one record.
    pub append_cpu: Dur,
    /// Shard route of the log writes (`shard % n_ssd` picks the device;
    /// `u64::MAX` lands on the last device of a power-of-two array). With
    /// `n_ssd = 1` the log shares the only device and its traffic visibly
    /// competes with foreground IO — the bandwidth-sharing term above.
    pub log_shard: u64,
}

impl Default for WalConfig {
    fn default() -> WalConfig {
        WalConfig {
            enabled: false,
            group_commit: true,
            record_bytes: 64,
            sector_bytes: 4096,
            append_cpu: Dur::ns(150.0),
            log_shard: u64::MAX,
        }
    }
}

impl WalConfig {
    pub fn on() -> WalConfig {
        WalConfig {
            enabled: true,
            ..WalConfig::default()
        }
    }

    pub fn per_op() -> WalConfig {
        WalConfig {
            enabled: true,
            group_commit: false,
            ..WalConfig::default()
        }
    }
}

/// Counters for the WAL cost model (all plain counts; `PartialEq` so the
/// idempotence property test can assert bit-identical state).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WalStats {
    /// Records appended.
    pub appends: u64,
    /// Log writes issued (leader flushes).
    pub flushes: u64,
    /// Records covered by completed flushes.
    pub flushed_records: u64,
    /// Bytes of completed log writes (sector-rounded).
    pub flush_bytes: u64,
    /// Follower commit-wait polls (each cost one `T_sw` yield).
    pub commit_polls: u64,
    /// Flushes aborted by a failed log write.
    pub aborted_flushes: u64,
}

/// The write-ahead log of one store. Purely structural — all timing is
/// charged by the store's op state machine through `Step`s.
#[derive(Debug, Clone)]
pub struct Wal {
    pub cfg: WalConfig,
    records: Vec<WalRecord>,
    acked: Vec<bool>,
    /// Records `[0, durable_lsn)` are on stable storage.
    durable_lsn: u64,
    /// A leader's in-flight flush seals `[durable_lsn, upto)`.
    flush_upto: Option<u64>,
    /// Replay watermark: records below this were already applied to the
    /// owning store by `wal_replay` (idempotence).
    applied_lsn: u64,
    pub stats: WalStats,
}

impl Wal {
    pub fn new(cfg: WalConfig) -> Wal {
        Wal {
            cfg,
            records: Vec::new(),
            acked: Vec::new(),
            durable_lsn: 0,
            flush_upto: None,
            applied_lsn: 0,
            stats: WalStats::default(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// Append one record; returns its LSN. The caller charges
    /// `cfg.append_cpu` as a `Step::Compute`.
    pub fn append(&mut self, kind: WalKind, key: u64, vsize: u32) -> u64 {
        let lsn = self.records.len() as u64;
        self.records.push(WalRecord { kind, key, vsize });
        self.acked.push(false);
        self.stats.appends += 1;
        lsn
    }

    #[inline]
    pub fn is_durable(&self, lsn: u64) -> bool {
        lsn < self.durable_lsn
    }

    /// Commit-state election. `None` = poll again later (a flush is in
    /// flight, or `my_lsn` is already durable — the caller checks
    /// `is_durable` first). `Some((upto, bytes))` = the caller is now the
    /// flush leader and must issue one log write of `bytes` on
    /// `cfg.log_shard`, then call `flush_done(upto)` (or `flush_aborted`
    /// if the write fails).
    pub fn try_lead(&mut self, my_lsn: u64) -> Option<(u64, u32)> {
        if self.flush_upto.is_some() || self.is_durable(my_lsn) {
            return None;
        }
        let upto = if self.cfg.group_commit {
            self.records.len() as u64
        } else {
            my_lsn + 1
        };
        debug_assert!(upto > self.durable_lsn);
        let raw = (upto - self.durable_lsn) as u32 * self.cfg.record_bytes;
        let sector = self.cfg.sector_bytes.max(1);
        let bytes = raw.div_ceil(sector) * sector;
        self.flush_upto = Some(upto);
        self.stats.flushes += 1;
        Some((upto, bytes))
    }

    /// The leader's log write completed: `[durable_lsn, upto)` is durable.
    pub fn flush_done(&mut self, upto: u64) {
        debug_assert_eq!(self.flush_upto, Some(upto));
        let sector = self.cfg.sector_bytes.max(1);
        let raw = (upto - self.durable_lsn) as u32 * self.cfg.record_bytes;
        self.stats.flush_bytes += (raw.div_ceil(sector) * sector) as u64;
        self.stats.flushed_records += upto - self.durable_lsn;
        self.durable_lsn = upto;
        self.flush_upto = None;
    }

    /// The leader's log write failed: release the flush so another op can
    /// re-elect itself (no wedged commit queue). The sealed records stay
    /// unflushed and unacked.
    pub fn flush_aborted(&mut self, upto: u64) {
        debug_assert_eq!(self.flush_upto, Some(upto));
        self.flush_upto = None;
        self.stats.aborted_flushes += 1;
    }

    /// Record a follower's commit-wait poll (cost charged by the caller's
    /// `Step::Yield`).
    #[inline]
    pub fn note_poll(&mut self) {
        self.stats.commit_polls += 1;
    }

    /// The op at `lsn` was acked to the client (only legal once durable).
    pub fn mark_acked(&mut self, lsn: u64) {
        debug_assert!(self.is_durable(lsn), "ack before durability");
        self.acked[lsn as usize] = true;
    }

    #[inline]
    pub fn durable_lsn(&self) -> u64 {
        self.durable_lsn
    }

    #[inline]
    pub fn applied_lsn(&self) -> u64 {
        self.applied_lsn
    }

    pub fn set_applied_lsn(&mut self, lsn: u64) {
        self.applied_lsn = self.applied_lsn.max(lsn);
    }

    pub fn records(&self) -> &[WalRecord] {
        &self.records
    }

    /// The durable prefix (what survives a crash).
    pub fn durable_records(&self) -> &[WalRecord] {
        &self.records[..self.durable_lsn as usize]
    }

    /// LSNs acked to clients.
    pub fn acked_lsns(&self) -> impl Iterator<Item = u64> + '_ {
        self.acked
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .map(|(i, _)| i as u64)
    }

    /// The acked-durable structural invariant: no op was ever acked whose
    /// record is not on stable storage.
    pub fn acked_all_durable(&self) -> bool {
        self.acked_lsns().all(|l| self.is_durable(l))
    }

    /// Last durable record per key — the recovery oracle: `Put` keys must
    /// be present after replay, `Delete` keys absent.
    pub fn durable_last_kind(&self) -> HashMap<u64, WalKind> {
        let mut m = HashMap::new();
        for r in self.durable_records() {
            m.insert(r.key, r.kind);
        }
        m
    }
}

/// Store-side crash–recovery surface. A store implements the three
/// accessors plus `replay_record`; `wal_replay` (provided) is the recovery
/// procedure, watermarked for idempotence.
pub trait Durable {
    fn wal(&self) -> &Wal;
    fn wal_mut(&mut self) -> &mut Wal;
    /// Presence oracle in the WAL's key encoding (treekv: digest).
    fn wal_present(&self, key: u64) -> bool;
    /// Apply one record structurally (no simulated time — recovery runs
    /// before the measured window).
    fn replay_record(&mut self, rec: &WalRecord, rng: &mut Rng);

    /// Replay `src`'s durable prefix into `self`, skipping records below
    /// the local applied watermark. Returns the number of records applied;
    /// a second call with the same `src` applies zero and leaves every
    /// counter bit-identical.
    fn wal_replay(&mut self, src: &Wal, rng: &mut Rng) -> u64 {
        let upto = src.durable_lsn();
        let from = self.wal().applied_lsn().min(upto);
        for rec in &src.records()[from as usize..upto as usize] {
            self.replay_record(rec, rng);
        }
        self.wal_mut().set_applied_lsn(upto);
        upto - from
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn append_lead_flush_ack_roundtrip() {
        let mut w = Wal::new(WalConfig::on());
        let a = w.append(WalKind::Put, 1, 100);
        let b = w.append(WalKind::Delete, 2, 0);
        assert_eq!((a, b), (0, 1));
        assert!(!w.is_durable(a));
        // First committer leads and seals both records (group commit).
        let (upto, bytes) = w.try_lead(a).expect("leader");
        assert_eq!(upto, 2);
        assert_eq!(bytes, 4096, "2×64B rounds up to one sector");
        // Another committer cannot lead while the flush is in flight.
        assert!(w.try_lead(b).is_none());
        w.note_poll();
        w.flush_done(upto);
        assert!(w.is_durable(a) && w.is_durable(b));
        w.mark_acked(a);
        w.mark_acked(b);
        assert!(w.acked_all_durable());
        assert_eq!(w.stats.flushes, 1);
        assert_eq!(w.stats.flushed_records, 2);
        assert_eq!(w.stats.flush_bytes, 4096);
        assert_eq!(w.stats.commit_polls, 1);
    }

    #[test]
    fn per_op_commit_seals_only_own_prefix() {
        let mut w = Wal::new(WalConfig::per_op());
        let a = w.append(WalKind::Put, 1, 0);
        let _b = w.append(WalKind::Put, 2, 0);
        let (upto, _) = w.try_lead(a).unwrap();
        assert_eq!(upto, 1, "per-op commit flushes just the leader's record");
        w.flush_done(upto);
        assert!(w.is_durable(a));
        assert!(!w.is_durable(1));
    }

    #[test]
    fn aborted_flush_allows_reelection() {
        let mut w = Wal::new(WalConfig::on());
        let a = w.append(WalKind::Put, 7, 0);
        let (upto, _) = w.try_lead(a).unwrap();
        w.flush_aborted(upto);
        assert!(!w.is_durable(a));
        assert_eq!(w.stats.aborted_flushes, 1);
        // A new election succeeds and can complete.
        let (upto2, _) = w.try_lead(a).unwrap();
        w.flush_done(upto2);
        assert!(w.is_durable(a));
    }

    #[test]
    fn durable_last_kind_tracks_final_state() {
        let mut w = Wal::new(WalConfig::on());
        w.append(WalKind::Put, 1, 0);
        w.append(WalKind::Delete, 1, 0);
        w.append(WalKind::Put, 2, 0);
        let not_durable = w.append(WalKind::Delete, 2, 0);
        // Flush only the first three records (per-op seal from lsn 2).
        w.cfg.group_commit = false;
        let (upto, _) = w.try_lead(2).unwrap();
        w.cfg.group_commit = true;
        assert_eq!(upto, 3);
        w.flush_done(upto);
        let last = w.durable_last_kind();
        assert_eq!(last.get(&1), Some(&WalKind::Delete));
        assert_eq!(last.get(&2), Some(&WalKind::Put), "record 3 is not durable");
        assert!(!w.is_durable(not_durable));
    }
}
