//! SSD-based KV store designs built on the simulator, mirroring the paper's
//! three modified systems (Fig 13):
//!
//! - [`treekv`] — Aerospike-like: in-memory search trees (sprigs) of 64-byte
//!   index entries on secondary memory; values on SSD; log-structured writes
//!   with a background defragmenter.
//! - [`lsmkv`] — RocksDB-like: LSM-tree on SSD with an in-memory sharded-LRU
//!   block cache on secondary memory; memtable in host DRAM; background
//!   flush/compaction.
//! - [`cachekv`] — CacheLib-like: two-tier cache; tier-1 chained hash items +
//!   LRU lists on secondary memory (bucket array in DRAM), tier-2 small-object
//!   cache on SSD.
//!
//! Each store holds *real* data structures: every simulated pointer
//! dereference corresponds to an actual traversal step over actual keys, so
//! the per-operation access count M varies operation-to-operation exactly the
//! way the paper's probabilistic model assumes. Reads verify data integrity
//! against a deterministic disk image.

pub mod cachekv;
pub mod common;
pub mod lsmkv;
pub mod treekv;

pub use cachekv::{CacheKv, CacheKvConfig};
pub use common::{fnv1a, KvStats};
pub use lsmkv::{LsmKv, LsmKvConfig};
pub use treekv::{TieringPolicy, TreeKv, TreeKvConfig};
