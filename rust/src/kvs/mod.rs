//! SSD-based KV store designs built on the simulator, mirroring the paper's
//! three modified systems (Fig 13):
//!
//! - [`treekv`] — Aerospike-like: in-memory search trees (sprigs) of 64-byte
//!   index entries on secondary memory; values on SSD; log-structured writes
//!   with a background defragmenter.
//! - [`lsmkv`] — RocksDB-like: LSM-tree on SSD with an in-memory sharded-LRU
//!   block cache on secondary memory; memtable in host DRAM; background
//!   flush/compaction.
//! - [`cachekv`] — CacheLib-like: two-tier cache; tier-1 chained hash items +
//!   LRU lists on secondary memory (bucket array in DRAM), tier-2 small-object
//!   cache on SSD.
//!
//! All three serve the **full operation surface** — point get/put plus
//! `Delete`, ordered `Scan`, and `ReadModifyWrite` — as state-machine ops
//! whose every pointer hop goes through the simulator's
//! `MemAccess(Tier)`/`Io` steps, so the measured per-op access count M and
//! IO count S stay physically meaningful for every operation kind:
//!
//! | op     | treekv                       | lsmkv                          | cachekv                   |
//! |--------|------------------------------|--------------------------------|---------------------------|
//! | delete | BST unlink under sprig lock  | memtable tombstone + purge     | two-tier invalidation     |
//! | scan   | sprig in-order walk + IOs    | merged memtable+sstable iter   | unsupported (no-op)       |
//! | rmw    | read path → write path       | read path → memtable write     | read → update-in-place    |
//!
//! Stores pick operations from [`crate::workload::OpWeights`] when
//! configured (the YCSB A–F presets in [`crate::workload::ycsb`]) and fall
//! back to the paper's two-kind read:write [`crate::workload::OpMix`].
//!
//! Tier selection is first-class: every `MemAccess` site consults a shared
//! [`placement::PlacementPolicy`] (all-secondary, all-DRAM, top levels, or
//! a DRAM byte budget over hotness-ranked structure classes), with
//! per-store accounting of the simulated DRAM bytes consumed — including
//! the **pinned** residual footprint (lsmkv's memtable, cachekv's bucket
//! directory and SOC index), which is DRAM by design under every policy.
//! Each access site also tags its structure class in a per-store
//! [`placement::AccessProfile`], so the planner can re-rank classes by
//! *measured* accesses per byte (`replan`) instead of the static hotness
//! prior — see [`placement`] for the split-hop Θ derivation, the measured
//! re-ranking rule, and per-store class lists. Offloadable classes can
//! additionally be held **compressed** in DRAM ([`placement::CompressMode`]):
//! fewer budget bytes at a per-access decompress CPU cost, chosen jointly
//! by the planner's two-variant knapsack and charged inline at every
//! compressed `MemAccess` site.
//!
//! Each store holds *real* data structures: every simulated pointer
//! dereference corresponds to an actual traversal step over actual keys, so
//! the per-operation access count M varies operation-to-operation exactly the
//! way the paper's probabilistic model assumes. Reads verify data integrity
//! against a deterministic disk image.

pub mod cachekv;
pub mod common;
pub mod lsmkv;
pub mod placement;
pub mod treekv;
pub mod wal;

pub use cachekv::{CacheKv, CacheKvConfig};
pub use common::{drive_op, drive_op_tiers, fnv1a, DriveCounts, KvStats};
pub use lsmkv::{LsmKv, LsmKvConfig};
pub use placement::{
    should_replan, AccessProfile, ClassState, CompressMode, Compression, HopSplit, Plan,
    PlacementPolicy, StructClass,
};
pub use treekv::{TreeKv, TreeKvConfig, SCAN_IO_BATCH};
pub use wal::{Durable, Wal, WalConfig, WalKind, WalRecord, WalStats};

use crate::model::KindCost;
use crate::workload::{OpKind, OpWeights};

/// Per-operation-kind model-parameter snapshots (the Θ_scan extension's
/// store-side half): each store derives a [`KindCost`] vector for every
/// operation kind from its **actual geometry** — sprig depth, chain
/// lengths, block fanout, measured hit ratios — so the coordinator can run
/// predicted-vs-simulated columns without hand-tuned per-store constants.
///
/// Snapshots are read-only and deterministic given the store's current
/// structural state. Hit-ratio-dependent kinds (lsmkv/cachekv reads) prefer
/// the store's measured counters when a run has populated them — the
/// paper's methodology for measured system parameters like ε — and fall
/// back to documented structural estimates on a cold store.
pub trait ModelCosts {
    fn model_params(&self, kind: OpKind) -> KindCost;
}

/// The `(fraction, KindCost)` mix for an [`OpWeights`] workload over a
/// store's snapshots — the input to `model::theta_mix_recip`. Kinds with
/// zero mass are omitted (an all-zero mix yields an empty vector, which the
/// combinator defines as zero work).
///
/// Each `model_params` call re-probes the store's structure (a few
/// thousand pointer hops, microseconds) — deliberately not cached across
/// kinds: every caller snapshots right after a multi-millisecond simulator
/// run, where a probe-once bulk API would complicate the trait for no
/// measurable win.
pub fn model_mix<S: ModelCosts + ?Sized>(store: &S, w: &OpWeights) -> Vec<(f64, KindCost)> {
    OpKind::ALL
        .iter()
        .filter_map(|&k| {
            let f = w.fraction(k);
            (f > 0.0).then(|| (f, store.model_params(k)))
        })
        .collect()
}
