//! First-class tier placement: which in-memory structures live in host
//! DRAM and which are offloaded to microsecond-latency (secondary) memory.
//!
//! The paper's premise (§5.2.3) is that *most* — not all — of a store's
//! indices and caches can move to slow memory while a small DRAM residue
//! (top index levels, hot directories, filter blocks) preserves throughput.
//! The seed reproduction hardcoded `Tier::Secondary` at every `MemAccess`
//! site, so it could express only the two endpoints of that trade. This
//! module extracts tier selection into one policy that every store consults
//! at each pointer-chase site, with per-store accounting of the simulated
//! DRAM bytes the policy consumes.
//!
//! ## Structure classes
//!
//! Each store describes its offloadable structures as a list of
//! [`StructClass`]es ranked hottest-first (expected secondary accesses
//! absorbed per operation, per byte):
//!
//! - **treekv**: one class per sprig-forest level (the top levels are on
//!   every descent path, so they absorb a disproportionate access share per
//!   byte; the value-log block pointers ride inside the 64-byte entries).
//! - **lsmkv**: block-cache handles (hash chains + LRU links + bucket
//!   heads) ≫ block restart arrays ≫ cached data-block bytes. The memtable
//!   is host-DRAM by design — a **pinned** class (below).
//! - **cachekv**: tier-1 hash chains (AccessContainer) ≻ tier-1 LRU lists
//!   (MMContainer). The bucket directory and the tier-2 SOC index are
//!   pinned classes.
//!
//! **Pinned classes** are the paper's residual DRAM footprint: structures
//! that stay in host DRAM *by design* under every policy (lsmkv's
//! memtable, cachekv's bucket directory and SOC index). They are outside
//! the policy's placement decision — never offloaded, never consuming the
//! `Budget` knob — but [`Plan::dram_bytes`] and [`Plan::total_bytes`]
//! include them, so the DRAM-byte columns the experiments report are the
//! bytes a configuration *really* consumes. (Before this accounting fix,
//! `AllDram` and `Budget` sweeps silently understated their footprint by
//! the residual; [`Plan::policy_dram_bytes`] still reports the
//! policy-consumed bytes alone for budget-cap checks.)
//!
//! A [`Plan`] resolves a [`PlacementPolicy`] over the offloadable classes
//! by taking the longest hottest-first **prefix** that the policy admits:
//! placement is all-or-nothing per class, and a colder class is never
//! DRAM-resident while a hotter one is offloaded (for a tree this is
//! exactly the "every descent passes the top levels" argument; a DRAM
//! level below a secondary level buys nothing). Prefix resolution makes
//! the reported DRAM bytes trivially monotone in the budget knob.
//!
//! ## Measured re-ranking: the access-frequency planner
//!
//! The static hotness ranking is a *prior*, and the prior is wrong exactly
//! where the workload mix matters most: under a scan-heavy mix the lsmkv
//! restart arrays are never touched (scans walk chains and block bytes;
//! only point reads binary-search the restarts), and under a write-heavy
//! mix the cachekv LRU lists — four eviction-candidate hops behind every
//! insert, a splice behind every update — out-access the hash chains.
//!
//! Every store therefore tags each `MemAccess` site with its class id (it
//! already knows the class to consult the plan) and accumulates an
//! [`AccessProfile`]: measured accesses per class. [`Plan::replan`]
//! re-ranks the offloadable classes by **measured accesses per byte**
//!
//! ```text
//! rank(c) = profile.accesses(c) / bytes(c)    (descending,
//!                                              ties → static order)
//! ```
//!
//! and resolves `Budget`/`TopLevels` over that order instead of the static
//! one. The ranking is the classic density heuristic for the placement
//! knapsack: with all-or-nothing classes and additive DRAM benefit per
//! absorbed access, packing by accesses-per-byte maximizes the absorbed
//! access share within the byte budget (exactly optimal when the chosen
//! prefix fills the budget; the class-granular remainder is the usual
//! knapsack rounding). An empty profile falls back to the static ranking,
//! so replanning is always defined; given the same profile the re-rank is
//! deterministic (stable sort, static-order tie-break). The coordinator's
//! `run_store_ycsb_profiled` drives the two-phase profile → replan →
//! measure path, and `cxlkvs run planner` gates measured-vs-static
//! placement at equal DRAM budget.
//!
//! ## Online replanning: decay, hysteresis, migration cost
//!
//! A two-phase offline plan goes stale the moment the access distribution
//! turns (hotspot shift, diurnal read↔write swing). The online planner in
//! `run_store_ycsb_adaptive` closes the loop with three mechanisms, each
//! with a knob whose derivation lives here:
//!
//! **Epoch-bucketed EWMA decay** ([`AccessProfile::decay`]). At every
//! simulated-time epoch boundary (never wall clock — determinism), each
//! class count is scaled by a rational retain factor `num/den` in integer
//! arithmetic: `c ← ⌊c · num / den⌋` through `u128`, so identical seeds
//! and epochs reproduce identical profiles bit-for-bit. After a workload
//! turn, the share of the profile still describing the *old* phase decays
//! as `(num/den)^k` over `k` epochs; with the default `1/2` the stale half
//! falls below 10% within 4 epochs and below 1% within 7 — the adaptation
//! horizon is `log(ε)/log(num/den)` epochs for staleness tolerance `ε`.
//! Larger retain fractions average over longer windows (smoother, slower);
//! `num = 0` forgets everything each epoch (memoryless, noisy).
//!
//! **Hysteresis** ([`should_replan`]). The replan trigger compares what the
//! *current* plan and a *candidate* replan would absorb into DRAM under the
//! decayed profile ([`Plan::absorbed`]: the profile mass of the placed
//! prefix). Replanning fires only when
//!
//! ```text
//! absorbed(candidate) > absorbed(current) · (1 + margin)
//! ```
//!
//! i.e. the measured density ordering must shift enough that the candidate
//! beats the incumbent by more than `margin` (relative). A ranking
//! perturbation from sampling noise flips neighboring classes of nearly
//! equal density, which changes `absorbed` by at most their density gap —
//! below any reasonable margin — while a genuine phase change moves whole
//! access mass between classes and clears it. `margin = 0` replans on any
//! measured gain (the thrash configuration the adaptive tests use);
//! `margin = ∞` never replans (the static arm, bit-identical by
//! construction — see `tests/adaptive.rs`).
//!
//! **Honest migration cost**. A replan that re-tiers entries is not free:
//! every migrated line costs a read from its old tier plus a write to its
//! new tier, and cache contents that move across the SSD shard route cost
//! their value IO. Each store's `replan_migrate` returns the migration
//! traffic as a `DriveCounts` (dram + secondary line touches, SSD reads),
//! and the machine's `charge_migration` turns it into simulated time on
//! the device servers — so a thrashing planner loses measured throughput
//! instead of teleporting structures between tiers for free.
//!
//! ## The split-hop Θ (Eq 14 with DRAM-resident hops)
//!
//! Eq 14 prices a whole operation as `S` split units of `M/S` dependent
//! secondary accesses each (prefetch, `T_sw` yield, reschedule) plus one
//! IO, floored by the device ceilings. A placement policy moves some hops
//! to DRAM, where a dependent access is an *inline* load: no prefetch
//! enqueue, no context switch, no window term — just `T_mem + L_DRAM` of
//! core-busy time. Splitting the hop count `M = M_sec + M_dram` therefore
//! yields
//!
//! ```text
//! Θ_k⁻¹(L) = max( S·Θ_rev⁻¹(M_sec/S, …; L),  S·A_IO/(n_ssd·B_IO),
//!                 S/(n_ssd·R_IO) )
//!            + M_dram·(T_mem + L_DRAM)  +  T_fixed,k
//! ```
//!
//! i.e. only `M_sec` participates in the per-IO split and its prefetch
//! window; `M_dram` is additive CPU time like `T_fixed` (it can never be
//! hidden behind the prefetch queue, and it never pays `T_sw` or the
//! queue-depth wall). `model::KindCost` carries both counts (`m` = M_sec,
//! `m_dram`), each store's `ModelCosts::model_params` derives them from the
//! live (possibly replanned) policy, and `theta_kind_recip`/CPR compose
//! unchanged. The `S = 0` branch degenerates the same way: `M_sec` at the
//! memory-only Eq 3 rate plus the inline `M_dram` term.
//!
//! `cxlkvs run placement` sweeps the DRAM budget × L_mem × store and
//! validates this split against the simulator within the documented
//! `modelcheck` tolerance bands; `cxlkvs run planner` does the same for
//! replanned placements.

use crate::sim::Tier;

/// How a store's offloadable structures are split between host DRAM and
/// secondary memory. The policy is mechanism-agnostic: stores with
/// entry-granular placement (treekv's per-node `in_dram` bit) honor
/// [`PlacementPolicy::Random`] per entry; class-granular stores resolve
/// every variant through [`Plan::resolve`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PlacementPolicy {
    /// Everything offloaded (the paper's base case, ρ = 1). Bit-identical
    /// to the pre-placement behavior of every store — the determinism
    /// guard in `tests/prop_placement.rs` and the YCSB goldens pin it.
    #[default]
    AllSecondary,
    /// Everything in host DRAM (the paper's baseline system).
    AllDram,
    /// The hottest `k` classes (for treekv: the top `k` levels of every
    /// sprig) stay in DRAM — the access-aware placement of §5.2.3.
    TopLevels { k: u32 },
    /// Hotness-ranked placement within a simulated DRAM byte budget: the
    /// longest hottest-first class prefix whose bytes fit. Pinned classes
    /// are outside the budget (they are DRAM regardless).
    Budget { dram_bytes: u64 },
    /// A uniformly random fraction of entries stays in DRAM (what Eq 15's
    /// ρ-interpolation assumes). Entry-granular where the store supports
    /// it (treekv); class-granular stores approximate it as
    /// `Budget { dram_frac · offloadable_bytes }`.
    Random { dram_frac: f64 },
}

/// One structure class: a contiguous placement unit with a simulated byte
/// footprint. Offloadable classes are supplied hottest-first ([`Plan`]
/// places prefixes only); pinned classes are DRAM-resident under every
/// policy (the residual footprint).
#[derive(Debug, Clone)]
pub struct StructClass {
    pub name: &'static str,
    /// Simulated bytes this class occupies if DRAM-resident.
    pub bytes: u64,
    /// Expected secondary accesses per operation this class absorbs when
    /// DRAM-placed (documentation/reporting; static resolution is
    /// rank-based, measured resolution uses the [`AccessProfile`]).
    pub hotness: f64,
    /// DRAM-resident by design, outside the placement policy (lsmkv's
    /// memtable, cachekv's bucket directory / SOC index). Pinned bytes
    /// count toward [`Plan::dram_bytes`] but never consume the budget.
    pub pinned: bool,
}

impl StructClass {
    /// An offloadable class (the policy decides its tier).
    pub fn new(name: &'static str, bytes: u64, hotness: f64) -> StructClass {
        StructClass {
            name,
            bytes,
            hotness,
            pinned: false,
        }
    }

    /// A pinned class: host-DRAM by design, reported but never offloaded.
    pub fn pinned(name: &'static str, bytes: u64) -> StructClass {
        StructClass {
            name,
            bytes,
            hotness: 0.0,
            pinned: true,
        }
    }
}

/// Measured per-class access counts, accumulated by a store at its
/// `MemAccess` sites (one tick per simulated access, pinned classes
/// included). The store-side half of the measured planner: feed it to
/// [`Plan::replan`] to re-rank the offloadable classes by observed
/// accesses per byte. Counting is pure bookkeeping — it never touches the
/// simulation's RNG or timing, so profiled runs stay bit-identical to
/// unprofiled ones.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AccessProfile {
    counts: Vec<u64>,
}

impl AccessProfile {
    pub fn new(n_classes: usize) -> AccessProfile {
        AccessProfile {
            counts: vec![0; n_classes],
        }
    }

    /// Record one access to `class` (auto-grows for stores whose class
    /// count is data-dependent, e.g. tree levels).
    #[inline]
    pub fn tick(&mut self, class: usize) {
        if class >= self.counts.len() {
            self.counts.resize(class + 1, 0);
        }
        self.counts[class] += 1;
    }

    /// Measured accesses of one class (0 for classes never seen).
    pub fn accesses(&self, class: usize) -> u64 {
        self.counts.get(class).copied().unwrap_or(0)
    }

    /// Total accesses across all classes.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// No accesses recorded — [`Plan::replan`] falls back to the static
    /// ranking.
    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    /// One epoch of EWMA decay: scale every class count by the rational
    /// retain factor `retain_num / retain_den` (module docs, "Online
    /// replanning"). Integer arithmetic through `u128` — no float
    /// rounding, so decayed profiles are bit-identical across runs with
    /// the same epoch schedule. Called at simulated-time epoch boundaries
    /// only, never from wall clock.
    ///
    /// Panics if `retain_den == 0` or `retain_num > retain_den` (the
    /// retain factor must be a fraction in `[0, 1]`).
    pub fn decay(&mut self, retain_num: u32, retain_den: u32) {
        assert!(
            retain_den > 0 && retain_num <= retain_den,
            "retain factor must be a fraction in [0, 1]: {retain_num}/{retain_den}"
        );
        if retain_num == retain_den {
            return;
        }
        for c in self.counts.iter_mut() {
            *c = (*c as u128 * retain_num as u128 / retain_den as u128) as u64;
        }
    }

    /// Merge another profile's counts into this one (the offline arm's
    /// whole-schedule aggregate profile).
    pub fn merge(&mut self, other: &AccessProfile) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }
}

/// The online planner's replan trigger (module docs, "Online replanning"):
/// replace `current` with `candidate` only when the candidate's absorbed
/// access mass under `profile` beats the incumbent's by more than the
/// relative `margin`.
///
/// `margin = 0.0` replans on any measured gain (thrash configuration);
/// `margin = f64::INFINITY` never replans (`x > y·∞` is false for every
/// finite `y > 0`, and `x > NaN` is false when `y == 0`), which makes the
/// adaptive loop bit-identical to a static run.
pub fn should_replan(
    current: &Plan,
    candidate: &Plan,
    profile: &AccessProfile,
    margin: f64,
) -> bool {
    let cur = current.absorbed(profile) as f64;
    let cand = candidate.absorbed(profile) as f64;
    cand > cur * (1.0 + margin)
}

/// A resolved placement: which classes are DRAM-resident under a policy,
/// over either the static hottest-first ranking ([`Plan::resolve`]) or a
/// measured accesses-per-byte re-ranking ([`Plan::replan`]).
#[derive(Debug, Clone)]
pub struct Plan {
    pub policy: PlacementPolicy,
    classes: Vec<StructClass>,
    /// Offloadable class ids, hottest-first (static order, or the measured
    /// re-rank). Pinned classes never appear here.
    order: Vec<usize>,
    /// Number of leading `order` entries resident in DRAM.
    dram_prefix: usize,
    /// Per-class DRAM residency (pinned, or inside the placed prefix).
    dram: Vec<bool>,
}

impl Plan {
    /// Resolve `policy` over `classes` in their static hottest-first order.
    /// See the module docs for the prefix rule; pinned classes are DRAM
    /// under every policy and never consume the budget.
    pub fn resolve(policy: PlacementPolicy, classes: Vec<StructClass>) -> Plan {
        let order: Vec<usize> = (0..classes.len()).filter(|&i| !classes[i].pinned).collect();
        Plan::resolve_order(policy, classes, order)
    }

    /// Resolve `policy` over `classes` re-ranked by **measured** accesses
    /// per byte (module docs, "Measured re-ranking"). An empty profile
    /// falls back to [`Plan::resolve`]; ties keep the static order, so the
    /// result is deterministic given the same profile.
    pub fn replan(
        policy: PlacementPolicy,
        classes: Vec<StructClass>,
        profile: &AccessProfile,
    ) -> Plan {
        if profile.is_empty() {
            return Plan::resolve(policy, classes);
        }
        let mut order: Vec<usize> = (0..classes.len()).filter(|&i| !classes[i].pinned).collect();
        let density = |i: usize| -> f64 {
            let b = classes[i].bytes;
            if b == 0 {
                // A zero-byte class is free to place: rank an *accessed*
                // one first (infinite density), an untouched one last.
                if profile.accesses(i) > 0 {
                    f64::INFINITY
                } else {
                    0.0
                }
            } else {
                profile.accesses(i) as f64 / b as f64
            }
        };
        order.sort_by(|&a, &b| {
            density(b)
                .partial_cmp(&density(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        Plan::resolve_order(policy, classes, order)
    }

    /// Shared resolution over an explicit offloadable ranking.
    fn resolve_order(
        policy: PlacementPolicy,
        classes: Vec<StructClass>,
        order: Vec<usize>,
    ) -> Plan {
        let offloadable: u64 = order.iter().map(|&i| classes[i].bytes).sum();
        let dram_prefix = match policy {
            PlacementPolicy::AllSecondary => 0,
            PlacementPolicy::AllDram => order.len(),
            PlacementPolicy::TopLevels { k } => (k as usize).min(order.len()),
            PlacementPolicy::Budget { dram_bytes } => prefix_within(&classes, &order, dram_bytes),
            PlacementPolicy::Random { dram_frac } => {
                let budget = (dram_frac.clamp(0.0, 1.0) * offloadable as f64).round() as u64;
                prefix_within(&classes, &order, budget)
            }
        };
        let mut dram: Vec<bool> = classes.iter().map(|c| c.pinned).collect();
        for &i in &order[..dram_prefix] {
            dram[i] = true;
        }
        Plan {
            policy,
            classes,
            order,
            dram_prefix,
            dram,
        }
    }

    /// Tier of one class's accesses. Out-of-range ids (e.g. tree levels
    /// deeper than the class list) are always secondary.
    #[inline]
    pub fn tier(&self, class: usize) -> Tier {
        if self.in_dram(class) {
            Tier::Dram
        } else {
            Tier::Secondary
        }
    }

    /// Whether one class is DRAM-resident (pinned or placed).
    #[inline]
    pub fn in_dram(&self, class: usize) -> bool {
        self.dram.get(class).copied().unwrap_or(false)
    }

    /// Number of leading (hottest-ranked) offloadable classes resident in
    /// DRAM.
    pub fn dram_classes(&self) -> usize {
        self.dram_prefix
    }

    /// The offloadable ranking this plan resolved over: class ids
    /// hottest-first — the static order from [`Plan::resolve`], the
    /// measured accesses-per-byte order from [`Plan::replan`].
    pub fn ranking(&self) -> &[usize] {
        &self.order
    }

    /// Profile access mass this plan's DRAM-placed offloadable prefix
    /// absorbs — the objective the density ranking maximizes, and the
    /// quantity [`should_replan`]'s hysteresis compares between the
    /// incumbent plan and a candidate replan. Pinned classes are DRAM
    /// under every plan, so they cancel in any comparison and are left
    /// out.
    pub fn absorbed(&self, profile: &AccessProfile) -> u64 {
        self.order[..self.dram_prefix]
            .iter()
            .map(|&i| profile.accesses(i))
            .sum()
    }

    /// Share of all *measured* offloadable accesses the DRAM-placed prefix
    /// absorbs, in `[0, 1]` (0.0 on an empty profile).
    ///
    /// ## Multi-tenant budget splitting
    ///
    /// Under `workload::tenants` the profile is accumulated by **every**
    /// tenant's ops against the *shared* structure classes, so a replan
    /// over it splits the one shared `Budget` across tenants implicitly:
    /// classes hot for high-traffic tenants out-rank classes only a light
    /// tenant touches, and the absorbed fraction reports how much of the
    /// *combined* multi-tenant access stream the split serves from DRAM.
    /// There is no per-tenant quota — isolation is scheduled (SWRR
    /// issuance shares), while placement optimizes aggregate absorbed
    /// accesses per DRAM byte exactly as in the single-tenant case. The
    /// `tenants` experiment reports this fraction per cell so the CSV
    /// shows what the shared budget bought under contention.
    pub fn absorbed_fraction(&self, profile: &AccessProfile) -> f64 {
        let total = profile.total();
        if total == 0 {
            return 0.0;
        }
        self.absorbed(profile) as f64 / total as f64
    }

    /// Split per-class expected access counts into `(m_sec, m_dram)`:
    /// DRAM-resident classes' hops move to the inline side of the
    /// split-hop Θ (module docs). The shared bucketing for every store's
    /// `ModelCosts` snapshot.
    pub fn split_hops(&self, per_class: &[(usize, f64)]) -> (f64, f64) {
        let (mut sec, mut dram) = (0.0, 0.0);
        for &(class, m) in per_class {
            if self.in_dram(class) {
                dram += m;
            } else {
                sec += m;
            }
        }
        (sec, dram)
    }

    /// Simulated DRAM bytes this placement consumes — the **honest** total:
    /// policy-placed offloadable classes *plus* the pinned residual
    /// footprint (`AllSecondary` on a store with pinned classes is nonzero
    /// by design).
    pub fn dram_bytes(&self) -> u64 {
        self.classes
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.dram[i])
            .map(|(_, c)| c.bytes)
            .sum()
    }

    /// DRAM bytes consumed by the *policy* alone (placed offloadable
    /// classes, excluding the pinned residual) — the quantity capped by
    /// `Budget { dram_bytes }`.
    pub fn policy_dram_bytes(&self) -> u64 {
        self.order[..self.dram_prefix]
            .iter()
            .map(|&i| self.classes[i].bytes)
            .sum()
    }

    /// The pinned residual footprint (DRAM under every policy).
    pub fn pinned_bytes(&self) -> u64 {
        self.classes.iter().filter(|c| c.pinned).map(|c| c.bytes).sum()
    }

    /// Total bytes of every class, pinned included (the honest `AllDram`
    /// footprint).
    pub fn total_bytes(&self) -> u64 {
        self.classes.iter().map(|c| c.bytes).sum()
    }

    /// Offloadable bytes alone — the denominator for budget fractions
    /// (`Budget { frac · offloadable_bytes }` spans all-secondary to
    /// all-DRAM for the policy-managed classes).
    pub fn offloadable_bytes(&self) -> u64 {
        self.order.iter().map(|&i| self.classes[i].bytes).sum()
    }

    /// DRAM share of the total footprint, by bytes.
    pub fn dram_fraction(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            return 0.0;
        }
        self.dram_bytes() as f64 / total as f64
    }

    pub fn classes(&self) -> &[StructClass] {
        &self.classes
    }
}

/// Longest prefix of `order` whose cumulative bytes fit `budget`.
fn prefix_within(classes: &[StructClass], order: &[usize], budget: u64) -> usize {
    let mut used = 0u64;
    for (pos, &i) in order.iter().enumerate() {
        used = used.saturating_add(classes[i].bytes);
        if used > budget {
            return pos;
        }
    }
    order.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<StructClass> {
        vec![
            StructClass::new("hot", 100, 4.0),
            StructClass::new("warm", 1_000, 1.0),
            StructClass::new("cold", 10_000, 0.5),
        ]
    }

    #[test]
    fn endpoints() {
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.dram_bytes(), 0);
        assert_eq!(none.tier(0), Tier::Secondary);
        let all = Plan::resolve(PlacementPolicy::AllDram, classes());
        assert_eq!(all.dram_bytes(), 11_100);
        assert_eq!(all.dram_fraction(), 1.0);
        assert_eq!(all.tier(2), Tier::Dram);
        // Out-of-range classes are always secondary, even under AllDram
        // (they model structures deeper than the class list, e.g. tree
        // levels created by later upserts — treekv places those per-entry).
        assert_eq!(all.tier(99), Tier::Secondary);
    }

    #[test]
    fn top_levels_takes_a_prefix() {
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert!(p.in_dram(0) && p.in_dram(1) && !p.in_dram(2));
        assert_eq!(p.dram_bytes(), 1_100);
        // k beyond the class list saturates.
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 64 }, classes());
        assert_eq!(p.dram_classes(), 3);
    }

    #[test]
    fn budget_places_longest_fitting_prefix() {
        let cases = [
            (0u64, 0usize),
            (99, 0),
            (100, 1),
            (1_099, 1),
            (1_100, 2),
            (11_099, 2),
            (11_100, 3),
            (u64::MAX, 3),
        ];
        for (budget, want) in cases {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, classes());
            assert_eq!(p.dram_classes(), want, "budget {budget}");
        }
    }

    #[test]
    fn absorbed_fraction_tracks_placed_prefix() {
        let mut profile = AccessProfile::new(3);
        for _ in 0..80 {
            profile.tick(0);
        }
        for _ in 0..15 {
            profile.tick(1);
        }
        for _ in 0..5 {
            profile.tick(2);
        }
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.absorbed_fraction(&profile), 0.0);
        let top2 = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert!((top2.absorbed_fraction(&profile) - 0.95).abs() < 1e-12);
        let all = Plan::resolve(PlacementPolicy::AllDram, classes());
        assert_eq!(all.absorbed_fraction(&profile), 1.0);
        // Empty profile → 0.0, not NaN.
        assert_eq!(all.absorbed_fraction(&AccessProfile::new(3)), 0.0);
    }

    #[test]
    fn dram_bytes_monotone_in_budget() {
        let mut prev = 0u64;
        for budget in (0..=12_000u64).step_by(37) {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, classes());
            let b = p.dram_bytes();
            assert!(b <= budget, "placement overshot the budget: {b} > {budget}");
            assert!(b >= prev, "dram bytes fell as budget grew: {prev} -> {b}");
            prev = b;
        }
    }

    #[test]
    fn random_is_a_byte_fraction_budget_for_class_plans() {
        let half = Plan::resolve(PlacementPolicy::Random { dram_frac: 0.5 }, classes());
        // 50% of 11,100 = 5,550: hot + warm fit, cold does not.
        assert_eq!(half.dram_classes(), 2);
        let none = Plan::resolve(PlacementPolicy::Random { dram_frac: 0.0 }, classes());
        assert_eq!(none.dram_classes(), 0);
        let all = Plan::resolve(PlacementPolicy::Random { dram_frac: 1.0 }, classes());
        assert_eq!(all.dram_classes(), 3);
    }

    #[test]
    fn empty_class_list_is_degenerate_but_sane() {
        let p = Plan::resolve(PlacementPolicy::AllDram, Vec::new());
        assert_eq!(p.dram_bytes(), 0);
        assert_eq!(p.dram_fraction(), 0.0);
        assert_eq!(p.tier(0), Tier::Secondary);
    }

    // ---- pinned classes (honest residual accounting) ----------------------

    fn with_pinned() -> Vec<StructClass> {
        let mut cs = classes();
        cs.push(StructClass::pinned("residual", 500));
        cs
    }

    #[test]
    fn pinned_classes_are_dram_under_every_policy_but_never_budgeted() {
        let none = Plan::resolve(PlacementPolicy::AllSecondary, with_pinned());
        assert_eq!(none.tier(3), Tier::Dram, "pinned is DRAM even at rho=1");
        assert_eq!(none.dram_bytes(), 500, "honest: residual reported");
        assert_eq!(none.policy_dram_bytes(), 0, "policy consumed nothing");
        assert_eq!(none.pinned_bytes(), 500);
        assert_eq!(none.offloadable_bytes(), 11_100);
        assert_eq!(none.total_bytes(), 11_600);
        // A budget of exactly the hot class places it — pinned bytes do not
        // consume the budget.
        let b = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 100 }, with_pinned());
        assert!(b.in_dram(0) && b.in_dram(3) && !b.in_dram(1));
        assert_eq!(b.policy_dram_bytes(), 100);
        assert_eq!(b.dram_bytes(), 600);
        // AllDram covers everything; Random{1.0} covers all offloadable.
        let all = Plan::resolve(PlacementPolicy::AllDram, with_pinned());
        assert_eq!(all.dram_bytes(), 11_600);
        let r = Plan::resolve(PlacementPolicy::Random { dram_frac: 1.0 }, with_pinned());
        assert_eq!(r.dram_classes(), 3);
        // The pinned class never appears in the offloadable ranking.
        assert!(!none.ranking().contains(&3));
    }

    // ---- measured re-ranking (Plan::replan) -------------------------------

    #[test]
    fn replan_reorders_by_measured_accesses_per_byte() {
        // Static order: hot(100B) ≻ warm(1kB) ≻ cold(10kB). Measured
        // densities: hot 10/100B = 0.1, cold 200/10kB = 0.02,
        // warm 1/1kB = 0.001 — the workload hammers "cold" past "warm".
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        prof.tick(1);
        for _ in 0..200 {
            prof.tick(2);
        }
        let p = Plan::replan(PlacementPolicy::AllSecondary, classes(), &prof);
        assert_eq!(p.ranking(), &[0, 2, 1], "measured density order");
        // Budget resolution follows the measured order: 10,100 B fits
        // hot + cold (10,100) exactly, leaving warm offloaded — the static
        // order would have placed hot + warm instead.
        let p = Plan::replan(
            PlacementPolicy::Budget { dram_bytes: 10_100 },
            classes(),
            &prof,
        );
        assert!(p.in_dram(0) && p.in_dram(2) && !p.in_dram(1));
        assert_eq!(p.policy_dram_bytes(), 10_100);
        let s = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 10_100 }, classes());
        assert!(s.in_dram(0) && s.in_dram(1) && !s.in_dram(2));
    }

    #[test]
    fn replan_is_deterministic_and_falls_back_to_static() {
        let mut prof = AccessProfile::new(3);
        prof.tick(2);
        prof.tick(2);
        prof.tick(0);
        let a = Plan::replan(PlacementPolicy::TopLevels { k: 1 }, classes(), &prof);
        let b = Plan::replan(PlacementPolicy::TopLevels { k: 1 }, classes(), &prof);
        assert_eq!(a.ranking(), b.ranking(), "same profile, same plan");
        assert_eq!(a.dram_bytes(), b.dram_bytes());
        // Empty profile → the static ranking, bit-for-bit.
        let empty = AccessProfile::new(3);
        let f = Plan::replan(PlacementPolicy::TopLevels { k: 1 }, classes(), &empty);
        let s = Plan::resolve(PlacementPolicy::TopLevels { k: 1 }, classes());
        assert_eq!(f.ranking(), s.ranking());
        assert_eq!(f.dram_bytes(), s.dram_bytes());
        // Ties (identical density) keep the static order: a uniform profile
        // over equal-density classes reproduces the static ranking.
        let eq = vec![
            StructClass::new("a", 100, 1.0),
            StructClass::new("b", 100, 1.0),
        ];
        let mut uni = AccessProfile::new(2);
        uni.tick(0);
        uni.tick(1);
        let t = Plan::replan(PlacementPolicy::AllSecondary, eq, &uni);
        assert_eq!(t.ranking(), &[0, 1]);
    }

    #[test]
    fn zero_byte_accessed_class_ranks_first() {
        // A degenerate zero-byte class is free to keep in DRAM: if the
        // workload touches it, the measured ranking must place it first
        // (infinite density), never last as a naive 0.0 density would.
        let cs = vec![
            StructClass::new("a", 100, 1.0),
            StructClass::new("free", 0, 1.0),
        ];
        let mut prof = AccessProfile::new(2);
        prof.tick(0);
        prof.tick(1);
        let p = Plan::replan(PlacementPolicy::Budget { dram_bytes: 0 }, cs, &prof);
        assert_eq!(p.ranking(), &[1, 0]);
        assert!(p.in_dram(1), "a free accessed class always fits the budget");
        assert!(!p.in_dram(0));
    }

    // ---- online replanning: decay + hysteresis -----------------------------

    #[test]
    fn decay_is_deterministic_integer_ewma() {
        let mut a = AccessProfile::new(3);
        for _ in 0..1_001 {
            a.tick(0);
        }
        for _ in 0..7 {
            a.tick(2);
        }
        let mut b = a.clone();
        a.decay(1, 2);
        b.decay(1, 2);
        assert_eq!(a, b, "same profile, same decay, bit-identical");
        assert_eq!(a.accesses(0), 500, "floor(1001/2)");
        assert_eq!(a.accesses(2), 3, "floor(7/2)");
        // Retain 1/1 is the identity; retain 0/1 forgets everything.
        let before = a.clone();
        a.decay(1, 1);
        assert_eq!(a, before);
        a.decay(0, 1);
        assert!(a.is_empty());
        // No u64 overflow on huge counts (u128 intermediate): double a
        // single tick up to 2^63 via merge, then decay by 3/4.
        let mut q = AccessProfile::new(1);
        q.tick(0);
        for _ in 0..63 {
            let clone = q.clone();
            q.merge(&clone);
        }
        assert_eq!(q.accesses(0), 1u64 << 63);
        q.decay(3, 4);
        assert_eq!(q.accesses(0), ((1u128 << 63) * 3 / 4) as u64);
    }

    #[test]
    #[should_panic(expected = "retain factor")]
    fn decay_rejects_improper_fraction() {
        AccessProfile::new(1).decay(3, 2);
    }

    #[test]
    fn merge_adds_and_grows() {
        let mut a = AccessProfile::new(1);
        a.tick(0);
        let mut b = AccessProfile::new(3);
        b.tick(0);
        b.tick(2);
        a.merge(&b);
        assert_eq!(a.accesses(0), 2);
        assert_eq!(a.accesses(2), 1);
        assert_eq!(a.total(), 3);
    }

    #[test]
    fn absorbed_sums_the_placed_prefix() {
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        for _ in 0..5 {
            prof.tick(1);
        }
        for _ in 0..200 {
            prof.tick(2);
        }
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.absorbed(&prof), 0);
        let top2 = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert_eq!(top2.absorbed(&prof), 15, "hot + warm");
        let re = Plan::replan(PlacementPolicy::TopLevels { k: 2 }, classes(), &prof);
        assert_eq!(re.absorbed(&prof), 210, "hot + cold after the re-rank");
        // Pinned classes never count: they cancel in any comparison.
        let pinned = Plan::resolve(PlacementPolicy::AllSecondary, with_pinned());
        assert_eq!(pinned.absorbed(&prof), 0);
    }

    #[test]
    fn hysteresis_margins_bracket_the_trigger() {
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        for _ in 0..200 {
            prof.tick(2);
        }
        let current = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        let candidate = Plan::replan(PlacementPolicy::TopLevels { k: 2 }, classes(), &prof);
        // Gain 210 vs 10: fires at margin 0 and at any margin below 20x,
        // not above it.
        assert!(should_replan(&current, &candidate, &prof, 0.0));
        assert!(should_replan(&current, &candidate, &prof, 0.10));
        assert!(!should_replan(&current, &candidate, &prof, 25.0));
        // margin = ∞ never fires — even from an absorbed-nothing incumbent
        // (0 · ∞ = NaN, and `x > NaN` is false).
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert!(!should_replan(&none, &candidate, &prof, f64::INFINITY));
        assert!(!should_replan(&current, &candidate, &prof, f64::INFINITY));
        // No gain → no replan at any margin (margin 0 requires *strict*
        // improvement, so identical plans never thrash).
        assert!(!should_replan(&candidate, &candidate, &prof, 0.0));
        assert!(!should_replan(&candidate, &current, &prof, 0.0));
    }

    #[test]
    fn profile_bookkeeping() {
        let mut p = AccessProfile::new(2);
        assert!(p.is_empty());
        p.tick(0);
        p.tick(5); // auto-grow
        assert_eq!(p.accesses(0), 1);
        assert_eq!(p.accesses(5), 1);
        assert_eq!(p.accesses(3), 0);
        assert_eq!(p.total(), 2);
        assert!(!p.is_empty());
        p.clear();
        assert!(p.is_empty());
    }
}
