//! First-class tier placement: which in-memory structures live in host
//! DRAM and which are offloaded to microsecond-latency (secondary) memory.
//!
//! The paper's premise (§5.2.3) is that *most* — not all — of a store's
//! indices and caches can move to slow memory while a small DRAM residue
//! (top index levels, hot directories, filter blocks) preserves throughput.
//! The seed reproduction hardcoded `Tier::Secondary` at every `MemAccess`
//! site, so it could express only the two endpoints of that trade. This
//! module extracts tier selection into one policy that every store consults
//! at each pointer-chase site, with per-store accounting of the simulated
//! DRAM bytes the policy consumes.
//!
//! ## Structure classes
//!
//! Each store describes its offloadable structures as a list of
//! [`StructClass`]es ranked hottest-first (expected secondary accesses
//! absorbed per operation, per byte):
//!
//! - **treekv**: one class per sprig-forest level (the top levels are on
//!   every descent path, so they absorb a disproportionate access share per
//!   byte; the value-log block pointers ride inside the 64-byte entries).
//! - **lsmkv**: block-cache handles (hash chains + LRU links + bucket
//!   heads) ≫ block restart arrays ≫ cached data-block bytes. The memtable
//!   is host-DRAM by design (the paper's residual footprint) and outside
//!   the policy.
//! - **cachekv**: tier-1 hash chains (AccessContainer) ≻ tier-1 LRU links
//!   (MMContainer). The bucket directory and the tier-2 SOC index are the
//!   paper's residual DRAM footprint and stay outside the policy.
//!
//! A [`Plan`] resolves a [`PlacementPolicy`] over those classes by taking
//! the longest hottest-first **prefix** that the policy admits: placement
//! is all-or-nothing per class, and a colder class is never DRAM-resident
//! while a hotter one is offloaded (for a tree this is exactly the
//! "every descent passes the top levels" argument; a DRAM level below a
//! secondary level buys nothing). Prefix resolution makes the reported
//! DRAM bytes trivially monotone in the budget knob.
//!
//! ## The split-hop Θ (Eq 14 with DRAM-resident hops)
//!
//! Eq 14 prices a whole operation as `S` split units of `M/S` dependent
//! secondary accesses each (prefetch, `T_sw` yield, reschedule) plus one
//! IO, floored by the device ceilings. A placement policy moves some hops
//! to DRAM, where a dependent access is an *inline* load: no prefetch
//! enqueue, no context switch, no window term — just `T_mem + L_DRAM` of
//! core-busy time. Splitting the hop count `M = M_sec + M_dram` therefore
//! yields
//!
//! ```text
//! Θ_k⁻¹(L) = max( S·Θ_rev⁻¹(M_sec/S, …; L),  S·A_IO/(n_ssd·B_IO),
//!                 S/(n_ssd·R_IO) )
//!            + M_dram·(T_mem + L_DRAM)  +  T_fixed,k
//! ```
//!
//! i.e. only `M_sec` participates in the per-IO split and its prefetch
//! window; `M_dram` is additive CPU time like `T_fixed` (it can never be
//! hidden behind the prefetch queue, and it never pays `T_sw` or the
//! queue-depth wall). `model::KindCost` carries both counts (`m` = M_sec,
//! `m_dram`), each store's `ModelCosts::model_params` derives them from the
//! live policy, and `theta_kind_recip`/CPR compose unchanged. The `S = 0`
//! branch degenerates the same way: `M_sec` at the memory-only Eq 3 rate
//! plus the inline `M_dram` term.
//!
//! `cxlkvs run placement` sweeps the DRAM budget × L_mem × store and
//! validates this split against the simulator within the documented
//! `modelcheck` tolerance bands.

use crate::sim::Tier;

/// How a store's offloadable structures are split between host DRAM and
/// secondary memory. The policy is mechanism-agnostic: stores with
/// entry-granular placement (treekv's per-node `in_dram` bit) honor
/// [`PlacementPolicy::Random`] per entry; class-granular stores resolve
/// every variant through [`Plan::resolve`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PlacementPolicy {
    /// Everything offloaded (the paper's base case, ρ = 1). Bit-identical
    /// to the pre-placement behavior of every store — the determinism
    /// guard in `tests/prop_placement.rs` and the YCSB goldens pin it.
    #[default]
    AllSecondary,
    /// Everything in host DRAM (the paper's baseline system).
    AllDram,
    /// The hottest `k` classes (for treekv: the top `k` levels of every
    /// sprig) stay in DRAM — the access-aware placement of §5.2.3.
    TopLevels { k: u32 },
    /// Hotness-ranked placement within a simulated DRAM byte budget: the
    /// longest hottest-first class prefix whose bytes fit.
    Budget { dram_bytes: u64 },
    /// A uniformly random fraction of entries stays in DRAM (what Eq 15's
    /// ρ-interpolation assumes). Entry-granular where the store supports
    /// it (treekv); class-granular stores approximate it as
    /// `Budget { dram_frac · total_bytes }`.
    Random { dram_frac: f64 },
}

/// One offloadable structure class: a contiguous placement unit with a
/// simulated byte footprint and an (approximate) access share used for
/// reporting. Classes are supplied hottest-first; [`Plan::resolve`] places
/// prefixes only.
#[derive(Debug, Clone)]
pub struct StructClass {
    pub name: &'static str,
    /// Simulated bytes this class occupies if DRAM-resident.
    pub bytes: u64,
    /// Expected secondary accesses per operation this class absorbs when
    /// DRAM-placed (documentation/reporting; resolution is rank-based).
    pub hotness: f64,
}

/// A resolved placement: which classes are DRAM-resident under a policy.
#[derive(Debug, Clone)]
pub struct Plan {
    pub policy: PlacementPolicy,
    classes: Vec<StructClass>,
    /// Number of leading (hottest) classes resident in DRAM.
    dram_prefix: usize,
}

impl Plan {
    /// Resolve `policy` over `classes` (hottest-first). See the module docs
    /// for the prefix rule.
    pub fn resolve(policy: PlacementPolicy, classes: Vec<StructClass>) -> Plan {
        let total: u64 = classes.iter().map(|c| c.bytes).sum();
        let dram_prefix = match policy {
            PlacementPolicy::AllSecondary => 0,
            PlacementPolicy::AllDram => classes.len(),
            PlacementPolicy::TopLevels { k } => (k as usize).min(classes.len()),
            PlacementPolicy::Budget { dram_bytes } => prefix_within(&classes, dram_bytes),
            PlacementPolicy::Random { dram_frac } => {
                let budget = (dram_frac.clamp(0.0, 1.0) * total as f64).round() as u64;
                prefix_within(&classes, budget)
            }
        };
        Plan {
            policy,
            classes,
            dram_prefix,
        }
    }

    /// Tier of one class's accesses. Out-of-range ids (e.g. tree levels
    /// deeper than the class list) are always secondary.
    #[inline]
    pub fn tier(&self, class: usize) -> Tier {
        if class < self.dram_prefix {
            Tier::Dram
        } else {
            Tier::Secondary
        }
    }

    /// Whether one class is DRAM-resident.
    #[inline]
    pub fn in_dram(&self, class: usize) -> bool {
        class < self.dram_prefix
    }

    /// Number of leading classes resident in DRAM.
    pub fn dram_classes(&self) -> usize {
        self.dram_prefix
    }

    /// Split per-class expected access counts into `(m_sec, m_dram)`:
    /// DRAM-resident classes' hops move to the inline side of the
    /// split-hop Θ (module docs). The shared bucketing for every store's
    /// `ModelCosts` snapshot.
    pub fn split_hops(&self, per_class: &[(usize, f64)]) -> (f64, f64) {
        let (mut sec, mut dram) = (0.0, 0.0);
        for &(class, m) in per_class {
            if self.in_dram(class) {
                dram += m;
            } else {
                sec += m;
            }
        }
        (sec, dram)
    }

    /// Simulated DRAM bytes the resolved placement consumes.
    pub fn dram_bytes(&self) -> u64 {
        self.classes[..self.dram_prefix].iter().map(|c| c.bytes).sum()
    }

    /// Total offloadable bytes (the `AllDram` footprint).
    pub fn total_bytes(&self) -> u64 {
        self.classes.iter().map(|c| c.bytes).sum()
    }

    /// DRAM share of the offloadable footprint, by bytes.
    pub fn dram_fraction(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            return 0.0;
        }
        self.dram_bytes() as f64 / total as f64
    }

    pub fn classes(&self) -> &[StructClass] {
        &self.classes
    }
}

/// Longest class prefix whose cumulative bytes fit `budget`.
fn prefix_within(classes: &[StructClass], budget: u64) -> usize {
    let mut used = 0u64;
    for (i, c) in classes.iter().enumerate() {
        used = used.saturating_add(c.bytes);
        if used > budget {
            return i;
        }
    }
    classes.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<StructClass> {
        vec![
            StructClass {
                name: "hot",
                bytes: 100,
                hotness: 4.0,
            },
            StructClass {
                name: "warm",
                bytes: 1_000,
                hotness: 1.0,
            },
            StructClass {
                name: "cold",
                bytes: 10_000,
                hotness: 0.5,
            },
        ]
    }

    #[test]
    fn endpoints() {
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.dram_bytes(), 0);
        assert_eq!(none.tier(0), Tier::Secondary);
        let all = Plan::resolve(PlacementPolicy::AllDram, classes());
        assert_eq!(all.dram_bytes(), 11_100);
        assert_eq!(all.dram_fraction(), 1.0);
        assert_eq!(all.tier(2), Tier::Dram);
        // Out-of-range classes are always secondary, even under AllDram
        // (they model structures deeper than the class list, e.g. tree
        // levels created by later upserts — treekv places those per-entry).
        assert_eq!(all.tier(99), Tier::Secondary);
    }

    #[test]
    fn top_levels_takes_a_prefix() {
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert!(p.in_dram(0) && p.in_dram(1) && !p.in_dram(2));
        assert_eq!(p.dram_bytes(), 1_100);
        // k beyond the class list saturates.
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 64 }, classes());
        assert_eq!(p.dram_classes(), 3);
    }

    #[test]
    fn budget_places_longest_fitting_prefix() {
        let cases = [
            (0u64, 0usize),
            (99, 0),
            (100, 1),
            (1_099, 1),
            (1_100, 2),
            (11_099, 2),
            (11_100, 3),
            (u64::MAX, 3),
        ];
        for (budget, want) in cases {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, classes());
            assert_eq!(p.dram_classes(), want, "budget {budget}");
        }
    }

    #[test]
    fn dram_bytes_monotone_in_budget() {
        let mut prev = 0u64;
        for budget in (0..=12_000u64).step_by(37) {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, classes());
            let b = p.dram_bytes();
            assert!(b <= budget, "placement overshot the budget: {b} > {budget}");
            assert!(b >= prev, "dram bytes fell as budget grew: {prev} -> {b}");
            prev = b;
        }
    }

    #[test]
    fn random_is_a_byte_fraction_budget_for_class_plans() {
        let half = Plan::resolve(PlacementPolicy::Random { dram_frac: 0.5 }, classes());
        // 50% of 11,100 = 5,550: hot + warm fit, cold does not.
        assert_eq!(half.dram_classes(), 2);
        let none = Plan::resolve(PlacementPolicy::Random { dram_frac: 0.0 }, classes());
        assert_eq!(none.dram_classes(), 0);
        let all = Plan::resolve(PlacementPolicy::Random { dram_frac: 1.0 }, classes());
        assert_eq!(all.dram_classes(), 3);
    }

    #[test]
    fn empty_class_list_is_degenerate_but_sane() {
        let p = Plan::resolve(PlacementPolicy::AllDram, Vec::new());
        assert_eq!(p.dram_bytes(), 0);
        assert_eq!(p.dram_fraction(), 0.0);
        assert_eq!(p.tier(0), Tier::Secondary);
    }
}
