//! First-class tier placement: which in-memory structures live in host
//! DRAM and which are offloaded to microsecond-latency (secondary) memory.
//!
//! The paper's premise (§5.2.3) is that *most* — not all — of a store's
//! indices and caches can move to slow memory while a small DRAM residue
//! (top index levels, hot directories, filter blocks) preserves throughput.
//! The seed reproduction hardcoded `Tier::Secondary` at every `MemAccess`
//! site, so it could express only the two endpoints of that trade. This
//! module extracts tier selection into one policy that every store consults
//! at each pointer-chase site, with per-store accounting of the simulated
//! DRAM bytes the policy consumes.
//!
//! ## Structure classes
//!
//! Each store describes its offloadable structures as a list of
//! [`StructClass`]es ranked hottest-first (expected secondary accesses
//! absorbed per operation, per byte):
//!
//! - **treekv**: one class per sprig-forest level (the top levels are on
//!   every descent path, so they absorb a disproportionate access share per
//!   byte; the value-log block pointers ride inside the 64-byte entries).
//! - **lsmkv**: block-cache handles (hash chains + LRU links + bucket
//!   heads) ≫ block restart arrays ≫ cached data-block bytes. The memtable
//!   is host-DRAM by design — a **pinned** class (below).
//! - **cachekv**: tier-1 hash chains (AccessContainer) ≻ tier-1 LRU lists
//!   (MMContainer). The bucket directory and the tier-2 SOC index are
//!   pinned classes.
//!
//! **Pinned classes** are the paper's residual DRAM footprint: structures
//! that stay in host DRAM *by design* under every policy (lsmkv's
//! memtable, cachekv's bucket directory and SOC index). They are outside
//! the policy's placement decision — never offloaded, never consuming the
//! `Budget` knob — but [`Plan::dram_bytes`] and [`Plan::total_bytes`]
//! include them, so the DRAM-byte columns the experiments report are the
//! bytes a configuration *really* consumes. (Before this accounting fix,
//! `AllDram` and `Budget` sweeps silently understated their footprint by
//! the residual; [`Plan::policy_dram_bytes`] still reports the
//! policy-consumed bytes alone for budget-cap checks.)
//!
//! A [`Plan`] resolves a [`PlacementPolicy`] over the offloadable classes
//! by taking the longest hottest-first **prefix** that the policy admits:
//! placement is all-or-nothing per class, and a colder class is never
//! DRAM-resident while a hotter one is offloaded (for a tree this is
//! exactly the "every descent passes the top levels" argument; a DRAM
//! level below a secondary level buys nothing). Prefix resolution makes
//! the reported DRAM bytes trivially monotone in the budget knob.
//!
//! ## Measured re-ranking: the access-frequency planner
//!
//! The static hotness ranking is a *prior*, and the prior is wrong exactly
//! where the workload mix matters most: under a scan-heavy mix the lsmkv
//! restart arrays are never touched (scans walk chains and block bytes;
//! only point reads binary-search the restarts), and under a write-heavy
//! mix the cachekv LRU lists — four eviction-candidate hops behind every
//! insert, a splice behind every update — out-access the hash chains.
//!
//! Every store therefore tags each `MemAccess` site with its class id (it
//! already knows the class to consult the plan) and accumulates an
//! [`AccessProfile`]: measured accesses per class. [`Plan::replan`]
//! re-ranks the offloadable classes by **measured accesses per byte**
//!
//! ```text
//! rank(c) = profile.accesses(c) / bytes(c)    (descending,
//!                                              ties → static order)
//! ```
//!
//! and resolves `Budget`/`TopLevels` over that order instead of the static
//! one. The ranking is the classic density heuristic for the placement
//! knapsack: with all-or-nothing classes and additive DRAM benefit per
//! absorbed access, packing by accesses-per-byte maximizes the absorbed
//! access share within the byte budget (exactly optimal when the chosen
//! prefix fills the budget; the class-granular remainder is the usual
//! knapsack rounding). An empty profile falls back to the static ranking,
//! so replanning is always defined; given the same profile the re-rank is
//! deterministic (stable sort, static-order tie-break). The coordinator's
//! `run_store_ycsb_profiled` drives the two-phase profile → replan →
//! measure path, and `cxlkvs run planner` gates measured-vs-static
//! placement at equal DRAM budget.
//!
//! ## Online replanning: decay, hysteresis, migration cost
//!
//! A two-phase offline plan goes stale the moment the access distribution
//! turns (hotspot shift, diurnal read↔write swing). The online planner in
//! `run_store_ycsb_adaptive` closes the loop with three mechanisms, each
//! with a knob whose derivation lives here:
//!
//! **Epoch-bucketed EWMA decay** ([`AccessProfile::decay`]). At every
//! simulated-time epoch boundary (never wall clock — determinism), each
//! class count is scaled by a rational retain factor `num/den` in integer
//! arithmetic: `c ← ⌊c · num / den⌋` through `u128`, so identical seeds
//! and epochs reproduce identical profiles bit-for-bit. After a workload
//! turn, the share of the profile still describing the *old* phase decays
//! as `(num/den)^k` over `k` epochs; with the default `1/2` the stale half
//! falls below 10% within 4 epochs and below 1% within 7 — the adaptation
//! horizon is `log(ε)/log(num/den)` epochs for staleness tolerance `ε`.
//! Larger retain fractions average over longer windows (smoother, slower);
//! `num = 0` forgets everything each epoch (memoryless, noisy).
//!
//! **Hysteresis** ([`should_replan`]). The replan trigger compares what the
//! *current* plan and a *candidate* replan would absorb into DRAM under the
//! decayed profile ([`Plan::absorbed`]: the profile mass of the placed
//! prefix). Replanning fires only when
//!
//! ```text
//! absorbed(candidate) > absorbed(current) · (1 + margin)
//! ```
//!
//! i.e. the measured density ordering must shift enough that the candidate
//! beats the incumbent by more than `margin` (relative). A ranking
//! perturbation from sampling noise flips neighboring classes of nearly
//! equal density, which changes `absorbed` by at most their density gap —
//! below any reasonable margin — while a genuine phase change moves whole
//! access mass between classes and clears it. `margin = 0` replans on any
//! measured gain (the thrash configuration the adaptive tests use);
//! `margin = ∞` never replans (the static arm, bit-identical by
//! construction — see `tests/adaptive.rs`).
//!
//! **Honest migration cost**. A replan that re-tiers entries is not free:
//! every migrated line costs a read from its old tier plus a write to its
//! new tier, and cache contents that move across the SSD shard route cost
//! their value IO. Each store's `replan_migrate` returns the migration
//! traffic as a `DriveCounts` (dram + secondary line touches, SSD reads),
//! and the machine's `charge_migration` turns it into simulated time on
//! the device servers — so a thrashing planner loses measured throughput
//! instead of teleporting structures between tiers for free.
//!
//! ## The split-hop Θ (Eq 14 with DRAM-resident hops)
//!
//! Eq 14 prices a whole operation as `S` split units of `M/S` dependent
//! secondary accesses each (prefetch, `T_sw` yield, reschedule) plus one
//! IO, floored by the device ceilings. A placement policy moves some hops
//! to DRAM, where a dependent access is an *inline* load: no prefetch
//! enqueue, no context switch, no window term — just `T_mem + L_DRAM` of
//! core-busy time. Splitting the hop count `M = M_sec + M_dram` therefore
//! yields
//!
//! ```text
//! Θ_k⁻¹(L) = max( S·Θ_rev⁻¹(M_sec/S, …; L),  S·A_IO/(n_ssd·B_IO),
//!                 S/(n_ssd·R_IO) )
//!            + M_dram·(T_mem + L_DRAM)  +  T_fixed,k
//! ```
//!
//! i.e. only `M_sec` participates in the per-IO split and its prefetch
//! window; `M_dram` is additive CPU time like `T_fixed` (it can never be
//! hidden behind the prefetch queue, and it never pays `T_sw` or the
//! queue-depth wall). `model::KindCost` carries both counts (`m` = M_sec,
//! `m_dram`), each store's `ModelCosts::model_params` derives them from the
//! live (possibly replanned) policy, and `theta_kind_recip`/CPR compose
//! unchanged. The `S = 0` branch degenerates the same way: `M_sec` at the
//! memory-only Eq 3 rate plus the inline `M_dram` term.
//!
//! `cxlkvs run placement` sweeps the DRAM budget × L_mem × store and
//! validates this split against the simulator within the documented
//! `modelcheck` tolerance bands; `cxlkvs run planner` does the same for
//! replanned placements.
//!
//! ## Joint placement×compression (the two-variant density knapsack)
//!
//! Compression adds a third per-class state: a class may live in DRAM
//! **compressed**, consuming only `⌈q·bytes⌉` of the budget
//! (`q =` [`Compression::ratio_q`] `< 1`) while every access pays an
//! inline decompress cost `t_cpu` on the accessing core. Per access and
//! per budget byte the three states cost:
//!
//! ```text
//! state        per-access time                  budget bytes
//! Dram         T_mem + L_DRAM                   bytes
//! Compressed   T_mem + L_DRAM + t_cpu           ⌈q·bytes⌉
//! Secondary    c_sec(L)   (prefetch + T_sw)     0
//! ```
//!
//! Compressed dominates Secondary per byte whenever
//! `t_cpu < Δ(L) = c_sec(L) − (T_mem + L_DRAM)`: at microsecond memory
//! latencies Δ(L) is microseconds while a Table 6-class decompressor
//! costs ~0.1 µs per line, so the dominance order is
//! `Dram ≻ Compressed ≻ Secondary` and the two-variant density knapsack
//! collapses to a greedy with an upgrade pass:
//!
//! 1. **Place** (pass 1): walk the (static or measured) ranking placing
//!    each class in its *cheapest-byte* variant — compressed when the
//!    class carries a spec — until the next class no longer fits. The
//!    prefix rule, deterministic ranking, and static-order tie-break of
//!    the plain knapsack are unchanged.
//! 2. **Upgrade** (pass 2): spend the leftover budget walking the placed
//!    prefix in rank order, upgrading each compressed class whose
//!    uncompression delta `(1−q)·bytes` still fits — each upgrade buys
//!    `accesses·t_cpu` of CPU, so hotter classes upgrade first. Classes
//!    with [`Compression::always`] (the forced-compression experiment
//!    arm) are never upgraded.
//!
//! The crossover `cxlkvs run compress` gates on falls out directly: at a
//! **tight budget** pass 1 fits strictly more hot classes than the
//! uncompressed knapsack can place in the same bytes, so throughput wins
//! whenever the absorbed secondary hops save more than the added
//! decompress CPU — i.e. at long `L_mem`, where `Δ(L) ≫ t_cpu`; at a
//! **loose budget** pass 2 upgrades everything, the plans coincide, and
//! forced compression can only lose (pure added CPU at equal placement).
//! With no compression specs (`ratio_q ≥ 1` is normalized away at
//! [`StructClass::with_compression`]) both passes degenerate to the plain
//! prefix rule bit-for-bit.
//!
//! In the split-hop Θ, compressed hops enter as a third bucket
//! `M_cpr·(T_mem + L_DRAM + t_cpu)` — inline core-busy time exactly like
//! `M_dram`, never prefetch-hidden and never paying `T_sw` (the
//! decompressor runs on the line the core just loaded). `KindCost`
//! carries `m_cpr`/`t_cpu`, [`Plan::split3`] buckets per-class expected
//! hops three ways for every store's `model_params` snapshot, and
//! `theta_kind_recip` adds the term in both the IO and memory-only
//! branches (`model/extended.rs` module docs carry the derivation).

use crate::sim::Tier;

/// How a store's offloadable structures are split between host DRAM and
/// secondary memory. The policy is mechanism-agnostic: stores with
/// entry-granular placement (treekv's per-node `in_dram` bit) honor
/// [`PlacementPolicy::Random`] per entry; class-granular stores resolve
/// every variant through [`Plan::resolve`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PlacementPolicy {
    /// Everything offloaded (the paper's base case, ρ = 1). Bit-identical
    /// to the pre-placement behavior of every store — the determinism
    /// guard in `tests/prop_placement.rs` and the YCSB goldens pin it.
    #[default]
    AllSecondary,
    /// Everything in host DRAM (the paper's baseline system).
    AllDram,
    /// The hottest `k` classes (for treekv: the top `k` levels of every
    /// sprig) stay in DRAM — the access-aware placement of §5.2.3.
    TopLevels { k: u32 },
    /// Hotness-ranked placement within a simulated DRAM byte budget: the
    /// longest hottest-first class prefix whose bytes fit. Pinned classes
    /// are outside the budget (they are DRAM regardless).
    Budget { dram_bytes: u64 },
    /// A uniformly random fraction of entries stays in DRAM (what Eq 15's
    /// ρ-interpolation assumes). Entry-granular where the store supports
    /// it (treekv); class-granular stores approximate it as
    /// `Budget { dram_frac · offloadable_bytes }`.
    Random { dram_frac: f64 },
}

/// Per-class compression spec: the joint planner's second item variant
/// (module docs, "Joint placement×compression"). A compressed class
/// consumes `⌈ratio_q · bytes⌉` of the DRAM budget and charges
/// `decompress_us` of inline CPU at every access.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Compression {
    /// Compressed-size ratio in `(0, 1)` — the paper's Table 6
    /// compressed-DRAM scenarios assume ~0.5. Values `≥ 1` (or non-finite,
    /// or `≤ 0`) are normalized to "no compression" at
    /// [`StructClass::with_compression`], which makes a `ratio = 1.0`
    /// passthrough arm bit-identical to compression off.
    pub ratio_q: f64,
    /// Inline decompress CPU per access, in µs — core-busy, never
    /// prefetch-hidden.
    pub decompress_us: f64,
    /// Never upgrade to uncompressed DRAM in pass 2 (the forced-compression
    /// experiment arm; the joint planner otherwise upgrades when budget
    /// allows).
    pub always: bool,
}

impl Compression {
    pub fn new(ratio_q: f64, decompress_us: f64) -> Compression {
        Compression {
            ratio_q,
            decompress_us,
            always: false,
        }
    }

    /// The forced variant: stays compressed even when the budget could
    /// upgrade it.
    pub fn forced(mut self) -> Compression {
        self.always = true;
        self
    }
}

/// Store-config knob attaching one [`Compression`] spec to every
/// offloadable class (`Off` by default — bit-identical to the
/// pre-compression stores; pinned by the placement property tests).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum CompressMode {
    /// No compression anywhere (the default; all plans bit-identical to
    /// the two-state knapsack).
    #[default]
    Off,
    /// The joint planner chooses per class: Dram, Compressed, or
    /// Secondary (pass 1 + upgrade pass).
    Joint(Compression),
    /// Every DRAM-placed class stays compressed (no upgrade pass) — the
    /// experiment's ablation arm isolating the decompress CPU cost.
    Forced(Compression),
}

impl CompressMode {
    /// The per-class spec this mode attaches to offloadable classes
    /// (`None` for `Off`).
    pub fn spec(&self) -> Option<Compression> {
        match *self {
            CompressMode::Off => None,
            CompressMode::Joint(c) => Some(c),
            CompressMode::Forced(c) => Some(c.forced()),
        }
    }
}

/// Resolved residency of one class under a [`Plan`] — the three states of
/// the joint knapsack. `Dram` and `Compressed` are both DRAM-tier at the
/// `MemAccess` site; `Compressed` additionally charges the class's
/// decompress cost inline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClassState {
    Dram,
    Compressed,
    Secondary,
}

/// One structure class: a contiguous placement unit with a simulated byte
/// footprint. Offloadable classes are supplied hottest-first ([`Plan`]
/// places prefixes only); pinned classes are DRAM-resident under every
/// policy (the residual footprint).
#[derive(Debug, Clone)]
pub struct StructClass {
    pub name: &'static str,
    /// Simulated bytes this class occupies if DRAM-resident uncompressed.
    pub bytes: u64,
    /// Expected secondary accesses per operation this class absorbs when
    /// DRAM-placed (documentation/reporting; static resolution is
    /// rank-based, measured resolution uses the [`AccessProfile`]).
    pub hotness: f64,
    /// DRAM-resident by design, outside the placement policy (lsmkv's
    /// memtable, cachekv's bucket directory / SOC index). Pinned bytes
    /// count toward [`Plan::dram_bytes`] but never consume the budget.
    pub pinned: bool,
    /// Optional compressed variant for the joint planner (module docs,
    /// "Joint placement×compression"). `None` — the default from every
    /// constructor — resolves exactly as before compression existed.
    pub compression: Option<Compression>,
}

impl StructClass {
    /// An offloadable class (the policy decides its tier).
    pub fn new(name: &'static str, bytes: u64, hotness: f64) -> StructClass {
        StructClass {
            name,
            bytes,
            hotness,
            pinned: false,
            compression: None,
        }
    }

    /// A pinned class: host-DRAM by design, reported but never offloaded.
    pub fn pinned(name: &'static str, bytes: u64) -> StructClass {
        StructClass {
            name,
            bytes,
            hotness: 0.0,
            pinned: true,
            compression: None,
        }
    }

    /// Attach (or clear) a compression spec. Specs that cannot shrink the
    /// class — `ratio_q ≥ 1`, non-positive, or non-finite — are normalized
    /// to `None`, so a `ratio = 1.0` passthrough is bit-identical to
    /// compression off by construction.
    pub fn with_compression(mut self, spec: Option<Compression>) -> StructClass {
        self.compression = match spec {
            Some(s) if s.ratio_q.is_finite() && s.ratio_q > 0.0 && s.ratio_q < 1.0 => Some(s),
            _ => None,
        };
        self
    }

    /// DRAM budget bytes this class consumes in its compressed variant
    /// (`⌈ratio_q · bytes⌉`, capped at the uncompressed size); the plain
    /// `bytes` without a spec.
    pub fn compressed_bytes(&self) -> u64 {
        match self.compression {
            Some(s) => ((s.ratio_q * self.bytes as f64).ceil() as u64).min(self.bytes),
            None => self.bytes,
        }
    }
}

/// Measured per-class access counts, accumulated by a store at its
/// `MemAccess` sites (one tick per simulated access, pinned classes
/// included). The store-side half of the measured planner: feed it to
/// [`Plan::replan`] to re-rank the offloadable classes by observed
/// accesses per byte. Counting is pure bookkeeping — it never touches the
/// simulation's RNG or timing, so profiled runs stay bit-identical to
/// unprofiled ones.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AccessProfile {
    counts: Vec<u64>,
}

impl AccessProfile {
    pub fn new(n_classes: usize) -> AccessProfile {
        AccessProfile {
            counts: vec![0; n_classes],
        }
    }

    /// Record one access to `class` (auto-grows for stores whose class
    /// count is data-dependent, e.g. tree levels).
    #[inline]
    pub fn tick(&mut self, class: usize) {
        if class >= self.counts.len() {
            self.counts.resize(class + 1, 0);
        }
        self.counts[class] += 1;
    }

    /// Measured accesses of one class (0 for classes never seen).
    pub fn accesses(&self, class: usize) -> u64 {
        self.counts.get(class).copied().unwrap_or(0)
    }

    /// Total accesses across all classes.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// No accesses recorded — [`Plan::replan`] falls back to the static
    /// ranking.
    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    /// One epoch of EWMA decay: scale every class count by the rational
    /// retain factor `retain_num / retain_den` (module docs, "Online
    /// replanning"). Integer arithmetic through `u128` — no float
    /// rounding, so decayed profiles are bit-identical across runs with
    /// the same epoch schedule. Called at simulated-time epoch boundaries
    /// only, never from wall clock.
    ///
    /// Panics if `retain_den == 0` or `retain_num > retain_den` (the
    /// retain factor must be a fraction in `[0, 1]`).
    pub fn decay(&mut self, retain_num: u32, retain_den: u32) {
        assert!(
            retain_den > 0 && retain_num <= retain_den,
            "retain factor must be a fraction in [0, 1]: {retain_num}/{retain_den}"
        );
        if retain_num == retain_den {
            return;
        }
        for c in self.counts.iter_mut() {
            *c = (*c as u128 * retain_num as u128 / retain_den as u128) as u64;
        }
    }

    /// Merge another profile's counts into this one (the offline arm's
    /// whole-schedule aggregate profile).
    pub fn merge(&mut self, other: &AccessProfile) {
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
    }
}

/// The online planner's replan trigger (module docs, "Online replanning"):
/// replace `current` with `candidate` only when the candidate's absorbed
/// access mass under `profile` beats the incumbent's by more than the
/// relative `margin`.
///
/// `margin = 0.0` replans on any measured gain (thrash configuration);
/// `margin = f64::INFINITY` never replans (`x > y·∞` is false for every
/// finite `y > 0`, and `x > NaN` is false when `y == 0`), which makes the
/// adaptive loop bit-identical to a static run.
pub fn should_replan(
    current: &Plan,
    candidate: &Plan,
    profile: &AccessProfile,
    margin: f64,
) -> bool {
    let cur = current.absorbed(profile) as f64;
    let cand = candidate.absorbed(profile) as f64;
    cand > cur * (1.0 + margin)
}

/// Per-kind expected hop counts bucketed by resolved class state
/// ([`Plan::split3`]): `sec` hops pay the secondary prefetch path, `dram`
/// hops are inline loads, `cpr` hops are inline loads plus `cpr_us` of
/// decompress CPU each (access-weighted mean over the compressed
/// classes).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HopSplit {
    pub sec: f64,
    pub dram: f64,
    pub cpr: f64,
    pub cpr_us: f64,
}

/// A resolved placement: which classes are DRAM-resident under a policy,
/// over either the static hottest-first ranking ([`Plan::resolve`]) or a
/// measured accesses-per-byte re-ranking ([`Plan::replan`]).
#[derive(Debug, Clone)]
pub struct Plan {
    pub policy: PlacementPolicy,
    classes: Vec<StructClass>,
    /// Offloadable class ids, hottest-first (static order, or the measured
    /// re-rank). Pinned classes never appear here.
    order: Vec<usize>,
    /// Number of leading `order` entries resident in DRAM.
    dram_prefix: usize,
    /// Per-class DRAM residency (pinned, or inside the placed prefix) —
    /// compressed classes count as DRAM-resident.
    dram: Vec<bool>,
    /// Per-class joint-knapsack state (pinned classes are `Dram`).
    state: Vec<ClassState>,
}

impl Plan {
    /// Resolve `policy` over `classes` in their static hottest-first order.
    /// See the module docs for the prefix rule; pinned classes are DRAM
    /// under every policy and never consume the budget.
    pub fn resolve(policy: PlacementPolicy, classes: Vec<StructClass>) -> Plan {
        let order: Vec<usize> = (0..classes.len()).filter(|&i| !classes[i].pinned).collect();
        Plan::resolve_order(policy, classes, order)
    }

    /// Resolve `policy` over `classes` re-ranked by **measured** accesses
    /// per byte (module docs, "Measured re-ranking"). An empty profile
    /// falls back to [`Plan::resolve`]; ties keep the static order, so the
    /// result is deterministic given the same profile.
    pub fn replan(
        policy: PlacementPolicy,
        classes: Vec<StructClass>,
        profile: &AccessProfile,
    ) -> Plan {
        if profile.is_empty() {
            return Plan::resolve(policy, classes);
        }
        let mut order: Vec<usize> = (0..classes.len()).filter(|&i| !classes[i].pinned).collect();
        let density = |i: usize| -> f64 {
            let b = classes[i].bytes;
            if b == 0 {
                // A zero-byte class is free to place: rank an *accessed*
                // one first (infinite density), an untouched one last.
                if profile.accesses(i) > 0 {
                    f64::INFINITY
                } else {
                    0.0
                }
            } else {
                profile.accesses(i) as f64 / b as f64
            }
        };
        order.sort_by(|&a, &b| {
            density(b)
                .partial_cmp(&density(a))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        Plan::resolve_order(policy, classes, order)
    }

    /// Shared resolution over an explicit offloadable ranking. Byte-budget
    /// policies run the joint placement×compression greedy (place at
    /// cheapest-byte variant, then upgrade — module docs); count-based
    /// policies place the prefix with compressed-variant classes only when
    /// their spec is forced (`always`), since without budget pressure
    /// uncompressed DRAM dominates. Without compression specs every branch
    /// is bit-identical to the plain prefix rule.
    fn resolve_order(
        policy: PlacementPolicy,
        classes: Vec<StructClass>,
        order: Vec<usize>,
    ) -> Plan {
        let offloadable: u64 = order.iter().map(|&i| classes[i].bytes).sum();
        let budget = match policy {
            PlacementPolicy::AllSecondary => None,
            PlacementPolicy::AllDram => None,
            PlacementPolicy::TopLevels { .. } => None,
            PlacementPolicy::Budget { dram_bytes } => Some(dram_bytes),
            PlacementPolicy::Random { dram_frac } => {
                Some((dram_frac.clamp(0.0, 1.0) * offloadable as f64).round() as u64)
            }
        };
        let mut state: Vec<ClassState> = classes
            .iter()
            .map(|c| {
                if c.pinned {
                    ClassState::Dram
                } else {
                    ClassState::Secondary
                }
            })
            .collect();
        let dram_prefix = match (policy, budget) {
            (PlacementPolicy::AllSecondary, _) => 0,
            (PlacementPolicy::AllDram, _) => order.len(),
            (PlacementPolicy::TopLevels { k }, _) => (k as usize).min(order.len()),
            (_, Some(budget)) => {
                // Pass 1: longest prefix at cheapest-byte variants.
                let mut used = 0u64;
                let mut prefix = 0usize;
                for &i in &order {
                    let b = classes[i].compressed_bytes();
                    if used.saturating_add(b) > budget {
                        break;
                    }
                    used = used.saturating_add(b);
                    prefix += 1;
                }
                // Pass 2: upgrade compressed → uncompressed DRAM in rank
                // order while the uncompression delta fits the leftover.
                let mut remaining = budget - used;
                for &i in &order[..prefix] {
                    match classes[i].compression {
                        Some(spec) => {
                            let delta = classes[i].bytes - classes[i].compressed_bytes();
                            if !spec.always && delta <= remaining {
                                remaining -= delta;
                                state[i] = ClassState::Dram;
                            } else {
                                state[i] = ClassState::Compressed;
                            }
                        }
                        None => state[i] = ClassState::Dram,
                    }
                }
                prefix
            }
            (_, None) => unreachable!("count-based policies matched above"),
        };
        if budget.is_none() {
            // Count-based placement: placed classes are uncompressed DRAM
            // unless their spec is forced.
            for &i in &order[..dram_prefix] {
                state[i] = match classes[i].compression {
                    Some(spec) if spec.always => ClassState::Compressed,
                    _ => ClassState::Dram,
                };
            }
        }
        let dram: Vec<bool> = state.iter().map(|&s| s != ClassState::Secondary).collect();
        Plan {
            policy,
            classes,
            order,
            dram_prefix,
            dram,
            state,
        }
    }

    /// Tier of one class's accesses. Out-of-range ids (e.g. tree levels
    /// deeper than the class list) are always secondary.
    #[inline]
    pub fn tier(&self, class: usize) -> Tier {
        if self.in_dram(class) {
            Tier::Dram
        } else {
            Tier::Secondary
        }
    }

    /// Whether one class is DRAM-resident (pinned or placed; compressed
    /// classes are DRAM-resident).
    #[inline]
    pub fn in_dram(&self, class: usize) -> bool {
        self.dram.get(class).copied().unwrap_or(false)
    }

    /// Joint-knapsack state of one class. Out-of-range ids are secondary,
    /// like [`Plan::tier`].
    #[inline]
    pub fn state(&self, class: usize) -> ClassState {
        self.state.get(class).copied().unwrap_or(ClassState::Secondary)
    }

    /// Whether one class is DRAM-resident **compressed** — its accesses
    /// charge [`Plan::decompress_us`] of inline CPU.
    #[inline]
    pub fn is_compressed(&self, class: usize) -> bool {
        self.state(class) == ClassState::Compressed
    }

    /// Inline decompress CPU per access of one class, in µs — 0.0 unless
    /// the class is placed compressed.
    #[inline]
    pub fn decompress_us(&self, class: usize) -> f64 {
        if self.is_compressed(class) {
            self.classes[class]
                .compression
                .map(|s| s.decompress_us)
                .unwrap_or(0.0)
        } else {
            0.0
        }
    }

    /// Number of classes placed compressed (reporting).
    pub fn compressed_classes(&self) -> usize {
        self.state
            .iter()
            .filter(|&&s| s == ClassState::Compressed)
            .count()
    }

    /// Number of leading (hottest-ranked) offloadable classes resident in
    /// DRAM.
    pub fn dram_classes(&self) -> usize {
        self.dram_prefix
    }

    /// The offloadable ranking this plan resolved over: class ids
    /// hottest-first — the static order from [`Plan::resolve`], the
    /// measured accesses-per-byte order from [`Plan::replan`].
    pub fn ranking(&self) -> &[usize] {
        &self.order
    }

    /// Profile access mass this plan's DRAM-placed offloadable prefix
    /// absorbs — the objective the density ranking maximizes, and the
    /// quantity [`should_replan`]'s hysteresis compares between the
    /// incumbent plan and a candidate replan. Pinned classes are DRAM
    /// under every plan, so they cancel in any comparison and are left
    /// out.
    pub fn absorbed(&self, profile: &AccessProfile) -> u64 {
        self.order[..self.dram_prefix]
            .iter()
            .map(|&i| profile.accesses(i))
            .sum()
    }

    /// Share of all *measured* offloadable accesses the DRAM-placed prefix
    /// absorbs, in `[0, 1]` (0.0 on an empty profile).
    ///
    /// ## Multi-tenant budget splitting
    ///
    /// Under `workload::tenants` the profile is accumulated by **every**
    /// tenant's ops against the *shared* structure classes, so a replan
    /// over it splits the one shared `Budget` across tenants implicitly:
    /// classes hot for high-traffic tenants out-rank classes only a light
    /// tenant touches, and the absorbed fraction reports how much of the
    /// *combined* multi-tenant access stream the split serves from DRAM.
    /// There is no per-tenant quota — isolation is scheduled (SWRR
    /// issuance shares), while placement optimizes aggregate absorbed
    /// accesses per DRAM byte exactly as in the single-tenant case. The
    /// `tenants` experiment reports this fraction per cell so the CSV
    /// shows what the shared budget bought under contention.
    pub fn absorbed_fraction(&self, profile: &AccessProfile) -> f64 {
        let total = profile.total();
        if total == 0 {
            return 0.0;
        }
        self.absorbed(profile) as f64 / total as f64
    }

    /// Split per-class expected access counts into `(m_sec, m_dram)`:
    /// DRAM-resident classes' hops move to the inline side of the
    /// split-hop Θ (module docs). Compressed classes count on the DRAM
    /// side here — use [`Plan::split3`] when the model needs the
    /// decompress term.
    pub fn split_hops(&self, per_class: &[(usize, f64)]) -> (f64, f64) {
        let (mut sec, mut dram) = (0.0, 0.0);
        for &(class, m) in per_class {
            if self.in_dram(class) {
                dram += m;
            } else {
                sec += m;
            }
        }
        (sec, dram)
    }

    /// Split per-class expected access counts three ways — secondary,
    /// uncompressed DRAM, compressed DRAM — with the access-weighted mean
    /// decompress cost over the compressed hops. The bucketing for every
    /// store's `ModelCosts` snapshot once compression is in play
    /// (`KindCost::with_compressed`).
    pub fn split3(&self, per_class: &[(usize, f64)]) -> HopSplit {
        let mut h = HopSplit::default();
        let mut cost = 0.0;
        for &(class, m) in per_class {
            match self.state(class) {
                ClassState::Secondary => h.sec += m,
                ClassState::Dram => h.dram += m,
                ClassState::Compressed => {
                    h.cpr += m;
                    cost += m * self.decompress_us(class);
                }
            }
        }
        if h.cpr > 0.0 {
            h.cpr_us = cost / h.cpr;
        }
        h
    }

    /// Simulated DRAM bytes this placement consumes — the **honest** total:
    /// policy-placed offloadable classes *plus* the pinned residual
    /// footprint (`AllSecondary` on a store with pinned classes is nonzero
    /// by design). Compressed classes count at their compressed size —
    /// that shrinkage is the whole point of the joint knapsack.
    pub fn dram_bytes(&self) -> u64 {
        self.classes
            .iter()
            .enumerate()
            .filter(|&(i, _)| self.dram[i])
            .map(|(i, c)| self.resident_bytes_of(i, c))
            .sum()
    }

    /// DRAM bytes consumed by the *policy* alone (placed offloadable
    /// classes, excluding the pinned residual) — the quantity capped by
    /// `Budget { dram_bytes }`. Compressed classes count at their
    /// compressed size.
    pub fn policy_dram_bytes(&self) -> u64 {
        self.order[..self.dram_prefix]
            .iter()
            .map(|&i| self.resident_bytes_of(i, &self.classes[i]))
            .sum()
    }

    /// Budget bytes class `i` consumes in its resolved state.
    fn resident_bytes_of(&self, i: usize, c: &StructClass) -> u64 {
        if self.state[i] == ClassState::Compressed {
            c.compressed_bytes()
        } else {
            c.bytes
        }
    }

    /// The pinned residual footprint (DRAM under every policy).
    pub fn pinned_bytes(&self) -> u64 {
        self.classes.iter().filter(|c| c.pinned).map(|c| c.bytes).sum()
    }

    /// Total bytes of every class, pinned included (the honest `AllDram`
    /// footprint).
    pub fn total_bytes(&self) -> u64 {
        self.classes.iter().map(|c| c.bytes).sum()
    }

    /// Offloadable bytes alone — the denominator for budget fractions
    /// (`Budget { frac · offloadable_bytes }` spans all-secondary to
    /// all-DRAM for the policy-managed classes).
    pub fn offloadable_bytes(&self) -> u64 {
        self.order.iter().map(|&i| self.classes[i].bytes).sum()
    }

    /// DRAM share of the total footprint, by bytes.
    pub fn dram_fraction(&self) -> f64 {
        let total = self.total_bytes();
        if total == 0 {
            return 0.0;
        }
        self.dram_bytes() as f64 / total as f64
    }

    pub fn classes(&self) -> &[StructClass] {
        &self.classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<StructClass> {
        vec![
            StructClass::new("hot", 100, 4.0),
            StructClass::new("warm", 1_000, 1.0),
            StructClass::new("cold", 10_000, 0.5),
        ]
    }

    #[test]
    fn endpoints() {
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.dram_bytes(), 0);
        assert_eq!(none.tier(0), Tier::Secondary);
        let all = Plan::resolve(PlacementPolicy::AllDram, classes());
        assert_eq!(all.dram_bytes(), 11_100);
        assert_eq!(all.dram_fraction(), 1.0);
        assert_eq!(all.tier(2), Tier::Dram);
        // Out-of-range classes are always secondary, even under AllDram
        // (they model structures deeper than the class list, e.g. tree
        // levels created by later upserts — treekv places those per-entry).
        assert_eq!(all.tier(99), Tier::Secondary);
    }

    #[test]
    fn top_levels_takes_a_prefix() {
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert!(p.in_dram(0) && p.in_dram(1) && !p.in_dram(2));
        assert_eq!(p.dram_bytes(), 1_100);
        // k beyond the class list saturates.
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 64 }, classes());
        assert_eq!(p.dram_classes(), 3);
    }

    #[test]
    fn budget_places_longest_fitting_prefix() {
        let cases = [
            (0u64, 0usize),
            (99, 0),
            (100, 1),
            (1_099, 1),
            (1_100, 2),
            (11_099, 2),
            (11_100, 3),
            (u64::MAX, 3),
        ];
        for (budget, want) in cases {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, classes());
            assert_eq!(p.dram_classes(), want, "budget {budget}");
        }
    }

    #[test]
    fn absorbed_fraction_tracks_placed_prefix() {
        let mut profile = AccessProfile::new(3);
        for _ in 0..80 {
            profile.tick(0);
        }
        for _ in 0..15 {
            profile.tick(1);
        }
        for _ in 0..5 {
            profile.tick(2);
        }
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.absorbed_fraction(&profile), 0.0);
        let top2 = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert!((top2.absorbed_fraction(&profile) - 0.95).abs() < 1e-12);
        let all = Plan::resolve(PlacementPolicy::AllDram, classes());
        assert_eq!(all.absorbed_fraction(&profile), 1.0);
        // Empty profile → 0.0, not NaN.
        assert_eq!(all.absorbed_fraction(&AccessProfile::new(3)), 0.0);
    }

    #[test]
    fn dram_bytes_monotone_in_budget() {
        let mut prev = 0u64;
        for budget in (0..=12_000u64).step_by(37) {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, classes());
            let b = p.dram_bytes();
            assert!(b <= budget, "placement overshot the budget: {b} > {budget}");
            assert!(b >= prev, "dram bytes fell as budget grew: {prev} -> {b}");
            prev = b;
        }
    }

    #[test]
    fn random_is_a_byte_fraction_budget_for_class_plans() {
        let half = Plan::resolve(PlacementPolicy::Random { dram_frac: 0.5 }, classes());
        // 50% of 11,100 = 5,550: hot + warm fit, cold does not.
        assert_eq!(half.dram_classes(), 2);
        let none = Plan::resolve(PlacementPolicy::Random { dram_frac: 0.0 }, classes());
        assert_eq!(none.dram_classes(), 0);
        let all = Plan::resolve(PlacementPolicy::Random { dram_frac: 1.0 }, classes());
        assert_eq!(all.dram_classes(), 3);
    }

    #[test]
    fn empty_class_list_is_degenerate_but_sane() {
        let p = Plan::resolve(PlacementPolicy::AllDram, Vec::new());
        assert_eq!(p.dram_bytes(), 0);
        assert_eq!(p.dram_fraction(), 0.0);
        assert_eq!(p.tier(0), Tier::Secondary);
    }

    // ---- pinned classes (honest residual accounting) ----------------------

    fn with_pinned() -> Vec<StructClass> {
        let mut cs = classes();
        cs.push(StructClass::pinned("residual", 500));
        cs
    }

    #[test]
    fn pinned_classes_are_dram_under_every_policy_but_never_budgeted() {
        let none = Plan::resolve(PlacementPolicy::AllSecondary, with_pinned());
        assert_eq!(none.tier(3), Tier::Dram, "pinned is DRAM even at rho=1");
        assert_eq!(none.dram_bytes(), 500, "honest: residual reported");
        assert_eq!(none.policy_dram_bytes(), 0, "policy consumed nothing");
        assert_eq!(none.pinned_bytes(), 500);
        assert_eq!(none.offloadable_bytes(), 11_100);
        assert_eq!(none.total_bytes(), 11_600);
        // A budget of exactly the hot class places it — pinned bytes do not
        // consume the budget.
        let b = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 100 }, with_pinned());
        assert!(b.in_dram(0) && b.in_dram(3) && !b.in_dram(1));
        assert_eq!(b.policy_dram_bytes(), 100);
        assert_eq!(b.dram_bytes(), 600);
        // AllDram covers everything; Random{1.0} covers all offloadable.
        let all = Plan::resolve(PlacementPolicy::AllDram, with_pinned());
        assert_eq!(all.dram_bytes(), 11_600);
        let r = Plan::resolve(PlacementPolicy::Random { dram_frac: 1.0 }, with_pinned());
        assert_eq!(r.dram_classes(), 3);
        // The pinned class never appears in the offloadable ranking.
        assert!(!none.ranking().contains(&3));
    }

    // ---- measured re-ranking (Plan::replan) -------------------------------

    #[test]
    fn replan_reorders_by_measured_accesses_per_byte() {
        // Static order: hot(100B) ≻ warm(1kB) ≻ cold(10kB). Measured
        // densities: hot 10/100B = 0.1, cold 200/10kB = 0.02,
        // warm 1/1kB = 0.001 — the workload hammers "cold" past "warm".
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        prof.tick(1);
        for _ in 0..200 {
            prof.tick(2);
        }
        let p = Plan::replan(PlacementPolicy::AllSecondary, classes(), &prof);
        assert_eq!(p.ranking(), &[0, 2, 1], "measured density order");
        // Budget resolution follows the measured order: 10,100 B fits
        // hot + cold (10,100) exactly, leaving warm offloaded — the static
        // order would have placed hot + warm instead.
        let p = Plan::replan(
            PlacementPolicy::Budget { dram_bytes: 10_100 },
            classes(),
            &prof,
        );
        assert!(p.in_dram(0) && p.in_dram(2) && !p.in_dram(1));
        assert_eq!(p.policy_dram_bytes(), 10_100);
        let s = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 10_100 }, classes());
        assert!(s.in_dram(0) && s.in_dram(1) && !s.in_dram(2));
    }

    #[test]
    fn replan_is_deterministic_and_falls_back_to_static() {
        let mut prof = AccessProfile::new(3);
        prof.tick(2);
        prof.tick(2);
        prof.tick(0);
        let a = Plan::replan(PlacementPolicy::TopLevels { k: 1 }, classes(), &prof);
        let b = Plan::replan(PlacementPolicy::TopLevels { k: 1 }, classes(), &prof);
        assert_eq!(a.ranking(), b.ranking(), "same profile, same plan");
        assert_eq!(a.dram_bytes(), b.dram_bytes());
        // Empty profile → the static ranking, bit-for-bit.
        let empty = AccessProfile::new(3);
        let f = Plan::replan(PlacementPolicy::TopLevels { k: 1 }, classes(), &empty);
        let s = Plan::resolve(PlacementPolicy::TopLevels { k: 1 }, classes());
        assert_eq!(f.ranking(), s.ranking());
        assert_eq!(f.dram_bytes(), s.dram_bytes());
        // Ties (identical density) keep the static order: a uniform profile
        // over equal-density classes reproduces the static ranking.
        let eq = vec![
            StructClass::new("a", 100, 1.0),
            StructClass::new("b", 100, 1.0),
        ];
        let mut uni = AccessProfile::new(2);
        uni.tick(0);
        uni.tick(1);
        let t = Plan::replan(PlacementPolicy::AllSecondary, eq, &uni);
        assert_eq!(t.ranking(), &[0, 1]);
    }

    #[test]
    fn zero_byte_accessed_class_ranks_first() {
        // A degenerate zero-byte class is free to keep in DRAM: if the
        // workload touches it, the measured ranking must place it first
        // (infinite density), never last as a naive 0.0 density would.
        let cs = vec![
            StructClass::new("a", 100, 1.0),
            StructClass::new("free", 0, 1.0),
        ];
        let mut prof = AccessProfile::new(2);
        prof.tick(0);
        prof.tick(1);
        let p = Plan::replan(PlacementPolicy::Budget { dram_bytes: 0 }, cs, &prof);
        assert_eq!(p.ranking(), &[1, 0]);
        assert!(p.in_dram(1), "a free accessed class always fits the budget");
        assert!(!p.in_dram(0));
    }

    // ---- online replanning: decay + hysteresis -----------------------------

    #[test]
    fn decay_is_deterministic_integer_ewma() {
        let mut a = AccessProfile::new(3);
        for _ in 0..1_001 {
            a.tick(0);
        }
        for _ in 0..7 {
            a.tick(2);
        }
        let mut b = a.clone();
        a.decay(1, 2);
        b.decay(1, 2);
        assert_eq!(a, b, "same profile, same decay, bit-identical");
        assert_eq!(a.accesses(0), 500, "floor(1001/2)");
        assert_eq!(a.accesses(2), 3, "floor(7/2)");
        // Retain 1/1 is the identity; retain 0/1 forgets everything.
        let before = a.clone();
        a.decay(1, 1);
        assert_eq!(a, before);
        a.decay(0, 1);
        assert!(a.is_empty());
        // No u64 overflow on huge counts (u128 intermediate): double a
        // single tick up to 2^63 via merge, then decay by 3/4.
        let mut q = AccessProfile::new(1);
        q.tick(0);
        for _ in 0..63 {
            let clone = q.clone();
            q.merge(&clone);
        }
        assert_eq!(q.accesses(0), 1u64 << 63);
        q.decay(3, 4);
        assert_eq!(q.accesses(0), ((1u128 << 63) * 3 / 4) as u64);
    }

    #[test]
    #[should_panic(expected = "retain factor")]
    fn decay_rejects_improper_fraction() {
        AccessProfile::new(1).decay(3, 2);
    }

    #[test]
    fn merge_adds_and_grows() {
        let mut a = AccessProfile::new(1);
        a.tick(0);
        let mut b = AccessProfile::new(3);
        b.tick(0);
        b.tick(2);
        a.merge(&b);
        assert_eq!(a.accesses(0), 2);
        assert_eq!(a.accesses(2), 1);
        assert_eq!(a.total(), 3);
    }

    #[test]
    fn absorbed_sums_the_placed_prefix() {
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        for _ in 0..5 {
            prof.tick(1);
        }
        for _ in 0..200 {
            prof.tick(2);
        }
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert_eq!(none.absorbed(&prof), 0);
        let top2 = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        assert_eq!(top2.absorbed(&prof), 15, "hot + warm");
        let re = Plan::replan(PlacementPolicy::TopLevels { k: 2 }, classes(), &prof);
        assert_eq!(re.absorbed(&prof), 210, "hot + cold after the re-rank");
        // Pinned classes never count: they cancel in any comparison.
        let pinned = Plan::resolve(PlacementPolicy::AllSecondary, with_pinned());
        assert_eq!(pinned.absorbed(&prof), 0);
    }

    #[test]
    fn hysteresis_margins_bracket_the_trigger() {
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        for _ in 0..200 {
            prof.tick(2);
        }
        let current = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, classes());
        let candidate = Plan::replan(PlacementPolicy::TopLevels { k: 2 }, classes(), &prof);
        // Gain 210 vs 10: fires at margin 0 and at any margin below 20x,
        // not above it.
        assert!(should_replan(&current, &candidate, &prof, 0.0));
        assert!(should_replan(&current, &candidate, &prof, 0.10));
        assert!(!should_replan(&current, &candidate, &prof, 25.0));
        // margin = ∞ never fires — even from an absorbed-nothing incumbent
        // (0 · ∞ = NaN, and `x > NaN` is false).
        let none = Plan::resolve(PlacementPolicy::AllSecondary, classes());
        assert!(!should_replan(&none, &candidate, &prof, f64::INFINITY));
        assert!(!should_replan(&current, &candidate, &prof, f64::INFINITY));
        // No gain → no replan at any margin (margin 0 requires *strict*
        // improvement, so identical plans never thrash).
        assert!(!should_replan(&candidate, &candidate, &prof, 0.0));
        assert!(!should_replan(&candidate, &current, &prof, 0.0));
    }

    // ---- joint placement×compression ---------------------------------------

    /// The standard classes, each compressible to half at 0.12 µs/access.
    fn cclasses() -> Vec<StructClass> {
        classes()
            .into_iter()
            .map(|c| c.with_compression(Some(Compression::new(0.5, 0.12))))
            .collect()
    }

    #[test]
    fn no_compression_specs_resolve_bit_identically() {
        for policy in [
            PlacementPolicy::AllSecondary,
            PlacementPolicy::AllDram,
            PlacementPolicy::TopLevels { k: 2 },
            PlacementPolicy::Budget { dram_bytes: 1_100 },
            PlacementPolicy::Random { dram_frac: 0.5 },
        ] {
            let p = Plan::resolve(policy, classes());
            assert_eq!(p.compressed_classes(), 0, "{policy:?}");
            for i in 0..3 {
                assert_eq!(
                    p.state(i) == ClassState::Secondary,
                    !p.in_dram(i),
                    "{policy:?} class {i}"
                );
                assert!(!p.is_compressed(i));
                assert_eq!(p.decompress_us(i), 0.0);
            }
        }
    }

    #[test]
    fn ratio_one_and_degenerate_specs_normalize_to_none() {
        for q in [1.0, 1.5, 0.0, -0.3, f64::NAN, f64::INFINITY] {
            let c = StructClass::new("x", 1_000, 1.0)
                .with_compression(Some(Compression::new(q, 0.12)));
            assert!(c.compression.is_none(), "ratio {q} must normalize away");
            assert_eq!(c.compressed_bytes(), 1_000);
        }
        let c = StructClass::new("x", 1_000, 1.0)
            .with_compression(Some(Compression::new(0.5, 0.12)));
        assert_eq!(c.compressed_bytes(), 500);
        // Ceiling, capped at the uncompressed size.
        let c = StructClass::new("x", 3, 1.0).with_compression(Some(Compression::new(0.5, 0.1)));
        assert_eq!(c.compressed_bytes(), 2);
    }

    #[test]
    fn tight_budget_fits_more_classes_compressed() {
        // Plain knapsack at 550 B: only hot (100 B) fits. Joint: hot + warm
        // fit compressed (50 + 500 = 550), absorbing warm's accesses too.
        let plain = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 550 }, classes());
        assert_eq!(plain.dram_classes(), 1);
        let joint = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 550 }, cclasses());
        assert_eq!(joint.dram_classes(), 2);
        assert_eq!(joint.state(0), ClassState::Compressed);
        assert_eq!(joint.state(1), ClassState::Compressed);
        assert_eq!(joint.state(2), ClassState::Secondary);
        assert_eq!(joint.dram_bytes(), 550);
        assert_eq!(joint.policy_dram_bytes(), 550);
        assert_eq!(joint.compressed_classes(), 2);
        assert_eq!(joint.decompress_us(0), 0.12);
        assert_eq!(joint.decompress_us(2), 0.0, "secondary never decompresses");
        // Uncompressed footprint accessors are state-independent.
        assert_eq!(joint.total_bytes(), 11_100);
        assert_eq!(joint.offloadable_bytes(), 11_100);
    }

    #[test]
    fn loose_budget_upgrades_everything_to_plain_dram() {
        // At the full uncompressed footprint the upgrade pass lifts every
        // class: the joint plan coincides with the plain one.
        let joint = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 11_100 }, cclasses());
        assert_eq!(joint.dram_classes(), 3);
        assert_eq!(joint.compressed_classes(), 0);
        for i in 0..3 {
            assert_eq!(joint.state(i), ClassState::Dram);
        }
        assert_eq!(joint.dram_bytes(), 11_100);
    }

    #[test]
    fn partial_upgrade_spends_leftover_hottest_first() {
        // 5,650 B: pass 1 places all three compressed (5,550); the 100 B
        // leftover upgrades hot (delta 50) but not warm (500) or cold
        // (5,000).
        let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 5_650 }, cclasses());
        assert_eq!(p.dram_classes(), 3);
        assert_eq!(p.state(0), ClassState::Dram);
        assert_eq!(p.state(1), ClassState::Compressed);
        assert_eq!(p.state(2), ClassState::Compressed);
        assert_eq!(p.dram_bytes(), 100 + 500 + 5_000);
    }

    #[test]
    fn forced_compression_never_upgrades() {
        let forced: Vec<StructClass> = classes()
            .into_iter()
            .map(|c| c.with_compression(Some(Compression::new(0.5, 0.12).forced())))
            .collect();
        let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: u64::MAX }, forced.clone());
        assert_eq!(p.dram_classes(), 3);
        assert_eq!(p.compressed_classes(), 3, "forced classes stay compressed");
        assert_eq!(p.dram_bytes(), 5_550);
        // Count-based policies honor forced specs too.
        let p = Plan::resolve(PlacementPolicy::TopLevels { k: 2 }, forced.clone());
        assert_eq!(p.state(0), ClassState::Compressed);
        assert_eq!(p.state(1), ClassState::Compressed);
        assert_eq!(p.state(2), ClassState::Secondary);
        let p = Plan::resolve(PlacementPolicy::AllDram, forced);
        assert_eq!(p.compressed_classes(), 3);
        // Joint (non-forced) specs under count-based policies place plain
        // DRAM — no budget pressure, so uncompressed dominates.
        let p = Plan::resolve(PlacementPolicy::AllDram, cclasses());
        assert_eq!(p.compressed_classes(), 0);
    }

    #[test]
    fn joint_replan_follows_the_measured_order() {
        // Same profile as replan_reorders_by_measured_accesses_per_byte:
        // measured order hot ≻ cold ≻ warm. Budget 5,050 fits hot + cold
        // compressed (50 + 5,000); the plain replan would place only hot.
        let mut prof = AccessProfile::new(3);
        for _ in 0..10 {
            prof.tick(0);
        }
        prof.tick(1);
        for _ in 0..200 {
            prof.tick(2);
        }
        let p = Plan::replan(PlacementPolicy::Budget { dram_bytes: 5_050 }, cclasses(), &prof);
        assert_eq!(p.ranking(), &[0, 2, 1]);
        assert!(p.is_compressed(0) && p.is_compressed(2) && !p.in_dram(1));
        assert_eq!(p.policy_dram_bytes(), 5_050);
    }

    #[test]
    fn split3_buckets_hops_and_averages_decompress_cost() {
        // hot compressed at 0.12 µs, warm compressed at 0.36 µs, cold
        // secondary.
        let cs = vec![
            StructClass::new("hot", 100, 4.0)
                .with_compression(Some(Compression::new(0.5, 0.12).forced())),
            StructClass::new("warm", 1_000, 1.0)
                .with_compression(Some(Compression::new(0.5, 0.36).forced())),
            StructClass::new("cold", 10_000, 0.5),
        ];
        let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: 550 }, cs);
        assert!(p.is_compressed(0) && p.is_compressed(1) && !p.in_dram(2));
        let h = p.split3(&[(0, 3.0), (1, 1.0), (2, 2.0)]);
        assert_eq!(h.sec, 2.0);
        assert_eq!(h.dram, 0.0);
        assert_eq!(h.cpr, 4.0);
        // Weighted mean: (3·0.12 + 1·0.36) / 4 = 0.18.
        assert!((h.cpr_us - 0.18).abs() < 1e-12);
        // Two-way split counts compressed hops as DRAM-side.
        let (sec, dram) = p.split_hops(&[(0, 3.0), (1, 1.0), (2, 2.0)]);
        assert_eq!(sec, 2.0);
        assert_eq!(dram, 4.0);
        // No compressed hops → cpr_us stays 0.0, not NaN.
        let h = p.split3(&[(2, 2.0)]);
        assert_eq!(h.cpr_us, 0.0);
    }

    #[test]
    fn compress_mode_spec_attaches_and_forces() {
        assert_eq!(CompressMode::Off.spec(), None);
        let spec = Compression::new(0.5, 0.12);
        assert_eq!(CompressMode::Joint(spec).spec(), Some(spec));
        let f = CompressMode::Forced(spec).spec().unwrap();
        assert!(f.always);
        assert_eq!(f.ratio_q, 0.5);
    }

    #[test]
    fn compressed_dram_bytes_stay_monotone_in_budget() {
        let mut prev = 0u64;
        for budget in (0..=12_000u64).step_by(37) {
            let p = Plan::resolve(PlacementPolicy::Budget { dram_bytes: budget }, cclasses());
            let b = p.dram_bytes();
            assert!(b <= budget, "joint placement overshot: {b} > {budget}");
            assert!(b >= prev, "dram bytes fell as budget grew: {prev} -> {b}");
            prev = b;
        }
    }

    #[test]
    fn profile_bookkeeping() {
        let mut p = AccessProfile::new(2);
        assert!(p.is_empty());
        p.tick(0);
        p.tick(5); // auto-grow
        assert_eq!(p.accesses(0), 1);
        assert_eq!(p.accesses(5), 1);
        assert_eq!(p.accesses(3), 0);
        assert_eq!(p.total(), 2);
        assert!(!p.is_empty());
        p.clear();
        assert!(p.is_empty());
    }
}
