//! CacheLib-like two-tier KV cache (paper §4.2, Fig 13 right).
//!
//! Tier 1 holds small items in memory: the bucket *array* stays in host DRAM
//! (it is what remains of the paper's CacheLib DRAM footprint), while the
//! chained items — which embed the LRU links — live on secondary memory, so
//! every chain hop and LRU splice is a dependent long-latency access. Tier 2
//! is an SSD Small Object Cache: tier-1 misses read a 4 kB page; tier-1
//! evictions are admitted to tier 2 with a configurable probability (flash
//! write endurance admission), writing a page. A miss in both tiers "fetches
//! from the backend" (compute only) and inserts into tier 1.
//!
//! LRU promotion uses CacheLib's refresh-ratio trick: a hit only splices the
//! item to the head with probability `lru_refresh_prob`, cutting lock
//! traffic.
//!
//! The full operation surface (beyond the paper's GET/PUT reproduction):
//!
//! - **Delete** is cache invalidation: chain walk, unlink from tier 1 under
//!   the LRU lock, and tier-2 index invalidation (the SOC entry is marked
//!   stale in its DRAM index — no flash IO, matching CacheLib's `remove`).
//!   A subsequent get misses both tiers (counted in `stats.absent`) and
//!   read-throughs from the backend.
//! - **ReadModifyWrite** is a read (either tier or backend) followed by an
//!   update-in-place: on a tier-1 hit the item is spliced to the LRU head
//!   under the lock (the write), on a miss the fetched value is inserted.
//! - **Scan is unsupported**: CacheLib's hash layout has no ordered
//!   iteration. `OpKind::Scan` is a documented no-op costing one API call
//!   of compute; it is counted in `stats.scans` so workload-E sweeps can
//!   report the store as degenerate rather than silently misbehaving.

use super::common::{fnv1a, DriveCounts, KvStats, NIL};
use super::placement::{AccessProfile, CompressMode, HopSplit, Plan, PlacementPolicy, StructClass};
use super::wal::{Durable, Wal, WalConfig, WalKind, WalRecord};
use crate::model::KindCost;
use crate::sim::{BgKind, Dur, IoKind, Rng, Service, Step, TrafficClass};
use crate::workload::{
    KeyDist, KeyGen, OpKind, OpMix, OpWeights, TenantRouter, TenantSet, TenantTracker, ValueSize,
};

/// Placement structure classes (`kvs::placement`), hottest-first: the
/// tier-1 hash chains (CacheLib's AccessContainer — walked on every
/// lookup, write, and invalidation) and the tier-1 LRU lists (MMContainer
/// — touched on refreshes and eviction-candidate walks). The bucket
/// directory and the tier-2 SOC index are the paper's residual DRAM
/// footprint — **pinned** classes: outside the policy's placement
/// decision, inside the DRAM-byte accounting and the [`AccessProfile`].
const CC_CHAINS: usize = 0;
const CC_LRU: usize = 1;
const CC_DIRECTORY: usize = 2;
const CC_SOC_INDEX: usize = 3;

/// Store-extra CPU attributed to tier-2 page IO pre/post suboperations
/// (µs). **Single source** for both the `Step::Io` sites below (`T2Read`,
/// `SocWrite`) and the model snapshots: page index + offset math before a
/// read, page scan + item copy + admit after it; buffered enqueue around a
/// write.
const PAGE_READ_EXTRA_PRE_US: f64 = 1.0;
const PAGE_READ_EXTRA_POST_US: f64 = 2.0;
const PAGE_WRITE_EXTRA_PRE_US: f64 = 0.5;
const PAGE_WRITE_EXTRA_POST_US: f64 = 0.3;

#[derive(Debug, Clone)]
pub struct CacheKvConfig {
    /// Distinct keys the workload touches.
    pub n_items: u64,
    /// Tier-1 capacity in items.
    pub t1_items: u32,
    /// Tier-2 (SSD) capacity in items.
    pub t2_items: u32,
    /// Tier-1 hash buckets.
    pub buckets: u32,
    pub key_dist: KeyDist,
    /// Read:write mix (paper figures). Ignored when `ops` is set.
    pub mix: OpMix,
    /// Full-surface operation weights (YCSB presets); `None` follows `mix`.
    pub ops: Option<OpWeights>,
    pub value_size: ValueSize,
    pub t_node: Dur,
    /// Probability a hit refreshes the LRU position.
    pub lru_refresh_prob: f64,
    /// Probability an evicted item is admitted to tier 2.
    pub t2_admit_prob: f64,
    /// SSD page size for tier-2 reads/writes.
    pub page_bytes: u32,
    /// Tier placement of the tier-1 item structures (`kvs::placement`):
    /// hash chains ≻ LRU lists. The write-path invalidations route through
    /// the same policy (they previously assumed secondary-tier hops).
    pub placement: PlacementPolicy,
    /// Write-ahead log (`kvs::wal`; disabled by default). For a cache the
    /// recovery contract is deliberately weaker: an acked **delete** must
    /// never resurrect after replay; an acked write is present-or-evicted
    /// (capacity eviction of a durable put is legal cache behavior).
    pub wal: WalConfig,
    /// Multi-tenant workload multiplexing (`workload::tenants`); `None`
    /// (the default) is the legacy single-tenant path, bit-identical to
    /// pre-tenant behaviour. The cache has no scan path, so tenant
    /// `scan_len` is ignored here.
    pub tenants: Option<TenantSet>,
    /// Per-class compression for the offloadable tier-1 structures
    /// (`kvs::placement`): chains and LRU lists may be held compressed in
    /// DRAM at `ratio_q` of their bytes for a per-access decompress cost.
    /// The pinned directory and SOC index never compress. `Off` (default)
    /// is bit-identical to pre-compression behaviour.
    pub compression: CompressMode,
}

impl Default for CacheKvConfig {
    fn default() -> Self {
        CacheKvConfig {
            // Paper's smaller workload: 100M items, 8 GB tier-1, 32 GB
            // tier-2, hit ratios 34% (t1) / 73% (t2 upon t1 miss). Scaled
            // 1000×: capacities keep the same ratios to the keyspace.
            n_items: 100_000,
            t1_items: 12_000,
            t2_items: 55_000,
            buckets: 16_384,
            key_dist: KeyDist::Gaussian { sigma_frac: 0.22 },
            mix: OpMix::ratio(2, 1),
            ops: None,
            value_size: ValueSize::Range(200, 300),
            t_node: Dur::ns(60.0),
            lru_refresh_prob: 0.1,
            t2_admit_prob: 0.9,
            page_bytes: 4096,
            placement: PlacementPolicy::AllSecondary,
            wal: WalConfig::default(),
            tenants: None,
            compression: CompressMode::Off,
        }
    }
}

/// Tier-1 item: chained hash entry with intrusive LRU links.
#[derive(Debug, Clone, Copy)]
struct Item {
    key: u64,
    hash_next: u32,
    lru_prev: u32,
    lru_next: u32,
    live: bool,
}

pub struct CacheKv {
    pub cfg: CacheKvConfig,
    keygen: KeyGen,
    buckets: Vec<u32>,
    items: Vec<Item>,
    free: Vec<u32>,
    lru_head: u32,
    lru_tail: u32,
    t1_len: u32,
    /// Tier-2 content: FIFO ring + membership map (the on-SSD truth; the
    /// in-DRAM SOC index is a small structure the paper leaves in DRAM).
    /// Ring entries carry an admission generation; invalidations remove
    /// only the index entry (flash blocks are not erased in place), so a
    /// ring entry whose generation no longer matches the index is stale
    /// and is skipped at eviction time. The ring is hard-bounded at
    /// `t2_items` entries.
    t2_ring: std::collections::VecDeque<(u64, u32)>,
    t2_set: std::collections::HashMap<u64, u32>,
    t2_gen: u32,
    /// Resolved tier placement over the tier-1 structure classes
    /// (re-resolved over measured access densities by [`CacheKv::replan`]).
    plan: Plan,
    /// Measured per-class access counts — every `MemAccess` site ticks its
    /// class, the pinned bucket directory included.
    pub profile: AccessProfile,
    pub stats: KvStats,
    /// Decompress CPU owed by the last access to a compressed class,
    /// drained as an inline `Step::Compute` at the top of the next step.
    pending_cpu: Option<Dur>,
    /// The store's write-ahead log (`kvs::wal`; inert when disabled).
    pub wal: Wal,
    /// Tenant scheduler + per-tenant key generators (`cfg.tenants`).
    tenants: Option<TenantRouter>,
    /// Which tenant owns each thread's in-flight op (`Service::op_tenant`).
    tenant_tids: TenantTracker,
}

#[derive(Debug)]
pub enum CacheOp {
    /// Bucket array probe (DRAM) then chain walk (secondary). `kind` is
    /// `Read`, `Write`, or `Rmw`.
    Lookup {
        kind: OpKind,
        key: u64,
        cur: u32,
        bucket_read: bool,
    },
    /// Hit: maybe refresh LRU (lock + 3 dependent accesses). `durable` —
    /// this is a write/RMW update-in-place that must WAL-commit before ack.
    Refresh { key: u64, hops: u8, durable: bool },
    /// Tier-1 miss: read the tier-2 page.
    T2Read { key: u64, durable: bool },
    /// After the page read (or backend fetch): insert into tier 1.
    Insert {
        key: u64,
        hops: u8,
        evict_write: bool,
        locked: bool,
        durable: bool,
    },
    /// Both tiers missed: backend fetch (compute), then insert.
    Backend { key: u64, durable: bool },
    /// Deferred SOC page write for an admitted tier-1 eviction; `shard` is
    /// the slab hash routing the page to its device of the SSD array.
    /// `commit` carries the op's WAL record into commit-wait afterwards.
    SocWrite { shard: u64, commit: Option<u64> },
    /// Invalidation: chain walk, locked tier-1 unlink, tier-2 index removal.
    Delete {
        key: u64,
        cur: u32,
        bucket_read: bool,
        hops: u8,
    },
    /// Unsupported ordered scan: one API-call of compute, then done.
    ScanNoop,
    /// WAL commit wait (`kvs::wal` protocol; entered lock-free).
    WalCommit { lsn: u64 },
    /// This op leads the flush of records `[.., upto)`; its own is `lsn`.
    WalFlush { upto: u64, lsn: u64 },
    Finished,
}

impl CacheKv {
    /// The placement structure classes (see the `CC_*` consts): each
    /// intrusive 64-byte item splits evenly between its chain half
    /// (key + hash link) and its LRU half (prev/next links).
    fn placement_classes(cfg: &CacheKvConfig) -> Vec<StructClass> {
        let items = cfg.t1_items as u64;
        let spec = cfg.compression.spec();
        vec![
            StructClass::new("t1-hash-chains", items * 32, 2.0).with_compression(spec),
            StructClass::new("t1-lru-lists", items * 32, 1.0).with_compression(spec),
            // The residual DRAM footprint: the bucket directory (one
            // pointer per bucket) and the tier-2 SOC index (key → page
            // entry per admitted item). Pinned — DRAM under every policy,
            // reported by `dram_bytes()`, never consuming the budget.
            StructClass::pinned("t1-bucket-directory", cfg.buckets as u64 * 8),
            StructClass::pinned("t2-soc-index", cfg.t2_items as u64 * 16),
        ]
    }

    pub fn new(cfg: CacheKvConfig, rng: &mut Rng) -> CacheKv {
        let plan = Plan::resolve(cfg.placement, Self::placement_classes(&cfg));
        debug_assert!(
            plan.classes()[CC_DIRECTORY].pinned && plan.classes()[CC_SOC_INDEX].pinned,
            "the residual classes must be pinned (class-id order contract)"
        );
        let profile = AccessProfile::new(plan.classes().len());
        let keygen = KeyGen::new(cfg.n_items, cfg.key_dist);
        let mut kv = CacheKv {
            buckets: vec![NIL; cfg.buckets as usize],
            items: Vec::with_capacity(cfg.t1_items as usize + 1),
            free: Vec::new(),
            lru_head: NIL,
            lru_tail: NIL,
            t1_len: 0,
            t2_ring: std::collections::VecDeque::with_capacity(cfg.t2_items as usize + 1),
            t2_set: std::collections::HashMap::new(),
            t2_gen: 0,
            plan,
            profile,
            stats: KvStats::default(),
            pending_cpu: None,
            wal: Wal::new(cfg.wal.clone()),
            tenants: cfg.tenants.as_ref().map(|set| TenantRouter::new(set, cfg.n_items)),
            tenant_tids: TenantTracker::default(),
            keygen,
            cfg,
        };
        // Structural warmup: populate both tiers from the key distribution
        // (the paper warms CacheLib for up to 6 hours; we shortcut the bulk
        // and let the sim warmup settle the rest).
        let mut wrng = rng.fork(0xcac4e);
        let draws = (kv.cfg.t1_items as u64 + kv.cfg.t2_items as u64) * 3;
        for _ in 0..draws {
            let key = kv.keygen.sample(&mut wrng);
            if kv.t1_lookup(key).is_none() {
                kv.t1_insert(key, &mut wrng);
            }
        }
        kv
    }

    /// Effective operation weights: explicit `ops` or the two-kind `mix`.
    fn weights(&self) -> OpWeights {
        match self.cfg.ops {
            Some(w) => w,
            None => OpWeights::from(self.cfg.mix),
        }
    }

    #[inline]
    fn bucket_of(&self, key: u64) -> usize {
        (fnv1a(key) % self.cfg.buckets as u64) as usize
    }

    fn t1_lookup(&self, key: u64) -> Option<u32> {
        let mut cur = self.buckets[self.bucket_of(key)];
        while cur != NIL {
            let it = &self.items[cur as usize];
            if it.live && it.key == key {
                return Some(cur);
            }
            cur = it.hash_next;
        }
        None
    }

    fn lru_unlink(&mut self, id: u32) {
        let it = self.items[id as usize];
        if it.lru_prev != NIL {
            self.items[it.lru_prev as usize].lru_next = it.lru_next;
        } else {
            self.lru_head = it.lru_next;
        }
        if it.lru_next != NIL {
            self.items[it.lru_next as usize].lru_prev = it.lru_prev;
        } else {
            self.lru_tail = it.lru_prev;
        }
    }

    fn lru_push_front(&mut self, id: u32) {
        self.items[id as usize].lru_prev = NIL;
        self.items[id as usize].lru_next = self.lru_head;
        if self.lru_head != NIL {
            self.items[self.lru_head as usize].lru_prev = id;
        } else {
            self.lru_tail = id;
        }
        self.lru_head = id;
    }

    fn bucket_remove(&mut self, id: u32) {
        let key = self.items[id as usize].key;
        let b = self.bucket_of(key);
        let mut cur = self.buckets[b];
        if cur == id {
            self.buckets[b] = self.items[id as usize].hash_next;
            return;
        }
        while cur != NIL {
            let next = self.items[cur as usize].hash_next;
            if next == id {
                self.items[cur as usize].hash_next = self.items[id as usize].hash_next;
                return;
            }
            cur = next;
        }
    }

    /// Unlink and free one tier-1 item (delete path / eviction core).
    fn t1_remove(&mut self, id: u32) {
        self.lru_unlink(id);
        self.bucket_remove(id);
        self.items[id as usize].live = false;
        self.free.push(id);
        self.t1_len -= 1;
    }

    /// Insert into tier 1, evicting the LRU tail if full. Returns whether an
    /// eviction was admitted to tier 2 (→ SSD page write).
    fn t1_insert(&mut self, key: u64, rng: &mut Rng) -> bool {
        let mut evict_write = false;
        if self.t1_len >= self.cfg.t1_items {
            let tail = self.lru_tail;
            if tail != NIL {
                let victim = self.items[tail as usize].key;
                self.t1_remove(tail);
                if rng.chance(self.cfg.t2_admit_prob) {
                    self.t2_insert(victim);
                    evict_write = true;
                }
            }
        }
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.items.push(Item {
                    key: 0,
                    hash_next: NIL,
                    lru_prev: NIL,
                    lru_next: NIL,
                    live: false,
                });
                (self.items.len() - 1) as u32
            }
        };
        let b = self.bucket_of(key);
        self.items[id as usize] = Item {
            key,
            hash_next: self.buckets[b],
            lru_prev: NIL,
            lru_next: NIL,
            live: true,
        };
        self.buckets[b] = id;
        self.lru_push_front(id);
        self.t1_len += 1;
        evict_write
    }

    fn t2_insert(&mut self, key: u64) {
        if self.t2_set.contains_key(&key) {
            return;
        }
        // Hard-bound the ring: rotate out the FIFO head until a slot frees.
        // Stale heads (generation no longer in the index — invalidated, or
        // re-admitted later under a newer generation) drain without
        // touching the index, so an old twin can never evict a live entry.
        while self.t2_ring.len() >= self.cfg.t2_items as usize {
            match self.t2_ring.pop_front() {
                Some((old, gen)) => {
                    if self.t2_set.get(&old) == Some(&gen) {
                        self.t2_set.remove(&old);
                    }
                }
                None => break,
            }
        }
        self.t2_gen = self.t2_gen.wrapping_add(1);
        self.t2_ring.push_back((key, self.t2_gen));
        self.t2_set.insert(key, self.t2_gen);
    }

    /// Remove the tier-2 index entry (invalidation); the ring entry goes
    /// stale. Returns whether the key was tier-2 resident.
    fn t2_invalidate(&mut self, key: u64) -> bool {
        self.t2_set.remove(&key).is_some()
    }

    pub fn t1_hit_ratio(&self) -> f64 {
        if self.stats.gets == 0 {
            0.0
        } else {
            self.stats.t1_hits as f64 / self.stats.gets as f64
        }
    }

    /// Tier-2 hit ratio *upon tier-1 misses* (the paper's 73% number).
    pub fn t2_hit_ratio(&self) -> f64 {
        let t1_misses = self.stats.gets - self.stats.t1_hits;
        if t1_misses == 0 {
            0.0
        } else {
            self.stats.t2_hits as f64 / t1_misses as f64
        }
    }

    /// Cache-residency oracle (tests; not simulated).
    pub fn contains_key(&self, key: u64) -> bool {
        self.t1_lookup(key).is_some() || self.t2_set.contains_key(&key)
    }

    /// Simulated DRAM bytes this configuration consumes — honest: the
    /// policy-placed tier-1 structures *plus* the pinned residual (bucket
    /// directory + SOC index; nonzero even under `AllSecondary`).
    pub fn dram_bytes(&self) -> u64 {
        self.plan.dram_bytes()
    }

    /// The pinned residual footprint (bucket directory + tier-2 SOC index).
    pub fn residual_dram_bytes(&self) -> u64 {
        self.plan.pinned_bytes()
    }

    /// Total offloadable bytes (what `Budget` fractions resolve against;
    /// excludes the pinned residual).
    pub fn offload_bytes_total(&self) -> u64 {
        self.plan.offloadable_bytes()
    }

    /// The resolved placement plan (static, or measured after
    /// [`CacheKv::replan`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Re-resolve the tier-1 placement over the **measured** per-class
    /// access profile (`kvs::placement` module docs, "Measured
    /// re-ranking"): under write-heavy mixes the LRU lists — four
    /// eviction-candidate hops behind every insert, a splice behind every
    /// update — out-access the hash chains per byte, flipping the static
    /// order. Class-granular, so it is a plan swap; the `ModelCosts`
    /// snapshots split `m`/`m_dram` from the replanned plan.
    pub fn replan(&mut self, profile: &AccessProfile) {
        self.plan = Plan::replan(self.cfg.placement, Self::placement_classes(&self.cfg), profile);
    }

    /// Swap the workload mid-run (phased schedules): new operation weights
    /// and key distribution over the same store. `KeyGen::new` draws no
    /// randomness, so the simulation's RNG stream is untouched and
    /// determinism holds.
    pub fn set_workload(&mut self, ops: Option<OpWeights>, key_dist: KeyDist) {
        self.cfg.ops = ops;
        self.cfg.key_dist = key_dist;
        self.keygen = KeyGen::new(self.cfg.n_items, key_dist);
    }

    /// [`CacheKv::replan`] with honest migration accounting (`kvs::placement`
    /// module docs, "Online replanning"). Placement is class-granular over
    /// the two intrusive tier-1 halves: a tier flip copies every 64-byte
    /// line of the flipped class — one read on the tier it leaves plus one
    /// write on the tier it lands (one `dram` + one `secondary` touch
    /// whichever direction). Item metadata is authoritative in memory, so
    /// no SSD traffic moves (`reads`/`writes` stay 0); the pinned directory
    /// and SOC index never move. An unchanged plan costs nothing.
    pub fn replan_migrate(&mut self, profile: &AccessProfile) -> DriveCounts {
        let before: Vec<bool> = (0..CC_DIRECTORY).map(|c| self.plan.in_dram(c)).collect();
        self.replan(profile);
        let mut mig = DriveCounts::default();
        for (c, &was) in before.iter().enumerate() {
            if self.plan.in_dram(c) == was {
                continue;
            }
            let lines = ((self.plan.classes()[c].bytes + 63) / 64) as u32;
            mig.dram += lines;
            mig.secondary += lines;
        }
        mig
    }

    /// One simulated access to a placement class: tag the [`AccessProfile`]
    /// and charge the access at the class's planned tier.
    #[inline]
    fn class_access(&mut self, class: usize) -> Step {
        self.profile.tick(class);
        if self.plan.is_compressed(class) {
            self.pending_cpu = Some(Dur::us(self.plan.decompress_us(class)));
        }
        Step::MemAccess(self.plan.tier(class))
    }

    // ---- directed operation constructors (also used by next_op) ----------

    pub fn op_get(&mut self, key: u64) -> CacheOp {
        self.stats.gets += 1;
        CacheOp::Lookup {
            kind: OpKind::Read,
            key,
            cur: NIL,
            bucket_read: false,
        }
    }

    pub fn op_put(&mut self, key: u64) -> CacheOp {
        self.stats.sets += 1;
        CacheOp::Lookup {
            kind: OpKind::Write,
            key,
            cur: NIL,
            bucket_read: false,
        }
    }

    /// Note: like the other stores, `gets` counts only pure reads — RMW
    /// issues are counted in `rmws` alone (their lookups still move the
    /// hit/miss counters).
    pub fn op_rmw(&mut self, key: u64) -> CacheOp {
        self.stats.rmws += 1;
        CacheOp::Lookup {
            kind: OpKind::Rmw,
            key,
            cur: NIL,
            bucket_read: false,
        }
    }

    pub fn op_delete(&mut self, key: u64) -> CacheOp {
        self.stats.deletes += 1;
        CacheOp::Delete {
            key,
            cur: NIL,
            bucket_read: false,
            hops: 0,
        }
    }

    pub fn op_scan(&mut self) -> CacheOp {
        self.stats.scans += 1;
        CacheOp::ScanNoop
    }
}

/// Lock sharding (CacheLib uses per-bucket spinlocks and a sharded LRU; a
/// pair of global locks would serialize the store once a lock is held
/// across microsecond-latency accesses).
const LOCK_SHARDS: u32 = 32;

#[inline]
fn lru_lock(key: u64) -> u32 {
    (fnv1a(key ^ 0x11) % LOCK_SHARDS as u64) as u32
}

#[inline]
fn evict_lock(key: u64) -> u32 {
    LOCK_SHARDS + (fnv1a(key ^ 0x22) % LOCK_SHARDS as u64) as u32
}

impl Service for CacheKv {
    type Op = CacheOp;

    fn next_op(&mut self, tid: usize, rng: &mut Rng) -> CacheOp {
        // Tenant selection is RNG-free (SWRR), so the single-tenant path
        // consumes the exact legacy draw sequence: key, kind.
        let tenant = self.tenants.as_mut().map(|r| r.pick());
        self.tenant_tids.note(tid, tenant);
        let (key, kind) = if let Some(t) = tenant {
            let router = self.tenants.as_ref().unwrap();
            let key = router.sample_key(t, rng);
            (key, router.spec(t).ops.sample(rng))
        } else {
            (self.keygen.sample(rng), self.weights().sample(rng))
        };
        match kind {
            OpKind::Read => self.op_get(key),
            OpKind::Write => self.op_put(key),
            OpKind::Delete => self.op_delete(key),
            OpKind::Rmw => self.op_rmw(key),
            OpKind::Scan => self.op_scan(),
        }
    }

    fn op_tenant(&self, tid: usize) -> Option<u32> {
        self.tenant_tids.current(tid)
    }

    fn step(&mut self, _tid: usize, op: &mut CacheOp, rng: &mut Rng) -> Step {
        // Inline decompress CPU owed by the previous compressed-class
        // access: a dependent Compute on the op's critical path (the op
        // state already advanced, so this purely adds busy time).
        if let Some(d) = self.pending_cpu.take() {
            return Step::Compute(d);
        }
        match op {
            CacheOp::Lookup {
                kind,
                key,
                cur,
                bucket_read,
            } => {
                if !*bucket_read {
                    *bucket_read = true;
                    *cur = self.buckets[self.bucket_of(*key)];
                    // Bucket array lives in host DRAM.
                    return self.class_access(CC_DIRECTORY);
                }
                let id = *cur;
                let k = *key;
                let kd = *kind;
                if id == NIL {
                    // Tier-1 miss (counted for every kind — see
                    // KvStats::t1_probes).
                    self.stats.t1_probes += 1;
                    // Writes and the RMW's write half are durable mutations
                    // (WAL-committed before ack when the log is enabled).
                    let durable = kd != OpKind::Read;
                    match kd {
                        OpKind::Read | OpKind::Rmw => {
                            if self.t2_set.contains_key(&k) {
                                *op = CacheOp::T2Read { key: k, durable };
                            } else {
                                // Absent from both tiers (deleted or never
                                // cached): read-through from the backend.
                                self.stats.misses += 1;
                                self.stats.absent += 1;
                                *op = CacheOp::Backend { key: k, durable };
                            }
                        }
                        _ => {
                            // Set of a non-resident key: insert fresh.
                            *op = CacheOp::Insert {
                                key: k,
                                hops: 0,
                                evict_write: false,
                                locked: false,
                                durable,
                            };
                        }
                    }
                    return Step::Compute(self.cfg.t_node);
                }
                let it = self.items[id as usize];
                if it.live && it.key == k {
                    // Tier-1 hit (read) or update-in-place (write / RMW's
                    // write half).
                    self.stats.hits += 1;
                    self.stats.t1_hits += 1;
                    self.stats.t1_probes += 1;
                    if rng.chance(self.cfg.lru_refresh_prob) || kd != OpKind::Read {
                        *op = CacheOp::Refresh {
                            key: k,
                            hops: 0,
                            durable: kd != OpKind::Read,
                        };
                        // Neighbor reads happen unlocked; only the final
                        // splice runs under the (sharded) LRU lock —
                        // holding a lock across prefetch+yield accesses
                        // would make hold time grow with memory latency.
                        return self.class_access(CC_CHAINS);
                    }
                    *op = CacheOp::Finished;
                    self.stats.verified += 1;
                    return self.class_access(CC_CHAINS);
                }
                *cur = it.hash_next;
                // Chain hop: dependent access at the chain class's tier.
                self.class_access(CC_CHAINS)
            }
            CacheOp::Refresh { key, hops, durable } => {
                let k = *key;
                let durable = *durable;
                match *hops {
                    0 => {
                        *hops = 1;
                        // Read the prev neighbor (LRU links).
                        self.class_access(CC_LRU)
                    }
                    1 => {
                        *hops = 2;
                        Step::Lock(lru_lock(k))
                    }
                    2 => {
                        *hops = 3;
                        // Splice under the lock: the neighbors were just read
                        // unlocked, so the writes hit cache — short critical
                        // section (compute), then release.
                        if let Some(id) = self.t1_lookup(k) {
                            self.lru_unlink(id);
                            self.lru_push_front(id);
                        }
                        Step::Compute(self.cfg.t_node)
                    }
                    _ => {
                        self.stats.verified += 1;
                        // Mutation done and lock released below: writes
                        // enter commit-wait, read refreshes just finish.
                        *op = if durable && self.wal.enabled() {
                            let vsize = self.cfg.value_size.mean() as u32;
                            let lsn = self.wal.append(WalKind::Put, k, vsize);
                            CacheOp::WalCommit { lsn }
                        } else {
                            CacheOp::Finished
                        };
                        Step::Unlock(lru_lock(k))
                    }
                }
            }
            CacheOp::T2Read { key, durable } => {
                let k = *key;
                self.stats.hits += 1;
                self.stats.t2_hits += 1;
                *op = CacheOp::Insert {
                    key: k,
                    hops: 0,
                    evict_write: false,
                    locked: false,
                    durable: *durable,
                };
                Step::Io {
                    kind: IoKind::Read,
                    bytes: self.cfg.page_bytes,
                    // See PAGE_READ_EXTRA_* above.
                    extra_pre: Dur::us(PAGE_READ_EXTRA_PRE_US),
                    extra_post: Dur::us(PAGE_READ_EXTRA_POST_US),
                    // The key's SOC slab hash picks the owning device.
                    shard: fnv1a(k),
                    class: TrafficClass::Foreground,
                }
            }
            CacheOp::Backend { key, durable } => {
                let k = *key;
                *op = CacheOp::Insert {
                    key: k,
                    hops: 0,
                    evict_write: false,
                    locked: false,
                    durable: *durable,
                };
                // Backend fetch: the paper's CacheBench treats this as a set;
                // charge marshalling compute only.
                Step::Compute(Dur::us(2.0))
            }
            CacheOp::Insert {
                key,
                hops,
                evict_write,
                locked,
                durable,
            } => {
                // Walk/eviction-candidate reads happen unlocked (4 dependent
                // accesses over the LRU lists); only the final structural
                // mutation runs under the sharded eviction lock.
                if *hops < 4 {
                    *hops += 1;
                    return self.class_access(CC_LRU);
                }
                if !*locked {
                    *locked = true;
                    return Step::Lock(evict_lock(*key));
                }
                if *hops == 4 {
                    *hops = 5;
                    let k = *key;
                    if self.t1_lookup(k).is_none() {
                        *evict_write = self.t1_insert(k, rng);
                    }
                    // Short critical section: mutation over cached lines.
                    return Step::Compute(self.cfg.t_node * 2);
                }
                let write_page = *evict_write;
                self.stats.verified += 1;
                // Release the lock first (CacheLib enqueues the flash write
                // outside the eviction critical section), then issue the
                // deferred SOC page write if the eviction was admitted.
                // Durable inserts append their record now (the mutation is
                // done) and commit-wait after the unlock / page write.
                let k = *key;
                let commit = if *durable && self.wal.enabled() {
                    let vsize = self.cfg.value_size.mean() as u32;
                    Some(self.wal.append(WalKind::Put, k, vsize))
                } else {
                    None
                };
                *op = if write_page {
                    CacheOp::SocWrite {
                        shard: fnv1a(k),
                        commit,
                    }
                } else if let Some(lsn) = commit {
                    CacheOp::WalCommit { lsn }
                } else {
                    CacheOp::Finished
                };
                Step::Unlock(evict_lock(k))
            }
            CacheOp::SocWrite { shard, commit } => {
                let s = *shard;
                *op = match *commit {
                    Some(lsn) => CacheOp::WalCommit { lsn },
                    None => CacheOp::Finished,
                };
                Step::Io {
                    kind: IoKind::Write,
                    bytes: self.cfg.page_bytes,
                    extra_pre: Dur::us(PAGE_WRITE_EXTRA_PRE_US),
                    extra_post: Dur::us(PAGE_WRITE_EXTRA_POST_US),
                    shard: s,
                    // SOC slab refill: a buffered eviction-path page write —
                    // the cache's flush lane, not foreground service.
                    class: TrafficClass::Background(BgKind::Flush),
                }
            }
            CacheOp::Delete {
                key,
                cur,
                bucket_read,
                hops,
            } => {
                let k = *key;
                if !*bucket_read {
                    *bucket_read = true;
                    *cur = self.buckets[self.bucket_of(k)];
                    return self.class_access(CC_DIRECTORY);
                }
                match *hops {
                    0 => {
                        // Chain walk toward the item.
                        let id = *cur;
                        if id == NIL {
                            // Not tier-1 resident: invalidate the tier-2
                            // index entry (a DRAM structure update). The
                            // invalidation is still acked — it must not
                            // resurrect after a crash, so it WAL-commits.
                            let was_t2 = self.t2_invalidate(k);
                            if !was_t2 {
                                self.stats.absent += 1;
                            }
                            *op = if self.wal.enabled() {
                                let lsn = self.wal.append(WalKind::Delete, k, 0);
                                CacheOp::WalCommit { lsn }
                            } else {
                                CacheOp::Finished
                            };
                            return Step::Compute(self.cfg.t_node);
                        }
                        let it = self.items[id as usize];
                        if it.live && it.key == k {
                            // Found: take the LRU lock for the unlink.
                            *hops = 1;
                            return Step::Lock(lru_lock(k));
                        }
                        *cur = it.hash_next;
                        // Invalidation chain hops route through the same
                        // placement policy as the read path (previously
                        // hardcoded secondary even when the chains would be
                        // DRAM-resident under any sane budget).
                        self.class_access(CC_CHAINS)
                    }
                    1 => {
                        // Unlink under the lock; also drop any tier-2 copy.
                        *hops = 2;
                        if let Some(id) = self.t1_lookup(k) {
                            self.t1_remove(id);
                        }
                        self.t2_invalidate(k);
                        Step::Compute(self.cfg.t_node)
                    }
                    _ => {
                        *op = if self.wal.enabled() {
                            let lsn = self.wal.append(WalKind::Delete, k, 0);
                            CacheOp::WalCommit { lsn }
                        } else {
                            CacheOp::Finished
                        };
                        Step::Unlock(lru_lock(k))
                    }
                }
            }
            CacheOp::ScanNoop => {
                // Unsupported on a hash-layout cache: the API call returns
                // immediately (see module docs).
                *op = CacheOp::Finished;
                Step::Compute(self.cfg.t_node)
            }
            CacheOp::WalCommit { lsn } => {
                let lsn = *lsn;
                if self.wal.is_durable(lsn) {
                    self.wal.mark_acked(lsn);
                    *op = CacheOp::Finished;
                    return Step::Compute(self.cfg.t_node);
                }
                if let Some((upto, bytes)) = self.wal.try_lead(lsn) {
                    *op = CacheOp::WalFlush { upto, lsn };
                    return Step::Io {
                        kind: IoKind::Write,
                        bytes,
                        extra_pre: Dur::ZERO,
                        extra_post: Dur::ZERO,
                        shard: self.wal.cfg.log_shard,
                        class: TrafficClass::Background(BgKind::WalFlush),
                    };
                }
                self.wal.note_poll();
                Step::Yield
            }
            CacheOp::WalFlush { upto, lsn } => {
                self.wal.flush_done(*upto);
                self.wal.mark_acked(*lsn);
                *op = CacheOp::Finished;
                Step::Compute(self.cfg.t_node)
            }
            CacheOp::Finished => Step::Done,
        }
    }

    fn io_failed(&mut self, _tid: usize, op: &mut CacheOp) {
        // Graceful degradation: surface the error per-op and terminate
        // without acking. No cachekv IO is issued while holding a lock
        // (T2Read fires before the eviction lock, the SOC write after the
        // unlock), so terminating here leaks nothing. A failed log flush
        // releases WAL leadership for re-election.
        self.stats.io_errors += 1;
        if let CacheOp::WalFlush { upto, .. } = *op {
            self.wal.flush_aborted(upto);
        }
        self.stats.failed_ops += 1;
        *op = CacheOp::Finished;
    }
}

impl Durable for CacheKv {
    fn wal(&self) -> &Wal {
        &self.wal
    }

    fn wal_mut(&mut self) -> &mut Wal {
        &mut self.wal
    }

    fn wal_present(&self, key: u64) -> bool {
        self.contains_key(key)
    }

    /// Cache recovery: replayed puts re-enter tier 1 (later capacity
    /// evictions are legal), replayed deletes invalidate both tiers — the
    /// no-resurrection half of the contract, which is strict.
    fn replay_record(&mut self, rec: &WalRecord, rng: &mut Rng) {
        match rec.kind {
            WalKind::Put => {
                if self.t1_lookup(rec.key).is_none() {
                    self.t1_insert(rec.key, rng);
                }
            }
            WalKind::Delete => {
                if let Some(id) = self.t1_lookup(rec.key) {
                    self.t1_remove(id);
                }
                self.t2_invalidate(rec.key);
            }
        }
    }
}

// Tier-2 page writes are issued outside the lock by a follow-up step: the
// evict_write flag converts the op into one more IO before Done.
impl CacheKv {
    /// Issue the deferred tier-2 page write if the last insert evicted.
    /// (Kept as an explicit helper for the flush-queue extension.)
    pub fn soc_write_bytes(&self) -> u32 {
        self.cfg.page_bytes
    }
}

// ---- Θ_scan model-parameter snapshots (kvs::ModelCosts) -------------------

/// Device-base (the `SsdConfig` defaults, 1.5/0.2) plus the *same* SOC
/// page extras the `Step::Io` sites charge.
const IO_PAGE_READ_PRE: f64 = 1.5 + PAGE_READ_EXTRA_PRE_US;
const IO_PAGE_READ_POST: f64 = 0.2 + PAGE_READ_EXTRA_POST_US;
const IO_PAGE_WRITE_PRE: f64 = 1.5 + PAGE_WRITE_EXTRA_PRE_US;
const IO_PAGE_WRITE_POST: f64 = 0.2 + PAGE_WRITE_EXTRA_POST_US;
/// Host-DRAM access latency assumed by the snapshots (the machine default).
const DRAM_US: f64 = 0.09;

impl CacheKv {
    /// Replicate the `Lookup` chain-access charging for one key: a hit
    /// costs its 1-based chain position, a miss the full chain length (the
    /// bucket-array read itself is DRAM).
    fn probe_lookup(&self, key: u64) -> (bool, f64) {
        let mut cur = self.buckets[self.bucket_of(key)];
        let mut acc = 0.0;
        while cur != NIL {
            let it = &self.items[cur as usize];
            acc += 1.0;
            if it.live && it.key == key {
                return (true, acc);
            }
            cur = it.hash_next;
        }
        (false, acc)
    }

    /// Deterministic structural probe over a key stride: average chain cost
    /// of tier-1 hits and misses, plus the structural tier-1 residency.
    fn probe_chains(&self) -> (f64, f64, f64) {
        let n = self.cfg.n_items.max(1);
        let step = (n / 2048).max(1);
        let (mut hit_acc, mut miss_acc) = (0.0f64, 0.0f64);
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut key = 0u64;
        while key < n {
            let (found, acc) = self.probe_lookup(key);
            if found {
                hits += 1;
                hit_acc += acc;
            } else {
                misses += 1;
                miss_acc += acc;
            }
            key += step;
        }
        (
            hit_acc / hits.max(1) as f64,
            miss_acc / misses.max(1) as f64,
            hits as f64 / (hits + misses).max(1) as f64,
        )
    }

    /// Split per-class expected access counts by the live placement plan
    /// (chains vs LRU lists; see [`Plan::split3`]): secondary vs plain-DRAM
    /// vs compressed-DRAM hops, with the access-weighted decompress cost.
    fn split_classes(&self, chains: f64, lru: f64) -> HopSplit {
        self.plan.split3(&[(CC_CHAINS, chains), (CC_LRU, lru)])
    }

    /// Snapshot tier hit ratios `(h1, h2 | t1-miss)`: measured counters when
    /// a run has populated them, else structural residency (an access-share
    /// underestimate for skewed key distributions on a cold store). `h1`
    /// uses the `t1_probes` denominator — hit-or-miss of **any** kind — so
    /// write-path misses (which the hit/miss counters never see) cannot
    /// bias it high.
    fn snapshot_hit_ratios(&self, structural_h1: f64) -> (f64, f64) {
        let h1 = if self.stats.t1_probes > 0 {
            (self.stats.t1_hits as f64 / self.stats.t1_probes as f64).clamp(0.0, 1.0)
        } else {
            structural_h1
        };
        // Only the read paths consult tier 2, so its counters are unbiased.
        let t1_miss = self.stats.t2_hits + self.stats.misses;
        let h2 = if t1_miss > 0 {
            (self.stats.t2_hits as f64 / t1_miss as f64).clamp(0.0, 1.0)
        } else {
            (self.t2_set.len() as f64 / self.cfg.n_items.max(1) as f64).clamp(0.0, 1.0)
        };
        (h1, h2)
    }
}

impl super::ModelCosts for CacheKv {
    /// Per-kind cost vectors from the live two-tier geometry: tier-1 chain
    /// positions from the actual bucket occupancy, measured tier hit
    /// ratios, the LRU refresh probability, and the tier-2 admission
    /// probability that turns evictions into SOC page writes. Scans are the
    /// documented no-op (hash layout has no ordered iteration): one API
    /// call of compute, no hops, no IO.
    fn model_params(&self, kind: OpKind) -> KindCost {
        let t_mem = self.cfg.t_node.as_us();
        // The no-op scan needs no structure probe.
        if kind == OpKind::Scan {
            return KindCost::memory_only(0.0, t_mem, t_mem);
        }
        let (hit_pos, miss_chain, structural_h1) = self.probe_chains();
        let (h1, h2) = self.snapshot_hit_ratios(structural_h1);
        // Tier-1 is at capacity after warmup; a partial fill evicts less.
        let p_evict = (self.t1_len as f64 / self.cfg.t1_items.max(1) as f64).clamp(0.0, 1.0);
        let admit = self.cfg.t2_admit_prob * p_evict;
        // Chain-class accesses are common to every kind; the LRU class adds
        // the refresh neighbor read on hits and the 4 eviction-candidate
        // walk accesses behind every insert.
        let chains = h1 * hit_pos + (1.0 - h1) * miss_chain;
        match kind {
            OpKind::Read | OpKind::Rmw => {
                let p_refresh = if kind == OpKind::Rmw {
                    1.0 // the write half always splices
                } else {
                    self.cfg.lru_refresh_prob
                };
                let hops = self.split_classes(chains, h1 * p_refresh + (1.0 - h1) * 4.0);
                // IOs: tier-2 page read on a t1-miss hit, plus the admitted
                // eviction's page write behind every tier-1 insert.
                let rd = (1.0 - h1) * h2;
                let wr = (1.0 - h1) * admit;
                let s = rd + wr;
                let (t_pre, t_post) = if s > 0.0 {
                    (
                        (rd * IO_PAGE_READ_PRE + wr * IO_PAGE_WRITE_PRE) / s,
                        (rd * IO_PAGE_READ_POST + wr * IO_PAGE_WRITE_POST) / s,
                    )
                } else {
                    (IO_PAGE_READ_PRE, IO_PAGE_READ_POST)
                };
                KindCost {
                    m: hops.sec,
                    m_dram: hops.dram,
                    m_cpr: hops.cpr,
                    t_cpu: hops.cpr_us,
                    s,
                    a_io: self.cfg.page_bytes as f64,
                    t_mem,
                    t_pre,
                    t_post,
                    // Bucket-array read + the backend fetch on a double miss.
                    t_fixed: DRAM_US + (1.0 - h1) * (1.0 - h2) * 2.0,
                }
            }
            OpKind::Write => {
                // Hit: update-in-place (splice always). Miss: fresh insert.
                let hops = self.split_classes(chains, h1 + (1.0 - h1) * 4.0);
                KindCost {
                    m: hops.sec,
                    m_dram: hops.dram,
                    m_cpr: hops.cpr,
                    t_cpu: hops.cpr_us,
                    s: (1.0 - h1) * admit,
                    a_io: self.cfg.page_bytes as f64,
                    t_mem,
                    t_pre: IO_PAGE_WRITE_PRE,
                    t_post: IO_PAGE_WRITE_POST,
                    t_fixed: DRAM_US,
                }
            }
            OpKind::Delete => {
                // Invalidation: the chain walk routes through the policy
                // just like the read path.
                let hops = self.split_classes(chains, 0.0);
                KindCost::memory_only(hops.sec, t_mem, DRAM_US + t_mem)
                    .with_m_dram(hops.dram)
                    .with_compressed(hops.cpr, hops.cpr_us)
            }
            // Handled by the early return above.
            OpKind::Scan => unreachable!(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, MachineConfig, MemConfig};

    fn small_cfg() -> CacheKvConfig {
        CacheKvConfig {
            n_items: 20_000,
            t1_items: 2_400,
            t2_items: 11_000,
            buckets: 4_096,
            ..Default::default()
        }
    }

    use super::super::common::drive_op as drive_generic;

    /// Drive an op to completion outside the machine (timing-free).
    /// Returns (mem accesses, read IOs, write IOs).
    fn drive(kv: &mut CacheKv, op: CacheOp, rng: &mut Rng) -> (u32, u32, u32) {
        drive_generic(kv, op, rng)
    }

    #[test]
    fn structure_invariants_after_churn() {
        let mut rng = Rng::new(1);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        for i in 0..50_000u64 {
            let key = i % 7_919;
            if kv.t1_lookup(key).is_none() {
                kv.t1_insert(key, &mut rng);
            }
        }
        assert!(kv.t1_len <= kv.cfg.t1_items);
        // LRU list length equals t1_len and links are consistent.
        let mut cur = kv.lru_head;
        let mut prev = NIL;
        let mut cnt = 0u32;
        while cur != NIL {
            assert_eq!(kv.items[cur as usize].lru_prev, prev);
            prev = cur;
            cur = kv.items[cur as usize].lru_next;
            cnt += 1;
            assert!(cnt <= kv.t1_len + 1);
        }
        assert_eq!(cnt, kv.t1_len);
        assert_eq!(kv.lru_tail, prev);
        // Tier-2 ring hard-bounded; the index never exceeds the ring (stale
        // invalidated entries await rotation inside the bound).
        assert!(kv.t2_ring.len() <= kv.cfg.t2_items as usize);
        assert!(kv.t2_set.len() <= kv.t2_ring.len());
    }

    #[test]
    fn lookup_finds_inserted_keys() {
        let mut rng = Rng::new(2);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        for key in 100..200u64 {
            if kv.t1_lookup(key).is_none() {
                kv.t1_insert(key, &mut rng);
            }
            assert!(kv.t1_lookup(key).is_some(), "key {key} just inserted");
        }
    }

    #[test]
    fn hit_ratios_in_paper_ballpark() {
        let mut rng = Rng::new(3);
        let kv = CacheKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let _ = m.run(Dur::ms(10.0), Dur::ms(30.0));
        let t1 = m.service.t1_hit_ratio();
        let t2 = m.service.t2_hit_ratio();
        // Paper: t1 34%, t2-on-miss 73%, overall 82%. Accept a band around
        // those (our scaled capacities + Gaussian profile land nearby).
        assert!((0.2..0.6).contains(&t1), "t1 hit ratio {t1}");
        assert!((0.4..0.95).contains(&t2), "t2 hit ratio {t2}");
        assert_eq!(m.service.stats.corruptions, 0);
    }

    #[test]
    fn io_happens_on_t1_misses_only() {
        let mut rng = Rng::new(4);
        let kv = CacheKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(5.0), Dur::ms(20.0));
        // A t1 hit does no IO; a miss does a t2 read plus sometimes an
        // eviction page write, so S stays well below 2 and reads/op < 1.
        assert!(st.mean_s < 1.5, "S = {}", st.mean_s);
        let reads_per_op = st.io_reads as f64 / st.ops as f64;
        assert!(reads_per_op < 1.0, "reads/op = {reads_per_op}");
        assert!(st.io_reads > 50, "tier-2 reads expected");
    }

    #[test]
    fn write_heavy_mix_generates_page_writes() {
        let mut rng = Rng::new(5);
        let kv = CacheKv::new(
            CacheKvConfig {
                mix: OpMix::ratio(1, 1),
                ..small_cfg()
            },
            &mut rng,
        );
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(5.0), Dur::ms(20.0));
        assert!(m.service.stats.sets > 500);
        assert!(st.io_writes > 10, "SOC page writes expected");
    }

    #[test]
    fn delete_invalidates_both_tiers() {
        let mut rng = Rng::new(6);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        // Ensure residency, then delete.
        let key = 9_999u64;
        if kv.t1_lookup(key).is_none() {
            kv.t1_insert(key, &mut rng);
        }
        kv.t2_insert(key);
        assert!(kv.contains_key(key));

        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert!(!kv.contains_key(key), "delete must invalidate both tiers");

        // Get after delete: misses both tiers (absent), read-throughs.
        let absent0 = kv.stats.absent;
        let op = kv.op_get(key);
        let (_, reads, _writes) = drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.absent, absent0 + 1, "get-after-delete absent");
        // Backend fetch is compute-only; only an eviction page write may
        // accompany the re-insert.
        assert_eq!(reads, 0, "backend fetch is not a tier-2 page read");
        // The read-through re-cached it (cache semantics).
        assert!(kv.t1_lookup(key).is_some());
    }

    #[test]
    fn delete_of_t2_only_key_drops_index_entry() {
        let mut rng = Rng::new(7);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        // Find a key resident in t2 but not in t1.
        let key = (0..kv.cfg.n_items)
            .find(|&k| kv.t1_lookup(k).is_none() && kv.t2_set.contains_key(&k));
        let Some(key) = key else {
            // Warmup left no t2-only key (unlikely); force one.
            let k = 1u64;
            if let Some(id) = kv.t1_lookup(k) {
                kv.t1_remove(id);
            }
            kv.t2_insert(k);
            let op = kv.op_delete(k);
            drive(&mut kv, op, &mut rng);
            assert!(!kv.t2_set.contains_key(&k));
            return;
        };
        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert!(!kv.t2_set.contains_key(&key));
    }

    #[test]
    fn rmw_hits_take_write_path_and_misses_insert() {
        let mut rng = Rng::new(8);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        let key = 42u64;
        if kv.t1_lookup(key).is_none() {
            kv.t1_insert(key, &mut rng);
        }
        // Hit: RMW always refreshes (update-in-place = the write half).
        let op = kv.op_rmw(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.lru_head, kv.t1_lookup(key).unwrap(), "spliced to head");

        // Miss in both tiers: the RMW read-throughs and inserts.
        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        let op = kv.op_rmw(key);
        drive(&mut kv, op, &mut rng);
        assert!(kv.t1_lookup(key).is_some(), "rmw miss must insert");
    }

    #[test]
    fn t2_ring_bounded_and_stale_twin_cannot_evict_live_entry() {
        let mut rng = Rng::new(10);
        let mut kv = CacheKv::new(
            CacheKvConfig {
                t2_items: 8,
                ..small_cfg()
            },
            &mut rng,
        );
        // Directed scenario on an empty tier 2.
        kv.t2_ring.clear();
        kv.t2_set.clear();
        kv.t2_insert(1);
        kv.t2_invalidate(1);
        kv.t2_insert(1); // re-admission leaves a stale twin at the FIFO head
        for k in 100..107u64 {
            kv.t2_insert(k);
            assert!(kv.t2_ring.len() <= 8, "ring must stay hard-bounded");
        }
        // The stale twin has rotated out; the live re-admission survived it.
        assert!(
            kv.t2_set.contains_key(&1),
            "stale twin evicted the live entry"
        );
        // One more insert reaches the live entry's own FIFO turn.
        kv.t2_insert(107);
        assert!(!kv.t2_set.contains_key(&1), "live entry evicted in FIFO order");
        assert!(kv.t2_ring.len() <= 8);
    }

    #[test]
    fn scan_is_documented_noop() {
        let mut rng = Rng::new(9);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        let op = kv.op_scan();
        let (mems, reads, writes) = drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.scans, 1);
        assert_eq!(kv.stats.scanned, 0, "no entries are ever returned");
        assert_eq!((mems, reads, writes), (0, 0, 0), "no accesses, no IO");
    }

    #[test]
    fn delete_invalidation_routes_through_the_placement_policy() {
        use super::super::common::drive_op_tiers;
        // The write-path invalidation fix: delete's chain-walk hops must
        // follow the policy instead of assuming secondary-tier hops. Use a
        // budget covering exactly the chain class: deletes then run fully
        // inline while the LRU walk (reads/inserts) stays secondary.
        let chains = CacheKv::placement_classes(&small_cfg())[0].bytes;
        let mut rng = Rng::new(30);
        let mut kv = CacheKv::new(
            CacheKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: chains },
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(kv.plan.in_dram(CC_CHAINS) && !kv.plan.in_dram(CC_LRU));
        assert_eq!(kv.dram_bytes(), chains + kv.residual_dram_bytes());
        let key = 4321u64;
        if kv.t1_lookup(key).is_none() {
            kv.t1_insert(key, &mut rng);
        }
        let op = kv.op_delete(key);
        let c = drive_op_tiers(&mut kv, op, &mut rng);
        assert_eq!(
            c.secondary, 0,
            "DRAM-resident chains: delete must not pay secondary hops: {c:?}"
        );
        assert!(c.dram >= 1, "bucket read + chain hops: {c:?}");
        // Control: under AllSecondary the same delete pays secondary hops
        // for every chain position past the bucket head.
        let mut rng = Rng::new(30);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        if kv.t1_lookup(key).is_none() {
            kv.t1_insert(key, &mut rng);
        }
        // Push the item behind at least one chain neighbor so the walk has
        // a secondary hop to charge.
        let bucket = kv.bucket_of(key);
        let mut twin = key + kv.cfg.buckets as u64;
        while kv.bucket_of(twin) != bucket {
            twin += 1;
        }
        if kv.t1_lookup(twin).is_none() {
            kv.t1_insert(twin, &mut rng);
        }
        let op = kv.op_delete(key);
        let c = drive_op_tiers(&mut kv, op, &mut rng);
        assert!(c.secondary >= 1, "AllSecondary delete walk: {c:?}");
        // The model snapshot mirrors the fix: deletes move to m_dram.
        use super::super::ModelCosts;
        let mut rng = Rng::new(31);
        let placed = CacheKv::new(
            CacheKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: chains },
                ..small_cfg()
            },
            &mut rng,
        );
        let del = placed.model_params(OpKind::Delete);
        assert_eq!(del.m, 0.0, "chain-resident deletes are inline");
        assert!(del.m_dram > 0.0);
    }

    #[test]
    fn placement_budget_accounts_bytes_monotonically() {
        let total = {
            let mut rng = Rng::new(32);
            CacheKv::new(
                CacheKvConfig {
                    placement: PlacementPolicy::AllDram,
                    ..small_cfg()
                },
                &mut rng,
            )
            .offload_bytes_total()
        };
        let mut prev = 0u64;
        for budget in [0, total / 4, total / 2, 3 * total / 4, total] {
            let mut rng = Rng::new(32);
            let kv = CacheKv::new(
                CacheKvConfig {
                    placement: PlacementPolicy::Budget { dram_bytes: budget },
                    ..small_cfg()
                },
                &mut rng,
            );
            // Policy bytes stay capped by the budget; the honest total adds
            // the constant pinned residual (directory + SOC index).
            let b = kv.plan().policy_dram_bytes();
            assert!(b <= budget && b >= prev, "budget {budget}: {prev} -> {b}");
            assert_eq!(kv.dram_bytes(), b + kv.residual_dram_bytes());
            prev = b;
        }
    }

    #[test]
    fn residual_directory_and_soc_index_reported_even_all_secondary() {
        // Satellite bugfix: the bucket directory and the tier-2 SOC index
        // are DRAM by design; before the pinned-class accounting they were
        // invisible to `dram_bytes()`.
        let mut rng = Rng::new(33);
        let kv = CacheKv::new(small_cfg(), &mut rng); // AllSecondary default
        let cfg = small_cfg();
        assert_eq!(
            kv.residual_dram_bytes(),
            cfg.buckets as u64 * 8 + cfg.t2_items as u64 * 16
        );
        assert_eq!(kv.dram_bytes(), kv.residual_dram_bytes());
        assert_eq!(kv.plan().policy_dram_bytes(), 0);
        assert!(kv.plan().in_dram(CC_DIRECTORY) && kv.plan().in_dram(CC_SOC_INDEX));
    }

    #[test]
    fn replan_under_write_heavy_mix_promotes_the_lru_lists() {
        // The measured planner's cachekv-A case: misses walk four
        // eviction-candidate LRU hops behind every insert and updates
        // splice unconditionally, so a write/miss-heavy profile ranks the
        // LRU lists above the hash chains per byte (the classes have equal
        // byte footprints), flipping the static chains-first order.
        let mut rng = Rng::new(34);
        let mut kv = CacheKv::new(small_cfg(), &mut rng);
        // Directed churn on cold keys: every op misses tier 1 (4 LRU hops
        // per insert, short chain walks).
        for key in 0..400u64 {
            let op = kv.op_put(key * 7 + 1);
            let _ = drive(&mut kv, op, &mut rng);
        }
        assert!(
            kv.profile.accesses(CC_LRU) > kv.profile.accesses(CC_CHAINS),
            "write churn must out-access the LRU lists: lru={} chains={}",
            kv.profile.accesses(CC_LRU),
            kv.profile.accesses(CC_CHAINS)
        );
        let profile = kv.profile.clone();
        kv.replan(&profile);
        assert_eq!(
            kv.plan().ranking(),
            &[CC_LRU, CC_CHAINS],
            "measured ranking must flip the static chains-first order"
        );
        // At a one-class budget the measured plan places the LRU lists
        // where the static plan placed the chains.
        let one_class = CacheKv::placement_classes(&small_cfg())[CC_CHAINS].bytes;
        let mut rng = Rng::new(34);
        let mut placed = CacheKv::new(
            CacheKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: one_class },
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(placed.plan().in_dram(CC_CHAINS) && !placed.plan().in_dram(CC_LRU));
        placed.replan(&profile);
        assert!(!placed.plan().in_dram(CC_CHAINS) && placed.plan().in_dram(CC_LRU));
        assert_eq!(placed.plan().policy_dram_bytes(), one_class);
    }

    #[test]
    fn replan_migrate_charges_the_swapped_halves() {
        // small_cfg: chains = lru = 2,400·32 = 76,800 B = 1,200 lines each.
        // A one-class budget statically holds the chains; a profile ranking
        // the LRU lists first swaps the halves — 2,400 lines move, one
        // touch on each tier per line, and no SSD traffic (tier-1 metadata
        // is authoritative in memory).
        let mut rng = Rng::new(41);
        let one_class = CacheKv::placement_classes(&small_cfg())[CC_CHAINS].bytes;
        let mut kv = CacheKv::new(
            CacheKvConfig {
                placement: PlacementPolicy::Budget {
                    dram_bytes: one_class,
                },
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(kv.plan().in_dram(CC_CHAINS) && !kv.plan().in_dram(CC_LRU));
        let mut profile = AccessProfile::new(4);
        for _ in 0..1_000 {
            profile.tick(CC_LRU);
        }
        profile.tick(CC_CHAINS);
        let mig = kv.replan_migrate(&profile);
        assert!(!kv.plan().in_dram(CC_CHAINS) && kv.plan().in_dram(CC_LRU));
        assert_eq!((mig.dram, mig.secondary), (2_400, 2_400), "{mig:?}");
        assert_eq!((mig.reads, mig.writes), (0, 0), "metadata moves carry no IO");
        // Same profile again: the plan is already optimal, nothing moves.
        assert_eq!(kv.replan_migrate(&profile), DriveCounts::default());
        // Ranking-independent policies never migrate.
        let mut rng = Rng::new(42);
        let mut all_sec = CacheKv::new(small_cfg(), &mut rng);
        assert_eq!(all_sec.replan_migrate(&profile), DriveCounts::default());
    }

    #[test]
    fn set_workload_keeps_rng_untouched() {
        let mut rng = Rng::new(43);
        let _kv = CacheKv::new(small_cfg(), &mut rng);
        let mark = rng.below(u64::MAX);
        let mut rng2 = Rng::new(43);
        let mut kv2 = CacheKv::new(small_cfg(), &mut rng2);
        kv2.set_workload(
            Some(OpWeights::new(0.5, 0.5, 0.0, 0.0, 0.0)),
            KeyDist::HotSet {
                hot_frac: 0.4,
                hot_weight: 0.95,
            },
        );
        assert_eq!(
            rng2.below(u64::MAX),
            mark,
            "set_workload must not consume randomness"
        );
        assert!(matches!(kv2.cfg.key_dist, KeyDist::HotSet { .. }));
        let key = kv2.keygen.sample(&mut rng2);
        let op = kv2.op_get(key);
        let _ = drive(&mut kv2, op, &mut rng2);
        assert!(kv2.stats.gets > 0);
    }

    #[test]
    fn model_params_track_two_tier_geometry() {
        use super::super::ModelCosts;
        let mut rng = Rng::new(23);
        let kv = CacheKv::new(small_cfg(), &mut rng);
        let read = kv.model_params(OpKind::Read);
        // Misses cost page reads plus admitted-eviction page writes: S can
        // exceed the t2 hit share but stays below read+write per miss.
        assert!(read.s > 0.0 && read.s < 2.0, "S_read = {}", read.s);
        assert!(read.m > 0.5 && read.m < 12.0, "M_read = {}", read.m);
        assert!(read.t_fixed > 0.0);
        // The no-op scan has no hops and no IO but still costs the API call.
        let scan = kv.model_params(OpKind::Scan);
        assert_eq!((scan.m, scan.s), (0.0, 0.0));
        assert!(scan.t_fixed > 0.0);
        // Deletes are invalidations: chain walk only.
        assert_eq!(kv.model_params(OpKind::Delete).s, 0.0);
        // The RMW write-half splices unconditionally: more hops than a read.
        let rmw = kv.model_params(OpKind::Rmw);
        assert!(rmw.m > read.m);
    }

    #[test]
    fn compressed_budget_accounting_and_results_stay_consistent() {
        use super::super::placement::Compression;
        use super::super::ModelCosts;
        // Half the chain class in budget: plain placement fits nothing,
        // the joint knapsack fits the chains *compressed* at ratio 0.5.
        let spec = Compression::new(0.5, 0.12);
        let chains = CacheKv::placement_classes(&small_cfg())[CC_CHAINS].bytes;
        let budget = chains / 2;
        let mut rng_j = Rng::new(60);
        let mut joint = CacheKv::new(
            CacheKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: budget },
                compression: CompressMode::Joint(spec),
                ..small_cfg()
            },
            &mut rng_j,
        );
        assert_eq!(joint.plan().compressed_classes(), 1);
        assert!(joint.plan().is_compressed(CC_CHAINS) && !joint.plan().in_dram(CC_LRU));
        // Byte accounting: the compressed class consumes exactly its
        // scaled bytes of budget; the honest total adds the pinned residual.
        assert_eq!(joint.plan().policy_dram_bytes(), budget);
        assert_eq!(joint.dram_bytes(), budget + joint.residual_dram_bytes());

        let mut rng_p = Rng::new(60);
        let mut plain = CacheKv::new(
            CacheKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: budget },
                ..small_cfg()
            },
            &mut rng_p,
        );
        assert_eq!(plain.plan().compressed_classes(), 0);
        assert!(!plain.plan().in_dram(CC_CHAINS));

        // Compression must be invisible to KV results: same ops, same
        // seeds, same access/IO counts and stats as the uncompressed twin
        // (the decompress Compute adds no accesses and draws no RNG).
        let mut dj = Rng::new(61);
        let mut dp = Rng::new(61);
        for key in [5u64, 1_234, 19_999] {
            let oj = joint.op_get(key);
            let op = plain.op_get(key);
            let cj = drive(&mut joint, oj, &mut dj);
            let cp = drive(&mut plain, op, &mut dp);
            assert_eq!(cj, cp, "key {key}: twin counts diverged");
        }
        assert_eq!(joint.stats, plain.stats);

        // Model snapshots: the compressed chain hops move to m_cpr with the
        // spec's decompress cost; total hops are conserved across twins.
        let read_j = joint.model_params(OpKind::Read);
        let read_p = plain.model_params(OpKind::Read);
        assert!(read_j.m_cpr > 0.3, "chain hops compressed: {}", read_j.m_cpr);
        assert!((read_j.t_cpu - 0.12).abs() < 1e-12);
        assert_eq!((read_p.m_cpr, read_p.t_cpu), (0.0, 0.0));
        let tot_j = read_j.m + read_j.m_dram + read_j.m_cpr;
        let tot_p = read_p.m + read_p.m_dram + read_p.m_cpr;
        assert!((tot_j - tot_p).abs() < 1e-9, "{tot_j} vs {tot_p}");
    }

    #[test]
    fn wal_commits_writes_and_deletes_before_ack() {
        let mut rng = Rng::new(50);
        let mut kv = CacheKv::new(
            CacheKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        let key = 77u64;
        let op = kv.op_put(key);
        let (_, _, writes) = drive(&mut kv, op, &mut rng);
        assert!(writes >= 1, "put must issue a log write");
        assert!(kv.wal.is_durable(0));
        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 2);
        assert!(kv.wal.acked_all_durable());
        // Reads never log — the get-after-delete read-throughs and
        // re-caches, but its insert is not a durable mutation.
        let op = kv.op_get(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 2, "reads must not log");
        // An RMW is a durable mutation whichever tier it lands on.
        let op = kv.op_rmw(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 3, "rmw must log its write half");
        assert!(kv.wal.acked_all_durable());
    }

    #[test]
    fn wal_replay_never_resurrects_acked_deletes() {
        let mut rng = Rng::new(51);
        let mut kv = CacheKv::new(
            CacheKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        // Interleave puts and deletes; the last durable record per key
        // decides its post-recovery fate.
        for k in 0..50u64 {
            let op = kv.op_put(k);
            drive(&mut kv, op, &mut rng);
        }
        for k in 0..50u64 {
            if k % 2 == 0 {
                let op = kv.op_delete(k);
                drive(&mut kv, op, &mut rng);
            }
        }
        assert!(kv.wal.acked_all_durable());

        // Crash and recover into a fresh store.
        let mut rng2 = Rng::new(51);
        let mut kv2 = CacheKv::new(
            CacheKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng2,
        );
        kv2.wal_replay(&kv.wal, &mut rng2);
        for (key, kind) in kv.wal.durable_last_kind() {
            if kind == WalKind::Delete {
                assert!(!kv2.contains_key(key), "resurrected delete {key}");
            }
            // Puts are present-or-evicted: no assertion (cache contract).
        }
        // Idempotence: a second replay applies nothing.
        assert_eq!(kv2.wal_replay(&kv.wal, &mut rng2), 0);
    }
}
