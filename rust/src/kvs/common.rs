//! Shared helpers for the KV store implementations.

/// FNV-1a 64-bit hash (key digests, bucket hashing).
#[inline]
pub fn fnv1a(x: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Correctness counters maintained by every store: reads verify the value
/// fetched from the (simulated) SSD against the deterministic disk image.
#[derive(Debug, Clone, Default)]
pub struct KvStats {
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub sets: u64,
    pub verified: u64,
    pub corruptions: u64,
    /// Tier-specific hit counters (cachekv).
    pub t1_hits: u64,
    pub t2_hits: u64,
    /// Background work performed.
    pub bg_ops: u64,
}

impl KvStats {
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }
}

pub const NIL: u32 = u32::MAX;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_spreads() {
        assert_eq!(fnv1a(0), fnv1a(0));
        assert_ne!(fnv1a(0), fnv1a(1));
        // Low bits should be well distributed.
        let mut buckets = [0u32; 16];
        for i in 0..16_000u64 {
            buckets[(fnv1a(i) % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!((800..1200).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn hit_ratio() {
        let s = KvStats {
            gets: 10,
            hits: 7,
            ..Default::default()
        };
        assert!((s.hit_ratio() - 0.7).abs() < 1e-12);
        assert_eq!(KvStats::default().hit_ratio(), 0.0);
    }
}
