//! Shared helpers for the KV store implementations.

use crate::sim::{IoKind, Rng, Service, Step, Tier};

/// Per-tier access and IO counts of one driven operation (see
/// [`drive_op_tiers`]): the tier-placement test surface.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DriveCounts {
    /// Inline DRAM accesses (no prefetch, no `T_sw`).
    pub dram: u32,
    /// Secondary-memory accesses (prefetch + yield path).
    pub secondary: u32,
    pub reads: u32,
    pub writes: u32,
}

/// Drive one operation's state machine to completion outside the machine:
/// timing-free — `Lock`/`Unlock`/`Yield` are acknowledged and IOs complete
/// instantly. Returns (memory accesses of either tier, read IOs, write
/// IOs). Intended for directed tests and offline diagnostics; simulated
/// runs go through [`crate::sim::Machine`].
pub fn drive_op<S: Service>(svc: &mut S, op: S::Op, rng: &mut Rng) -> (u32, u32, u32) {
    let c = drive_op_tiers(svc, op, rng);
    (c.dram + c.secondary, c.reads, c.writes)
}

/// [`drive_op`] with the memory accesses split by [`Tier`] — the placement
/// invariant tests assert which side of the DRAM/secondary split each
/// traversal's hops land on under a given `kvs::placement` policy.
pub fn drive_op_tiers<S: Service>(svc: &mut S, mut op: S::Op, rng: &mut Rng) -> DriveCounts {
    let mut c = DriveCounts::default();
    let mut guard = 0u32;
    loop {
        match svc.step(0, &mut op, rng) {
            Step::Done => break,
            Step::MemAccess(Tier::Dram) => c.dram += 1,
            Step::MemAccess(Tier::Secondary) => c.secondary += 1,
            Step::Io { kind, .. } => match kind {
                IoKind::Read => c.reads += 1,
                IoKind::Write => c.writes += 1,
            },
            _ => {}
        }
        guard += 1;
        assert!(guard < 200_000, "op did not terminate");
    }
    c
}

/// FNV-1a 64-bit hash (key digests, bucket hashing).
#[inline]
pub fn fnv1a(x: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Correctness counters maintained by every store: reads verify the value
/// fetched from the (simulated) SSD against the deterministic disk image.
/// (`PartialEq` so the WAL replay-idempotence property test can assert
/// bit-identical recovered state.)
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KvStats {
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub sets: u64,
    /// Delete operations issued.
    pub deletes: u64,
    /// Scan operations issued.
    pub scans: u64,
    /// Read-modify-write operations issued.
    pub rmws: u64,
    /// Entries returned across all scans.
    pub scanned: u64,
    /// Point lookups / deletes that found no entry (deleted or never
    /// written keys).
    pub absent: u64,
    pub verified: u64,
    pub corruptions: u64,
    /// Tier-specific hit counters (cachekv).
    pub t1_hits: u64,
    pub t2_hits: u64,
    /// Tier-1 lookups resolved (hit or miss, **any** operation kind) —
    /// the unbiased denominator for the measured tier-1 hit ratio:
    /// `hits`/`misses` alone skew it because write-path hits count while
    /// write-path misses do not.
    pub t1_probes: u64,
    /// Background work performed.
    pub bg_ops: u64,
    /// Store-side background-IO byte ledger (the write-amplification
    /// columns): bytes the store wrote flushing memtables, and bytes it
    /// read/wrote compacting or defragmenting. Each counter increments at
    /// the same site that tags the IO's `TrafficClass`, so in a fault-free
    /// run (no retries) they match the device's bg lanes byte-for-byte.
    pub flush_write_bytes: u64,
    pub compact_read_bytes: u64,
    pub compact_write_bytes: u64,
    /// IO errors surfaced to this store (`Service::io_failed` deliveries).
    pub io_errors: u64,
    /// Operations that finished with an error instead of a result (the
    /// graceful-degradation path: errors surface per-op, nothing wedges).
    pub failed_ops: u64,
}

impl KvStats {
    pub fn hit_ratio(&self) -> f64 {
        if self.gets == 0 {
            0.0
        } else {
            self.hits as f64 / self.gets as f64
        }
    }
}

pub const NIL: u32 = u32::MAX;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_is_stable_and_spreads() {
        assert_eq!(fnv1a(0), fnv1a(0));
        assert_ne!(fnv1a(0), fnv1a(1));
        // Low bits should be well distributed.
        let mut buckets = [0u32; 16];
        for i in 0..16_000u64 {
            buckets[(fnv1a(i) % 16) as usize] += 1;
        }
        for &b in &buckets {
            assert!((800..1200).contains(&b), "bucket count {b}");
        }
    }

    #[test]
    fn hit_ratio() {
        let s = KvStats {
            gets: 10,
            hits: 7,
            ..Default::default()
        };
        assert!((s.hit_ratio() - 0.7).abs() < 1e-12);
        assert_eq!(KvStats::default().hit_ratio(), 0.0);
    }
}
