//! Aerospike-like SSD-based KV store (paper §4.2, Fig 13 left).
//!
//! The primary index is a forest of binary search trees ("sprigs") of
//! 64-byte entries keyed by a 64-bit digest; the entries live on secondary
//! memory and every descent hop is a dependent (prefetch+yield) access.
//! Values live on SSD in a log-structured space: writes append to the log
//! and update the index entry; a background defragmenter copies live entries
//! out of old blocks (Aerospike's defrag thread), which is the "background
//! worker" slowdown the paper's write-mix experiments exhibit.
//!
//! Keys are digests (hashes), so plain BST insertion yields expectedly
//! balanced trees — the average descent length M ≈ 1.39·log2(items/sprigs),
//! matching the paper's measured Aerospike M once sprig count is set.

use super::common::{fnv1a, KvStats, NIL};
use crate::sim::{Dur, IoKind, Rng, Service, Step, Tier};
use crate::workload::{KeyGen, OpKind, OpMix, ValueSize};

/// One 64-byte index entry (Aerospike's as_index).
#[derive(Debug, Clone, Copy)]
struct Node {
    digest: u64,
    left: u32,
    right: u32,
    /// SSD block holding the current value.
    block: u32,
    /// Value size in bytes.
    vsize: u32,
    /// §5.2.3 tiering extension: this entry lives in host DRAM.
    in_dram: bool,
}

/// §5.2.3 extension: how index entries are split between host DRAM and
/// secondary memory when only part of the index is offloaded.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TieringPolicy {
    /// Everything on secondary memory (the paper's base case, ρ = 1).
    FullOffload,
    /// A uniformly random fraction `dram_frac` of entries stays in DRAM
    /// (what Eq 15's access-frequency interpolation assumes).
    Random { dram_frac: f64 },
    /// Access-aware: the top `levels` of every sprig stay in DRAM. Since
    /// every descent passes through the top levels, a small DRAM budget
    /// absorbs a disproportionate share of the accesses — the "designing
    /// tiering for microsecond-latency memory" direction of §5.2.3.
    TopLevels { levels: u32 },
}

#[derive(Debug, Clone)]
pub struct TreeKvConfig {
    pub n_items: u64,
    /// Number of sprigs (sub-trees); items/sprigs sets the tree depth M.
    pub sprigs: u32,
    /// Index placement policy (§5.2.3 extension).
    pub tiering: TieringPolicy,
    pub key_dist: crate::workload::KeyDist,
    pub mix: OpMix,
    pub value_size: ValueSize,
    /// CPU cost per index hop (comparisons, address arithmetic).
    pub t_node: Dur,
    /// Run one background defragmenter thread per core when writes happen.
    pub defrag: bool,
    /// Number of sprig locks (write path).
    pub n_locks: u32,
}

impl Default for TreeKvConfig {
    fn default() -> Self {
        TreeKvConfig {
            // Paper: 500M items; scaled so that M ≈ 13-14 like the paper's
            // measured Aerospike runs (depth tracks items/sprigs only).
            n_items: 500_000,
            sprigs: 512,
            tiering: TieringPolicy::FullOffload,
            key_dist: crate::workload::KeyDist::Uniform,
            mix: OpMix::READ_ONLY,
            value_size: ValueSize::Fixed(1536),
            t_node: Dur::ns(110.0),
            defrag: true,
            n_locks: 64,
        }
    }
}

/// The store (the `Service` the machine drives).
pub struct TreeKv {
    pub cfg: TreeKvConfig,
    keygen: KeyGen,
    roots: Vec<u32>,
    nodes: Vec<Node>,
    /// Disk image: block → digest currently stored (verification oracle).
    disk: Vec<u64>,
    /// Log head for appending writes.
    log_head: u32,
    /// Blocks freed by updates, pending defrag.
    dead_blocks: u64,
    pub stats: KvStats,
    /// `tid % bg_threads_per_core == bg_tid_floor` marks a background
    /// defragger thread (one per core); `usize::MAX` disables them.
    bg_tid_floor: usize,
    bg_threads_per_core: usize,
}

/// Operation state machine.
#[derive(Debug)]
pub enum TreeOp {
    /// Descend toward `digest`; `node` is the next node to visit.
    Descend {
        kind: OpKind,
        digest: u64,
        node: u32,
        compute_done: bool,
        vsize: u32,
    },
    /// Read the value from SSD and verify.
    ReadValue { digest: u64, block: u32, vsize: u32 },
    /// Write path: append the new value to the log, then re-descend to
    /// update the index entry under the sprig lock.
    WriteValue {
        digest: u64,
        vsize: u32,
    },
    UpdateIndex {
        digest: u64,
        new_block: u32,
        node: u32,
        locked: u32,
        compute_done: bool,
    },
    Unlock {
        lock: u32,
    },
    /// Background defrag: read an old block, re-append its live entry.
    DefragRead,
    DefragWrite,
    DefragPause,
    DefragYield,
    Finished,
    Verify { ok: bool },
}

impl TreeKv {
    pub fn new(cfg: TreeKvConfig, rng: &mut Rng) -> TreeKv {
        let keygen = KeyGen::new(cfg.n_items, cfg.key_dist);
        let mut kv = TreeKv {
            roots: vec![NIL; cfg.sprigs as usize],
            nodes: Vec::with_capacity(cfg.n_items as usize),
            disk: Vec::with_capacity(cfg.n_items as usize * 2),
            log_head: 0,
            dead_blocks: 0,
            stats: KvStats::default(),
            bg_tid_floor: usize::MAX,
            bg_threads_per_core: 1,
            keygen,
            cfg,
        };
        // Populate directly (construction is not simulated, like the paper's
        // untimed load phase).
        let mut vrng = rng.fork(0x7ee);
        for key in 0..kv.cfg.n_items {
            let digest = fnv1a(key);
            let vsize = kv.cfg.value_size.sample(&mut vrng);
            let block = kv.append_to_log(digest);
            kv.insert_unsimulated(digest, block, vsize, &mut vrng);
        }
        kv
    }

    /// Designate background threads: the machine's thread ids are laid out
    /// core-major; the last thread of each core becomes the defragger.
    pub fn with_background(mut self, cores: usize, threads_per_core: usize) -> TreeKv {
        if self.cfg.defrag && self.cfg.mix.read_ratio < 1.0 {
            self.bg_tid_floor = threads_per_core - 1; // tid % tpc == floor
            self.bg_threads_per_core = threads_per_core;
            let _ = cores;
        }
        self
    }

    fn append_to_log(&mut self, digest: u64) -> u32 {
        let b = self.log_head;
        self.disk.push(digest);
        self.log_head += 1;
        b
    }

    fn sprig_of(&self, digest: u64) -> usize {
        (digest % self.cfg.sprigs as u64) as usize
    }

    fn insert_unsimulated(&mut self, digest: u64, block: u32, vsize: u32, rng: &mut Rng) {
        let sprig = self.sprig_of(digest);
        let id = self.nodes.len() as u32;
        self.nodes.push(Node {
            digest,
            left: NIL,
            right: NIL,
            block,
            vsize,
            in_dram: false,
        });
        let mut cur = self.roots[sprig];
        let mut depth = 0u32;
        if cur == NIL {
            self.roots[sprig] = id;
        } else {
            loop {
                depth += 1;
                let n = self.nodes[cur as usize];
                if digest < n.digest {
                    if n.left == NIL {
                        self.nodes[cur as usize].left = id;
                        break;
                    }
                    cur = n.left;
                } else {
                    if n.right == NIL {
                        self.nodes[cur as usize].right = id;
                        break;
                    }
                    cur = n.right;
                }
            }
        }
        self.nodes[id as usize].in_dram = match self.cfg.tiering {
            TieringPolicy::FullOffload => false,
            TieringPolicy::Random { dram_frac } => rng.chance(dram_frac),
            TieringPolicy::TopLevels { levels } => depth < levels,
        };
    }

    /// Fraction of index entries resident in DRAM (capacity-side ρ probe).
    pub fn dram_entry_fraction(&self) -> f64 {
        if self.nodes.is_empty() {
            return 0.0;
        }
        self.nodes.iter().filter(|n| n.in_dram).count() as f64 / self.nodes.len() as f64
    }

    /// Average descent depth (tests / parameter probes).
    pub fn mean_depth(&self, samples: u64, rng: &mut Rng) -> f64 {
        let mut total = 0u64;
        for _ in 0..samples {
            let key = rng.below(self.cfg.n_items);
            let digest = fnv1a(key);
            let mut cur = self.roots[self.sprig_of(digest)];
            let mut d = 0u64;
            while cur != NIL {
                d += 1;
                let n = self.nodes[cur as usize];
                if digest == n.digest {
                    break;
                }
                cur = if digest < n.digest { n.left } else { n.right };
            }
            total += d;
        }
        total as f64 / samples as f64
    }

    fn lock_of(&self, digest: u64) -> u32 {
        (self.sprig_of(digest) as u32) % self.cfg.n_locks
    }
}

// Extra field defined outside the struct literal flow above.
impl TreeKv {
    fn is_bg(&self, tid: usize) -> bool {
        self.bg_tid_floor != usize::MAX && tid % self.bg_threads_per_core == self.bg_tid_floor
    }
}

impl Service for TreeKv {
    type Op = TreeOp;

    fn next_op(&mut self, tid: usize, rng: &mut Rng) -> TreeOp {
        if self.is_bg(tid) {
            // Defrag pacing: only work when enough dead blocks accumulated.
            if self.dead_blocks > 64 {
                return TreeOp::DefragRead;
            }
            return TreeOp::DefragPause;
        }
        let key = self.keygen.sample(rng);
        let digest = fnv1a(key);
        let kind = self.mix_sample(rng);
        let vsize = self.cfg.value_size.sample(rng);
        match kind {
            OpKind::Read => {
                self.stats.gets += 1;
                TreeOp::Descend {
                    kind,
                    digest,
                    node: self.roots[self.sprig_of(digest)],
                    compute_done: false,
                    vsize,
                }
            }
            OpKind::Write => {
                self.stats.sets += 1;
                TreeOp::WriteValue { digest, vsize }
            }
        }
    }

    fn step(&mut self, _tid: usize, op: &mut TreeOp, rng: &mut Rng) -> Step {
        match op {
            TreeOp::Descend {
                kind,
                digest,
                node,
                compute_done,
                vsize,
            } => {
                if *node == NIL {
                    // Not found (cannot happen for in-population keys).
                    self.stats.misses += 1;
                    *op = TreeOp::Finished;
                    return Step::Done;
                }
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let n = self.nodes[*node as usize];
                let step = Step::MemAccess(if n.in_dram {
                    Tier::Dram
                } else {
                    Tier::Secondary
                });
                if *digest == n.digest {
                    self.stats.hits += 1;
                    match kind {
                        OpKind::Read => {
                            *op = TreeOp::ReadValue {
                                digest: *digest,
                                block: n.block,
                                vsize: n.vsize,
                            };
                        }
                        OpKind::Write => {
                            // (unused path: writes go through WriteValue)
                            let _ = vsize;
                            *op = TreeOp::Finished;
                        }
                    }
                } else {
                    *node = if *digest < n.digest { n.left } else { n.right };
                }
                step
            }
            TreeOp::ReadValue {
                digest,
                block,
                vsize,
            } => {
                let ok = self.disk[*block as usize] == *digest;
                let bytes = *vsize;
                *op = TreeOp::Verify { ok };
                Step::Io {
                    kind: IoKind::Read,
                    bytes,
                    // Calibrated to the paper's measured Aerospike IO
                    // suboperation times (T_pre ≈ 3.5 µs, T_post ≈ 2.5 µs):
                    // record lookup bookkeeping, rbuffer management, and
                    // copy-out dominate the CPU side of each read.
                    extra_pre: Dur::us(2.0),
                    extra_post: Dur::us(2.3),
                }
            }
            TreeOp::Verify { ok } => {
                if *ok {
                    self.stats.verified += 1;
                } else {
                    self.stats.corruptions += 1;
                }
                *op = TreeOp::Finished;
                Step::Done
            }
            TreeOp::WriteValue { digest, vsize } => {
                // Log-structured append: write the value to the SSD first...
                let new_block = self.append_to_log(*digest);
                let d = *digest;
                let bytes = *vsize;
                *op = TreeOp::UpdateIndex {
                    digest: d,
                    new_block,
                    node: NIL, // filled after lock
                    locked: self.lock_of(d),
                    compute_done: false,
                };
                Step::Io {
                    kind: IoKind::Write,
                    bytes,
                    extra_pre: Dur::ns(400.0), // write-buffer handling
                    extra_post: Dur::ns(200.0),
                }
            }
            TreeOp::UpdateIndex {
                digest,
                new_block,
                node,
                locked,
                compute_done,
            } => {
                if *node == NIL {
                    // First visit after the IO: take the sprig lock, start at root.
                    *node = self.roots[self.sprig_of(*digest)];
                    return Step::Lock(*locked);
                }
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let idx = *node as usize;
                let n = self.nodes[idx];
                if *digest == n.digest {
                    // Update in place; the old block becomes garbage.
                    self.nodes[idx].block = *new_block;
                    self.dead_blocks += 1;
                    let lock = *locked;
                    *op = TreeOp::Unlock { lock };
                } else {
                    *node = if *digest < n.digest { n.left } else { n.right };
                }
                Step::MemAccess(if n.in_dram {
                    Tier::Dram
                } else {
                    Tier::Secondary
                })
            }
            TreeOp::Unlock { lock } => {
                let l = *lock;
                *op = TreeOp::Finished;
                Step::Unlock(l)
            }
            TreeOp::DefragRead => {
                // Read a random old block...
                *op = TreeOp::DefragWrite;
                Step::Io {
                    kind: IoKind::Read,
                    bytes: 4096,
                    extra_pre: Dur::ns(300.0),
                    extra_post: Dur::us(1.0), // sift live entries
                }
            }
            TreeOp::DefragWrite => {
                // ...and rewrite its live data at the head.
                self.dead_blocks = self.dead_blocks.saturating_sub(2);
                self.stats.bg_ops += 1;
                let digest = fnv1a(rng.next_u64());
                let _ = self.append_to_log(digest);
                *op = TreeOp::Finished;
                Step::Io {
                    kind: IoKind::Write,
                    bytes: 4096,
                    extra_pre: Dur::ns(300.0),
                    extra_post: Dur::ns(200.0),
                }
            }
            TreeOp::DefragPause => {
                // Nothing to do: pace, then cooperatively yield so a quiet
                // defragger cannot monopolize its core's slice.
                *op = TreeOp::DefragYield;
                Step::Compute(Dur::us(5.0))
            }
            TreeOp::DefragYield => {
                *op = TreeOp::Finished;
                Step::Yield
            }
            TreeOp::Finished => Step::Done,
        }
    }
}

impl TreeKv {
    fn mix_sample(&self, rng: &mut Rng) -> OpKind {
        self.cfg.mix.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, MachineConfig, MemConfig};
    use crate::workload::KeyDist;

    fn small_cfg() -> TreeKvConfig {
        TreeKvConfig {
            n_items: 20_000,
            sprigs: 16,
            ..Default::default()
        }
    }

    #[test]
    fn population_is_complete_and_searchable() {
        let mut rng = Rng::new(1);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        assert_eq!(kv.nodes.len(), 20_000);
        // Every key must be findable by plain descent.
        for key in (0..20_000u64).step_by(97) {
            let digest = fnv1a(key);
            let mut cur = kv.roots[kv.sprig_of(digest)];
            let mut found = false;
            while cur != NIL {
                let n = kv.nodes[cur as usize];
                if n.digest == digest {
                    found = true;
                    break;
                }
                cur = if digest < n.digest { n.left } else { n.right };
            }
            assert!(found, "key {key} missing");
        }
    }

    #[test]
    fn mean_depth_tracks_log() {
        let mut rng = Rng::new(2);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        let d = kv.mean_depth(2000, &mut rng);
        // 20k items / 16 sprigs = 1250/sprig: expected ~1.39*log2(1250) ≈ 14
        // (average node depth is ~2 below that; accept a window).
        assert!((9.0..16.0).contains(&d), "mean depth {d}");
    }

    #[test]
    fn read_ops_verify_against_disk() {
        let mut rng = Rng::new(3);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(2.0), Dur::ms(10.0));
        assert!(st.ops > 1000, "ops={}", st.ops);
        assert!(m.service.stats.verified > 1000);
        assert_eq!(m.service.stats.corruptions, 0);
        // Measured M should be the tree depth (≈ 9-16).
        assert!((9.0..17.0).contains(&st.mean_m), "mean M = {}", st.mean_m);
        assert!((st.mean_s - 1.0).abs() < 0.01);
    }

    #[test]
    fn write_mix_updates_index_and_defrags() {
        let mut rng = Rng::new(4);
        let cfg = TreeKvConfig {
            mix: OpMix::ratio(1, 1),
            ..small_cfg()
        };
        let kv = TreeKv::new(cfg, &mut rng).with_background(1, 32);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(2.0), Dur::ms(20.0));
        assert!(m.service.stats.sets > 500);
        assert!(st.io_writes > 500, "writes={}", st.io_writes);
        assert!(m.service.stats.bg_ops > 0, "defrag never ran");
        assert_eq!(m.service.stats.corruptions, 0);
    }

    #[test]
    fn top_levels_tiering_absorbs_disproportionate_accesses() {
        // §5.2.3 extension: pinning the top 4 levels of every sprig to DRAM
        // uses a small capacity share but absorbs a much larger access
        // share, and the measured per-op secondary-access count M drops
        // accordingly.
        let mut rng = Rng::new(6);
        let full = TreeKv::new(small_cfg(), &mut rng);
        let tiered = TreeKv::new(
            TreeKvConfig {
                tiering: TieringPolicy::TopLevels { levels: 4 },
                ..small_cfg()
            },
            &mut rng,
        );
        let cap_frac = tiered.dram_entry_fraction();
        assert!(cap_frac < 0.03, "top-4 levels should be tiny: {cap_frac}");
        let run_m = |kv: TreeKv| {
            let mut m = Machine::new(
                MachineConfig {
                    threads_per_core: 32,
                    n_locks: 64,
                    mem: MemConfig::fpga(Dur::us(5.0)),
                    ..Default::default()
                },
                kv,
            );
            m.run(Dur::ms(2.0), Dur::ms(8.0)).mean_m
        };
        let m_full = run_m(full);
        let m_tiered = run_m(tiered);
        // 4 of ~13 descent levels move to DRAM: M drops by ~25-35%.
        assert!(
            m_tiered < m_full - 2.5,
            "tiering should cut secondary accesses: {m_full} -> {m_tiered}"
        );
    }

    #[test]
    fn random_tiering_matches_requested_fraction() {
        let mut rng = Rng::new(7);
        let kv = TreeKv::new(
            TreeKvConfig {
                tiering: TieringPolicy::Random { dram_frac: 0.3 },
                ..small_cfg()
            },
            &mut rng,
        );
        let f = kv.dram_entry_fraction();
        assert!((f - 0.3).abs() < 0.02, "dram fraction {f}");
    }

    #[test]
    fn zipf_reads_still_verify() {
        let mut rng = Rng::new(5);
        let cfg = TreeKvConfig {
            key_dist: KeyDist::Zipf {
                s: 1.1,
                scrambled: true,
            },
            ..small_cfg()
        };
        let kv = TreeKv::new(cfg, &mut rng);
        let mut m = Machine::new(MachineConfig::default(), kv);
        let _ = m.run(Dur::ms(1.0), Dur::ms(5.0));
        assert_eq!(m.service.stats.corruptions, 0);
        assert!(m.service.stats.verified > 100);
    }
}
