//! Aerospike-like SSD-based KV store (paper §4.2, Fig 13 left).
//!
//! The primary index is a forest of binary search trees ("sprigs") of
//! 64-byte entries keyed by a 64-bit digest; the entries live on secondary
//! memory and every descent hop is a dependent (prefetch+yield) access.
//! Values live on SSD in a log-structured space: writes append to the log
//! and update the index entry; a background defragmenter copies live entries
//! out of old blocks (Aerospike's defrag thread), which is the "background
//! worker" slowdown the paper's write-mix experiments exhibit.
//!
//! The full operation surface (this repo's extension beyond the paper's
//! GET/PUT reproduction):
//!
//! - **Write** upserts: a write of an absent key attaches a fresh index
//!   entry under the sprig lock (Aerospike set semantics).
//! - **Delete** removes the index entry (BST unlink under the sprig lock,
//!   successor splice for two-child nodes — every hop a simulated access)
//!   and marks the value block dead for the defragmenter.
//! - **Scan** walks one sprig in digest order from an anchor (in-order
//!   traversal; each visited entry is a dependent access) and reads the
//!   values from SSD in batches of [`SCAN_IO_BATCH`] records per IO.
//! - **ReadModifyWrite** chains the full read path (descent + value IO +
//!   verify) into the full write path (log append IO + locked index
//!   update) on the same digest.
//!
//! Keys are digests (hashes), so plain BST insertion yields expectedly
//! balanced trees — the average descent length M ≈ 1.39·log2(items/sprigs),
//! matching the paper's measured Aerospike M once sprig count is set.
//!
//! ## Concurrency model
//!
//! Structural mutations (upsert attach, delete unlink) run under the sprig
//! lock, and the root is read only **after** the lock grant (a queued
//! waiter must not descend from a pre-mutation root). Point reads and scans
//! are deliberately lock-free, as in the seed reproduction: under a
//! churn mix an in-flight reader can therefore observe a torn snapshot —
//! a spurious miss when a delete restructures the subtree mid-descent, or
//! a recycled node slot. This can never panic, corrupt, or flag a false
//! verification failure (the value log is append-only and node slots stay
//! index-valid); the observable effect is bounded stat skew under heavy
//! churn. Scans additionally validate their snapshot (anchored, strictly
//! increasing digests) so the ordered/duplicate-free result contract holds
//! even when slots are recycled mid-scan.

use super::common::{fnv1a, DriveCounts, KvStats, NIL};
use super::placement::{AccessProfile, CompressMode, Plan, PlacementPolicy, StructClass};
use super::wal::{Durable, Wal, WalConfig, WalKind, WalRecord};
use crate::model::KindCost;
use crate::sim::{BgKind, Dur, IoKind, Rng, Service, Step, Tier, TrafficClass};
use crate::workload::{
    KeyDist, KeyGen, OpKind, OpMix, OpWeights, ScanLen, TenantRouter, TenantSet, TenantTracker,
    ValueSize,
};

/// Records fetched per scan value-read IO (Aerospike batches record reads).
pub const SCAN_IO_BATCH: usize = 8;

/// Store-extra CPU attributed to each IO kind's pre/post suboperations
/// (µs). **Single source** for both the `Step::Io` sites below and the
/// model snapshots (`ModelCosts`), so the model cannot drift from the
/// simulated costs. Calibrated to the paper's measured Aerospike times:
/// record-lookup bookkeeping, rbuffer management, and copy-out dominate
/// the CPU side of each read; batch assembly and record unpack the scans.
const READ_EXTRA_PRE_US: f64 = 2.0;
const READ_EXTRA_POST_US: f64 = 2.3;
const WRITE_EXTRA_PRE_US: f64 = 0.4; // write-buffer handling
const WRITE_EXTRA_POST_US: f64 = 0.2;
const SCAN_EXTRA_PRE_US: f64 = 1.0; // batch assembly
const SCAN_EXTRA_POST_US: f64 = 1.5; // record unpack + copy-out

/// One 64-byte index entry (Aerospike's as_index).
#[derive(Debug, Clone, Copy)]
struct Node {
    digest: u64,
    left: u32,
    right: u32,
    /// SSD block holding the current value.
    block: u32,
    /// Value size in bytes.
    vsize: u32,
    /// Tier placement: this entry lives in host DRAM (§5.2.3 extension,
    /// resolved per-entry from the [`PlacementPolicy`]).
    in_dram: bool,
    /// Sprig-forest depth at attach time — the entry's placement structure
    /// class (`kvs::placement`), used to tag every access of this entry in
    /// the per-class [`AccessProfile`]. Not updated when an unlink shifts a
    /// subtree up a level (placement decisions were made at attach depth
    /// too, so class and tier stay consistent under churn).
    depth: u16,
}

#[derive(Debug, Clone)]
pub struct TreeKvConfig {
    pub n_items: u64,
    /// Number of sprigs (sub-trees); items/sprigs sets the tree depth M.
    pub sprigs: u32,
    /// Index tier placement (`kvs::placement`): the structure classes are
    /// the sprig-forest levels, hottest-first — every descent passes the
    /// top levels, so a small DRAM budget absorbs a disproportionate
    /// access share. `Random` is honored per entry (Eq 15's
    /// ρ-interpolation); `Budget` resolves to the deepest level prefix
    /// whose 64-byte entries fit.
    pub placement: PlacementPolicy,
    pub key_dist: crate::workload::KeyDist,
    /// Read:write mix (paper figures). Ignored when `ops` is set.
    pub mix: OpMix,
    /// Full-surface operation weights (YCSB presets); `None` follows `mix`.
    pub ops: Option<OpWeights>,
    /// Scan length distribution for `OpKind::Scan`.
    pub scan_len: ScanLen,
    pub value_size: ValueSize,
    /// CPU cost per index hop (comparisons, address arithmetic).
    pub t_node: Dur,
    /// Run one background defragmenter thread per core when writes happen.
    pub defrag: bool,
    /// Number of sprig locks (write path).
    pub n_locks: u32,
    /// Write-ahead log (`kvs::wal`; disabled by default). Records are keyed
    /// by **digest** — the index's native encoding — so recovery replays at
    /// the digest level.
    pub wal: WalConfig,
    /// Multi-tenant workload multiplexing (`workload::tenants`): when set,
    /// each op is issued on behalf of a deterministically scheduled tenant
    /// using that tenant's keyspace slice, mix, and scan lengths; `ops`/
    /// `mix`/`key_dist` then only describe the sizing baseline. `None`
    /// (the default) is the legacy single-tenant path, bit-identical to
    /// pre-tenant behaviour.
    pub tenants: Option<TenantSet>,
    /// Joint placement×compression (`kvs::placement` module docs): when not
    /// `Off`, every offloadable level class carries the given
    /// [`super::placement::Compression`] spec and the `Budget` knapsack may
    /// place levels compressed-in-DRAM — fewer resident bytes, an inline
    /// decompress `Compute` charged on every access. `Off` (the default)
    /// is bit-identical to pre-compression behaviour.
    pub compression: CompressMode,
}

impl Default for TreeKvConfig {
    fn default() -> Self {
        TreeKvConfig {
            // Paper: 500M items; scaled so that M ≈ 13-14 like the paper's
            // measured Aerospike runs (depth tracks items/sprigs only).
            n_items: 500_000,
            sprigs: 512,
            placement: PlacementPolicy::AllSecondary,
            key_dist: crate::workload::KeyDist::Uniform,
            mix: OpMix::READ_ONLY,
            ops: None,
            scan_len: ScanLen::default(),
            value_size: ValueSize::Fixed(1536),
            t_node: Dur::ns(110.0),
            defrag: true,
            n_locks: 64,
            wal: WalConfig::default(),
            tenants: None,
            compression: CompressMode::Off,
        }
    }
}

/// The store (the `Service` the machine drives).
pub struct TreeKv {
    pub cfg: TreeKvConfig,
    keygen: KeyGen,
    roots: Vec<u32>,
    nodes: Vec<Node>,
    /// Physical node slots released by deletes, reused by upserts.
    free_nodes: Vec<u32>,
    /// Disk image: block → digest currently stored (verification oracle).
    disk: Vec<u64>,
    /// Log head for appending writes.
    log_head: u32,
    /// Blocks freed by updates/deletes, pending defrag.
    dead_blocks: u64,
    /// Resolved tier placement over the sprig-forest level classes
    /// ([`TreeKv::level_classes`]): `Budget`/`TopLevels` entries at a
    /// DRAM-placed level class are DRAM-resident. Re-resolved over the
    /// measured per-level access profile by [`TreeKv::replan`].
    plan: Plan,
    /// Measured per-level access counts (every index-entry `MemAccess`
    /// ticks its level class) — the input to [`TreeKv::replan`].
    pub profile: AccessProfile,
    pub stats: KvStats,
    /// Pending inline decompress CPU from the last access to a
    /// compressed-in-DRAM entry, charged as the next step's `Compute`
    /// (dependent work on the op's critical path — never prefetch-hidden).
    pending_cpu: Option<Dur>,
    /// The store's write-ahead log (`kvs::wal`; inert when disabled).
    pub wal: Wal,
    /// `tid % bg_threads_per_core == bg_tid_floor` marks a background
    /// defragger thread (one per core); `usize::MAX` disables them.
    bg_tid_floor: usize,
    bg_threads_per_core: usize,
    /// Tenant scheduler + per-tenant key generators (`cfg.tenants`).
    tenants: Option<TenantRouter>,
    /// Which tenant owns each thread's in-flight op (`Service::op_tenant`).
    tenant_tids: TenantTracker,
}

/// Operation state machine.
#[derive(Debug)]
pub enum TreeOp {
    /// Descend toward `digest`; `node` is the next node to visit. `kind` is
    /// `Read` or `Rmw` (writes/deletes use their own states).
    Descend {
        kind: OpKind,
        digest: u64,
        node: u32,
        compute_done: bool,
        /// New-value size for the RMW write half.
        vsize: u32,
    },
    /// Read the value from SSD and verify.
    ReadValue {
        digest: u64,
        block: u32,
        vsize: u32,
        rmw: bool,
        new_vsize: u32,
    },
    Verify {
        ok: bool,
        rmw: bool,
        digest: u64,
        vsize: u32,
    },
    /// Write path: append the new value to the log, then re-descend to
    /// upsert the index entry under the sprig lock.
    WriteValue {
        digest: u64,
        vsize: u32,
    },
    UpdateIndex {
        digest: u64,
        new_block: u32,
        vsize: u32,
        node: u32,
        parent: u32,
        depth: u32,
        locked: u32,
        lock_taken: bool,
        /// Root read after the lock grant (never before: a queued waiter
        /// must not descend from a root captured pre-mutation).
        entered: bool,
        compute_done: bool,
    },
    /// Delete path: locked descent tracking the parent, then BST unlink.
    DeleteDescend {
        digest: u64,
        node: u32,
        parent: u32,
        locked: u32,
        lock_taken: bool,
        /// See [`TreeOp::UpdateIndex::entered`].
        entered: bool,
        compute_done: bool,
    },
    /// Two-child delete: walk to the successor (min of right subtree).
    DeleteSucc {
        target: u32,
        parent: u32,
        cur: u32,
        locked: u32,
        compute_done: bool,
    },
    /// Range scan: replay the index walk (every visited node one dependent
    /// access), then read values in batched IOs.
    Scan {
        /// Nodes in visit order, reversed (pop() = next to charge).
        walk: Vec<u32>,
        /// Result entries in digest order, reversed (pop() = next value).
        todo: Vec<u32>,
        /// Snapshot validation floor: only entries with digest >= this are
        /// emitted, so concurrent delete/upsert slot reuse cannot break
        /// the ordered/duplicate-free/anchored result contract.
        min_next: u64,
        compute_done: bool,
    },
    Unlock {
        lock: u32,
        /// The op's WAL record to commit-wait on after the lock release
        /// (`None`: nothing durable happened, finish directly).
        commit: Option<u64>,
    },
    /// WAL commit wait (`kvs::wal` protocol; entered lock-free).
    WalCommit {
        lsn: u64,
    },
    /// This op leads the flush of records `[.., upto)`; its own is `lsn`.
    WalFlush {
        upto: u64,
        lsn: u64,
    },
    /// Background defrag: read an old block, re-append its live entry.
    DefragRead,
    DefragWrite,
    DefragPause,
    DefragYield,
    Finished,
}

impl TreeKv {
    pub fn new(cfg: TreeKvConfig, rng: &mut Rng) -> TreeKv {
        let keygen = KeyGen::new(cfg.n_items, cfg.key_dist);
        let plan = Plan::resolve(cfg.placement, Self::placement_classes(&cfg));
        let n_classes = plan.classes().len();
        let mut kv = TreeKv {
            roots: vec![NIL; cfg.sprigs as usize],
            nodes: Vec::with_capacity(cfg.n_items as usize),
            free_nodes: Vec::new(),
            disk: Vec::with_capacity(cfg.n_items as usize * 2),
            log_head: 0,
            dead_blocks: 0,
            plan,
            profile: AccessProfile::new(n_classes),
            stats: KvStats::default(),
            pending_cpu: None,
            wal: Wal::new(cfg.wal.clone()),
            bg_tid_floor: usize::MAX,
            bg_threads_per_core: 1,
            tenants: cfg.tenants.as_ref().map(|set| TenantRouter::new(set, cfg.n_items)),
            tenant_tids: TenantTracker::default(),
            keygen,
            cfg,
        };
        // Populate directly (construction is not simulated, like the paper's
        // untimed load phase).
        let mut vrng = rng.fork(0x7ee);
        for key in 0..kv.cfg.n_items {
            let digest = fnv1a(key);
            let vsize = kv.cfg.value_size.sample(&mut vrng);
            let block = kv.append_to_log(digest);
            kv.insert_unsimulated(digest, block, vsize, &mut vrng);
        }
        kv
    }

    /// Effective operation weights: explicit `ops` or the two-kind `mix`.
    fn weights(&self) -> OpWeights {
        match self.cfg.ops {
            Some(w) => w,
            None => OpWeights::from(self.cfg.mix),
        }
    }

    /// Whether the effective workload (tenant set when present, else the
    /// store's own mix) has mutating mass — drives background defrag.
    fn workload_has_writes(&self) -> bool {
        match &self.cfg.tenants {
            Some(set) => set.any_writes(),
            None => self.weights().has_writes(),
        }
    }

    /// Designate background threads: the machine's thread ids are laid out
    /// core-major; the last thread of each core becomes the defragger.
    pub fn with_background(mut self, cores: usize, threads_per_core: usize) -> TreeKv {
        if self.cfg.defrag && self.workload_has_writes() {
            self.bg_tid_floor = threads_per_core - 1; // tid % tpc == floor
            self.bg_threads_per_core = threads_per_core;
            let _ = cores;
        }
        self
    }

    fn append_to_log(&mut self, digest: u64) -> u32 {
        let b = self.log_head;
        self.disk.push(digest);
        self.log_head += 1;
        b
    }

    fn sprig_of(&self, digest: u64) -> usize {
        (digest % self.cfg.sprigs as u64) as usize
    }

    /// Placement structure class of an entry at `depth` (one class per
    /// sprig-forest level, clamped to the 64-class cap of
    /// [`TreeKv::level_classes`]).
    #[inline]
    fn level_class(depth: u32) -> usize {
        (depth as usize).min(63)
    }

    /// One simulated access to entry `id`: tag its level class in the
    /// [`AccessProfile`] and return the access step at the entry's tier.
    /// Accesses to a compressed-in-DRAM class additionally queue the
    /// class's inline decompress CPU, charged as the next step's `Compute`.
    #[inline]
    fn entry_access(&mut self, id: u32) -> Step {
        let n = &self.nodes[id as usize];
        let class = Self::level_class(n.depth as u32);
        self.profile.tick(class);
        if n.in_dram {
            if self.plan.is_compressed(class) {
                self.pending_cpu = Some(Dur::us(self.plan.decompress_us(class)));
            }
            Step::MemAccess(Tier::Dram)
        } else {
            Step::MemAccess(Tier::Secondary)
        }
    }

    /// The resolved placement plan (static, or measured after
    /// [`TreeKv::replan`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Re-resolve the placement over the **measured** per-level access
    /// profile (`kvs::placement` module docs, "Measured re-ranking") and
    /// re-tier every live entry accordingly. `Random` keeps its per-entry
    /// draws (re-drawing would disturb the RNG stream); `AllSecondary`/
    /// `AllDram` are ranking-independent, so only `TopLevels`/`Budget`
    /// actually move entries. An empty profile keeps the static plan.
    pub fn replan(&mut self, profile: &AccessProfile) {
        self.plan = Plan::replan(
            self.cfg.placement,
            Self::placement_classes(&self.cfg),
            profile,
        );
        if !matches!(
            self.cfg.placement,
            PlacementPolicy::TopLevels { .. } | PlacementPolicy::Budget { .. }
        ) {
            return;
        }
        let mut free = vec![false; self.nodes.len()];
        for &id in &self.free_nodes {
            free[id as usize] = true;
        }
        for (id, node) in self.nodes.iter_mut().enumerate() {
            if free[id] {
                continue; // freed slots stay out of the DRAM accounting
            }
            node.in_dram = self.plan.in_dram(Self::level_class(node.depth as u32));
        }
    }

    /// Swap the workload mid-run (phased schedules): new operation weights
    /// and key distribution over the same store. The keygen rebuild is
    /// pure arithmetic (`KeyGen::new` draws no randomness), so the
    /// simulation's RNG stream is untouched and determinism holds.
    pub fn set_workload(&mut self, ops: Option<OpWeights>, key_dist: KeyDist) {
        self.cfg.ops = ops;
        self.cfg.key_dist = key_dist;
        self.keygen = KeyGen::new(self.cfg.n_items, key_dist);
    }

    /// [`TreeKv::replan`] with honest migration accounting (`kvs::placement`
    /// module docs, "Online replanning"): every live entry whose tier flips
    /// is one 64-byte line copied between tiers — a read on the side it
    /// leaves and a write on the side it lands, tallied as one `dram` plus
    /// one `secondary` line touch whichever direction it moves. Index
    /// entries carry their value-block pointers with them, so no value IO
    /// moves (`reads`/`writes` stay 0). Feed the counts to
    /// `sim::Machine::charge_migration`; an unchanged plan costs nothing.
    pub fn replan_migrate(&mut self, profile: &AccessProfile) -> DriveCounts {
        let before: Vec<bool> = self.nodes.iter().map(|n| n.in_dram).collect();
        self.replan(profile);
        let mut mig = DriveCounts::default();
        if !matches!(
            self.cfg.placement,
            PlacementPolicy::TopLevels { .. } | PlacementPolicy::Budget { .. }
        ) {
            return mig;
        }
        let mut free = vec![false; self.nodes.len()];
        for &id in &self.free_nodes {
            free[id as usize] = true;
        }
        for (id, node) in self.nodes.iter().enumerate() {
            if free[id] || node.in_dram == before[id] {
                continue;
            }
            mig.dram += 1;
            mig.secondary += 1;
        }
        mig
    }

    /// The placement structure classes: one per sprig-forest level,
    /// hottest-first. Level `d` holds `min(sprigs·2^d, remaining)` 64-byte
    /// entries; its access share is ≈ the probability a descent reaches it
    /// (1 for full levels, the fill fraction for the last partial one).
    fn level_classes(n_items: u64, sprigs: u32) -> Vec<StructClass> {
        let mut classes = Vec::new();
        let mut remaining = n_items;
        let mut width = sprigs.max(1) as u64;
        while remaining > 0 && classes.len() < 64 {
            let count = width.min(remaining);
            classes.push(StructClass::new(
                "index-level",
                count * 64,
                count as f64 / width as f64,
            ));
            remaining -= count;
            width = width.saturating_mul(2);
        }
        classes
    }

    /// The level classes with the configured compression spec attached —
    /// the planner's knapsack items (`kvs::placement`, joint
    /// placement×compression).
    fn placement_classes(cfg: &TreeKvConfig) -> Vec<StructClass> {
        Self::level_classes(cfg.n_items, cfg.sprigs)
            .into_iter()
            .map(|c| c.with_compression(cfg.compression.spec()))
            .collect()
    }

    /// Modeled per-hop decompress CPU (µs) for compressed-in-DRAM hops.
    fn t_cpu_us(&self) -> f64 {
        self.cfg.compression.spec().map_or(0.0, |s| s.decompress_us)
    }

    fn place_in_dram(&self, depth: u32, rng: &mut Rng) -> bool {
        match self.cfg.placement {
            PlacementPolicy::AllSecondary => false,
            PlacementPolicy::AllDram => true,
            PlacementPolicy::Random { dram_frac } => rng.chance(dram_frac),
            // Prefix policies follow the plan's (possibly measured) level
            // ranking — for the static resolution this is exactly the old
            // `depth < k` / `depth < dram_levels` rule.
            PlacementPolicy::TopLevels { .. } | PlacementPolicy::Budget { .. } => {
                self.plan.in_dram(Self::level_class(depth))
            }
        }
    }

    /// Allocate (or reuse) a node slot and link it under `parent`.
    fn attach_new(
        &mut self,
        digest: u64,
        block: u32,
        vsize: u32,
        parent: u32,
        depth: u32,
        rng: &mut Rng,
    ) -> u32 {
        let in_dram = self.place_in_dram(depth, rng);
        let node = Node {
            digest,
            left: NIL,
            right: NIL,
            block,
            vsize,
            in_dram,
            depth: depth.min(u16::MAX as u32) as u16,
        };
        let id = match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id as usize] = node;
                id
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        };
        if parent == NIL {
            let sprig = self.sprig_of(digest);
            self.roots[sprig] = id;
        } else if digest < self.nodes[parent as usize].digest {
            self.nodes[parent as usize].left = id;
        } else {
            self.nodes[parent as usize].right = id;
        }
        id
    }

    /// Point `parent`'s link to `child` at `with` (root link when parent is
    /// NIL).
    fn replace_child(&mut self, sprig: usize, parent: u32, child: u32, with: u32) {
        if parent == NIL {
            self.roots[sprig] = with;
        } else if self.nodes[parent as usize].left == child {
            self.nodes[parent as usize].left = with;
        } else {
            debug_assert_eq!(self.nodes[parent as usize].right, child);
            self.nodes[parent as usize].right = with;
        }
    }

    /// Append a WAL record for a completed index mutation (digest-keyed);
    /// `None` when the log is disabled.
    #[inline]
    fn wal_append(&mut self, kind: WalKind, digest: u64, vsize: u32) -> Option<u64> {
        self.wal
            .enabled()
            .then(|| self.wal.append(kind, digest, vsize))
    }

    /// Recovery applier for a durable `Put`: upsert at the digest level
    /// (update-in-place when the digest exists, fresh attach otherwise) —
    /// unsimulated, like the load phase.
    fn upsert_unsimulated(&mut self, digest: u64, vsize: u32, rng: &mut Rng) {
        let block = self.append_to_log(digest);
        let sprig = self.sprig_of(digest);
        let mut cur = self.roots[sprig];
        let mut parent = NIL;
        let mut depth = 0u32;
        while cur != NIL {
            let n = self.nodes[cur as usize];
            if digest == n.digest {
                self.nodes[cur as usize].block = block;
                self.nodes[cur as usize].vsize = vsize;
                self.dead_blocks += 1;
                return;
            }
            depth += 1;
            parent = cur;
            cur = if digest < n.digest { n.left } else { n.right };
        }
        self.attach_new(digest, block, vsize, parent, depth, rng);
    }

    /// Recovery applier for a durable `Delete`: BST unlink at the digest
    /// level (successor splice for two-child nodes), mirroring the
    /// simulated delete path's structural effect.
    fn delete_unsimulated(&mut self, digest: u64) {
        let sprig = self.sprig_of(digest);
        let mut parent = NIL;
        let mut cur = self.roots[sprig];
        while cur != NIL {
            let n = self.nodes[cur as usize];
            if digest == n.digest {
                break;
            }
            parent = cur;
            cur = if digest < n.digest { n.left } else { n.right };
        }
        if cur == NIL {
            return;
        }
        let n = self.nodes[cur as usize];
        if n.left != NIL && n.right != NIL {
            let mut sp = cur;
            let mut s = n.right;
            while self.nodes[s as usize].left != NIL {
                sp = s;
                s = self.nodes[s as usize].left;
            }
            let succ = self.nodes[s as usize];
            if sp == cur {
                self.nodes[cur as usize].right = succ.right;
            } else {
                self.nodes[sp as usize].left = succ.right;
            }
            let tn = &mut self.nodes[cur as usize];
            tn.digest = succ.digest;
            tn.block = succ.block;
            tn.vsize = succ.vsize;
            self.nodes[s as usize].in_dram = false;
            self.free_nodes.push(s);
        } else {
            let child = if n.left != NIL { n.left } else { n.right };
            self.replace_child(sprig, parent, cur, child);
            self.nodes[cur as usize].in_dram = false;
            self.free_nodes.push(cur);
        }
        self.dead_blocks += 1;
    }

    fn insert_unsimulated(&mut self, digest: u64, block: u32, vsize: u32, rng: &mut Rng) {
        let sprig = self.sprig_of(digest);
        let mut cur = self.roots[sprig];
        let mut parent = NIL;
        let mut depth = 0u32;
        while cur != NIL {
            depth += 1;
            parent = cur;
            let n = self.nodes[cur as usize];
            cur = if digest < n.digest { n.left } else { n.right };
        }
        self.attach_new(digest, block, vsize, parent, depth, rng);
    }

    /// Fraction of index entries resident in DRAM (capacity-side ρ probe).
    pub fn dram_entry_fraction(&self) -> f64 {
        if self.nodes.is_empty() {
            return 0.0;
        }
        self.nodes.iter().filter(|n| n.in_dram).count() as f64 / self.nodes.len() as f64
    }

    /// Simulated DRAM bytes the placement consumes: 64 bytes per
    /// DRAM-resident entry (exact, entry-granular — freed slots are
    /// cleared when recycled into the free list). Entries of a
    /// compressed-in-DRAM level class count at the compressed ratio
    /// (⌈q·bytes⌉ per class, matching `Plan::dram_bytes` accounting);
    /// with compression off this is exactly `64 × resident entries`.
    pub fn dram_bytes(&self) -> u64 {
        let mut per_class = [0u64; 64];
        for n in self.nodes.iter().filter(|n| n.in_dram) {
            per_class[Self::level_class(n.depth as u32)] += 64;
        }
        per_class
            .iter()
            .enumerate()
            .map(|(class, &bytes)| {
                if self.plan.is_compressed(class) {
                    let q = self
                        .plan
                        .classes()
                        .get(class)
                        .and_then(|c| c.compression)
                        .map_or(1.0, |s| s.ratio_q);
                    ((q * bytes as f64).ceil() as u64).min(bytes)
                } else {
                    bytes
                }
            })
            .sum()
    }

    /// Fraction of index entries resident compressed-in-DRAM (the walk-side
    /// analog of [`TreeKv::dram_entry_fraction`] for the scan split).
    fn compressed_entry_fraction(&self) -> f64 {
        if self.nodes.is_empty() {
            return 0.0;
        }
        let cpr = self
            .nodes
            .iter()
            .filter(|n| n.in_dram && self.plan.is_compressed(Self::level_class(n.depth as u32)))
            .count();
        cpr as f64 / self.nodes.len() as f64
    }

    /// Total offloadable index bytes (the `AllDram` footprint).
    pub fn offload_bytes_total(&self) -> u64 {
        (self.nodes.len() - self.free_nodes.len()) as u64 * 64
    }

    /// Average descent depth (tests / parameter probes).
    pub fn mean_depth(&self, samples: u64, rng: &mut Rng) -> f64 {
        let mut total = 0u64;
        for _ in 0..samples {
            let key = rng.below(self.cfg.n_items);
            let digest = fnv1a(key);
            let mut cur = self.roots[self.sprig_of(digest)];
            let mut d = 0u64;
            while cur != NIL {
                d += 1;
                let n = self.nodes[cur as usize];
                if digest == n.digest {
                    break;
                }
                cur = if digest < n.digest { n.left } else { n.right };
            }
            total += d;
        }
        total as f64 / samples as f64
    }

    fn lock_of(&self, digest: u64) -> u32 {
        (self.sprig_of(digest) as u32) % self.cfg.n_locks
    }

    /// Structural membership probe (oracle for tests; not simulated).
    pub fn contains_key(&self, key: u64) -> bool {
        let digest = fnv1a(key);
        let mut cur = self.roots[self.sprig_of(digest)];
        while cur != NIL {
            let n = self.nodes[cur as usize];
            if digest == n.digest {
                return true;
            }
            cur = if digest < n.digest { n.left } else { n.right };
        }
        false
    }

    /// In-order index walk from `anchor` within one sprig. Returns
    /// (entries in digest order capped at `len`, all visited node ids in
    /// visit order) — the scan op replays the visit list as dependent
    /// accesses, so the measured M reflects the real traversal.
    fn scan_collect(&self, sprig: usize, anchor: u64, len: u32) -> (Vec<u32>, Vec<u32>) {
        let mut stack: Vec<u32> = Vec::new();
        let mut visit: Vec<u32> = Vec::new();
        let mut out: Vec<u32> = Vec::new();
        let mut cur = self.roots[sprig];
        while cur != NIL {
            visit.push(cur);
            let n = &self.nodes[cur as usize];
            if anchor <= n.digest {
                stack.push(cur);
                cur = n.left;
            } else {
                cur = n.right;
            }
        }
        while let Some(id) = stack.pop() {
            out.push(id);
            if out.len() as u32 >= len {
                break;
            }
            let mut c = self.nodes[id as usize].right;
            while c != NIL {
                visit.push(c);
                stack.push(c);
                c = self.nodes[c as usize].left;
            }
        }
        (out, visit)
    }

    /// Digest-ordered scan results starting at `key`'s digest (oracle for
    /// the ordering/duplicate property tests; not simulated).
    pub fn scan_digests(&self, key: u64, len: u32) -> Vec<u64> {
        let anchor = fnv1a(key);
        let (out, _) = self.scan_collect(self.sprig_of(anchor), anchor, len.max(1));
        out.iter().map(|&id| self.nodes[id as usize].digest).collect()
    }

    // ---- directed operation constructors (also used by next_op) ----------

    pub fn op_get(&mut self, key: u64) -> TreeOp {
        self.stats.gets += 1;
        let digest = fnv1a(key);
        TreeOp::Descend {
            kind: OpKind::Read,
            digest,
            node: self.roots[self.sprig_of(digest)],
            compute_done: false,
            vsize: 0,
        }
    }

    pub fn op_write(&mut self, key: u64, vsize: u32) -> TreeOp {
        self.stats.sets += 1;
        TreeOp::WriteValue {
            digest: fnv1a(key),
            vsize,
        }
    }

    pub fn op_delete(&mut self, key: u64) -> TreeOp {
        self.stats.deletes += 1;
        let digest = fnv1a(key);
        TreeOp::DeleteDescend {
            digest,
            node: NIL,
            parent: NIL,
            locked: self.lock_of(digest),
            lock_taken: false,
            entered: false,
            compute_done: false,
        }
    }

    pub fn op_rmw(&mut self, key: u64, vsize: u32) -> TreeOp {
        self.stats.rmws += 1;
        let digest = fnv1a(key);
        TreeOp::Descend {
            kind: OpKind::Rmw,
            digest,
            node: self.roots[self.sprig_of(digest)],
            compute_done: false,
            vsize,
        }
    }

    pub fn op_scan(&mut self, key: u64, len: u32) -> TreeOp {
        self.stats.scans += 1;
        let anchor = fnv1a(key);
        let sprig = self.sprig_of(anchor);
        let (mut order, mut visit) = self.scan_collect(sprig, anchor, len.max(1));
        if order.is_empty() {
            self.stats.absent += 1;
        }
        order.reverse();
        visit.reverse();
        TreeOp::Scan {
            walk: visit,
            todo: order,
            min_next: anchor,
            compute_done: false,
        }
    }
}

// Extra field defined outside the struct literal flow above.
impl TreeKv {
    fn is_bg(&self, tid: usize) -> bool {
        self.bg_tid_floor != usize::MAX && tid % self.bg_threads_per_core == self.bg_tid_floor
    }
}

// ---- Θ_scan model-parameter snapshots (kvs::ModelCosts) -------------------

/// Device-base per-IO CPU suboperation times assumed by the snapshots (the
/// `SsdConfig` defaults).
const SSD_BASE_PRE_US: f64 = 1.5;
const SSD_BASE_POST_US: f64 = 0.2;
/// T_pre/T_post per IO kind: device base plus the *same* store-extra
/// constants the `Step::Io` sites charge.
const IO_READ_PRE: f64 = SSD_BASE_PRE_US + READ_EXTRA_PRE_US;
const IO_READ_POST: f64 = SSD_BASE_POST_US + READ_EXTRA_POST_US;
const IO_WRITE_PRE: f64 = SSD_BASE_PRE_US + WRITE_EXTRA_PRE_US;
const IO_WRITE_POST: f64 = SSD_BASE_POST_US + WRITE_EXTRA_POST_US;
const IO_SCAN_PRE: f64 = SSD_BASE_PRE_US + SCAN_EXTRA_PRE_US;
const IO_SCAN_POST: f64 = SSD_BASE_POST_US + SCAN_EXTRA_POST_US;

impl TreeKv {
    /// Deterministic structural probe of the descent cost: walk the index
    /// for a fixed stride of the keyspace (no RNG — snapshots must be
    /// reproducible) and average the hops a point lookup performs. Returns
    /// `(hops, secondary_hops, compressed_hops)`: the parts differ only
    /// under a tiering policy that pins some levels/entries to DRAM (and,
    /// for the third, places some of those levels compressed).
    fn probe_descent(&self) -> (f64, f64, f64) {
        let n = self.cfg.n_items.max(1);
        let step = (n / 2048).max(1);
        let (mut hops, mut sec, mut cpr, mut probes) = (0u64, 0u64, 0u64, 0u64);
        let mut key = 0u64;
        while key < n {
            let digest = fnv1a(key);
            let mut cur = self.roots[self.sprig_of(digest)];
            while cur != NIL {
                let node = &self.nodes[cur as usize];
                hops += 1;
                if !node.in_dram {
                    sec += 1;
                } else if self.plan.is_compressed(Self::level_class(node.depth as u32)) {
                    cpr += 1;
                }
                if digest == node.digest {
                    break;
                }
                cur = if digest < node.digest {
                    node.left
                } else {
                    node.right
                };
            }
            probes += 1;
            key += step;
        }
        let p = probes.max(1) as f64;
        (hops as f64 / p, sec as f64 / p, cpr as f64 / p)
    }

    /// Θ_scan cost vector for an explicit scan length (the
    /// `model_params(Scan)` snapshot instead uses the configured length
    /// *distribution* via [`TreeKv::scan_cost_dist`]; tests probe specific
    /// lengths including zero here). The in-order walk visits ≈ descent +
    /// `len` nodes, and values are read `SCAN_IO_BATCH` records per IO.
    pub fn scan_model_params(&self, len: f64) -> KindCost {
        let (hops, sec_hops, cpr_hops) = self.probe_descent();
        let vbytes = self.cfg.value_size.mean().max(64.0);
        let c = KindCost::scan(
            hops,
            len,
            SCAN_IO_BATCH as f64,
            vbytes,
            self.cfg.t_node.as_us(),
            IO_SCAN_PRE,
            IO_SCAN_POST,
        );
        self.split_scan_hops(c, hops, sec_hops, cpr_hops)
    }

    /// The `model_params(Scan)` snapshot: the configured scan-length
    /// distribution's first two moments feed `KindCost::scan_dist`, so
    /// uniform scan mixes stop biasing the batched IO count (the PR 3
    /// follow-up on scan-length distributions beyond the mean).
    fn scan_cost_dist(&self, hops: f64, sec_hops: f64, cpr_hops: f64) -> KindCost {
        let vbytes = self.cfg.value_size.mean().max(64.0);
        let c = KindCost::scan_dist(
            hops,
            self.cfg.scan_len.mean(),
            self.cfg.scan_len.second_moment(),
            SCAN_IO_BATCH as f64,
            vbytes,
            self.cfg.t_node.as_us(),
            IO_SCAN_PRE,
            IO_SCAN_POST,
        );
        self.split_scan_hops(c, hops, sec_hops, cpr_hops)
    }

    /// Tier placement splits the scan's hops in two parts: the anchor
    /// *descent* passes the (possibly DRAM-resident) top levels at the
    /// probed descent ratio, while the in-order *walk* visits nodes in
    /// node-count proportion — dominated by the deep levels, so its DRAM
    /// share is the entry-granular capacity fraction, not the descent
    /// ratio (which would overstate the walk's DRAM side under top-levels
    /// placement). Compressed-in-DRAM hops split the same way: the descent
    /// at the probed compressed ratio, the walk at the entry-granular
    /// compressed fraction — each such hop carries the inline `t_cpu`.
    fn split_scan_hops(&self, mut c: KindCost, hops: f64, sec_hops: f64, cpr_hops: f64) -> KindCost {
        let descent_sec = if hops > 0.0 { sec_hops / hops } else { 1.0 };
        let descent_cpr = if hops > 0.0 { cpr_hops / hops } else { 0.0 };
        let total = c.m;
        let walk = (total - hops).max(0.0);
        let walk_sec = 1.0 - self.dram_entry_fraction();
        let walk_cpr = self.compressed_entry_fraction();
        let m_sec = (total - walk) * descent_sec + walk * walk_sec;
        let m_cpr = (total - walk) * descent_cpr + walk * walk_cpr;
        c.m = m_sec;
        c.with_m_dram((total - m_sec - m_cpr).max(0.0))
            .with_compressed(m_cpr, self.t_cpu_us())
    }
}

impl super::ModelCosts for TreeKv {
    /// Per-kind cost vectors from the live tree geometry: the descent depth
    /// is probed from the actual sprig forest (≈ 1.39·log2(items/sprigs))
    /// and split into secondary/DRAM hops by the live placement, IO CPU
    /// times are the configured device+store constants, and scans follow
    /// the Θ_scan shape with the configured length distribution's first
    /// two moments ([`TreeKv::scan_cost_dist`]). The background
    /// defragmenter is not part of the per-op model (its IOs ride on
    /// separate threads).
    fn model_params(&self, kind: OpKind) -> KindCost {
        let (hops, sec_hops, cpr_hops) = self.probe_descent();
        let dram_hops = (hops - sec_hops - cpr_hops).max(0.0);
        let t_cpu = self.t_cpu_us();
        let t_mem = self.cfg.t_node.as_us();
        let vbytes = self.cfg.value_size.mean().max(64.0);
        // The leaf attach/unlink access happens at the deepest level of its
        // sprig: under the prefix policies it is DRAM-resident only when
        // the whole descent is. Under per-entry `Random` the leaf is DRAM
        // with the entry-granular capacity fraction — the former binary
        // split (always secondary once any hop was) drifted the
        // write/delete snapshots by up to a full hop at high `dram_frac`.
        let leaf_dram = match self.cfg.placement {
            PlacementPolicy::Random { .. } => self.dram_entry_fraction(),
            _ => {
                if sec_hops > 0.0 {
                    0.0
                } else {
                    1.0
                }
            }
        };
        let leaf_sec = 1.0 - leaf_dram;
        match kind {
            OpKind::Read => {
                KindCost::point(sec_hops, 1.0, vbytes, t_mem, IO_READ_PRE, IO_READ_POST)
                    .with_m_dram(dram_hops)
                    .with_compressed(cpr_hops, t_cpu)
            }
            // Log append IO + locked re-descent + entry write.
            OpKind::Write => KindCost::point(
                sec_hops + leaf_sec,
                1.0,
                vbytes,
                t_mem,
                IO_WRITE_PRE,
                IO_WRITE_POST,
            )
            .with_m_dram(dram_hops + leaf_dram)
            .with_compressed(cpr_hops, t_cpu),
            // Locked descent + unlink (occasional successor walk folded into
            // the +1); no synchronous IO — the block is reclaimed by defrag.
            OpKind::Delete => KindCost::memory_only(sec_hops + leaf_sec, t_mem, t_mem)
                .with_m_dram(dram_hops + leaf_dram)
                .with_compressed(cpr_hops, t_cpu),
            OpKind::Scan => self.scan_cost_dist(hops, sec_hops, cpr_hops),
            // Full read path chained into the full write path.
            OpKind::Rmw => KindCost::point(
                2.0 * sec_hops + leaf_sec,
                2.0,
                vbytes,
                t_mem,
                (IO_READ_PRE + IO_WRITE_PRE) / 2.0,
                (IO_READ_POST + IO_WRITE_POST) / 2.0,
            )
            .with_m_dram(2.0 * dram_hops + leaf_dram)
            .with_compressed(2.0 * cpr_hops, t_cpu),
        }
    }
}

impl Service for TreeKv {
    type Op = TreeOp;

    fn next_op(&mut self, tid: usize, rng: &mut Rng) -> TreeOp {
        if self.is_bg(tid) {
            // Defrag ops are the store's own work, owned by no tenant.
            self.tenant_tids.note(tid, None);
            // Defrag pacing: only work when enough dead blocks accumulated.
            if self.dead_blocks > 64 {
                return TreeOp::DefragRead;
            }
            return TreeOp::DefragPause;
        }
        // Tenant selection is RNG-free (SWRR), so the single-tenant path
        // consumes the exact legacy draw sequence: key, kind, vsize[, len].
        let tenant = self.tenants.as_mut().map(|r| r.pick());
        self.tenant_tids.note(tid, tenant);
        let (key, kind, scan_len) = if let Some(t) = tenant {
            let router = self.tenants.as_ref().unwrap();
            let key = router.sample_key(t, rng);
            let spec = router.spec(t);
            (key, spec.ops.sample(rng), spec.scan_len)
        } else {
            (
                self.keygen.sample(rng),
                self.weights().sample(rng),
                self.cfg.scan_len,
            )
        };
        let vsize = self.cfg.value_size.sample(rng);
        match kind {
            OpKind::Read => self.op_get(key),
            OpKind::Write => self.op_write(key, vsize),
            OpKind::Delete => self.op_delete(key),
            OpKind::Rmw => self.op_rmw(key, vsize),
            OpKind::Scan => {
                let len = scan_len.sample(rng);
                self.op_scan(key, len)
            }
        }
    }

    fn op_tenant(&self, tid: usize) -> Option<u32> {
        self.tenant_tids.current(tid)
    }

    fn step(&mut self, _tid: usize, op: &mut TreeOp, rng: &mut Rng) -> Step {
        // Inline decompress CPU owed by the previous compressed-class
        // access: a dependent Compute on the op's critical path (the op
        // state already advanced, so this purely adds busy time).
        if let Some(d) = self.pending_cpu.take() {
            return Step::Compute(d);
        }
        match op {
            TreeOp::Descend {
                kind,
                digest,
                node,
                compute_done,
                vsize,
            } => {
                if *node == NIL {
                    // Not found (deleted or never written).
                    self.stats.misses += 1;
                    self.stats.absent += 1;
                    if *kind == OpKind::Rmw {
                        // Read-miss RMW still writes (upsert).
                        let (d, vs) = (*digest, *vsize);
                        *op = TreeOp::WriteValue {
                            digest: d,
                            vsize: vs,
                        };
                        return Step::Compute(self.cfg.t_node);
                    }
                    *op = TreeOp::Finished;
                    return Step::Done;
                }
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let n = self.nodes[*node as usize];
                let step = self.entry_access(*node);
                if *digest == n.digest {
                    self.stats.hits += 1;
                    let rmw = *kind == OpKind::Rmw;
                    *op = TreeOp::ReadValue {
                        digest: *digest,
                        block: n.block,
                        vsize: n.vsize,
                        rmw,
                        new_vsize: *vsize,
                    };
                } else {
                    *node = if *digest < n.digest { n.left } else { n.right };
                }
                step
            }
            TreeOp::ReadValue {
                digest,
                block,
                vsize,
                rmw,
                new_vsize,
            } => {
                let ok = self.disk[*block as usize] == *digest;
                let bytes = *vsize;
                // Route to the array device owning this value-log block.
                let shard = *block as u64;
                *op = TreeOp::Verify {
                    ok,
                    rmw: *rmw,
                    digest: *digest,
                    vsize: *new_vsize,
                };
                Step::Io {
                    kind: IoKind::Read,
                    bytes,
                    // See READ_EXTRA_* (T_pre ≈ 3.5 µs, T_post ≈ 2.5 µs with
                    // the device base).
                    extra_pre: Dur::us(READ_EXTRA_PRE_US),
                    extra_post: Dur::us(READ_EXTRA_POST_US),
                    shard,
                    class: TrafficClass::Foreground,
                }
            }
            TreeOp::Verify {
                ok,
                rmw,
                digest,
                vsize,
            } => {
                if *ok {
                    self.stats.verified += 1;
                } else {
                    self.stats.corruptions += 1;
                }
                if *rmw {
                    // Modify step between the read and write halves.
                    let (d, vs) = (*digest, *vsize);
                    *op = TreeOp::WriteValue {
                        digest: d,
                        vsize: vs,
                    };
                    return Step::Compute(self.cfg.t_node);
                }
                *op = TreeOp::Finished;
                Step::Done
            }
            TreeOp::WriteValue { digest, vsize } => {
                // Log-structured append: write the value to the SSD first...
                let new_block = self.append_to_log(*digest);
                let d = *digest;
                let bytes = (*vsize).max(64);
                *op = TreeOp::UpdateIndex {
                    digest: d,
                    new_block,
                    vsize: *vsize,
                    node: NIL,
                    parent: NIL,
                    depth: 0,
                    locked: self.lock_of(d),
                    lock_taken: false,
                    entered: false,
                    compute_done: false,
                };
                Step::Io {
                    kind: IoKind::Write,
                    bytes,
                    extra_pre: Dur::us(WRITE_EXTRA_PRE_US),
                    extra_post: Dur::us(WRITE_EXTRA_POST_US),
                    // The appended block's device owns the write.
                    shard: new_block as u64,
                    class: TrafficClass::Foreground,
                }
            }
            TreeOp::UpdateIndex {
                digest,
                new_block,
                vsize,
                node,
                parent,
                depth,
                locked,
                lock_taken,
                entered,
                compute_done,
            } => {
                if !*lock_taken {
                    // First visit after the IO: take the sprig lock.
                    *lock_taken = true;
                    return Step::Lock(*locked);
                }
                if !*entered {
                    // Lock granted: only now read the root — a contended
                    // waiter resumes here after the holder's mutations, so a
                    // root captured before Lock could be stale or freed.
                    *entered = true;
                    *node = self.roots[self.sprig_of(*digest)];
                    *parent = NIL;
                    *depth = 0;
                }
                if *node == NIL {
                    // Upsert: attach a fresh entry under the tracked parent
                    // (write of the new 64-byte entry is one access at its
                    // placement tier).
                    let (d, nb, vs, par, dep, lock) =
                        (*digest, *new_block, *vsize, *parent, *depth, *locked);
                    let id = self.attach_new(d, nb, vs, par, dep, rng);
                    let commit = self.wal_append(WalKind::Put, d, vs);
                    *op = TreeOp::Unlock { lock, commit };
                    return self.entry_access(id);
                }
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let idx = *node as usize;
                let n = self.nodes[idx];
                if *digest == n.digest {
                    // Update in place; the old block becomes garbage.
                    self.nodes[idx].block = *new_block;
                    self.nodes[idx].vsize = *vsize;
                    self.dead_blocks += 1;
                    let (d, vs, lock) = (*digest, *vsize, *locked);
                    let commit = self.wal_append(WalKind::Put, d, vs);
                    *op = TreeOp::Unlock { lock, commit };
                } else {
                    *parent = *node;
                    *depth += 1;
                    *node = if *digest < n.digest { n.left } else { n.right };
                }
                self.entry_access(idx as u32)
            }
            TreeOp::DeleteDescend {
                digest,
                node,
                parent,
                locked,
                lock_taken,
                entered,
                compute_done,
            } => {
                if !*lock_taken {
                    *lock_taken = true;
                    return Step::Lock(*locked);
                }
                if !*entered {
                    // Root read deferred to after the lock grant (see
                    // UpdateIndex).
                    *entered = true;
                    *node = self.roots[self.sprig_of(*digest)];
                    *parent = NIL;
                }
                if *node == NIL {
                    // Key absent (already deleted / never written): nothing
                    // mutated, nothing to log.
                    self.stats.absent += 1;
                    let lock = *locked;
                    *op = TreeOp::Unlock { lock, commit: None };
                    return Step::Compute(self.cfg.t_node);
                }
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let idx = *node as usize;
                let n = self.nodes[idx];
                let step = self.entry_access(idx as u32);
                if *digest == n.digest {
                    if n.left != NIL && n.right != NIL {
                        // Two children: splice in the successor.
                        let (t, lock) = (*node, *locked);
                        *op = TreeOp::DeleteSucc {
                            target: t,
                            parent: t,
                            cur: n.right,
                            locked: lock,
                            compute_done: false,
                        };
                    } else {
                        // Leaf / one child: unlink directly.
                        let (nd, par, lock) = (*node, *parent, *locked);
                        let child = if n.left != NIL { n.left } else { n.right };
                        let sprig = self.sprig_of(*digest);
                        self.replace_child(sprig, par, nd, child);
                        // Freed slots leave the DRAM accounting (the slot
                        // stays index-valid for in-flight lock-free scans).
                        self.nodes[nd as usize].in_dram = false;
                        self.free_nodes.push(nd);
                        self.dead_blocks += 1;
                        let d = *digest;
                        let commit = self.wal_append(WalKind::Delete, d, 0);
                        *op = TreeOp::Unlock { lock, commit };
                    }
                } else {
                    *parent = *node;
                    *node = if *digest < n.digest { n.left } else { n.right };
                }
                step
            }
            TreeOp::DeleteSucc {
                target,
                parent,
                cur,
                locked,
                compute_done,
            } => {
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let id = *cur;
                let n = self.nodes[id as usize];
                let step = self.entry_access(id);
                if n.left != NIL {
                    *parent = *cur;
                    *cur = n.left;
                } else {
                    // `cur` is the successor: splice it out, move its payload
                    // into the target slot (the target's old value block
                    // becomes garbage).
                    let (t, p, c, lock) = (*target, *parent, *cur, *locked);
                    let deleted = self.nodes[t as usize].digest;
                    let succ = self.nodes[c as usize];
                    if p == t {
                        self.nodes[t as usize].right = succ.right;
                    } else {
                        self.nodes[p as usize].left = succ.right;
                    }
                    let tn = &mut self.nodes[t as usize];
                    tn.digest = succ.digest;
                    tn.block = succ.block;
                    tn.vsize = succ.vsize;
                    self.nodes[c as usize].in_dram = false;
                    self.free_nodes.push(c);
                    self.dead_blocks += 1;
                    let commit = self.wal_append(WalKind::Delete, deleted, 0);
                    *op = TreeOp::Unlock { lock, commit };
                }
                step
            }
            TreeOp::Scan {
                walk,
                todo,
                min_next,
                compute_done,
            } => {
                if let Some(&id) = walk.last() {
                    // Replay the index traversal: one dependent access per
                    // visited node (paired with per-hop compute, like
                    // Descend).
                    if !*compute_done {
                        *compute_done = true;
                        return Step::Compute(self.cfg.t_node);
                    }
                    *compute_done = false;
                    walk.pop();
                    return self.entry_access(id);
                }
                if todo.is_empty() {
                    *op = TreeOp::Finished;
                    return Step::Compute(self.cfg.t_node);
                }
                // Batched value reads: up to SCAN_IO_BATCH records per IO.
                let mut bytes = 0u32;
                let mut fetched = 0usize;
                let mut shard = 0u64;
                while fetched < SCAN_IO_BATCH {
                    match todo.pop() {
                        Some(id) => {
                            let n = self.nodes[id as usize];
                            // Snapshot validation: a concurrent delete may
                            // have freed this slot and an upsert reused it
                            // for a different digest. Emit only entries that
                            // keep the result anchored and strictly
                            // increasing (ordered ⇒ duplicate-free); stale
                            // slots are dropped from the snapshot.
                            if n.digest < *min_next {
                                continue;
                            }
                            *min_next = n.digest.saturating_add(1);
                            if fetched == 0 {
                                // The batch IO lands on the device owning
                                // its first record's value-log block.
                                shard = n.block as u64;
                            }
                            bytes += n.vsize.max(64);
                            if self.disk[n.block as usize] == n.digest {
                                self.stats.verified += 1;
                            } else {
                                self.stats.corruptions += 1;
                            }
                            self.stats.scanned += 1;
                            fetched += 1;
                        }
                        None => break,
                    }
                }
                if fetched == 0 {
                    // Every snapshot entry went stale under churn: nothing
                    // to read.
                    *op = TreeOp::Finished;
                    return Step::Compute(self.cfg.t_node);
                }
                Step::Io {
                    kind: IoKind::Read,
                    bytes,
                    extra_pre: Dur::us(SCAN_EXTRA_PRE_US),
                    extra_post: Dur::us(SCAN_EXTRA_POST_US),
                    shard,
                    class: TrafficClass::Foreground,
                }
            }
            TreeOp::Unlock { lock, commit } => {
                let l = *lock;
                *op = match *commit {
                    Some(lsn) => TreeOp::WalCommit { lsn },
                    None => TreeOp::Finished,
                };
                Step::Unlock(l)
            }
            TreeOp::WalCommit { lsn } => {
                let lsn = *lsn;
                if self.wal.is_durable(lsn) {
                    self.wal.mark_acked(lsn);
                    *op = TreeOp::Finished;
                    return Step::Compute(self.cfg.t_node);
                }
                if let Some((upto, bytes)) = self.wal.try_lead(lsn) {
                    *op = TreeOp::WalFlush { upto, lsn };
                    return Step::Io {
                        kind: IoKind::Write,
                        bytes,
                        extra_pre: Dur::ZERO,
                        extra_post: Dur::ZERO,
                        shard: self.wal.cfg.log_shard,
                        class: TrafficClass::Background(BgKind::WalFlush),
                    };
                }
                self.wal.note_poll();
                Step::Yield
            }
            TreeOp::WalFlush { upto, lsn } => {
                self.wal.flush_done(*upto);
                self.wal.mark_acked(*lsn);
                *op = TreeOp::Finished;
                Step::Compute(self.cfg.t_node)
            }
            TreeOp::DefragRead => {
                // Read a random old block; the dead-block cursor stands in
                // for the wipe position (deterministic: no extra RNG draw,
                // which would shift every downstream random number).
                let shard = self.dead_blocks;
                *op = TreeOp::DefragWrite;
                Step::Io {
                    kind: IoKind::Read,
                    bytes: 4096,
                    extra_pre: Dur::ns(300.0),
                    extra_post: Dur::us(1.0), // sift live entries
                    shard,
                    class: TrafficClass::Background(BgKind::Defrag),
                }
            }
            TreeOp::DefragWrite => {
                // ...and rewrite its live data at the head.
                self.dead_blocks = self.dead_blocks.saturating_sub(2);
                self.stats.bg_ops += 1;
                let digest = fnv1a(rng.next_u64());
                let b = self.append_to_log(digest);
                *op = TreeOp::Finished;
                Step::Io {
                    kind: IoKind::Write,
                    bytes: 4096,
                    extra_pre: Dur::ns(300.0),
                    extra_post: Dur::ns(200.0),
                    shard: b as u64,
                    class: TrafficClass::Background(BgKind::Defrag),
                }
            }
            TreeOp::DefragPause => {
                // Nothing to do: pace, then cooperatively yield so a quiet
                // defragger cannot monopolize its core's slice.
                *op = TreeOp::DefragYield;
                Step::Compute(Dur::us(5.0))
            }
            TreeOp::DefragYield => {
                *op = TreeOp::Finished;
                Step::Yield
            }
            TreeOp::Finished => Step::Done,
        }
    }

    fn io_failed(&mut self, _tid: usize, op: &mut TreeOp) {
        // Graceful degradation: surface the error per-op and terminate
        // without acking. Every IO here is issued lock-free — the value
        // read/write fires before the sprig lock is taken (`UpdateIndex`
        // locks on its first visit *after* the IO), the log flush after the
        // unlock — so terminating mid-op leaks nothing. A failed log flush
        // releases WAL leadership for re-election; a failed value write
        // leaves only an unreferenced log block (append-only garbage), so
        // unacked writes stay atomic.
        self.stats.io_errors += 1;
        if let TreeOp::WalFlush { upto, .. } = *op {
            self.wal.flush_aborted(upto);
        }
        self.stats.failed_ops += 1;
        *op = TreeOp::Finished;
    }
}

impl Durable for TreeKv {
    fn wal(&self) -> &Wal {
        &self.wal
    }

    fn wal_mut(&mut self) -> &mut Wal {
        &mut self.wal
    }

    /// Presence at the WAL's key encoding: records store digests.
    fn wal_present(&self, key: u64) -> bool {
        let digest = key;
        let mut cur = self.roots[self.sprig_of(digest)];
        while cur != NIL {
            let n = self.nodes[cur as usize];
            if digest == n.digest {
                return true;
            }
            cur = if digest < n.digest { n.left } else { n.right };
        }
        false
    }

    fn replay_record(&mut self, rec: &WalRecord, rng: &mut Rng) {
        match rec.kind {
            WalKind::Put => self.upsert_unsimulated(rec.key, rec.vsize, rng),
            WalKind::Delete => self.delete_unsimulated(rec.key),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, MachineConfig, MemConfig};
    use crate::workload::KeyDist;

    fn small_cfg() -> TreeKvConfig {
        TreeKvConfig {
            n_items: 20_000,
            sprigs: 16,
            ..Default::default()
        }
    }

    use super::super::common::drive_op;

    fn drive(kv: &mut TreeKv, op: TreeOp, rng: &mut Rng) {
        let _ = drive_op(kv, op, rng);
    }

    #[test]
    fn population_is_complete_and_searchable() {
        let mut rng = Rng::new(1);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        assert_eq!(kv.nodes.len(), 20_000);
        for key in (0..20_000u64).step_by(97) {
            assert!(kv.contains_key(key), "key {key} missing");
        }
    }

    #[test]
    fn mean_depth_tracks_log() {
        let mut rng = Rng::new(2);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        let d = kv.mean_depth(2000, &mut rng);
        // 20k items / 16 sprigs = 1250/sprig: expected ~1.39*log2(1250) ≈ 14
        // (average node depth is ~2 below that; accept a window).
        assert!((9.0..16.0).contains(&d), "mean depth {d}");
    }

    #[test]
    fn read_ops_verify_against_disk() {
        let mut rng = Rng::new(3);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(2.0), Dur::ms(10.0));
        assert!(st.ops > 1000, "ops={}", st.ops);
        assert!(m.service.stats.verified > 1000);
        assert_eq!(m.service.stats.corruptions, 0);
        // Measured M should be the tree depth (≈ 9-16).
        assert!((9.0..17.0).contains(&st.mean_m), "mean M = {}", st.mean_m);
        assert!((st.mean_s - 1.0).abs() < 0.01);
    }

    #[test]
    fn write_mix_updates_index_and_defrags() {
        let mut rng = Rng::new(4);
        let cfg = TreeKvConfig {
            mix: OpMix::ratio(1, 1),
            ..small_cfg()
        };
        let kv = TreeKv::new(cfg, &mut rng).with_background(1, 32);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(2.0), Dur::ms(20.0));
        assert!(m.service.stats.sets > 500);
        assert!(st.io_writes > 500, "writes={}", st.io_writes);
        assert!(m.service.stats.bg_ops > 0, "defrag never ran");
        assert_eq!(m.service.stats.corruptions, 0);
    }

    #[test]
    fn delete_then_get_is_absent_and_write_reinserts() {
        let mut rng = Rng::new(8);
        let mut kv = TreeKv::new(small_cfg(), &mut rng);
        let key = 1234u64;
        assert!(kv.contains_key(key));

        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert!(!kv.contains_key(key), "delete must remove the index entry");

        let misses_before = kv.stats.misses;
        let op = kv.op_get(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.misses, misses_before + 1, "get after delete");

        // Upsert brings it back, fully readable.
        let op = kv.op_write(key, 500);
        drive(&mut kv, op, &mut rng);
        assert!(kv.contains_key(key), "write after delete must reinsert");
        let verified_before = kv.stats.verified;
        let op = kv.op_get(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.verified, verified_before + 1);
        assert_eq!(kv.stats.corruptions, 0);
    }

    #[test]
    fn delete_two_child_nodes_keeps_tree_searchable() {
        let mut rng = Rng::new(9);
        let mut kv = TreeKv::new(small_cfg(), &mut rng);
        // Delete a swath of keys (some will be two-child interior nodes),
        // then verify every remaining key is still findable.
        for key in (0..2000u64).step_by(3) {
            let op = kv.op_delete(key);
            drive(&mut kv, op, &mut rng);
            assert!(!kv.contains_key(key));
        }
        for key in 0..2000u64 {
            let expect = key % 3 != 0;
            assert_eq!(kv.contains_key(key), expect, "key {key}");
        }
        // Deleted slots are recycled by upserts.
        assert!(!kv.free_nodes.is_empty());
        let free_before = kv.free_nodes.len();
        let op = kv.op_write(0, 100);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.free_nodes.len(), free_before - 1);
    }

    #[test]
    fn scan_returns_ordered_unique_digests() {
        let mut rng = Rng::new(10);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        for key in [0u64, 17, 4242, 19_999] {
            let ds = kv.scan_digests(key, 50);
            assert!(!ds.is_empty(), "scan from {key} found nothing");
            let anchor = fnv1a(key);
            for w in ds.windows(2) {
                assert!(w[0] < w[1], "scan out of order: {} >= {}", w[0], w[1]);
            }
            assert!(ds[0] >= anchor, "scan started before the anchor");
        }
    }

    #[test]
    fn scan_op_issues_accesses_and_batched_ios() {
        let mut rng = Rng::new(11);
        let mut kv = TreeKv::new(small_cfg(), &mut rng);
        let op = kv.op_scan(77, 20);
        let (mems, ios, _) = drive_op(&mut kv, op, &mut rng);
        let scanned = kv.stats.scanned;
        assert!(scanned > 0, "scan returned nothing");
        assert!(
            mems as u64 >= scanned,
            "every scanned entry is at least one access: {mems} < {scanned}"
        );
        // Batched: ceil(scanned / SCAN_IO_BATCH) IOs.
        let b = SCAN_IO_BATCH as u64;
        assert!(ios >= 1, "no value IOs");
        assert!(
            (ios as u64 - 1) * b < scanned && scanned <= ios as u64 * b,
            "ios={ios} scanned={scanned}"
        );
    }

    #[test]
    fn rmw_reads_then_writes_same_key() {
        let mut rng = Rng::new(12);
        let mut kv = TreeKv::new(small_cfg(), &mut rng);
        let key = 555u64;
        let verified_before = kv.stats.verified;
        let sets_dead_before = kv.dead_blocks;
        let op = kv.op_rmw(key, 800);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.verified, verified_before + 1, "read half verified");
        assert_eq!(kv.dead_blocks, sets_dead_before + 1, "write half landed");
        // Read-your-write: the value block now holds the new digest mapping.
        let verified2 = kv.stats.verified;
        let op = kv.op_get(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.verified, verified2 + 1);
        assert_eq!(kv.stats.corruptions, 0);
    }

    #[test]
    fn top_levels_tiering_absorbs_disproportionate_accesses() {
        // §5.2.3 extension: pinning the top 4 levels of every sprig to DRAM
        // uses a small capacity share but absorbs a much larger access
        // share, and the measured per-op secondary-access count M drops
        // accordingly.
        let mut rng = Rng::new(6);
        let full = TreeKv::new(small_cfg(), &mut rng);
        let tiered = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::TopLevels { k: 4 },
                ..small_cfg()
            },
            &mut rng,
        );
        let cap_frac = tiered.dram_entry_fraction();
        assert!(cap_frac < 0.03, "top-4 levels should be tiny: {cap_frac}");
        let run_m = |kv: TreeKv| {
            let mut m = Machine::new(
                MachineConfig {
                    threads_per_core: 32,
                    n_locks: 64,
                    mem: MemConfig::fpga(Dur::us(5.0)),
                    ..Default::default()
                },
                kv,
            );
            m.run(Dur::ms(2.0), Dur::ms(8.0)).mean_m
        };
        let m_full = run_m(full);
        let m_tiered = run_m(tiered);
        // 4 of ~13 descent levels move to DRAM: M drops by ~25-35%.
        assert!(
            m_tiered < m_full - 2.5,
            "tiering should cut secondary accesses: {m_full} -> {m_tiered}"
        );
    }

    #[test]
    fn random_tiering_matches_requested_fraction() {
        let mut rng = Rng::new(7);
        let kv = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Random { dram_frac: 0.3 },
                ..small_cfg()
            },
            &mut rng,
        );
        let f = kv.dram_entry_fraction();
        assert!((f - 0.3).abs() < 0.02, "dram fraction {f}");
    }

    #[test]
    fn budget_placement_pins_top_levels_and_accounts_bytes() {
        let mut rng = Rng::new(13);
        // 20k items / 16 sprigs; level d holds 16·2^d entries of 64 B.
        // A 16-entry budget fits exactly level 0.
        let kv = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: 16 * 64 },
                ..small_cfg()
            },
            &mut rng,
        );
        assert_eq!(kv.plan().dram_classes(), 1);
        assert_eq!(kv.dram_bytes(), 16 * 64);
        // DRAM bytes are monotone in the budget knob and never overshoot.
        let mut prev = 0u64;
        for budget in [0u64, 100, 16 * 64, 5_000, 100_000, 2_000_000] {
            let kv = TreeKv::new(
                TreeKvConfig {
                    placement: PlacementPolicy::Budget { dram_bytes: budget },
                    ..small_cfg()
                },
                &mut rng,
            );
            let b = kv.dram_bytes();
            assert!(b <= budget, "budget {budget}: used {b}");
            assert!(b >= prev, "budget {budget}: dram bytes fell {prev} -> {b}");
            prev = b;
        }
        // The endpoints.
        let none = TreeKv::new(small_cfg(), &mut rng);
        assert_eq!(none.dram_bytes(), 0);
        let all = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::AllDram,
                ..small_cfg()
            },
            &mut rng,
        );
        assert_eq!(all.dram_bytes(), all.offload_bytes_total());
        assert_eq!(all.dram_entry_fraction(), 1.0);
    }

    #[test]
    fn compressed_budget_packs_more_levels_and_stays_correct() {
        use super::super::placement::{CompressMode, Compression};
        let spec = Compression::new(0.5, 0.12);
        // 20k items / 16 sprigs: level 0 is 16 entries (1024 B), level 1 is
        // 32 (2048 B). A 1536 B budget fits only level 0 plain, but both
        // top levels compressed at q = 0.5 (512 + 1024 B).
        let budget = 1536u64;
        let mut rng = Rng::new(70);
        let plain = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Budget {
                    dram_bytes: budget,
                },
                ..small_cfg()
            },
            &mut rng,
        );
        let mut rng = Rng::new(70);
        let mut joint = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Budget {
                    dram_bytes: budget,
                },
                compression: CompressMode::Joint(spec),
                ..small_cfg()
            },
            &mut rng,
        );
        assert_eq!(plain.plan().dram_classes(), 1);
        assert_eq!(plain.dram_bytes(), 1024);
        assert_eq!(joint.plan().dram_classes(), 2);
        assert_eq!(joint.plan().compressed_classes(), 2);
        assert_eq!(joint.dram_bytes(), 1536);
        assert!(joint.dram_entry_fraction() > plain.dram_entry_fraction());
        // The compressed store still reads correctly — the decompress is
        // pure added Compute, invisible to drive_op's result accounting.
        let mut rng = Rng::new(71);
        for key in [1u64, 999, 7_777] {
            let before = joint.stats.verified;
            let op = joint.op_get(key);
            drive(&mut joint, op, &mut rng);
            assert_eq!(joint.stats.verified, before + 1);
        }
        assert_eq!(joint.stats.corruptions, 0);
        // The model snapshot sees the compressed hops inline.
        use super::super::ModelCosts;
        let read = joint.model_params(OpKind::Read);
        assert!(read.m_cpr > 0.5, "m_cpr = {}", read.m_cpr);
        assert!((read.t_cpu - 0.12).abs() < 1e-12);
        // Degenerate ratio 1.0 normalizes away: identical accounting to
        // compression off.
        let mut rng = Rng::new(70);
        let noop = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Budget {
                    dram_bytes: budget,
                },
                compression: CompressMode::Joint(Compression::new(1.0, 0.5)),
                ..small_cfg()
            },
            &mut rng,
        );
        assert_eq!(noop.plan().compressed_classes(), 0);
        assert_eq!(noop.dram_bytes(), plain.dram_bytes());
    }

    #[test]
    fn all_dram_placement_has_no_secondary_hops() {
        use super::super::common::drive_op_tiers;
        let mut rng = Rng::new(14);
        let mut kv = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::AllDram,
                ..small_cfg()
            },
            &mut rng,
        );
        let op = kv.op_get(123);
        let c = drive_op_tiers(&mut kv, op, &mut rng);
        assert_eq!(c.secondary, 0, "AllDram read must not touch secondary");
        assert!(c.dram > 0, "the descent still happens");
        // The model snapshot agrees: every hop on the DRAM side.
        use super::super::ModelCosts;
        let read = kv.model_params(OpKind::Read);
        assert_eq!(read.m, 0.0);
        assert!(read.m_dram > 5.0, "m_dram = {}", read.m_dram);
    }

    #[test]
    fn model_params_track_geometry() {
        use super::super::ModelCosts;
        let mut rng = Rng::new(20);
        let kv = TreeKv::new(small_cfg(), &mut rng);
        // Probed descent depth agrees with the sampled oracle.
        let read = kv.model_params(OpKind::Read);
        let d = kv.mean_depth(2000, &mut rng);
        assert!(
            (read.m - d).abs() < 2.0,
            "probed depth {} vs sampled {d}",
            read.m
        );
        assert_eq!(read.s, 1.0, "one value IO per read");
        assert!((read.t_mem - kv.cfg.t_node.as_us()).abs() < 1e-12);
        // Scan: batched IO count and hop growth.
        let scan = kv.scan_model_params(20.0);
        assert_eq!(scan.s, 3.0, "ceil(20/8) batch IOs");
        assert!(scan.m > read.m + 15.0, "scan hops grow with len");
        let zero = kv.scan_model_params(0.0);
        assert_eq!(zero.s, 0.0, "len=0 scan issues no IO");
        assert!(zero.a_io == 0.0 && zero.m > 0.0);
        // Delete never touches the SSD synchronously; RMW doubles it.
        assert_eq!(kv.model_params(OpKind::Delete).s, 0.0);
        assert_eq!(kv.model_params(OpKind::Rmw).s, 2.0);
        // Tiering shrinks the secondary hop count — and the placed hops
        // reappear on the DRAM side of the split (total is conserved).
        let tiered = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::TopLevels { k: 4 },
                ..small_cfg()
            },
            &mut rng,
        );
        let tread = tiered.model_params(OpKind::Read);
        assert!(
            tread.m < read.m - 2.0,
            "top-level tiering must cut secondary hops: {} vs {}",
            tread.m,
            read.m
        );
        assert!(
            (tread.m + tread.m_dram - read.m - read.m_dram).abs() < 0.5,
            "hops must move tiers, not vanish: {}+{} vs {}+{}",
            tread.m,
            tread.m_dram,
            read.m,
            read.m_dram
        );
    }

    #[test]
    fn random_snapshot_splits_leaf_by_entry_fraction() {
        // Satellite bugfix: under per-entry `Random` placement the
        // write/delete snapshots pinned the leaf attach/unlink access to
        // the secondary side whenever any descent hop was secondary; it
        // must split by the entry-granular DRAM fraction instead.
        use super::super::ModelCosts;
        for frac in [0.3, 0.7] {
            let mut rng = Rng::new(40);
            let kv = TreeKv::new(
                TreeKvConfig {
                    placement: PlacementPolicy::Random { dram_frac: frac },
                    ..small_cfg()
                },
                &mut rng,
            );
            let f = kv.dram_entry_fraction();
            let r = kv.model_params(OpKind::Read);
            let w = kv.model_params(OpKind::Write);
            // The write's extra (leaf) access beyond the read's descent:
            // secondary with probability 1 - f (was always 1.0).
            let leaf_sec = w.m - r.m;
            let leaf_dram = w.m_dram - r.m_dram;
            assert!(
                (leaf_sec - (1.0 - f)).abs() < 0.02,
                "frac {frac}: leaf_sec {leaf_sec} vs {}",
                1.0 - f
            );
            assert!((leaf_dram - f).abs() < 0.02, "frac {frac}: {leaf_dram}");
            // The hop moved tiers, it did not vanish.
            assert!(((w.m + w.m_dram) - (r.m + r.m_dram) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn replan_keeps_the_hot_level_prefix_static() {
        // Tree levels are the canonical case where the static prior is
        // right where it matters: every descent passes the top levels, so
        // the measured accesses-per-byte ranking keeps the *full* levels
        // in depth order and a small budget places the same top prefix.
        // (Only the last, partially-filled level may legitimately move —
        // it is a small class still sitting on most descent paths, so its
        // density can exceed its full predecessor's.)
        let mut rng = Rng::new(41);
        let mut kv = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: 16 * 64 },
                ..small_cfg()
            },
            &mut rng,
        );
        let bytes0 = kv.dram_bytes();
        for key in 0..500u64 {
            let op = kv.op_get(key);
            drive(&mut kv, op, &mut rng);
        }
        let profile = kv.profile.clone();
        assert!(!profile.is_empty(), "reads must have populated the profile");
        kv.replan(&profile);
        // The hottest classes stay the top levels in depth order (full
        // levels have strictly decreasing accesses-per-byte: reach
        // decreases while bytes double).
        assert_eq!(
            &kv.plan().ranking()[..4],
            &[0, 1, 2, 3],
            "the hot prefix must stay in static depth order: {:?}",
            kv.plan().ranking()
        );
        assert_eq!(
            kv.dram_bytes(),
            bytes0,
            "the small budget places the same top level after replanning"
        );
        // Deterministic: replaying the same profile reproduces the plan.
        let rank0 = kv.plan().ranking().to_vec();
        kv.replan(&profile);
        assert_eq!(kv.plan().ranking(), rank0.as_slice());
    }

    #[test]
    fn replan_migrate_charges_exactly_the_flipped_entries() {
        // Budget of 2048 B: statically the 16-entry level 0 fits (1024 B)
        // and level 1 (2048 B) does not. A synthetic profile making level 1
        // the densest class flips the plan — 16 entries leave DRAM, 32
        // enter — and the migration bill is exactly those 48 line copies,
        // one touch on each tier per line, no value IO. Replaying the same
        // profile is free.
        let mut rng = Rng::new(43);
        let mut kv = TreeKv::new(
            TreeKvConfig {
                placement: PlacementPolicy::Budget {
                    dram_bytes: 32 * 64,
                },
                ..small_cfg()
            },
            &mut rng,
        );
        let mut profile = AccessProfile::new(4);
        for _ in 0..10_000 {
            profile.tick(1);
        }
        profile.tick(0);
        let mig = kv.replan_migrate(&profile);
        assert_eq!((mig.dram, mig.secondary), (48, 48), "{mig:?}");
        assert_eq!((mig.reads, mig.writes), (0, 0), "index moves carry no IO");
        assert_eq!(kv.plan().ranking()[0], 1, "level 1 must out-rank level 0");
        let again = kv.replan_migrate(&profile);
        assert_eq!(again, DriveCounts::default(), "same plan, no migration");
        // Policies that never re-tier entries migrate nothing.
        let mut rng = Rng::new(44);
        let mut all_sec = TreeKv::new(small_cfg(), &mut rng);
        assert_eq!(all_sec.replan_migrate(&profile), DriveCounts::default());
    }

    #[test]
    fn set_workload_swaps_mix_and_keys_without_rng_draws() {
        let mut rng = Rng::new(45);
        let mut kv = TreeKv::new(small_cfg(), &mut rng);
        let mark = rng.below(u64::MAX);
        let mut rng2 = Rng::new(45);
        let mut kv2 = TreeKv::new(small_cfg(), &mut rng2);
        kv2.set_workload(
            Some(OpWeights::new(0.0, 0.05, 0.0, 0.95, 0.0)),
            KeyDist::Zipf {
                s: 1.0,
                scrambled: true,
            },
        );
        assert_eq!(
            rng2.below(u64::MAX),
            mark,
            "set_workload must not consume randomness"
        );
        assert!(kv2.cfg.ops.is_some());
        // The swapped keygen actually drives sampling (guarded θ = 1 pole).
        let key = kv2.keygen.sample(&mut rng2);
        let op = kv2.op_scan(key, 4);
        drive(&mut kv2, op, &mut rng2);
        assert!(kv2.stats.scans > 0);
        let _ = kv.op_get(1);
    }

    #[test]
    fn zipf_reads_still_verify() {
        let mut rng = Rng::new(5);
        let cfg = TreeKvConfig {
            key_dist: KeyDist::Zipf {
                s: 1.1,
                scrambled: true,
            },
            ..small_cfg()
        };
        let kv = TreeKv::new(cfg, &mut rng);
        let mut m = Machine::new(MachineConfig::default(), kv);
        let _ = m.run(Dur::ms(1.0), Dur::ms(5.0));
        assert_eq!(m.service.stats.corruptions, 0);
        assert!(m.service.stats.verified > 100);
    }

    #[test]
    fn wal_logs_mutations_by_digest_and_acks_after_flush() {
        use super::super::wal::WalKind;
        let mut rng = Rng::new(60);
        let mut kv = TreeKv::new(
            TreeKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        let key = 123u64;
        let op = kv.op_write(key, 512);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 1);
        assert!(kv.wal.is_durable(0));
        assert_eq!(kv.wal.records()[0].key, fnv1a(key), "digest encoding");
        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 2);
        assert_eq!(kv.wal.records()[1].kind, WalKind::Delete);
        assert!(kv.wal.acked_all_durable());
        // An absent delete mutates nothing and logs nothing.
        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 2);
        // Reads never log.
        let op = kv.op_get(1);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 2);
    }

    #[test]
    fn wal_replay_restores_durable_index_state() {
        let mut rng = Rng::new(61);
        let kv = TreeKv::new(
            TreeKvConfig {
                ops: Some(OpWeights::new(0.3, 0.4, 0.3, 0.0, 0.0)),
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 16,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let _ = m.run(Dur::ms(1.0), Dur::ms(8.0));
        let old = m.service;
        assert!(old.wal.stats.appends > 20);
        assert!(old.wal.acked_all_durable());

        // Crash; recover a fresh store from the durable WAL prefix.
        let mut rng2 = Rng::new(61);
        let mut kv2 = TreeKv::new(
            TreeKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng2,
        );
        let applied = kv2.wal_replay(&old.wal, &mut rng2);
        assert_eq!(applied, old.wal.durable_lsn());
        for (digest, kind) in old.wal.durable_last_kind() {
            use super::super::wal::WalKind;
            match kind {
                WalKind::Put => assert!(kv2.wal_present(digest), "lost put {digest:#x}"),
                WalKind::Delete => {
                    assert!(!kv2.wal_present(digest), "resurrected delete {digest:#x}")
                }
            }
        }
        // Idempotent: re-replay applies zero records.
        assert_eq!(kv2.wal_replay(&old.wal, &mut rng2), 0);
    }
}
