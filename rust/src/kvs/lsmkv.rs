//! RocksDB-like SSD-based KV store (paper §4.2, Fig 13 middle).
//!
//! An LSM-tree's data blocks live on SSD; an in-memory **block cache**
//! (sharded hash + LRU, RocksDB's `LRUCache`) lives on secondary memory and
//! is the store's dominant DRAM consumer that the paper offloads. A get
//! first probes the memtable (host DRAM), then the block cache: the shard's
//! hash-bucket chain walk and the LRU list manipulation are dependent
//! secondary-memory accesses; the in-block sorted-key traversal (restart
//! array binary search) also runs over cached block bytes on secondary
//! memory. A cache miss fetches the block from SSD (one IO) and inserts it,
//! evicting the shard's LRU tail. Writes go to the memtable; a background
//! thread flushes and compacts (bulk IO).
//!
//! The full operation surface (beyond the paper's GET/PUT reproduction):
//!
//! - **Delete** writes a tombstone into the memtable (DRAM accesses + WAL
//!   append, like a write). While the tombstone is memtable-resident a read
//!   of the key short-circuits at the memtable; once the background thread
//!   flushes it, reads take the full block-cache path and discover absence
//!   in the data block (compaction purges the tombstone record itself —
//!   the key stays absent, modeled by the logical `deleted` set).
//! - **Scan** is a merged memtable+sstable iterator: one memtable seek
//!   (DRAM), then sequential blocks through the block cache — chain walk
//!   per block, an in-block access per restart interval, an SSD fetch per
//!   cache-missing block. Tombstoned keys are skipped (merge cost only).
//! - **ReadModifyWrite** chains the full read path into a memtable write
//!   of the same key.
//!
//! With Zipf-skewed keys the cache hit ratio lands near the paper's 67%, so
//! the average IOs per operation S ≈ 0.33 and the extended model's per-IO
//! split (§3.2.3) applies.

use std::collections::HashSet;

use super::common::{fnv1a, DriveCounts, KvStats, NIL};
use super::placement::{AccessProfile, CompressMode, HopSplit, Plan, PlacementPolicy, StructClass};
use super::wal::{Durable, Wal, WalConfig, WalKind, WalRecord};
use crate::model::KindCost;
use crate::sim::{BgKind, Dur, IoKind, Rng, Service, Step, TrafficClass};
use crate::workload::{
    KeyDist, KeyGen, OpKind, OpMix, OpWeights, ScanLen, TenantRouter, TenantSet, TenantTracker,
    ValueSize,
};

/// Placement structure classes (`kvs::placement`), hottest-first: the
/// sharded hash + LRU cache handles are touched several times per lookup
/// per ~64 B each, the per-block restart arrays once per in-block search,
/// and the cached data-block bytes once or twice per op over the largest
/// footprint. The memtable is host-DRAM by design — a **pinned** class:
/// outside the policy's placement decision, but inside the DRAM-byte
/// accounting (the paper's residual footprint) and tagged in the
/// [`AccessProfile`] like every other access site.
const PC_HANDLES: usize = 0;
const PC_RESTARTS: usize = 1;
const PC_DATA: usize = 2;
const PC_MEMTABLE: usize = 3;

/// Store-extra CPU attributed to each block fetch's pre/post suboperations
/// (µs). **Single source** for both the `Step::Io` sites below (point-read
/// `Fetch` and the scan iterator's block fetch) and the model snapshots:
/// block-handle resolution + file offset (pre), CRC32 of the block,
/// decompression stub, and block-object construction (post) — calibrated
/// to RocksDB's measured per-read CPU cost.
const BLOCK_EXTRA_PRE_US: f64 = 1.5;
const BLOCK_EXTRA_POST_US: f64 = 3.0;

#[derive(Debug, Clone)]
pub struct LsmKvConfig {
    pub n_items: u64,
    /// Entries per data block (RocksDB 4 kB blocks / (key+value) bytes).
    pub keys_per_block: u32,
    /// Block cache capacity in blocks.
    pub cache_blocks: u32,
    /// Cache shards (RocksDB default 2^6).
    pub shards: u32,
    /// Hash buckets per shard.
    pub buckets_per_shard: u32,
    pub key_dist: KeyDist,
    /// Read:write mix (paper figures). Ignored when `ops` is set.
    pub mix: OpMix,
    /// Full-surface operation weights (YCSB presets); `None` follows `mix`.
    pub ops: Option<OpWeights>,
    /// Scan length distribution for `OpKind::Scan`.
    pub scan_len: ScanLen,
    pub value_size: ValueSize,
    /// CPU cost per pointer hop / key comparison.
    pub t_node: Dur,
    /// Memtable capacity (writes before a flush cycle is signalled).
    pub memtable_cap: u32,
    /// Run the background flush/compaction thread.
    pub compaction: bool,
    /// Tier placement of the block cache's structures (`kvs::placement`):
    /// handles (chains+LRU) ≻ restart arrays ≻ data-block bytes.
    pub placement: PlacementPolicy,
    /// Write-ahead log (`kvs::wal`; disabled by default — mutations then
    /// ack straight from the memtable, the historical behavior).
    pub wal: WalConfig,
    /// Multi-tenant workload multiplexing (`workload::tenants`); `None`
    /// (the default) is the legacy single-tenant path, bit-identical to
    /// pre-tenant behaviour.
    pub tenants: Option<TenantSet>,
    /// Joint placement×compression (`kvs::placement` module docs): when not
    /// `Off`, the offloadable cache classes carry the given
    /// [`super::placement::Compression`] spec and the `Budget` knapsack may
    /// place them compressed-in-DRAM — fewer resident bytes, an inline
    /// decompress `Compute` on every access. The pinned memtable never
    /// compresses. `Off` (the default) is bit-identical to pre-compression
    /// behaviour.
    pub compression: CompressMode,
}

impl Default for LsmKvConfig {
    fn default() -> Self {
        LsmKvConfig {
            // Paper: 1B items, 32 GB cache, Zipf 0.99, hit ratio 67%. Scaled:
            // cache_blocks / n_blocks tuned to land at the same hit ratio.
            n_items: 1_000_000,
            keys_per_block: 8,
            cache_blocks: 6_000,
            shards: 64,
            buckets_per_shard: 128,
            // Scrambled: hot ranks are hashed across the keyspace (YCSB /
            // db_bench behaviour), so hot keys land in *different* blocks
            // and cache shards rather than piling onto one shard lock.
            key_dist: KeyDist::Zipf {
                s: 0.99,
                scrambled: true,
            },
            mix: OpMix::READ_ONLY,
            ops: None,
            scan_len: ScanLen::default(),
            value_size: ValueSize::Fixed(400),
            t_node: Dur::ns(100.0),
            memtable_cap: 4096,
            compaction: true,
            placement: PlacementPolicy::AllSecondary,
            wal: WalConfig::default(),
            tenants: None,
            compression: CompressMode::Off,
        }
    }
}

/// One block-cache entry: intrusive hash chain + LRU links (secondary mem).
#[derive(Debug, Clone, Copy)]
struct CacheEntry {
    block: u32,
    hash_next: u32,
    lru_prev: u32,
    lru_next: u32,
    /// Entry currently valid (false = free slot awaiting reuse).
    live: bool,
}

/// One cache shard: bucket heads + LRU list head/tail.
#[derive(Debug, Clone)]
struct Shard {
    buckets: Vec<u32>,
    lru_head: u32, // most recent
    lru_tail: u32, // eviction candidate
    len: u32,
}

pub struct LsmKv {
    pub cfg: LsmKvConfig,
    keygen: KeyGen,
    shards: Vec<Shard>,
    entries: Vec<CacheEntry>,
    free: Vec<u32>,
    cap_per_shard: u32,
    /// Total number of data blocks in the (simulated) LSM keyspace.
    pub n_blocks: u32,
    /// Pending writes in the memtable.
    memtable_fill: u32,
    /// Flush backlog (memtable generations awaiting the background thread).
    flush_backlog: u32,
    /// Logical deleted-key set (the store's truth about tombstoned keys).
    deleted: HashSet<u64>,
    /// Tombstones in the *active* memtable: reads short-circuit at the
    /// memtable. Moved to `sealed_tombstones` when the memtable rotates.
    fresh_tombstones: HashSet<u64>,
    /// Tombstones in rotated (immutable, not yet flushed) memtables: still
    /// DRAM-resident, so reads also short-circuit; cleared when the
    /// background thread flushes them into the SSTable levels.
    sealed_tombstones: HashSet<u64>,
    pub stats: KvStats,
    /// Pending inline decompress CPU from the last access to a
    /// compressed-in-DRAM class, charged as the next step's `Compute`
    /// (dependent work on the op's critical path — never prefetch-hidden).
    pending_cpu: Option<Dur>,
    /// The store's write-ahead log (`kvs::wal`; inert when disabled).
    pub wal: Wal,
    /// Resolved tier placement over the block-cache structure classes
    /// (re-resolved over measured access densities by [`LsmKv::replan`]).
    plan: Plan,
    /// Measured per-class access counts — every `MemAccess` site ticks its
    /// class, the memtable's pinned class included.
    pub profile: AccessProfile,
    bg_tid_floor: usize,
    bg_threads_per_core: usize,
    /// Tenant scheduler + per-tenant key generators (`cfg.tenants`).
    tenants: Option<TenantRouter>,
    /// Which tenant owns each thread's in-flight op (`Service::op_tenant`).
    tenant_tids: TenantTracker,
}

#[derive(Debug)]
pub enum LsmOp {
    /// Probe the memtable (DRAM accesses), then go to the cache. `kind` is
    /// `Read` or `Rmw`.
    Memtable { kind: OpKind, key: u64, probes: u8 },
    /// Walk the shard's hash chain looking for the block.
    ChainWalk {
        key: u64,
        entry: u32,
        first: bool,
        rmw: bool,
    },
    /// Found in cache: splice the entry to the LRU head (3 dependent
    /// accesses: prev, next, head), then search inside the block.
    LruPromote {
        key: u64,
        entry: u32,
        hops: u8,
        rmw: bool,
    },
    /// Cache miss: fetch the block from SSD.
    Fetch { key: u64, rmw: bool },
    /// Insert fetched block: evict tail if needed, link into bucket + LRU.
    Insert { key: u64, hops: u8, rmw: bool },
    /// Binary search over the block's restart array + final linear scan.
    InBlock {
        key: u64,
        lo: u32,
        hi: u32,
        compute_done: bool,
        rmw: bool,
    },
    /// Write path: memtable insert (DRAM) + occasional flush signal.
    WriteMem { key: u64, probes: u8 },
    /// Delete path: memtable tombstone insert (DRAM) + WAL append.
    DeleteMem { key: u64, probes: u8 },
    /// Merged memtable+sstable range iterator.
    Scan {
        /// Next key the iterator will produce.
        key: u64,
        /// Entries still to produce.
        left: u32,
        /// Initial memtable-seek probes (DRAM).
        probes: u8,
        /// Chain-walk accesses still to charge for the current block.
        chain_left: u8,
        /// Chain probe performed for the current block.
        chain_init: bool,
        /// Current block misses the cache (needs an SSD fetch).
        need_io: bool,
        /// Post-fetch cache insert progress: 0 = none, 1 = take the shard
        /// lock, 2 = locked mutation, 3 = release (mirrors the point-read
        /// `Insert` path's locked mutation).
        insert_step: u8,
        /// Current block is resident; consuming entries.
        in_block: bool,
        /// Entries consumed in the current restart interval.
        stride: u8,
    },
    /// Background flush/compaction bulk IO.
    BgFlush { ios_left: u8, write: bool },
    BgPause,
    BgYield,
    /// WAL commit wait: ack once the record at `lsn` is durable, leading a
    /// group flush if none is in flight (`kvs::wal` protocol).
    WalCommit { lsn: u64 },
    /// This op leads the flush of records `[.., upto)`; its own record is
    /// `lsn`. Reached after the log write completes (or fails).
    WalFlush { upto: u64, lsn: u64 },
    Finished,
}

impl LsmKv {
    /// The placement structure classes (see the `PC_*` consts): byte
    /// footprints from the configured cache geometry, access shares from
    /// the default chain/in-block costs (reporting only — resolution is
    /// rank-based).
    fn placement_classes(cfg: &LsmKvConfig) -> Vec<StructClass> {
        let blocks = cfg.cache_blocks as u64;
        let block_bytes = cfg.keys_per_block as u64 * (cfg.value_size.mean() as u64 + 20 + 8);
        let spec = cfg.compression.spec();
        vec![
            StructClass::new(
                "cache-handles(chains+lru)",
                blocks * 64 + cfg.shards as u64 * cfg.buckets_per_shard as u64 * 8,
                4.0,
            )
            .with_compression(spec),
            StructClass::new(
                "block-restarts",
                blocks * ((cfg.keys_per_block as u64 / 4).max(1) * 4 + 4),
                1.0,
            )
            .with_compression(spec),
            StructClass::new("block-data", blocks * block_bytes, 1.5).with_compression(spec),
            // The residual DRAM footprint: skiplist memtable entries (key +
            // value + tower links, ~60 B overhead each) for the active plus
            // one sealed (rotated, not yet flushed) generation. Pinned —
            // DRAM under every policy, reported by `dram_bytes()`, never
            // consuming the `Budget` knob.
            StructClass::pinned(
                "memtable(active+sealed)",
                2 * cfg.memtable_cap as u64 * (cfg.value_size.mean() as u64 + 60),
            ),
        ]
    }

    pub fn new(cfg: LsmKvConfig, rng: &mut Rng) -> LsmKv {
        let plan = Plan::resolve(cfg.placement, Self::placement_classes(&cfg));
        let profile = AccessProfile::new(plan.classes().len());
        let n_blocks = ((cfg.n_items + cfg.keys_per_block as u64 - 1)
            / cfg.keys_per_block as u64) as u32;
        let shards = (0..cfg.shards)
            .map(|_| Shard {
                buckets: vec![NIL; cfg.buckets_per_shard as usize],
                lru_head: NIL,
                lru_tail: NIL,
                len: 0,
            })
            .collect();
        let cap = cfg.cache_blocks / cfg.shards;
        let keygen = KeyGen::new(cfg.n_items, cfg.key_dist);
        let mut kv = LsmKv {
            shards,
            entries: Vec::with_capacity(cfg.cache_blocks as usize),
            free: Vec::new(),
            cap_per_shard: cap.max(2),
            n_blocks,
            memtable_fill: 0,
            flush_backlog: 0,
            deleted: HashSet::new(),
            fresh_tombstones: HashSet::new(),
            sealed_tombstones: HashSet::new(),
            stats: KvStats::default(),
            pending_cpu: None,
            wal: Wal::new(cfg.wal.clone()),
            plan,
            profile,
            bg_tid_floor: usize::MAX,
            bg_threads_per_core: 1,
            tenants: cfg.tenants.as_ref().map(|set| TenantRouter::new(set, cfg.n_items)),
            tenant_tids: TenantTracker::default(),
            keygen,
            cfg,
        };
        // Warm the cache with draws from the workload distribution so the
        // measured window starts near steady state (the paper warms up for
        // hours; we warm structurally and then still run a sim warmup).
        let mut wrng = rng.fork(0x15a);
        let draws = kv.cfg.cache_blocks as u64 * 4;
        for _ in 0..draws {
            let key = kv.keygen.sample(&mut wrng);
            let block = kv.block_of(key);
            if kv.cache_lookup(block).is_none() {
                kv.cache_insert(block);
            }
        }
        kv
    }

    /// Effective operation weights: explicit `ops` or the two-kind `mix`.
    fn weights(&self) -> OpWeights {
        match self.cfg.ops {
            Some(w) => w,
            None => OpWeights::from(self.cfg.mix),
        }
    }

    /// Whether the effective workload (tenant set when present, else the
    /// store's own mix) has mutating mass — drives background flushes.
    fn workload_has_writes(&self) -> bool {
        match &self.cfg.tenants {
            Some(set) => set.any_writes(),
            None => self.weights().has_writes(),
        }
    }

    pub fn with_background(mut self, threads_per_core: usize) -> LsmKv {
        if self.cfg.compaction && self.workload_has_writes() {
            self.bg_tid_floor = threads_per_core - 1;
            self.bg_threads_per_core = threads_per_core;
        }
        self
    }

    fn is_bg(&self, tid: usize) -> bool {
        self.bg_tid_floor != usize::MAX && tid % self.bg_threads_per_core == self.bg_tid_floor
    }

    #[inline]
    fn block_of(&self, key: u64) -> u32 {
        (key / self.cfg.keys_per_block as u64) as u32
    }

    #[inline]
    fn shard_of(&self, block: u32) -> usize {
        (fnv1a(block as u64) % self.cfg.shards as u64) as usize
    }

    #[inline]
    fn bucket_of(&self, block: u32) -> usize {
        ((fnv1a(block as u64) >> 8) % self.cfg.buckets_per_shard as u64) as usize
    }

    /// Bytes of one data block (also the per-read IO size a `PC_DATA`
    /// migration refill pays — see [`LsmKv::replan_migrate`]).
    #[inline]
    pub fn block_bytes(&self) -> u32 {
        self.cfg.keys_per_block * (self.cfg.value_size.mean() as u32 + 20 + 8)
    }

    /// Pure lookup (no timing): entry id if cached.
    fn cache_lookup(&self, block: u32) -> Option<u32> {
        let s = &self.shards[self.shard_of(block)];
        let mut cur = s.buckets[self.bucket_of(block)];
        while cur != NIL {
            let e = &self.entries[cur as usize];
            if e.live && e.block == block {
                return Some(cur);
            }
            cur = e.hash_next;
        }
        None
    }

    /// Structural chain probe: (accesses to reach the entry or chain end —
    /// bucket head included — , found?). Drives the scan's per-block cost.
    fn chain_probe(&self, block: u32) -> (u8, bool) {
        let s = &self.shards[self.shard_of(block)];
        let mut cur = s.buckets[self.bucket_of(block)];
        let mut hops = 1u32; // reading the bucket head
        while cur != NIL {
            let e = &self.entries[cur as usize];
            if e.live && e.block == block {
                return (hops.min(250) as u8, true);
            }
            hops += 1;
            cur = e.hash_next;
        }
        (hops.min(250) as u8, false)
    }

    /// Unlink from LRU list (structure mutation only).
    fn lru_unlink(&mut self, sid: usize, id: u32) {
        let e = self.entries[id as usize];
        if e.lru_prev != NIL {
            self.entries[e.lru_prev as usize].lru_next = e.lru_next;
        } else {
            self.shards[sid].lru_head = e.lru_next;
        }
        if e.lru_next != NIL {
            self.entries[e.lru_next as usize].lru_prev = e.lru_prev;
        } else {
            self.shards[sid].lru_tail = e.lru_prev;
        }
    }

    fn lru_push_front(&mut self, sid: usize, id: u32) {
        let head = self.shards[sid].lru_head;
        self.entries[id as usize].lru_prev = NIL;
        self.entries[id as usize].lru_next = head;
        if head != NIL {
            self.entries[head as usize].lru_prev = id;
        } else {
            self.shards[sid].lru_tail = id;
        }
        self.shards[sid].lru_head = id;
    }

    fn bucket_remove(&mut self, sid: usize, id: u32) {
        let block = self.entries[id as usize].block;
        let b = self.bucket_of(block);
        let mut cur = self.shards[sid].buckets[b];
        if cur == id {
            self.shards[sid].buckets[b] = self.entries[id as usize].hash_next;
            return;
        }
        while cur != NIL {
            let next = self.entries[cur as usize].hash_next;
            if next == id {
                self.entries[cur as usize].hash_next = self.entries[id as usize].hash_next;
                return;
            }
            cur = next;
        }
        debug_assert!(false, "entry not in its bucket");
    }

    /// Insert a block (evicting if full); returns (entry, evicted?).
    fn cache_insert(&mut self, block: u32) -> (u32, bool) {
        let sid = self.shard_of(block);
        let mut evicted = false;
        if self.shards[sid].len >= self.cap_per_shard {
            let tail = self.shards[sid].lru_tail;
            debug_assert_ne!(tail, NIL);
            self.lru_unlink(sid, tail);
            self.bucket_remove(sid, tail);
            self.entries[tail as usize].live = false;
            self.free.push(tail);
            self.shards[sid].len -= 1;
            evicted = true;
        }
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.entries.push(CacheEntry {
                    block: 0,
                    hash_next: NIL,
                    lru_prev: NIL,
                    lru_next: NIL,
                    live: false,
                });
                (self.entries.len() - 1) as u32
            }
        };
        let b = self.bucket_of(block);
        let head = self.shards[sid].buckets[b];
        self.entries[id as usize] = CacheEntry {
            block,
            hash_next: head,
            lru_prev: NIL,
            lru_next: NIL,
            live: true,
        };
        self.shards[sid].buckets[b] = id;
        self.lru_push_front(sid, id);
        self.shards[sid].len += 1;
        (id, evicted)
    }

    /// Measured cache hit ratio over the metrics window.
    pub fn hit_ratio(&self) -> f64 {
        self.stats.hit_ratio()
    }

    /// Simulated DRAM bytes this configuration consumes — honest: the
    /// policy-placed cache structures *plus* the pinned memtable residual
    /// (nonzero even under `AllSecondary`).
    pub fn dram_bytes(&self) -> u64 {
        self.plan.dram_bytes()
    }

    /// The pinned residual footprint (the DRAM-by-design memtable).
    pub fn residual_dram_bytes(&self) -> u64 {
        self.plan.pinned_bytes()
    }

    /// Total offloadable bytes (what `Budget` fractions resolve against;
    /// excludes the pinned residual).
    pub fn offload_bytes_total(&self) -> u64 {
        self.plan.offloadable_bytes()
    }

    /// The resolved placement plan (static, or measured after
    /// [`LsmKv::replan`]).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// Re-resolve the block-cache placement over the **measured** per-class
    /// access profile (`kvs::placement` module docs, "Measured
    /// re-ranking"). Class-granular, so it is a plan swap: every later
    /// access consults the replanned tiers, and the `ModelCosts` snapshots
    /// split `m`/`m_dram` from the replanned plan.
    pub fn replan(&mut self, profile: &AccessProfile) {
        self.plan = Plan::replan(self.cfg.placement, Self::placement_classes(&self.cfg), profile);
    }

    /// Swap the workload mid-run (phased schedules): new operation weights
    /// and key distribution over the same store. `KeyGen::new` draws no
    /// randomness, so the simulation's RNG stream is untouched and
    /// determinism holds.
    pub fn set_workload(&mut self, ops: Option<OpWeights>, key_dist: KeyDist) {
        self.cfg.ops = ops;
        self.cfg.key_dist = key_dist;
        self.keygen = KeyGen::new(self.cfg.n_items, key_dist);
    }

    /// [`LsmKv::replan`] with honest migration accounting (`kvs::placement`
    /// module docs, "Online replanning"). Placement is class-granular, so a
    /// tier flip moves the whole class: every 64-byte line of a flipped
    /// class is one read on the tier it leaves plus one write on the tier
    /// it lands (one `dram` + one `secondary` touch whichever direction).
    /// A `PC_DATA` flip additionally refetches every live cached block over
    /// the SSD shard route — `reads` live block reads of
    /// [`LsmKv::block_bytes`] each — because block *bytes* are not pointer
    /// metadata: rehoming them re-reads the authoritative SSD copy. The
    /// pinned memtable never moves. An unchanged plan costs nothing.
    pub fn replan_migrate(&mut self, profile: &AccessProfile) -> DriveCounts {
        let before: Vec<bool> = (0..PC_MEMTABLE).map(|c| self.plan.in_dram(c)).collect();
        self.replan(profile);
        let mut mig = DriveCounts::default();
        for (c, &was) in before.iter().enumerate() {
            if self.plan.in_dram(c) == was {
                continue;
            }
            let lines = ((self.plan.classes()[c].bytes + 63) / 64) as u32;
            mig.dram += lines;
            mig.secondary += lines;
            if c == PC_DATA {
                mig.reads += self.shards.iter().map(|s| s.len).sum::<u32>();
            }
        }
        mig
    }

    /// One simulated access to a placement class: tag the [`AccessProfile`]
    /// and charge the access at the class's planned tier. Accesses to a
    /// compressed-in-DRAM class additionally queue the class's inline
    /// decompress CPU, charged as the next step's `Compute`.
    #[inline]
    fn class_access(&mut self, class: usize) -> Step {
        self.profile.tick(class);
        if self.plan.is_compressed(class) {
            self.pending_cpu = Some(Dur::us(self.plan.decompress_us(class)));
        }
        Step::MemAccess(self.plan.tier(class))
    }

    fn lock_of(&self, block: u32) -> u32 {
        (self.shard_of(block) as u32) % 64
    }

    /// Logical membership oracle (tests; not simulated).
    pub fn contains_key(&self, key: u64) -> bool {
        key < self.cfg.n_items && !self.deleted.contains(&key)
    }

    /// Keys a scan of `len` from `start` returns (oracle for the ordering
    /// and tombstone-skip property tests; not simulated).
    pub fn scan_keys(&self, start: u64, len: u32) -> Vec<u64> {
        let mut out = Vec::new();
        let mut k = start;
        let mut budget = len;
        while budget > 0 && k < self.cfg.n_items {
            if !self.deleted.contains(&k) {
                out.push(k);
            }
            budget -= 1;
            k += 1;
        }
        out
    }

    // ---- directed operation constructors (also used by next_op) ----------

    pub fn op_get(&mut self, key: u64) -> LsmOp {
        self.stats.gets += 1;
        LsmOp::Memtable {
            kind: OpKind::Read,
            key,
            probes: 3,
        }
    }

    pub fn op_put(&mut self, key: u64) -> LsmOp {
        self.stats.sets += 1;
        LsmOp::WriteMem { key, probes: 4 }
    }

    pub fn op_delete(&mut self, key: u64) -> LsmOp {
        self.stats.deletes += 1;
        LsmOp::DeleteMem { key, probes: 4 }
    }

    pub fn op_rmw(&mut self, key: u64) -> LsmOp {
        self.stats.rmws += 1;
        LsmOp::Memtable {
            kind: OpKind::Rmw,
            key,
            probes: 3,
        }
    }

    pub fn op_scan(&mut self, start: u64, len: u32) -> LsmOp {
        self.stats.scans += 1;
        LsmOp::Scan {
            key: start,
            left: len.max(1),
            probes: 3,
            chain_left: 0,
            chain_init: false,
            need_io: false,
            insert_step: 0,
            in_block: false,
            stride: 0,
        }
    }

    /// Count one memtable insert toward the flush threshold (shared by
    /// value writes and tombstone writes). On rotation the active
    /// memtable's tombstones become sealed (immutable-memtable resident).
    fn memtable_fill_tick(&mut self) {
        self.memtable_fill += 1;
        if self.memtable_fill >= self.cfg.memtable_cap {
            self.memtable_fill = 0;
            self.flush_backlog += 1;
            let fresh: Vec<u64> = self.fresh_tombstones.drain().collect();
            self.sealed_tombstones.extend(fresh);
        }
    }

    /// Memtable insert shared by writes and RMW write-halves.
    fn memtable_write(&mut self, key: u64) {
        self.deleted.remove(&key);
        self.fresh_tombstones.remove(&key);
        self.sealed_tombstones.remove(&key);
        self.memtable_fill_tick();
    }

    /// A tombstone for `key` is still DRAM-resident (active or immutable
    /// memtable), so a read resolves to absent without touching the cache.
    fn tombstone_in_memtable(&self, key: u64) -> bool {
        self.fresh_tombstones.contains(&key) || self.sealed_tombstones.contains(&key)
    }
}

// ---- Θ_scan model-parameter snapshots (kvs::ModelCosts) -------------------

/// Device-base (the `SsdConfig` defaults, 1.5/0.2) plus the *same*
/// block-fetch extras the `Step::Io` sites charge.
const IO_BLOCK_PRE: f64 = 1.5 + BLOCK_EXTRA_PRE_US;
const IO_BLOCK_POST: f64 = 0.2 + BLOCK_EXTRA_POST_US;
/// Host-DRAM access latency assumed by the snapshots (the machine default).
const DRAM_US: f64 = 0.09;

impl LsmKv {
    /// Replicate the point-read `ChainWalk` access charging for one block
    /// (bucket-head read, one access per traversed entry, one for the
    /// match). Returns `(found, secondary_accesses)`.
    fn probe_read_path(&self, block: u32) -> (bool, f64) {
        let s = &self.shards[self.shard_of(block)];
        let mut cur = s.buckets[self.bucket_of(block)];
        let mut acc = 1.0; // bucket head
        while cur != NIL {
            let e = &self.entries[cur as usize];
            if e.live && e.block == block {
                return (true, acc + 1.0); // the match entry's access
            }
            cur = e.hash_next;
            if cur != NIL {
                acc += 1.0;
            }
        }
        (false, acc)
    }

    /// Structural probe over a deterministic block stride: average chain
    /// cost of hits and misses for the point path and the scan path (which
    /// uses [`LsmKv::chain_probe`] like the simulator), plus the structural
    /// cache coverage. No RNG — snapshots must be reproducible.
    fn probe_cache(&self) -> CacheProbe {
        let stride = (self.n_blocks / 1024).max(1);
        let (mut hit_acc, mut miss_acc) = (0.0f64, 0.0f64);
        let (mut hit_scan, mut miss_scan) = (0.0f64, 0.0f64);
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut b = 0u32;
        while b < self.n_blocks {
            let (found, acc) = self.probe_read_path(b);
            let (scan_hops, _) = self.chain_probe(b);
            if found {
                hits += 1;
                hit_acc += acc;
                hit_scan += scan_hops as f64;
            } else {
                misses += 1;
                miss_acc += acc;
                miss_scan += scan_hops as f64;
            }
            b += stride;
        }
        CacheProbe {
            hit_acc: hit_acc / hits.max(1) as f64,
            miss_acc: miss_acc / misses.max(1) as f64,
            hit_scan: hit_scan / hits.max(1) as f64,
            miss_scan: miss_scan / misses.max(1) as f64,
            coverage: hits as f64 / (hits + misses).max(1) as f64,
        }
    }

    /// Block-cache hit ratio for the snapshot: the measured counters when a
    /// run has populated them (the paper's treatment of measured system
    /// parameters), else the structural coverage — a documented
    /// underestimate for Zipf-weighted accesses on a cold store.
    fn snapshot_hit_ratio(&self, probe: &CacheProbe) -> f64 {
        let resolved = self.stats.hits + self.stats.misses;
        if resolved > 0 {
            (self.stats.hits as f64 / resolved as f64).clamp(0.0, 1.0)
        } else {
            probe.coverage
        }
    }

    /// Split per-class expected access counts by the live placement plan
    /// into secondary / plain-DRAM / compressed-DRAM hops plus the mean
    /// per-compressed-hop decompress CPU (see [`Plan::split3`]).
    fn split_classes(&self, handles: f64, restarts: f64, data: f64) -> HopSplit {
        let classes = [(PC_HANDLES, handles), (PC_RESTARTS, restarts), (PC_DATA, data)];
        self.plan.split3(&classes)
    }

    /// Θ_scan cost vector for an explicit scan length: the merged iterator
    /// touches ≈ `len/keys_per_block + 1` blocks (chain walk each, SSD
    /// fetch for the cache-missing share), plus one dependent access per
    /// restart interval (`len/4`). Every term is **linear** in `len`, so
    /// the mean length is unbiased here (unlike treekv's batched-IO
    /// ceiling, which needs the second moment).
    pub fn scan_model_params(&self, len: f64) -> KindCost {
        let probe = self.probe_cache();
        let h = self.snapshot_hit_ratio(&probe);
        self.scan_cost(len, &probe, h)
    }

    /// [`LsmKv::scan_model_params`] with the structure probe precomputed
    /// (callers that snapshot several kinds probe once).
    fn scan_cost(&self, len: f64, probe: &CacheProbe, h: f64) -> KindCost {
        let t_mem = self.cfg.t_node.as_us();
        if len <= 0.0 {
            // Zero-length scan: the memtable seek alone — no blocks, no IO.
            return KindCost::memory_only(0.0, t_mem, 3.0 * DRAM_US + t_mem);
        }
        let blocks = len / self.cfg.keys_per_block as f64 + 1.0;
        // Per block: chain walk (simulator's chain_probe hops) over the
        // handles, +1 first data touch on a cached block; per entry: one
        // data access per 4-entry restart interval, compute otherwise.
        let handles = blocks * (h * probe.hit_scan + (1.0 - h) * probe.miss_scan);
        let data = blocks * h + len / 4.0;
        let hops = self.split_classes(handles, 0.0, data);
        KindCost {
            m: hops.sec,
            m_dram: hops.dram,
            m_cpr: hops.cpr,
            t_cpu: hops.cpr_us,
            s: blocks * (1.0 - h),
            a_io: self.block_bytes() as f64,
            t_mem,
            t_pre: IO_BLOCK_PRE,
            t_post: IO_BLOCK_POST,
            t_fixed: 3.0 * DRAM_US + 0.75 * len * t_mem,
        }
    }
}

/// Averages from [`LsmKv::probe_cache`].
struct CacheProbe {
    hit_acc: f64,
    miss_acc: f64,
    hit_scan: f64,
    miss_scan: f64,
    coverage: f64,
}

impl super::ModelCosts for LsmKv {
    /// Per-kind cost vectors from the live cache geometry: chain lengths
    /// from the actual shard/bucket occupancy, the in-block restart-array
    /// search (2 accesses), measured hit ratio, and the memtable's
    /// DRAM-only write path. Background flush/compaction is not part of the
    /// per-op model (its bulk IOs ride on separate threads).
    fn model_params(&self, kind: OpKind) -> KindCost {
        let t_mem = self.cfg.t_node.as_us();
        // Memtable insert: 4 DRAM probes + the buffered WAL append.
        let write_fixed = 4.0 * DRAM_US + 0.15;
        // Writes and deletes are memtable-only: no structure probe needed.
        if matches!(kind, OpKind::Write | OpKind::Delete) {
            return KindCost::memory_only(0.0, t_mem, write_fixed);
        }
        let probe = self.probe_cache();
        let h = self.snapshot_hit_ratio(&probe);
        match kind {
            OpKind::Read | OpKind::Rmw => {
                // Hit: chain walk + 2 in-block accesses (1 restart probe +
                // 1 data read). Miss: chain to the end + 3 insert-walk
                // handle accesses + the same 2 in-block after the fetch.
                let handles = h * probe.hit_acc + (1.0 - h) * (probe.miss_acc + 3.0);
                let hops = self.split_classes(handles, 1.0, 1.0);
                let t_fixed = 3.0 * DRAM_US
                    + t_mem
                    + if kind == OpKind::Rmw { write_fixed } else { 0.0 };
                KindCost {
                    m: hops.sec,
                    m_dram: hops.dram,
                    m_cpr: hops.cpr,
                    t_cpu: hops.cpr_us,
                    s: 1.0 - h,
                    a_io: self.block_bytes() as f64,
                    t_mem,
                    t_pre: IO_BLOCK_PRE,
                    t_post: IO_BLOCK_POST,
                    t_fixed,
                }
            }
            OpKind::Scan => self.scan_cost(self.cfg.scan_len.mean(), &probe, h),
            // Handled by the early return above.
            OpKind::Write | OpKind::Delete => unreachable!(),
        }
    }
}

impl Service for LsmKv {
    type Op = LsmOp;

    fn next_op(&mut self, tid: usize, rng: &mut Rng) -> LsmOp {
        if self.is_bg(tid) {
            // Flush ops are the store's own work, owned by no tenant.
            self.tenant_tids.note(tid, None);
            if self.flush_backlog > 0 {
                self.flush_backlog -= 1;
                // The flush moves *sealed* (rotated-memtable) tombstones
                // into the SSTable levels: those reads stop short-circuiting
                // at the memtable (compaction later purges the records; the
                // keys stay logically deleted). The active memtable's
                // tombstones are untouched.
                self.sealed_tombstones.clear();
                return LsmOp::BgFlush {
                    ios_left: 8,
                    write: false,
                };
            }
            return LsmOp::BgPause;
        }
        // Tenant selection is RNG-free (SWRR), so the single-tenant path
        // consumes the exact legacy draw sequence: key, kind[, len].
        let tenant = self.tenants.as_mut().map(|r| r.pick());
        self.tenant_tids.note(tid, tenant);
        let (key, kind, scan_len) = if let Some(t) = tenant {
            let router = self.tenants.as_ref().unwrap();
            let key = router.sample_key(t, rng);
            let spec = router.spec(t);
            (key, spec.ops.sample(rng), spec.scan_len)
        } else {
            (
                self.keygen.sample(rng),
                self.weights().sample(rng),
                self.cfg.scan_len,
            )
        };
        match kind {
            OpKind::Read => self.op_get(key),
            OpKind::Write => self.op_put(key),
            OpKind::Delete => self.op_delete(key),
            OpKind::Rmw => self.op_rmw(key),
            OpKind::Scan => {
                let len = scan_len.sample(rng);
                self.op_scan(key, len)
            }
        }
    }

    fn op_tenant(&self, tid: usize) -> Option<u32> {
        self.tenant_tids.current(tid)
    }

    fn step(&mut self, _tid: usize, op: &mut LsmOp, _rng: &mut Rng) -> Step {
        // Inline decompress CPU owed by the previous compressed-class
        // access: a dependent Compute on the op's critical path (the op
        // state already advanced, so this purely adds busy time).
        if let Some(d) = self.pending_cpu.take() {
            return Step::Compute(d);
        }
        match op {
            LsmOp::Memtable { kind, key, probes } => {
                // Skiplist probe in host DRAM: inline accesses, no yield.
                if *probes > 0 {
                    *probes -= 1;
                    return self.class_access(PC_MEMTABLE);
                }
                debug_assert!(matches!(*kind, OpKind::Read | OpKind::Rmw));
                let k = *key;
                let rmw = *kind == OpKind::Rmw;
                if self.tombstone_in_memtable(k) {
                    // Memtable-resident tombstone (active or immutable
                    // generation): the read resolves to absent right here.
                    self.stats.absent += 1;
                    if rmw {
                        *op = LsmOp::WriteMem { key: k, probes: 4 };
                    } else {
                        *op = LsmOp::Finished;
                    }
                    return Step::Compute(self.cfg.t_node);
                }
                let block = self.block_of(k);
                let sid = self.shard_of(block);
                let first = self.shards[sid].buckets[self.bucket_of(block)];
                *op = LsmOp::ChainWalk {
                    key: k,
                    entry: first,
                    first: true,
                    rmw,
                };
                Step::Compute(self.cfg.t_node)
            }
            LsmOp::ChainWalk {
                key,
                entry,
                first,
                rmw,
            } => {
                let k = *key;
                let r = *rmw;
                let block = self.block_of(k);
                if *first {
                    // Reading the bucket head itself is one cache-handle
                    // access (placement class PC_HANDLES).
                    *first = false;
                    if *entry == NIL {
                        self.stats.misses += 1;
                        *op = LsmOp::Fetch { key: k, rmw: r };
                    }
                    return self.class_access(PC_HANDLES);
                }
                let id = *entry;
                if id == NIL {
                    self.stats.misses += 1;
                    *op = LsmOp::Fetch { key: k, rmw: r };
                    return Step::Compute(self.cfg.t_node);
                }
                let e = self.entries[id as usize];
                if e.live && e.block == block {
                    self.stats.hits += 1;
                    self.stats.t1_hits += 1;
                    // Neighbor read happens unlocked; only the splice runs
                    // under the shard lock (holding a lock across
                    // prefetch+yield accesses would make hold time grow
                    // with memory latency and serialize hot shards).
                    *op = LsmOp::LruPromote {
                        key: k,
                        entry: id,
                        hops: 0,
                        rmw: r,
                    };
                    return self.class_access(PC_HANDLES);
                }
                *entry = e.hash_next;
                if *entry == NIL {
                    self.stats.misses += 1;
                    *op = LsmOp::Fetch { key: k, rmw: r };
                    return Step::Compute(self.cfg.t_node);
                }
                self.class_access(PC_HANDLES)
            }
            LsmOp::LruPromote {
                key,
                entry,
                hops,
                rmw,
            } => {
                let k = *key;
                let r = *rmw;
                let block = self.block_of(k);
                match *hops {
                    0 => {
                        *hops = 1;
                        Step::Lock(self.lock_of(block))
                    }
                    1 => {
                        // Splice under the lock: the entry and neighbors were
                        // just read (unlocked), so the pointer writes hit the
                        // CPU cache — charge compute, not a long-latency
                        // access, and release quickly.
                        *hops = 2;
                        let sid = self.shard_of(block);
                        let id = *entry;
                        self.lru_unlink(sid, id);
                        self.lru_push_front(sid, id);
                        Step::Compute(self.cfg.t_node)
                    }
                    _ => {
                        *op = LsmOp::InBlock {
                            key: k,
                            lo: block * self.cfg.keys_per_block,
                            hi: (block + 1) * self.cfg.keys_per_block,
                            compute_done: false,
                            rmw: r,
                        };
                        Step::Unlock(self.lock_of(block))
                    }
                }
            }
            LsmOp::Fetch { key, rmw } => {
                let k = *key;
                let r = *rmw;
                // The SSTable block id routes the read to its owning device.
                let shard = self.block_of(k) as u64;
                *op = LsmOp::Insert {
                    key: k,
                    hops: 0,
                    rmw: r,
                };
                Step::Io {
                    kind: IoKind::Read,
                    bytes: self.block_bytes(),
                    // See BLOCK_EXTRA_* above.
                    extra_pre: Dur::us(BLOCK_EXTRA_PRE_US),
                    extra_post: Dur::us(BLOCK_EXTRA_POST_US),
                    shard,
                    class: TrafficClass::Foreground,
                }
            }
            LsmOp::Insert { key, hops, rmw } => {
                let k = *key;
                let r = *rmw;
                let block = self.block_of(k);
                // Eviction-candidate walk (3 accesses over the LRU handles)
                // runs unlocked; the lock covers only the final mutation.
                if *hops < 3 {
                    *hops += 1;
                    return self.class_access(PC_HANDLES);
                }
                if *hops == 3 {
                    *hops = 4;
                    return Step::Lock(self.lock_of(block));
                }
                if *hops == 4 {
                    *hops = 5;
                    if self.cache_lookup(block).is_none() {
                        self.cache_insert(block);
                    }
                    // Mutation writes hit lines brought in by the unlocked
                    // walk: short critical section.
                    return Step::Compute(self.cfg.t_node * 2);
                }
                *op = LsmOp::InBlock {
                    key: k,
                    lo: block * self.cfg.keys_per_block,
                    hi: (block + 1) * self.cfg.keys_per_block,
                    compute_done: false,
                    rmw: r,
                };
                Step::Unlock(self.lock_of(block))
            }
            LsmOp::InBlock {
                key,
                lo,
                hi,
                compute_done,
                rmw,
            } => {
                // RocksDB block layout: binary-search the restart array
                // (blocks this small have ~2 restart points), then scan one
                // restart interval. Each probe = compute + secondary access.
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let width = *hi - *lo;
                if width <= self.cfg.keys_per_block / 2 {
                    // Within one restart interval: single sequential scan
                    // access resolves the entry (length-prefixed entries in
                    // adjacent lines).
                    let k = *key;
                    debug_assert!((*lo..*hi).contains(&(k as u32)));
                    if self.deleted.contains(&k) {
                        // Tombstone was flushed: the data block no longer
                        // holds the key — the read resolves to absent.
                        self.stats.absent += 1;
                    } else {
                        self.stats.verified += 1;
                    }
                    if *rmw {
                        // Write half: memtable insert of the same key.
                        *op = LsmOp::WriteMem { key: k, probes: 4 };
                    } else {
                        *op = LsmOp::Finished;
                    }
                    // The final interval scan reads the block's data bytes.
                    return self.class_access(PC_DATA);
                }
                let mid = (*lo + *hi) / 2;
                if (*key as u32) < mid {
                    *hi = mid;
                } else {
                    *lo = mid;
                }
                // Restart-array probe (placement class PC_RESTARTS).
                self.class_access(PC_RESTARTS)
            }
            LsmOp::WriteMem { key, probes } => {
                // Memtable skiplist insert: DRAM accesses only.
                if *probes > 0 {
                    *probes -= 1;
                    return self.class_access(PC_MEMTABLE);
                }
                let k = *key;
                self.memtable_write(k);
                if self.wal.enabled() {
                    let vsize = self.cfg.value_size.mean() as u32;
                    let lsn = self.wal.append(WalKind::Put, k, vsize);
                    *op = LsmOp::WalCommit { lsn };
                    return Step::Compute(self.wal.cfg.append_cpu);
                }
                *op = LsmOp::Finished;
                Step::Compute(Dur::ns(150.0)) // WAL append (buffered)
            }
            LsmOp::DeleteMem { key, probes } => {
                // Tombstone insert: same memtable path as a write.
                if *probes > 0 {
                    *probes -= 1;
                    return self.class_access(PC_MEMTABLE);
                }
                let k = *key;
                self.deleted.insert(k);
                self.fresh_tombstones.insert(k);
                self.memtable_fill_tick();
                if self.wal.enabled() {
                    let lsn = self.wal.append(WalKind::Delete, k, 0);
                    *op = LsmOp::WalCommit { lsn };
                    return Step::Compute(self.wal.cfg.append_cpu);
                }
                *op = LsmOp::Finished;
                Step::Compute(Dur::ns(150.0)) // WAL tombstone append
            }
            LsmOp::Scan {
                key,
                left,
                probes,
                chain_left,
                chain_init,
                need_io,
                insert_step,
                in_block,
                stride,
            } => {
                // Iterator seek: memtable probe first (DRAM).
                if *probes > 0 {
                    *probes -= 1;
                    return self.class_access(PC_MEMTABLE);
                }
                if *left == 0 || *key >= self.cfg.n_items {
                    *op = LsmOp::Finished;
                    return Step::Compute(self.cfg.t_node);
                }
                let k = *key;
                let block = self.block_of(k);
                if *insert_step > 0 {
                    // Post-fetch cache insert, under the shard lock exactly
                    // like the point-read `Insert` path.
                    match *insert_step {
                        1 => {
                            *insert_step = 2;
                            return Step::Lock(self.lock_of(block));
                        }
                        2 => {
                            *insert_step = 3;
                            if self.cache_lookup(block).is_none() {
                                self.cache_insert(block);
                            }
                            return Step::Compute(self.cfg.t_node * 2);
                        }
                        _ => {
                            *insert_step = 0;
                            *in_block = true;
                            *stride = 0;
                            return Step::Unlock(self.lock_of(block));
                        }
                    }
                }
                if !*in_block {
                    if !*chain_init {
                        *chain_init = true;
                        let (hops, hit) = self.chain_probe(block);
                        *chain_left = hops;
                        *need_io = !hit;
                    }
                    if *chain_left > 0 {
                        // Bucket-head + chain-walk accesses for this block.
                        *chain_left -= 1;
                        return self.class_access(PC_HANDLES);
                    }
                    if *need_io {
                        *need_io = false;
                        *insert_step = 1;
                        self.stats.misses += 1;
                        return Step::Io {
                            kind: IoKind::Read,
                            bytes: self.block_bytes(),
                            extra_pre: Dur::us(BLOCK_EXTRA_PRE_US),
                            extra_post: Dur::us(BLOCK_EXTRA_POST_US),
                            shard: block as u64,
                            class: TrafficClass::Foreground,
                        };
                    }
                    self.stats.hits += 1;
                    self.stats.t1_hits += 1;
                    *in_block = true;
                    *stride = 0;
                    // First touch of the cached block's bytes.
                    return self.class_access(PC_DATA);
                }
                // Consume one key from the resident block; tombstoned keys
                // are merged out (compute only).
                if !self.deleted.contains(&k) {
                    self.stats.scanned += 1;
                    self.stats.verified += 1;
                }
                *left -= 1;
                *key += 1;
                *stride = stride.wrapping_add(1);
                if *left > 0 && *key < self.cfg.n_items && self.block_of(*key) != block {
                    *in_block = false;
                    *chain_init = false;
                }
                if *stride % 4 == 0 {
                    // Crossing into the next restart interval: one more
                    // dependent access over the cached block bytes.
                    self.class_access(PC_DATA)
                } else {
                    Step::Compute(self.cfg.t_node)
                }
            }
            LsmOp::BgFlush { ios_left, write } => {
                self.stats.bg_ops += 1;
                if *ios_left == 0 {
                    *op = LsmOp::Finished;
                    return Step::Compute(Dur::us(1.0));
                }
                // Compaction stripes its bulk IOs across the array (one
                // output file per device in a real multi-disk db_path).
                let shard = *ios_left as u64;
                *ios_left -= 1;
                let kind = if *write {
                    IoKind::Write
                } else {
                    IoKind::Read
                };
                *write = !*write;
                let bytes = 32 * 1024; // bulk compaction IO
                // Traffic-class split of the 8-IO cycle: the *first* write
                // (entry ios_left == 7) persists the sealed memtable — the
                // flush; every other IO is the L0→L1 merge — compaction
                // reads of existing SSTables and rewritten-output writes.
                // The byte ledger increments at exactly these sites so the
                // interference experiment can cross-check the device's bg
                // lanes against store-side write-amplification accounting.
                let class = match kind {
                    IoKind::Write if shard == 7 => {
                        self.stats.flush_write_bytes += bytes as u64;
                        TrafficClass::Background(BgKind::Flush)
                    }
                    IoKind::Write => {
                        self.stats.compact_write_bytes += bytes as u64;
                        TrafficClass::Background(BgKind::Compaction)
                    }
                    IoKind::Read => {
                        self.stats.compact_read_bytes += bytes as u64;
                        TrafficClass::Background(BgKind::Compaction)
                    }
                };
                Step::Io {
                    kind,
                    bytes,
                    extra_pre: Dur::ns(500.0),
                    extra_post: Dur::us(2.0), // merge work
                    shard,
                    class,
                }
            }
            LsmOp::BgPause => {
                // Pace, then cooperatively yield (see treekv::DefragPause).
                *op = LsmOp::BgYield;
                Step::Compute(Dur::us(5.0))
            }
            LsmOp::BgYield => {
                *op = LsmOp::Finished;
                Step::Yield
            }
            LsmOp::WalCommit { lsn } => {
                let lsn = *lsn;
                if self.wal.is_durable(lsn) {
                    // Another leader's group flush covered this record.
                    self.wal.mark_acked(lsn);
                    *op = LsmOp::Finished;
                    return Step::Compute(self.cfg.t_node);
                }
                if let Some((upto, bytes)) = self.wal.try_lead(lsn) {
                    *op = LsmOp::WalFlush { upto, lsn };
                    return Step::Io {
                        kind: IoKind::Write,
                        bytes,
                        extra_pre: Dur::ZERO,
                        extra_post: Dur::ZERO,
                        shard: self.wal.cfg.log_shard,
                        class: TrafficClass::Background(BgKind::WalFlush),
                    };
                }
                // A flush is in flight: commit-wait (one T_sw poll).
                self.wal.note_poll();
                Step::Yield
            }
            LsmOp::WalFlush { upto, lsn } => {
                // Reached only when the log write succeeded (`io_failed`
                // reroutes failures before this state is re-entered).
                self.wal.flush_done(*upto);
                self.wal.mark_acked(*lsn);
                *op = LsmOp::Finished;
                Step::Compute(self.cfg.t_node)
            }
            LsmOp::Finished => Step::Done,
        }
    }

    fn io_failed(&mut self, _tid: usize, op: &mut LsmOp) {
        // Graceful degradation: the op surfaces an error and terminates;
        // nothing wedges. A failed log flush releases the WAL leadership so
        // a later committer can re-elect itself. Every IO-bearing state in
        // this store holds no lock at IO time, so terminating here leaks
        // nothing.
        self.stats.io_errors += 1;
        if let LsmOp::WalFlush { upto, .. } = *op {
            self.wal.flush_aborted(upto);
        }
        self.stats.failed_ops += 1;
        *op = LsmOp::Finished;
    }
}

impl Durable for LsmKv {
    fn wal(&self) -> &Wal {
        &self.wal
    }

    fn wal_mut(&mut self) -> &mut Wal {
        &mut self.wal
    }

    fn wal_present(&self, key: u64) -> bool {
        self.contains_key(key)
    }

    fn replay_record(&mut self, rec: &WalRecord, _rng: &mut Rng) {
        match rec.kind {
            WalKind::Put => self.memtable_write(rec.key),
            WalKind::Delete => {
                self.deleted.insert(rec.key);
                self.fresh_tombstones.insert(rec.key);
                self.memtable_fill_tick();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, MachineConfig, MemConfig};

    fn small_cfg() -> LsmKvConfig {
        LsmKvConfig {
            n_items: 100_000,
            cache_blocks: 1024,
            shards: 16,
            buckets_per_shard: 64,
            ..Default::default()
        }
    }

    use super::super::common::drive_op;
    use super::super::wal::WalStats;

    /// Drive an op to completion; returns (mem accesses, total IOs).
    fn drive(kv: &mut LsmKv, op: LsmOp, rng: &mut Rng) -> (u32, u32) {
        let (mems, reads, writes) = drive_op(kv, op, rng);
        (mems, reads + writes)
    }

    #[test]
    fn cache_structure_invariants() {
        let mut rng = Rng::new(1);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        // Insert many blocks; shard lengths never exceed capacity and
        // lookups find exactly what was inserted last.
        for b in 0..5000u32 {
            if kv.cache_lookup(b).is_none() {
                kv.cache_insert(b);
            }
        }
        for s in &kv.shards {
            assert!(s.len <= kv.cap_per_shard);
            // LRU list length == shard len.
            let mut cur = s.lru_head;
            let mut cnt = 0;
            let mut prev = NIL;
            while cur != NIL {
                assert_eq!(kv.entries[cur as usize].lru_prev, prev);
                prev = cur;
                cur = kv.entries[cur as usize].lru_next;
                cnt += 1;
                assert!(cnt <= s.len, "LRU list longer than shard");
            }
            assert_eq!(cnt, s.len);
            assert_eq!(s.lru_tail, prev);
        }
    }

    #[test]
    fn eviction_is_lru_order() {
        let mut rng = Rng::new(2);
        let mut kv = LsmKv::new(
            LsmKvConfig {
                cache_blocks: 32,
                shards: 1,
                buckets_per_shard: 16,
                ..small_cfg()
            },
            &mut rng,
        );
        // Clear warmup state by filling with known blocks.
        for b in 1000..1032u32 {
            if kv.cache_lookup(b).is_none() {
                kv.cache_insert(b);
            }
        }
        // 1000 is now the tail (oldest of ours) unless warmup left newer.
        // Insert one more: some block must be evicted and it must not be
        // the most recently inserted.
        kv.cache_insert(2000);
        assert!(kv.cache_lookup(2000).is_some());
        assert!(kv.cache_lookup(1031).is_some(), "MRU must survive");
    }

    #[test]
    fn zipf_hit_ratio_in_paper_range() {
        let mut rng = Rng::new(3);
        let kv = LsmKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let _ = m.run(Dur::ms(5.0), Dur::ms(20.0));
        let hr = m.service.hit_ratio();
        // Paper: 67% with Zipf 0.99 and a 32/400 GB cache. Our scaled cache
        // (1024*8 / 100k ≈ 8% of keys) under Zipf 0.99 lands nearby.
        assert!((0.5..0.85).contains(&hr), "hit ratio {hr}");
        assert_eq!(m.service.stats.corruptions, 0);
        assert!(m.service.stats.verified > 500);
    }

    #[test]
    fn misses_cause_io_and_s_below_one() {
        let mut rng = Rng::new(4);
        let kv = LsmKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(5.0), Dur::ms(20.0));
        assert!(st.mean_s > 0.05 && st.mean_s < 0.9, "S = {}", st.mean_s);
        assert!(st.io_reads > 100);
        // M per op: bucket walk + LRU + in-block ≈ 6-12.
        assert!((4.0..15.0).contains(&st.mean_m), "M = {}", st.mean_m);
    }

    #[test]
    fn write_mix_triggers_flushes() {
        let mut rng = Rng::new(5);
        let kv = LsmKv::new(
            LsmKvConfig {
                mix: OpMix::ratio(1, 1),
                memtable_cap: 256,
                ..small_cfg()
            },
            &mut rng,
        )
        .with_background(32);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(5.0), Dur::ms(30.0));
        assert!(m.service.stats.sets > 1000);
        assert!(m.service.stats.bg_ops > 0, "compaction never ran");
        assert!(st.io_writes > 0);
    }

    #[test]
    fn delete_then_get_is_absent_fresh_and_flushed() {
        let mut rng = Rng::new(6);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        let key = 4242u64;
        assert!(kv.contains_key(key));

        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert!(!kv.contains_key(key));

        // Memtable-resident tombstone: the read stops at the memtable
        // (DRAM probes only, no secondary access, no IO).
        let absent0 = kv.stats.absent;
        let op = kv.op_get(key);
        let (mems, ios) = drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.absent, absent0 + 1);
        assert_eq!(ios, 0, "fresh tombstone must not reach the SSD");
        assert_eq!(mems, 3, "memtable probes only");

        // Simulate rotation + flush of the tombstone's generation: the read
        // then takes the full path and discovers absence in the data block.
        kv.fresh_tombstones.clear();
        kv.sealed_tombstones.clear();
        let absent1 = kv.stats.absent;
        let op = kv.op_get(key);
        let (mems, _ios) = drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.absent, absent1 + 1);
        assert!(mems > 3, "flushed tombstone requires the block path");

        // Re-write resurrects the key.
        let op = kv.op_put(key);
        drive(&mut kv, op, &mut rng);
        assert!(kv.contains_key(key));
        let verified0 = kv.stats.verified;
        let op = kv.op_get(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.verified, verified0 + 1);
    }

    #[test]
    fn scan_skips_tombstones_and_reads_blocks() {
        let mut rng = Rng::new(7);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        for key in [100u64, 103, 110] {
            let op = kv.op_delete(key);
            drive(&mut kv, op, &mut rng);
        }
        let keys = kv.scan_keys(100, 16);
        assert_eq!(keys.len(), 13, "3 of 16 keys tombstoned");
        for w in keys.windows(2) {
            assert!(w[0] < w[1], "scan keys out of order");
        }
        assert!(!keys.contains(&100) && !keys.contains(&103) && !keys.contains(&110));

        let scanned0 = kv.stats.scanned;
        let op = kv.op_scan(100, 16);
        let (mems, _ios) = drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.scanned, scanned0 + 13);
        // 16 keys over blocks of 8 → at least 2 block transitions' worth of
        // chain accesses plus per-interval touches.
        assert!(mems >= 6, "scan must traverse the cache: {mems} accesses");
    }

    #[test]
    fn flush_clears_only_sealed_generation_tombstones() {
        let mut rng = Rng::new(9);
        let mut kv = LsmKv::new(
            LsmKvConfig {
                memtable_cap: 2,
                mix: OpMix::ratio(1, 1),
                ..small_cfg()
            },
            &mut rng,
        )
        .with_background(4);
        // Two tombstones fill the tiny memtable and rotate it (sealed).
        let op = kv.op_delete(11);
        drive(&mut kv, op, &mut rng);
        let op = kv.op_delete(22);
        drive(&mut kv, op, &mut rng);
        assert!(kv.sealed_tombstones.contains(&11) && kv.sealed_tombstones.contains(&22));
        // A third tombstone lands in the new active memtable.
        let op = kv.op_delete(33);
        drive(&mut kv, op, &mut rng);
        assert!(kv.fresh_tombstones.contains(&33));
        // Background flush of the sealed generation (tid 3 = bg thread).
        let bg = kv.next_op(3, &mut rng);
        drive(&mut kv, bg, &mut rng);
        assert!(kv.sealed_tombstones.is_empty(), "sealed generation flushed");
        assert!(
            kv.fresh_tombstones.contains(&33),
            "active-memtable tombstone must survive an older generation's flush"
        );
        for k in [11u64, 22, 33] {
            assert!(!kv.contains_key(k), "key {k} must stay logically deleted");
        }
    }

    #[test]
    fn model_params_track_cache_geometry() {
        use super::super::ModelCosts;
        let mut rng = Rng::new(21);
        let kv = LsmKv::new(small_cfg(), &mut rng);
        let read = kv.model_params(OpKind::Read);
        // S_read is the structural miss ratio on a cold store: the warmed
        // cache covers ~8% of blocks, so most stride-sampled blocks miss.
        assert!(read.s > 0.0 && read.s < 1.0, "S_read = {}", read.s);
        assert!(read.m > 2.0 && read.m < 20.0, "M_read = {}", read.m);
        // Writes and deletes never touch the SSD or secondary memory.
        let w = kv.model_params(OpKind::Write);
        assert_eq!((w.m, w.s), (0.0, 0.0));
        assert!(w.t_fixed > 0.0);
        assert_eq!(kv.model_params(OpKind::Delete).s, 0.0);
        // Scan: blocks scale with len/keys_per_block; len=0 has no IO.
        let scan = kv.scan_model_params(16.0);
        assert!(scan.s > 0.0, "16-key scan must fetch missing blocks");
        assert!(scan.m > read.m, "scan walks more than a point read");
        let zero = kv.scan_model_params(0.0);
        assert_eq!(zero.s, 0.0);
        assert!(zero.t_fixed > 0.0 && !zero.t_fixed.is_nan());
        // After a simulated run the measured hit ratio takes over and the
        // snapshot hit ratio rises (Zipf-weighted accesses beat coverage).
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let _ = m.run(Dur::ms(4.0), Dur::ms(10.0));
        let warm = m.service.model_params(OpKind::Read);
        assert!(
            warm.s < read.s,
            "measured hit ratio should cut S: {} -> {}",
            read.s,
            warm.s
        );
    }

    #[test]
    fn placement_routes_cache_accesses_and_accounts_bytes() {
        use super::super::common::drive_op_tiers;
        use super::super::placement::PlacementPolicy;
        // AllDram: no secondary hop anywhere on the read path. The honest
        // footprint is the offloadable classes plus the pinned memtable.
        let mut rng = Rng::new(20);
        let mut kv = LsmKv::new(
            LsmKvConfig {
                placement: PlacementPolicy::AllDram,
                ..small_cfg()
            },
            &mut rng,
        );
        assert_eq!(
            kv.dram_bytes(),
            kv.offload_bytes_total() + kv.residual_dram_bytes()
        );
        let op = kv.op_get(777);
        let c = drive_op_tiers(&mut kv, op, &mut rng);
        assert_eq!(c.secondary, 0, "AllDram get must stay inline: {c:?}");
        assert!(c.dram >= 4, "memtable probes + chain walk: {c:?}");
        // Budget covering only the handles: chain hops go DRAM, the
        // in-block data read stays secondary.
        let mut rng = Rng::new(20);
        let handles = LsmKv::placement_classes(&small_cfg())[0].bytes;
        let mut kv = LsmKv::new(
            LsmKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: handles },
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(kv.plan.in_dram(PC_HANDLES) && !kv.plan.in_dram(PC_DATA));
        assert_eq!(kv.dram_bytes(), handles + kv.residual_dram_bytes());
        let op = kv.op_get(777);
        let c = drive_op_tiers(&mut kv, op, &mut rng);
        assert!(
            c.secondary >= 1 && c.secondary <= 2,
            "only the in-block restart/data accesses stay secondary: {c:?}"
        );
        // Policy-consumed DRAM bytes stay capped by and monotone in the
        // budget knob (the honest total adds the constant pinned residual).
        let total = kv.offload_bytes_total();
        let mut prev = 0u64;
        for budget in [0, handles / 2, handles, total / 2, total] {
            let mut rng = Rng::new(20);
            let kv = LsmKv::new(
                LsmKvConfig {
                    placement: PlacementPolicy::Budget { dram_bytes: budget },
                    ..small_cfg()
                },
                &mut rng,
            );
            let b = kv.plan().policy_dram_bytes();
            assert!(b <= budget && b >= prev, "budget {budget}: {prev} -> {b}");
            assert_eq!(kv.dram_bytes(), b + kv.residual_dram_bytes());
            prev = b;
        }
        // The model snapshot splits accordingly: handles-only placement
        // moves the chain hops to m_dram but keeps the two in-block
        // accesses (restart probe + data read) on the secondary side.
        use super::super::ModelCosts;
        let read = kv.model_params(OpKind::Read);
        assert_eq!(read.m, 2.0, "in-block accesses stay secondary");
        assert!(read.m_dram > 0.5, "chain hops moved to DRAM: {}", read.m_dram);
    }

    #[test]
    fn compressed_budget_accounting_and_results_stay_consistent() {
        use super::super::placement::{CompressMode, Compression, PlacementPolicy};
        let spec = Compression::new(0.5, 0.12);
        // Half the handles class: nothing fits plain, but the handles fit
        // compressed (bytes are even, so ⌈q·bytes⌉ = bytes/2 exactly).
        let handles = LsmKv::placement_classes(&small_cfg())[PC_HANDLES].bytes;
        let budget = handles / 2;
        let mut rng = Rng::new(50);
        let mut joint = LsmKv::new(
            LsmKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: budget },
                compression: CompressMode::Joint(spec),
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(joint.plan().is_compressed(PC_HANDLES));
        assert!(!joint.plan().in_dram(PC_DATA));
        assert_eq!(joint.plan().policy_dram_bytes(), budget);
        assert_eq!(joint.dram_bytes(), budget + joint.residual_dram_bytes());
        // KV-visible results and access counts match an uncompressed twin
        // at the same seeds: the decompress is pure added Compute, which
        // drive_op ignores.
        let mut rng2 = Rng::new(50);
        let mut plain = LsmKv::new(
            LsmKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: budget },
                ..small_cfg()
            },
            &mut rng2,
        );
        assert_eq!(plain.plan().policy_dram_bytes(), 0, "nothing fits plain");
        for key in [7u64, 500, 99_999] {
            let mut ra = Rng::new(60);
            let mut rb = Rng::new(60);
            let oa = joint.op_get(key);
            let ob = plain.op_get(key);
            let a = drive(&mut joint, oa, &mut ra);
            let b = drive(&mut plain, ob, &mut rb);
            assert_eq!(a, b, "key {key}: (mems, ios) must match");
        }
        assert_eq!(joint.stats, plain.stats);
        // The model snapshot carries the compressed hops + their t_cpu.
        use super::super::ModelCosts;
        let read = joint.model_params(OpKind::Read);
        assert!(read.m_cpr > 0.5, "m_cpr = {}", read.m_cpr);
        assert!((read.t_cpu - 0.12).abs() < 1e-12);
        let pread = plain.model_params(OpKind::Read);
        assert_eq!((pread.m_cpr, pread.t_cpu), (0.0, 0.0));
        assert!(
            ((read.m + read.m_dram + read.m_cpr) - (pread.m + pread.m_dram)).abs() < 1e-9,
            "hops move buckets, they do not vanish"
        );
    }

    #[test]
    fn residual_memtable_footprint_is_reported_even_all_secondary() {
        // Satellite bugfix: the memtable is DRAM by design; before the
        // pinned-class accounting it was invisible to `dram_bytes()`, so
        // `AllSecondary`/`AllDram` sweeps understated the bytes a
        // configuration really consumes.
        let mut rng = Rng::new(24);
        let kv = LsmKv::new(small_cfg(), &mut rng); // AllSecondary default
        assert!(kv.residual_dram_bytes() > 0);
        assert_eq!(kv.dram_bytes(), kv.residual_dram_bytes());
        assert_eq!(kv.plan().policy_dram_bytes(), 0);
        // The pinned class never consumes the budget: a budget of exactly
        // the handles class still places the whole handles class.
        let handles = LsmKv::placement_classes(&small_cfg())[PC_HANDLES].bytes;
        let mut rng = Rng::new(24);
        let kv = LsmKv::new(
            LsmKvConfig {
                placement: PlacementPolicy::Budget { dram_bytes: handles },
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(kv.plan().in_dram(PC_HANDLES));
        assert_eq!(kv.plan().policy_dram_bytes(), handles);
    }

    #[test]
    fn replan_under_scan_mix_demotes_the_untouched_restarts() {
        // The measured planner's lsmkv-E case: scans walk chains and block
        // bytes but never binary-search the restart arrays, so a scan-only
        // profile ranks restarts last (zero accesses per byte) while the
        // static prior ranks them second.
        let mut rng = Rng::new(25);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        for start in (0..5_000u64).step_by(97) {
            let op = kv.op_scan(start, 16);
            drive(&mut kv, op, &mut rng);
        }
        assert!(kv.profile.accesses(PC_HANDLES) > 0);
        assert!(kv.profile.accesses(PC_DATA) > 0);
        assert_eq!(kv.profile.accesses(PC_RESTARTS), 0, "scans skip restarts");
        let profile = kv.profile.clone();
        kv.replan(&profile);
        assert_eq!(
            kv.plan().ranking(),
            &[PC_HANDLES, PC_DATA, PC_RESTARTS],
            "measured ranking demotes the untouched restart arrays"
        );
        // Replanning is deterministic given the same profile.
        let rank0 = kv.plan().ranking().to_vec();
        kv.replan(&profile);
        assert_eq!(kv.plan().ranking(), rank0.as_slice());
    }

    #[test]
    fn replan_migrate_charges_lines_and_block_refills() {
        // small_cfg class bytes: handles 1024·64 + 16·64·8 = 73,728;
        // restarts 1024·12 = 12,288; data 1024·3,424 = 3,506,176. A budget
        // of 3,580,000 statically places {handles, restarts} (data
        // overflows); a profile ranking data past restarts re-resolves to
        // {handles, data} — restarts leave DRAM (192 lines), data enters
        // (54,784 lines), and every live cached block refills over the SSD
        // shard route.
        let mut rng = Rng::new(31);
        let mut kv = LsmKv::new(
            LsmKvConfig {
                placement: PlacementPolicy::Budget {
                    dram_bytes: 3_580_000,
                },
                ..small_cfg()
            },
            &mut rng,
        );
        assert!(kv.plan().in_dram(PC_HANDLES) && kv.plan().in_dram(PC_RESTARTS));
        assert!(!kv.plan().in_dram(PC_DATA));
        let live: u32 = kv.shards.iter().map(|s| s.len).sum();
        assert!(live > 0, "the warmed cache must hold blocks");
        let mut profile = AccessProfile::new(4);
        for _ in 0..1_000 {
            profile.tick(PC_HANDLES);
            profile.tick(PC_DATA);
        }
        let mig = kv.replan_migrate(&profile);
        assert!(kv.plan().in_dram(PC_DATA) && !kv.plan().in_dram(PC_RESTARTS));
        assert_eq!((mig.dram, mig.secondary), (54_976, 54_976), "{mig:?}");
        assert_eq!(mig.reads, live, "every cached block refills from SSD");
        assert_eq!(mig.writes, 0);
        // Same profile again: the plan is already optimal, nothing moves.
        assert_eq!(kv.replan_migrate(&profile), DriveCounts::default());
        // Ranking-independent policies never migrate.
        let mut rng = Rng::new(32);
        let mut all_sec = LsmKv::new(small_cfg(), &mut rng);
        assert_eq!(all_sec.replan_migrate(&profile), DriveCounts::default());
    }

    #[test]
    fn set_workload_keeps_rng_untouched() {
        let mut rng = Rng::new(33);
        let _kv = LsmKv::new(small_cfg(), &mut rng);
        let mark = rng.below(u64::MAX);
        let mut rng2 = Rng::new(33);
        let mut kv2 = LsmKv::new(small_cfg(), &mut rng2);
        kv2.set_workload(
            Some(OpWeights::new(0.0, 0.05, 0.0, 0.95, 0.0)),
            KeyDist::Uniform,
        );
        assert_eq!(
            rng2.below(u64::MAX),
            mark,
            "set_workload must not consume randomness"
        );
        assert!(matches!(kv2.cfg.key_dist, KeyDist::Uniform));
        let key = kv2.keygen.sample(&mut rng2);
        let op = kv2.op_scan(key, 8);
        drive(&mut kv2, op, &mut rng2);
        assert!(kv2.stats.scans > 0);
    }

    #[test]
    fn rmw_reads_then_writes() {
        let mut rng = Rng::new(8);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        let key = 77u64;
        let verified0 = kv.stats.verified;
        let fill0 = kv.memtable_fill;
        let op = kv.op_rmw(key);
        drive(&mut kv, op, &mut rng);
        assert_eq!(kv.stats.verified, verified0 + 1, "read half");
        assert_eq!(kv.memtable_fill, fill0 + 1, "write half");

        // RMW of a tombstoned key upserts it.
        let op = kv.op_delete(key);
        drive(&mut kv, op, &mut rng);
        assert!(!kv.contains_key(key));
        let op = kv.op_rmw(key);
        drive(&mut kv, op, &mut rng);
        assert!(kv.contains_key(key), "rmw must resurrect the key");
    }

    #[test]
    fn wal_disabled_is_inert() {
        let mut rng = Rng::new(40);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        for k in 0..10u64 {
            let op = kv.op_put(k);
            drive(&mut kv, op, &mut rng);
            let op = kv.op_delete(k);
            drive(&mut kv, op, &mut rng);
        }
        assert_eq!(kv.wal.stats, WalStats::default(), "WAL off must be inert");
    }

    #[test]
    fn wal_commit_acks_only_after_log_write() {
        let mut rng = Rng::new(41);
        let mut kv = LsmKv::new(
            LsmKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        let op = kv.op_put(5);
        let (_, _, writes) = drive_op(&mut kv, op, &mut rng);
        assert!(writes >= 1, "commit must issue a log write");
        assert!(kv.wal.is_durable(0));
        assert!(kv.wal.acked_all_durable());
        assert_eq!(kv.wal.stats.appends, 1);
        assert_eq!(kv.wal.stats.flushes, 1);
        assert_eq!(kv.wal.stats.flush_bytes, 4096);

        let op = kv.op_delete(5);
        drive_op(&mut kv, op, &mut rng);
        assert_eq!(kv.wal.stats.appends, 2);
        assert!(kv.wal.acked_all_durable());
        assert_eq!(
            kv.wal.durable_last_kind().get(&5),
            Some(&WalKind::Delete)
        );
    }

    #[test]
    fn wal_group_commit_amortizes_flushes_under_machine() {
        let mut rng = Rng::new(42);
        let kv = LsmKv::new(
            LsmKvConfig {
                mix: OpMix::ratio(0, 1),
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(2.0), Dur::ms(10.0));
        let w = &m.service.wal;
        assert!(st.ops > 100);
        assert!(w.stats.appends > 100);
        assert!(
            w.stats.flushes * 2 < w.stats.appends,
            "group commit must amortize: {} flushes for {} appends",
            w.stats.flushes,
            w.stats.appends
        );
        assert!(w.acked_all_durable(), "never ack before durability");
    }

    #[test]
    fn wal_replay_restores_durable_state_and_is_idempotent() {
        let mut rng = Rng::new(43);
        let kv = LsmKv::new(
            LsmKvConfig {
                mix: OpMix::ratio(1, 3),
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng,
        );
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let _ = m.run(Dur::ms(1.0), Dur::ms(8.0));
        // Crash: drop the machine mid-flight; only the WAL survives.
        let old = m.service;
        assert!(old.wal.stats.appends > 50);
        assert!(old.wal.acked_all_durable());

        let mut rng2 = Rng::new(43);
        let mut kv2 = LsmKv::new(
            LsmKvConfig {
                wal: WalConfig::on(),
                ..small_cfg()
            },
            &mut rng2,
        );
        let applied = kv2.wal_replay(&old.wal, &mut rng2);
        assert_eq!(applied, old.wal.durable_lsn());
        // Recovery oracle: last durable record per key decides presence.
        for (key, kind) in old.wal.durable_last_kind() {
            match kind {
                WalKind::Put => assert!(kv2.contains_key(key), "lost put {key}"),
                WalKind::Delete => {
                    assert!(!kv2.contains_key(key), "resurrected delete {key}")
                }
            }
        }
        // Idempotence: a second replay applies nothing and changes nothing.
        let stats_before = kv2.stats.clone();
        let fill_before = kv2.memtable_fill;
        assert_eq!(kv2.wal_replay(&old.wal, &mut rng2), 0);
        assert_eq!(kv2.stats, stats_before);
        assert_eq!(kv2.memtable_fill, fill_before);
    }
}
