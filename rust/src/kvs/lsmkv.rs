//! RocksDB-like SSD-based KV store (paper §4.2, Fig 13 middle).
//!
//! An LSM-tree's data blocks live on SSD; an in-memory **block cache**
//! (sharded hash + LRU, RocksDB's `LRUCache`) lives on secondary memory and
//! is the store's dominant DRAM consumer that the paper offloads. A get
//! first probes the memtable (host DRAM), then the block cache: the shard's
//! hash-bucket chain walk and the LRU list manipulation are dependent
//! secondary-memory accesses; the in-block sorted-key traversal (restart
//! array binary search) also runs over cached block bytes on secondary
//! memory. A cache miss fetches the block from SSD (one IO) and inserts it,
//! evicting the shard's LRU tail. Writes go to the memtable; a background
//! thread flushes and compacts (bulk IO).
//!
//! With Zipf-skewed keys the cache hit ratio lands near the paper's 67%, so
//! the average IOs per operation S ≈ 0.33 and the extended model's per-IO
//! split (§3.2.3) applies.

use super::common::{fnv1a, KvStats, NIL};
use crate::sim::{Dur, IoKind, Rng, Service, Step, Tier};
use crate::workload::{KeyDist, KeyGen, OpKind, OpMix, ValueSize};

#[derive(Debug, Clone)]
pub struct LsmKvConfig {
    pub n_items: u64,
    /// Entries per data block (RocksDB 4 kB blocks / (key+value) bytes).
    pub keys_per_block: u32,
    /// Block cache capacity in blocks.
    pub cache_blocks: u32,
    /// Cache shards (RocksDB default 2^6).
    pub shards: u32,
    /// Hash buckets per shard.
    pub buckets_per_shard: u32,
    pub key_dist: KeyDist,
    pub mix: OpMix,
    pub value_size: ValueSize,
    /// CPU cost per pointer hop / key comparison.
    pub t_node: Dur,
    /// Memtable capacity (writes before a flush cycle is signalled).
    pub memtable_cap: u32,
    /// Run the background flush/compaction thread.
    pub compaction: bool,
}

impl Default for LsmKvConfig {
    fn default() -> Self {
        LsmKvConfig {
            // Paper: 1B items, 32 GB cache, Zipf 0.99, hit ratio 67%. Scaled:
            // cache_blocks / n_blocks tuned to land at the same hit ratio.
            n_items: 1_000_000,
            keys_per_block: 8,
            cache_blocks: 6_000,
            shards: 64,
            buckets_per_shard: 128,
            // Scrambled: hot ranks are hashed across the keyspace (YCSB /
            // db_bench behaviour), so hot keys land in *different* blocks
            // and cache shards rather than piling onto one shard lock.
            key_dist: KeyDist::Zipf {
                s: 0.99,
                scrambled: true,
            },
            mix: OpMix::READ_ONLY,
            value_size: ValueSize::Fixed(400),
            t_node: Dur::ns(100.0),
            memtable_cap: 4096,
            compaction: true,
        }
    }
}

/// One block-cache entry: intrusive hash chain + LRU links (secondary mem).
#[derive(Debug, Clone, Copy)]
struct CacheEntry {
    block: u32,
    hash_next: u32,
    lru_prev: u32,
    lru_next: u32,
    /// Entry currently valid (false = free slot awaiting reuse).
    live: bool,
}

/// One cache shard: bucket heads + LRU list head/tail.
#[derive(Debug, Clone)]
struct Shard {
    buckets: Vec<u32>,
    lru_head: u32, // most recent
    lru_tail: u32, // eviction candidate
    len: u32,
}

pub struct LsmKv {
    pub cfg: LsmKvConfig,
    keygen: KeyGen,
    shards: Vec<Shard>,
    entries: Vec<CacheEntry>,
    free: Vec<u32>,
    cap_per_shard: u32,
    /// Total number of data blocks in the (simulated) LSM keyspace.
    pub n_blocks: u32,
    /// Pending writes in the memtable.
    memtable_fill: u32,
    /// Flush backlog (memtable generations awaiting the background thread).
    flush_backlog: u32,
    pub stats: KvStats,
    bg_tid_floor: usize,
    bg_threads_per_core: usize,
}

#[derive(Debug)]
pub enum LsmOp {
    /// Probe the memtable (DRAM accesses), then go to the cache.
    Memtable { kind: OpKind, key: u64, probes: u8 },
    /// Walk the shard's hash chain looking for the block.
    ChainWalk {
        key: u64,
        entry: u32,
        first: bool,
    },
    /// Found in cache: splice the entry to the LRU head (3 dependent
    /// accesses: prev, next, head), then search inside the block.
    LruPromote { key: u64, entry: u32, hops: u8 },
    /// Cache miss: fetch the block from SSD.
    Fetch { key: u64 },
    /// Insert fetched block: evict tail if needed, link into bucket + LRU.
    Insert { key: u64, hops: u8 },
    /// Binary search over the block's restart array + final linear scan.
    InBlock {
        key: u64,
        lo: u32,
        hi: u32,
        compute_done: bool,
    },
    /// Write path: memtable insert (DRAM) + occasional flush signal.
    WriteMem { probes: u8 },
    /// Background flush/compaction bulk IO.
    BgFlush { ios_left: u8, write: bool },
    BgPause,
    BgYield,
    Finished,
}

impl LsmKv {
    pub fn new(cfg: LsmKvConfig, rng: &mut Rng) -> LsmKv {
        let n_blocks = ((cfg.n_items + cfg.keys_per_block as u64 - 1)
            / cfg.keys_per_block as u64) as u32;
        let shards = (0..cfg.shards)
            .map(|_| Shard {
                buckets: vec![NIL; cfg.buckets_per_shard as usize],
                lru_head: NIL,
                lru_tail: NIL,
                len: 0,
            })
            .collect();
        let cap = cfg.cache_blocks / cfg.shards;
        let keygen = KeyGen::new(cfg.n_items, cfg.key_dist);
        let mut kv = LsmKv {
            shards,
            entries: Vec::with_capacity(cfg.cache_blocks as usize),
            free: Vec::new(),
            cap_per_shard: cap.max(2),
            n_blocks,
            memtable_fill: 0,
            flush_backlog: 0,
            stats: KvStats::default(),
            bg_tid_floor: usize::MAX,
            bg_threads_per_core: 1,
            keygen,
            cfg,
        };
        // Warm the cache with draws from the workload distribution so the
        // measured window starts near steady state (the paper warms up for
        // hours; we warm structurally and then still run a sim warmup).
        let mut wrng = rng.fork(0x15a);
        let draws = kv.cfg.cache_blocks as u64 * 4;
        for _ in 0..draws {
            let key = kv.keygen.sample(&mut wrng);
            let block = kv.block_of(key);
            if kv.cache_lookup(block).is_none() {
                kv.cache_insert(block);
            }
        }
        kv
    }

    pub fn with_background(mut self, threads_per_core: usize) -> LsmKv {
        if self.cfg.compaction && self.cfg.mix.read_ratio < 1.0 {
            self.bg_tid_floor = threads_per_core - 1;
            self.bg_threads_per_core = threads_per_core;
        }
        self
    }

    fn is_bg(&self, tid: usize) -> bool {
        self.bg_tid_floor != usize::MAX && tid % self.bg_threads_per_core == self.bg_tid_floor
    }

    #[inline]
    fn block_of(&self, key: u64) -> u32 {
        (key / self.cfg.keys_per_block as u64) as u32
    }

    #[inline]
    fn shard_of(&self, block: u32) -> usize {
        (fnv1a(block as u64) % self.cfg.shards as u64) as usize
    }

    #[inline]
    fn bucket_of(&self, block: u32) -> usize {
        ((fnv1a(block as u64) >> 8) % self.cfg.buckets_per_shard as u64) as usize
    }

    /// Pure lookup (no timing): entry id if cached.
    fn cache_lookup(&self, block: u32) -> Option<u32> {
        let s = &self.shards[self.shard_of(block)];
        let mut cur = s.buckets[self.bucket_of(block)];
        while cur != NIL {
            let e = &self.entries[cur as usize];
            if e.live && e.block == block {
                return Some(cur);
            }
            cur = e.hash_next;
        }
        None
    }

    /// Unlink from LRU list (structure mutation only).
    fn lru_unlink(&mut self, sid: usize, id: u32) {
        let e = self.entries[id as usize];
        if e.lru_prev != NIL {
            self.entries[e.lru_prev as usize].lru_next = e.lru_next;
        } else {
            self.shards[sid].lru_head = e.lru_next;
        }
        if e.lru_next != NIL {
            self.entries[e.lru_next as usize].lru_prev = e.lru_prev;
        } else {
            self.shards[sid].lru_tail = e.lru_prev;
        }
    }

    fn lru_push_front(&mut self, sid: usize, id: u32) {
        let head = self.shards[sid].lru_head;
        self.entries[id as usize].lru_prev = NIL;
        self.entries[id as usize].lru_next = head;
        if head != NIL {
            self.entries[head as usize].lru_prev = id;
        } else {
            self.shards[sid].lru_tail = id;
        }
        self.shards[sid].lru_head = id;
    }

    fn bucket_remove(&mut self, sid: usize, id: u32) {
        let block = self.entries[id as usize].block;
        let b = self.bucket_of(block);
        let mut cur = self.shards[sid].buckets[b];
        if cur == id {
            self.shards[sid].buckets[b] = self.entries[id as usize].hash_next;
            return;
        }
        while cur != NIL {
            let next = self.entries[cur as usize].hash_next;
            if next == id {
                self.entries[cur as usize].hash_next = self.entries[id as usize].hash_next;
                return;
            }
            cur = next;
        }
        debug_assert!(false, "entry not in its bucket");
    }

    /// Insert a block (evicting if full); returns (entry, evicted?).
    fn cache_insert(&mut self, block: u32) -> (u32, bool) {
        let sid = self.shard_of(block);
        let mut evicted = false;
        if self.shards[sid].len >= self.cap_per_shard {
            let tail = self.shards[sid].lru_tail;
            debug_assert_ne!(tail, NIL);
            self.lru_unlink(sid, tail);
            self.bucket_remove(sid, tail);
            self.entries[tail as usize].live = false;
            self.free.push(tail);
            self.shards[sid].len -= 1;
            evicted = true;
        }
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.entries.push(CacheEntry {
                    block: 0,
                    hash_next: NIL,
                    lru_prev: NIL,
                    lru_next: NIL,
                    live: false,
                });
                (self.entries.len() - 1) as u32
            }
        };
        let b = self.bucket_of(block);
        let head = self.shards[sid].buckets[b];
        self.entries[id as usize] = CacheEntry {
            block,
            hash_next: head,
            lru_prev: NIL,
            lru_next: NIL,
            live: true,
        };
        self.shards[sid].buckets[b] = id;
        self.lru_push_front(sid, id);
        self.shards[sid].len += 1;
        (id, evicted)
    }

    /// Measured cache hit ratio over the metrics window.
    pub fn hit_ratio(&self) -> f64 {
        self.stats.hit_ratio()
    }

    fn lock_of(&self, block: u32) -> u32 {
        (self.shard_of(block) as u32) % 64
    }
}

impl Service for LsmKv {
    type Op = LsmOp;

    fn next_op(&mut self, tid: usize, rng: &mut Rng) -> LsmOp {
        if self.is_bg(tid) {
            if self.flush_backlog > 0 {
                self.flush_backlog -= 1;
                return LsmOp::BgFlush {
                    ios_left: 8,
                    write: false,
                };
            }
            return LsmOp::BgPause;
        }
        let key = self.keygen.sample(rng);
        match self.cfg.mix.sample(rng) {
            OpKind::Read => {
                self.stats.gets += 1;
                LsmOp::Memtable {
                    kind: OpKind::Read,
                    key,
                    probes: 3,
                }
            }
            OpKind::Write => {
                self.stats.sets += 1;
                LsmOp::WriteMem { probes: 4 }
            }
        }
    }

    fn step(&mut self, _tid: usize, op: &mut LsmOp, _rng: &mut Rng) -> Step {
        match op {
            LsmOp::Memtable { kind, key, probes } => {
                // Skiplist probe in host DRAM: inline accesses, no yield.
                if *probes > 0 {
                    *probes -= 1;
                    return Step::MemAccess(Tier::Dram);
                }
                debug_assert_eq!(*kind, OpKind::Read);
                let k = *key;
                let block = self.block_of(k);
                let sid = self.shard_of(block);
                let first = self.shards[sid].buckets[self.bucket_of(block)];
                *op = LsmOp::ChainWalk {
                    key: k,
                    entry: first,
                    first: true,
                };
                Step::Compute(self.cfg.t_node)
            }
            LsmOp::ChainWalk { key, entry, first } => {
                let k = *key;
                let block = self.block_of(k);
                if *first {
                    // Reading the bucket head itself is one secondary access.
                    *first = false;
                    if *entry == NIL {
                        self.stats.misses += 1;
                        *op = LsmOp::Fetch { key: k };
                    }
                    return Step::MemAccess(Tier::Secondary);
                }
                let id = *entry;
                if id == NIL {
                    self.stats.misses += 1;
                    *op = LsmOp::Fetch { key: k };
                    return Step::Compute(self.cfg.t_node);
                }
                let e = self.entries[id as usize];
                if e.live && e.block == block {
                    self.stats.hits += 1;
                    self.stats.t1_hits += 1;
                    // Neighbor read happens unlocked; only the splice runs
                    // under the shard lock (holding a lock across
                    // prefetch+yield accesses would make hold time grow
                    // with memory latency and serialize hot shards).
                    *op = LsmOp::LruPromote {
                        key: k,
                        entry: id,
                        hops: 0,
                    };
                    return Step::MemAccess(Tier::Secondary);
                }
                *entry = e.hash_next;
                if *entry == NIL {
                    self.stats.misses += 1;
                    *op = LsmOp::Fetch { key: k };
                    return Step::Compute(self.cfg.t_node);
                }
                Step::MemAccess(Tier::Secondary)
            }
            LsmOp::LruPromote { key, entry, hops } => {
                let k = *key;
                let block = self.block_of(k);
                match *hops {
                    0 => {
                        *hops = 1;
                        Step::Lock(self.lock_of(block))
                    }
                    1 => {
                        // Splice under the lock: the entry and neighbors were
                        // just read (unlocked), so the pointer writes hit the
                        // CPU cache — charge compute, not a long-latency
                        // access, and release quickly.
                        *hops = 2;
                        let sid = self.shard_of(block);
                        let id = *entry;
                        self.lru_unlink(sid, id);
                        self.lru_push_front(sid, id);
                        Step::Compute(self.cfg.t_node)
                    }
                    _ => {
                        *op = LsmOp::InBlock {
                            key: k,
                            lo: block * self.cfg.keys_per_block,
                            hi: (block + 1) * self.cfg.keys_per_block,
                            compute_done: false,
                        };
                        Step::Unlock(self.lock_of(block))
                    }
                }
            }
            LsmOp::Fetch { key } => {
                let k = *key;
                *op = LsmOp::Insert { key: k, hops: 0 };
                Step::Io {
                    kind: IoKind::Read,
                    bytes: self.cfg.keys_per_block
                        * (self.cfg.value_size.mean() as u32 + 20 + 8),
                    // Calibrated to RocksDB's measured per-read CPU cost:
                    // block-handle resolution + file offset (pre), CRC32 of
                    // the 4 kB block, decompression stub, and block-object
                    // construction (post).
                    extra_pre: Dur::us(1.5),
                    extra_post: Dur::us(3.0),
                }
            }
            LsmOp::Insert { key, hops } => {
                let k = *key;
                let block = self.block_of(k);
                // Eviction-candidate walk (3 accesses) runs unlocked; the
                // lock covers only the final structural mutation.
                if *hops < 3 {
                    *hops += 1;
                    return Step::MemAccess(Tier::Secondary);
                }
                if *hops == 3 {
                    *hops = 4;
                    return Step::Lock(self.lock_of(block));
                }
                if *hops == 4 {
                    *hops = 5;
                    if self.cache_lookup(block).is_none() {
                        self.cache_insert(block);
                    }
                    // Mutation writes hit lines brought in by the unlocked
                    // walk: short critical section.
                    return Step::Compute(self.cfg.t_node * 2);
                }
                *op = LsmOp::InBlock {
                    key: k,
                    lo: block * self.cfg.keys_per_block,
                    hi: (block + 1) * self.cfg.keys_per_block,
                    compute_done: false,
                };
                Step::Unlock(self.lock_of(block))
            }
            LsmOp::InBlock {
                key,
                lo,
                hi,
                compute_done,
            } => {
                // RocksDB block layout: binary-search the restart array
                // (blocks this small have ~2 restart points), then scan one
                // restart interval. Each probe = compute + secondary access.
                if !*compute_done {
                    *compute_done = true;
                    return Step::Compute(self.cfg.t_node);
                }
                *compute_done = false;
                let width = *hi - *lo;
                if width <= self.cfg.keys_per_block / 2 {
                    // Within one restart interval: single sequential scan
                    // access resolves the entry (length-prefixed entries in
                    // adjacent lines).
                    debug_assert!((*lo..*hi).contains(&(*key as u32)));
                    self.stats.verified += 1;
                    *op = LsmOp::Finished;
                    return Step::MemAccess(Tier::Secondary);
                }
                let mid = (*lo + *hi) / 2;
                if (*key as u32) < mid {
                    *hi = mid;
                } else {
                    *lo = mid;
                }
                Step::MemAccess(Tier::Secondary)
            }
            LsmOp::WriteMem { probes } => {
                // Memtable skiplist insert: DRAM accesses only.
                if *probes > 0 {
                    *probes -= 1;
                    return Step::MemAccess(Tier::Dram);
                }
                self.memtable_fill += 1;
                if self.memtable_fill >= self.cfg.memtable_cap {
                    self.memtable_fill = 0;
                    self.flush_backlog += 1;
                }
                *op = LsmOp::Finished;
                Step::Compute(Dur::ns(150.0)) // WAL append (buffered)
            }
            LsmOp::BgFlush { ios_left, write } => {
                self.stats.bg_ops += 1;
                if *ios_left == 0 {
                    *op = LsmOp::Finished;
                    return Step::Compute(Dur::us(1.0));
                }
                *ios_left -= 1;
                let kind = if *write {
                    IoKind::Write
                } else {
                    IoKind::Read
                };
                *write = !*write;
                Step::Io {
                    kind,
                    bytes: 32 * 1024, // bulk compaction IO
                    extra_pre: Dur::ns(500.0),
                    extra_post: Dur::us(2.0), // merge work
                }
            }
            LsmOp::BgPause => {
                // Pace, then cooperatively yield (see treekv::DefragPause).
                *op = LsmOp::BgYield;
                Step::Compute(Dur::us(5.0))
            }
            LsmOp::BgYield => {
                *op = LsmOp::Finished;
                Step::Yield
            }
            LsmOp::Finished => Step::Done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Machine, MachineConfig, MemConfig};

    fn small_cfg() -> LsmKvConfig {
        LsmKvConfig {
            n_items: 100_000,
            cache_blocks: 1024,
            shards: 16,
            buckets_per_shard: 64,
            ..Default::default()
        }
    }

    #[test]
    fn cache_structure_invariants() {
        let mut rng = Rng::new(1);
        let mut kv = LsmKv::new(small_cfg(), &mut rng);
        // Insert many blocks; shard lengths never exceed capacity and
        // lookups find exactly what was inserted last.
        for b in 0..5000u32 {
            if kv.cache_lookup(b).is_none() {
                kv.cache_insert(b);
            }
        }
        for s in &kv.shards {
            assert!(s.len <= kv.cap_per_shard);
            // LRU list length == shard len.
            let mut cur = s.lru_head;
            let mut cnt = 0;
            let mut prev = NIL;
            while cur != NIL {
                assert_eq!(kv.entries[cur as usize].lru_prev, prev);
                prev = cur;
                cur = kv.entries[cur as usize].lru_next;
                cnt += 1;
                assert!(cnt <= s.len, "LRU list longer than shard");
            }
            assert_eq!(cnt, s.len);
            assert_eq!(s.lru_tail, prev);
        }
    }

    #[test]
    fn eviction_is_lru_order() {
        let mut rng = Rng::new(2);
        let mut kv = LsmKv::new(
            LsmKvConfig {
                cache_blocks: 32,
                shards: 1,
                buckets_per_shard: 16,
                ..small_cfg()
            },
            &mut rng,
        );
        // Clear warmup state by filling with known blocks.
        for b in 1000..1032u32 {
            if kv.cache_lookup(b).is_none() {
                kv.cache_insert(b);
            }
        }
        // 1000 is now the tail (oldest of ours) unless warmup left newer.
        // Insert one more: some block must be evicted and it must not be
        // the most recently inserted.
        kv.cache_insert(2000);
        assert!(kv.cache_lookup(2000).is_some());
        assert!(kv.cache_lookup(1031).is_some(), "MRU must survive");
    }

    #[test]
    fn zipf_hit_ratio_in_paper_range() {
        let mut rng = Rng::new(3);
        let kv = LsmKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                mem: MemConfig::fpga(Dur::us(1.0)),
                ..Default::default()
            },
            kv,
        );
        let _ = m.run(Dur::ms(5.0), Dur::ms(20.0));
        let hr = m.service.hit_ratio();
        // Paper: 67% with Zipf 0.99 and a 32/400 GB cache. Our scaled cache
        // (1024*8 / 100k ≈ 8% of keys) under Zipf 0.99 lands nearby.
        assert!((0.5..0.85).contains(&hr), "hit ratio {hr}");
        assert_eq!(m.service.stats.corruptions, 0);
        assert!(m.service.stats.verified > 500);
    }

    #[test]
    fn misses_cause_io_and_s_below_one() {
        let mut rng = Rng::new(4);
        let kv = LsmKv::new(small_cfg(), &mut rng);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(5.0), Dur::ms(20.0));
        assert!(st.mean_s > 0.05 && st.mean_s < 0.9, "S = {}", st.mean_s);
        assert!(st.io_reads > 100);
        // M per op: bucket walk + LRU + in-block ≈ 6-12.
        assert!((4.0..15.0).contains(&st.mean_m), "M = {}", st.mean_m);
    }

    #[test]
    fn write_mix_triggers_flushes() {
        let mut rng = Rng::new(5);
        let kv = LsmKv::new(
            LsmKvConfig {
                mix: OpMix::ratio(1, 1),
                memtable_cap: 256,
                ..small_cfg()
            },
            &mut rng,
        )
        .with_background(32);
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 64,
                ..Default::default()
            },
            kv,
        );
        let st = m.run(Dur::ms(5.0), Dur::ms(30.0));
        assert!(m.service.stats.sets > 1000);
        assert!(m.service.stats.bg_ops > 0, "compaction never ran");
        assert!(st.io_writes > 0);
    }
}
