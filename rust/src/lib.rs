//! # cxlkvs
//!
//! A reproduction of *"Analysis and Evaluation of Using Microsecond-Latency
//! Memory for In-Memory Indices and Caches in SSD-Based Key-Value Stores"*
//! (Proc. ACM Manag. Data 3(6), 2025, DOI 10.1145/3769759).
//!
//! The crate provides:
//!
//! - [`sim`] — a discrete-event simulator of the paper's testbed (cores with
//!   a depth-`P` prefetch queue, user-level threads, microsecond-latency
//!   memory with tail/bandwidth knobs, SSDs with bandwidth/IOPS caps).
//! - [`model`] — the paper's analytic throughput models (Eq 1–16), native.
//! - [`runtime`] — the PJRT runtime that loads the AOT-compiled JAX+Pallas
//!   implementation of the same models (`artifacts/*.hlo.txt`) and evaluates
//!   them in batch from Rust. Python never runs at experiment time.
//! - [`microbench`] — the paper's §4.1 microbenchmark (pointer chasing + IO).
//! - [`kvs`] — three SSD-based KV store designs mirroring the paper's
//!   modified Aerospike / RocksDB / CacheLib, built on the simulator. All
//!   three serve the **full operation surface**: point get/put plus
//!   `Delete` (BST unlink / LSM tombstone / cache invalidation), ordered
//!   `Scan` (sprig walk / merged iterator; documented no-op on the cache),
//!   and `ReadModifyWrite` — every traversal hop a simulated
//!   `MemAccess`/`Io` step routed through the first-class tier-placement
//!   layer ([`kvs::placement`]: hybrid DRAM/µs-memory placement over
//!   hotness-ranked structure classes, with DRAM-byte accounting).
//! - [`workload`] — key/value/operation generators (uniform, Zipf, Gaussian,
//!   hotset; read:write mixes; full-surface [`workload::OpWeights`]) and the
//!   six standard YCSB core-workload presets A–F ([`workload::ycsb`]).
//! - [`coordinator`] — the experiment registry and sweep runner that
//!   regenerates every figure and table in the paper's evaluation, plus the
//!   `ycsb` sweep (L_mem × workload A–F × store).

pub mod coordinator;
pub mod kvs;
pub mod microbench;
pub mod model;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod workload;
