//! cxlkvs CLI — run any of the paper's experiments from the command line.
//!
//! Usage:
//!   cxlkvs list
//!   cxlkvs run <experiment> [--fast]
//!   cxlkvs all [--fast]
//!
//! Experiments: fig3 fig10 fig11micro fig11kvs fig12 fig14 fig15 fig16
//!              fig17 fig18 table6 val1404 ycsb ssdscale modelcheck
//!              placement planner adaptive durability tenants ablation
//!              compress interference
//! (The offline image has no argument-parsing crate; parsing is by hand.)
//!
//! `modelcheck` validates the Θ_scan-extended analytic model against the
//! simulator for every store × YCSB workload × memory latency (and the
//! SSD-array axis in slow mode) and **exits non-zero** when any point
//! drifts outside the documented tolerance — CI gates on it. `placement`
//! sweeps the DRAM-budget axis (`kvs::placement`) and exits non-zero when
//! throughput or DRAM-byte accounting is non-monotone in the budget or the
//! split-hop model drifts outside the same bands. `planner` runs the
//! two-phase profile → replan → measure path and exits non-zero when the
//! measured-ranking placement loses more than the documented slack against
//! the static prior at equal DRAM budget, when no discriminator workload
//! (lsmkv-E / cachekv-A) actually re-ranks, or when the replanned model
//! drifts outside the modelcheck bands. `adaptive` races online
//! hysteresis replanning against static and offline-replanned placements
//! across drifting (phased) schedules and exits non-zero when the online
//! arm loses more than the documented slack after a workload turn, or when
//! the designed adapting cell (cachekv × diurnal) never actually replans.
//! `durability` drills crash–recovery on every store's WAL, gates the WAL's
//! measured throughput overhead against the extended model's log-traffic
//! terms, requires group commit to beat per-op commit at equal durability,
//! and injects a transient SSD error window to check retry/backoff keeps
//! goodput with bounded p99 while a no-retry control errors out.
//! `tenants` multiplexes a point-read tenant against a scan-heavy noisy
//! neighbor on one shared store/SSD/DRAM budget and exits non-zero when the
//! point tenant's p99 leaves the documented isolation band versus its solo
//! baseline, a per-tenant latency lane is empty or non-monotone, or the
//! completed-ops split drifts from the scheduler's weight ratio.
//! `ablation` pits random residency against the hotness-ranked knapsack at
//! equal DRAM bytes (with an Eq 15 ρ-interpolation overlay column) and
//! exits non-zero when the ranked arm loses beyond the slack, the treekv
//! discriminator never separates, the arms' byte accounting diverges, or
//! the split-hop model drifts outside its bands. `compress` sweeps budget ×
//! L_mem × compression ratio through the joint placement×compression
//! planner and exits non-zero unless the model-predicted crossover shows up
//! in the simulator: compressed arms win (within slack) at tight budgets
//! and long latencies, forced compression loses where there is nothing to
//! buy, the joint plan folds to the uncompressed plan bit-identically at a
//! loose budget, the ratio-1.0 passthrough is bit-identical to compression
//! off, and the t_cpu-extended Eq 14 stays within its documented band.
//! `interference` drives lsmkv compaction storms against YCSB A foreground
//! traffic under the fg/bg bandwidth-sharing policies and exits non-zero
//! unless the storm depresses foreground throughput on shared servers, the
//! device's per-class lanes match the store's own flush/compaction byte
//! ledger exactly, the idle arm is background-free, `Cap{0.5}` recovers a
//! documented fraction of the foreground IO-p99 inflation (monotone in the
//! cap), and the Eq 14 interference term holds its documented band.

use cxlkvs::coordinator::experiments::{self, ModelBackend};

const EXPERIMENTS: &[&str] = &[
    "fig3", "fig10", "fig11micro", "fig11kvs", "fig12", "fig14", "fig15", "fig16", "fig17",
    "fig18", "table6", "val1404", "ycsb", "ssdscale", "modelcheck", "placement", "planner",
    "adaptive", "durability", "tenants", "ablation", "compress", "interference",
];

fn run_one(name: &str, backend: &mut ModelBackend, fast: bool) -> bool {
    match name {
        "fig3" => experiments::fig03(backend).print(),
        "fig10" => experiments::fig10(fast).iter().for_each(|r| r.print()),
        "fig11micro" => experiments::fig11_micro(backend, fast)
            .iter()
            .for_each(|r| r.print()),
        "fig11kvs" => experiments::fig11_kvs(backend, fast)
            .iter()
            .for_each(|r| r.print()),
        "fig12" => experiments::fig12(backend, fast).iter().for_each(|r| r.print()),
        "fig14" => experiments::fig14(fast).iter().for_each(|r| r.print()),
        "fig15" => experiments::fig15(fast).print(),
        "fig16" => experiments::fig16(fast).print(),
        "fig17" => experiments::fig17(fast).print(),
        "fig18" => experiments::fig18(fast).print(),
        "table6" => experiments::table6(fast).print(),
        "val1404" => experiments::val1404(backend, fast).print(),
        "ycsb" => experiments::ycsb_sweep(fast).print(),
        "ssdscale" => experiments::ssd_scaling(backend, fast).print(),
        "modelcheck" => {
            let (r, ok) = experiments::modelcheck(fast);
            r.print();
            if !ok {
                eprintln!(
                    "modelcheck: model-vs-simulator drift exceeded the documented \
                     tolerance (see err% vs tol% columns)"
                );
                std::process::exit(1);
            }
        }
        "placement" => {
            let (r, ok) = experiments::placement(fast);
            r.print();
            if !ok {
                eprintln!(
                    "placement: a DRAM-budget gate failed (non-monotone throughput \
                     or bytes, or model drift — see the GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        "planner" => {
            let (r, ok) = experiments::planner(fast);
            r.print();
            if !ok {
                eprintln!(
                    "planner: a measured-placement gate failed (measured worse than \
                     static beyond the slack, no discriminator re-rank, or replanned \
                     model drift — see the GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        "adaptive" => {
            let (r, ok) = experiments::adaptive(fast);
            r.print();
            if !ok {
                eprintln!(
                    "adaptive: an online-replanning gate failed (online worse than \
                     the best frozen arm beyond the slack after a turn, or the \
                     designed adapting cell never replanned — see the GATE FAILED \
                     notes)"
                );
                std::process::exit(1);
            }
        }
        "durability" => {
            let (r, ok) = experiments::durability(fast);
            r.print();
            if !ok {
                eprintln!(
                    "durability: a WAL/fault gate failed (crash-recovery invariant, \
                     acked-durability, WAL overhead outside the model band, group \
                     commit not beating per-op, or unbounded faulted p99 — see the \
                     GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        "tenants" => {
            let (r, ok) = experiments::tenants(fast);
            r.print();
            if !ok {
                eprintln!(
                    "tenants: a multi-tenant gate failed (point-tenant p99 outside \
                     the isolation band vs its solo baseline, an empty/non-monotone \
                     tenant latency lane, or completed-ops share off the weight \
                     ratio — see the GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        "ablation" => {
            let (r, ok) = experiments::ablation(fast);
            r.print();
            if !ok {
                eprintln!(
                    "ablation: a placement-ablation gate failed (ranked placement \
                     lost to random at equal bytes, the treekv discriminator never \
                     separated, byte accounting diverged between arms, or model \
                     drift — see the GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        "compress" => {
            let (r, ok) = experiments::compress(fast);
            r.print();
            if !ok {
                eprintln!(
                    "compress: a compression-crossover gate failed (no compressed \
                     win at tight budget/long L_mem, forced compression beating \
                     uncompressed with nothing to buy, joint plan not folding to \
                     off at a loose budget, ratio-1.0 passthrough not bit-identical, \
                     or t_cpu-extended model drift — see the GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        "interference" => {
            let (r, ok) = experiments::interference(fast);
            r.print();
            if !ok {
                eprintln!(
                    "interference: a traffic-class gate failed (no storm bite on \
                     shared servers, device lanes not matching the store's \
                     flush/compaction ledger, background IO in the idle arm, cap \
                     not recovering fg io_p99 or non-monotone, or Eq 14 \
                     interference-term drift — see the GATE FAILED notes)"
                );
                std::process::exit(1);
            }
        }
        _ => return false,
    }
    true
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast")
        || cxlkvs::coordinator::runner::fast_mode();
    let cmd = args.first().map(String::as_str).unwrap_or("help");

    match cmd {
        "list" => {
            println!("experiments:");
            for e in EXPERIMENTS {
                println!("  {e}");
            }
        }
        "run" => {
            let name = args.get(1).map(String::as_str).unwrap_or("");
            let mut backend = ModelBackend::auto();
            eprintln!("model backend: {}", backend.name());
            if !run_one(name, &mut backend, fast) {
                eprintln!("unknown experiment '{name}'; try `cxlkvs list`");
                std::process::exit(2);
            }
        }
        "all" => {
            let mut backend = ModelBackend::auto();
            eprintln!("model backend: {}", backend.name());
            for e in EXPERIMENTS {
                eprintln!(">> {e}");
                run_one(e, &mut backend, fast);
            }
        }
        _ => {
            println!("usage: cxlkvs list | run <experiment> [--fast] | all [--fast]");
            std::process::exit(if cmd == "help" { 0 } else { 2 });
        }
    }
}
