//! Virtual time for the discrete-event testbed.
//!
//! All simulated time is kept in integer **picoseconds** so that event ordering
//! is exact and runs are bit-reproducible. The paper's quantities span ~50 ns
//! (context switch) to ~50 µs (tail latency), so picoseconds give >4 decimal
//! digits of headroom on the smallest quantity while `u64` still allows ~200
//! days of simulated time.

use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// An instant in simulated time (picoseconds since simulation start).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Time(pub u64);

/// A span of simulated time (picoseconds).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Dur(pub u64);

pub const PS_PER_NS: u64 = 1_000;
pub const PS_PER_US: u64 = 1_000_000;
pub const PS_PER_MS: u64 = 1_000_000_000;
pub const PS_PER_S: u64 = 1_000_000_000_000;

impl Dur {
    pub const ZERO: Dur = Dur(0);

    /// Construct from nanoseconds (fractional allowed; rounded to ps).
    #[inline]
    pub fn ns(v: f64) -> Dur {
        Dur((v * PS_PER_NS as f64).round() as u64)
    }
    /// Construct from microseconds.
    #[inline]
    pub fn us(v: f64) -> Dur {
        Dur((v * PS_PER_US as f64).round() as u64)
    }
    /// Construct from milliseconds.
    #[inline]
    pub fn ms(v: f64) -> Dur {
        Dur((v * PS_PER_MS as f64).round() as u64)
    }
    /// Construct from seconds.
    #[inline]
    pub fn secs(v: f64) -> Dur {
        Dur((v * PS_PER_S as f64).round() as u64)
    }
    #[inline]
    pub fn as_ns(self) -> f64 {
        self.0 as f64 / PS_PER_NS as f64
    }
    #[inline]
    pub fn as_us(self) -> f64 {
        self.0 as f64 / PS_PER_US as f64
    }
    #[inline]
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / PS_PER_S as f64
    }
    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 == 0
    }
    #[inline]
    pub fn saturating_sub(self, rhs: Dur) -> Dur {
        Dur(self.0.saturating_sub(rhs.0))
    }
}

impl Time {
    pub const ZERO: Time = Time(0);

    #[inline]
    pub fn as_us(self) -> f64 {
        self.0 as f64 / PS_PER_US as f64
    }
    #[inline]
    pub fn as_secs(self) -> f64 {
        self.0 as f64 / PS_PER_S as f64
    }
    /// Span since an earlier instant (saturating: returns ZERO if `earlier` is later).
    #[inline]
    pub fn since(self, earlier: Time) -> Dur {
        Dur(self.0.saturating_sub(earlier.0))
    }
}

impl Add<Dur> for Time {
    type Output = Time;
    #[inline]
    fn add(self, rhs: Dur) -> Time {
        Time(self.0 + rhs.0)
    }
}
impl AddAssign<Dur> for Time {
    #[inline]
    fn add_assign(&mut self, rhs: Dur) {
        self.0 += rhs.0;
    }
}
impl Sub<Time> for Time {
    type Output = Dur;
    #[inline]
    fn sub(self, rhs: Time) -> Dur {
        Dur(self.0 - rhs.0)
    }
}
impl Add<Dur> for Dur {
    type Output = Dur;
    #[inline]
    fn add(self, rhs: Dur) -> Dur {
        Dur(self.0 + rhs.0)
    }
}
impl AddAssign<Dur> for Dur {
    #[inline]
    fn add_assign(&mut self, rhs: Dur) {
        self.0 += rhs.0;
    }
}
impl Sub<Dur> for Dur {
    type Output = Dur;
    #[inline]
    fn sub(self, rhs: Dur) -> Dur {
        Dur(self.0 - rhs.0)
    }
}
impl SubAssign<Dur> for Dur {
    #[inline]
    fn sub_assign(&mut self, rhs: Dur) {
        self.0 -= rhs.0;
    }
}
impl Mul<u64> for Dur {
    type Output = Dur;
    #[inline]
    fn mul(self, rhs: u64) -> Dur {
        Dur(self.0 * rhs)
    }
}
impl Div<u64> for Dur {
    type Output = Dur;
    #[inline]
    fn div(self, rhs: u64) -> Dur {
        Dur(self.0 / rhs)
    }
}

impl fmt::Display for Dur {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= PS_PER_MS {
            write!(f, "{:.3}ms", self.0 as f64 / PS_PER_MS as f64)
        } else if self.0 >= PS_PER_US {
            write!(f, "{:.3}us", self.0 as f64 / PS_PER_US as f64)
        } else {
            write!(f, "{:.1}ns", self.0 as f64 / PS_PER_NS as f64)
        }
    }
}
impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t+{}", Dur(self.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_round_trip() {
        assert_eq!(Dur::ns(1.0).0, 1_000);
        assert_eq!(Dur::us(1.0).0, 1_000_000);
        assert_eq!(Dur::ms(1.0).0, 1_000_000_000);
        assert_eq!(Dur::secs(1.0).0, 1_000_000_000_000);
        assert!((Dur::us(0.05).as_ns() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn arithmetic() {
        let t = Time::ZERO + Dur::us(1.0);
        assert_eq!((t + Dur::ns(500.0)) - t, Dur::ns(500.0));
        assert_eq!(Dur::us(2.0) / 4, Dur::ns(500.0));
        assert_eq!(Dur::ns(100.0) * 3, Dur::ns(300.0));
    }

    #[test]
    fn since_saturates() {
        let a = Time(100);
        let b = Time(50);
        assert_eq!(b.since(a), Dur::ZERO);
        assert_eq!(a.since(b), Dur(50));
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(format!("{}", Dur::ns(50.0)), "50.0ns");
        assert_eq!(format!("{}", Dur::us(5.0)), "5.000us");
    }
}
