//! Deterministic pseudo-random number generation for the testbed.
//!
//! The offline build has no `rand` crate, so we implement xoshiro256++ seeded
//! via SplitMix64 (the reference construction from Blackman & Vigna). Every
//! simulated run takes an explicit seed, making all experiments reproducible
//! bit-for-bit.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-core / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0. Uses Lemire's method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply-shift; slight modulo bias is < 2^-64, irrelevant here.
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi].
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (used by the Gaussian key distribution).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 0.0 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher-Yates shuffle of a u32 slice (used to permute pointer chains).
    pub fn shuffle_u32(&mut self, xs: &mut [u32]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..1000).collect();
        r.shuffle_u32(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<u32>>());
        assert_ne!(xs, (0..1000).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }
}
