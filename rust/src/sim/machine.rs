//! The simulated testbed machine: cores, user-level threads, prefetch queues,
//! the CPU cache, locks, one secondary-memory device, and a sharded SSD
//! array (`n_ssd` independent devices; each `Step::Io` carries a shard
//! route — see `sim::ssd`).
//!
//! This is the substitute for the paper's Xeon + FPGA-CXL + Optane testbed
//! (DESIGN.md §2). It implements the *mechanisms* the paper's model
//! approximates — run-to-yield user-level threads with context-switch cost
//! `T_sw`, a per-core prefetch queue of depth `P` whose entries complete
//! `L_mem` after they start, core stalls on not-yet-arrived lines, premature
//! cache eviction, asynchronous IO with pre/post CPU suboperations — so that
//! comparing simulator measurements against the analytic model is the same
//! experiment the paper runs against its hardware.
//!
//! ## Execution semantics (one "slice")
//!
//! A core repeatedly pops the front of its FIFO ready queue and runs that
//! thread until it yields. Steps a thread's state machine can request:
//!
//! - `Compute(d)`       — core busy for `d`; no yield.
//! - `MemAccess(Dram)`  — inline load (~`L_DRAM`); no yield.
//! - `MemAccess(Secondary)` — issue a prefetch (subject to the depth-`P`
//!   queue and the device bandwidth server), charge `T_sw`, yield to the back
//!   of the ready queue. When rescheduled, the load completes: if the line
//!   has not arrived the core *stalls* until it does (Fig 5's gray bars); if
//!   the line was prematurely evicted (ε path) the core performs a fresh
//!   synchronous fetch.
//! - `Io{..}`           — charge `T_IO_pre` (+ any extra), submit to the SSD,
//!   charge `T_sw`, block until the completion event; when rescheduled charge
//!   `T_sw` (the model's second switch in `E`) + `T_IO_post` (+ extra).
//! - `Lock(id)`/`Unlock(id)` — FIFO mutex; contended acquires block.
//! - `Done`             — operation complete; the service supplies the next
//!   operation and the thread continues within the same slice.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use super::mem::{MemConfig, MemDevice};
use super::metrics::{CoreBreakdown, Metrics};
use super::rng::Rng;
use super::ssd::{IoError, IoKind, SsdArray, SsdConfig, TrafficClass, N_TRAFFIC_LANES};
use super::time::{Dur, Time};

/// Which memory a (simulated) pointer dereference goes to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Host DRAM — short inline latency, no prefetch+yield needed.
    Dram,
    /// Secondary (microsecond-latency) memory — prefetch+yield path.
    Secondary,
}

/// One suboperation requested by an operation state machine.
#[derive(Debug, Clone, Copy)]
pub enum Step {
    /// CPU-only work.
    Compute(Dur),
    /// One dependent memory access (pointer chase hop).
    MemAccess(Tier),
    /// One asynchronous IO. `extra_pre`/`extra_post` are CPU work attributed
    /// to the IO suboperations beyond the device's configured `t_pre`/`t_post`
    /// (the microbenchmark's +1/+2 µs variations; block parsing in KV stores).
    /// `shard` is the placement key routing the IO to one device of the SSD
    /// array (value-log block / SSTable id / slab hash — see `sim::ssd`);
    /// with a single-device array every value routes to device 0.
    /// `class` tags the IO foreground or background for the SSD's
    /// bandwidth-sharing policy and the per-class accounting lanes; under
    /// the default `BgShare::None` it is pure accounting (bit-identical
    /// timing — see `sim::ssd`).
    Io {
        kind: IoKind,
        bytes: u32,
        extra_pre: Dur,
        extra_post: Dur,
        shard: u64,
        class: TrafficClass,
    },
    /// Acquire a simulated lock (FIFO; blocks if held).
    Lock(u32),
    /// Release a simulated lock.
    Unlock(u32),
    /// Cooperative yield (T_sw, back of the ready queue) without a memory
    /// access — used by background workers' pacing loops.
    Yield,
    /// Operation finished.
    Done,
}

/// A workload/service drives each thread's operations. The service owns the
/// real data structures (pointer chains, trees, caches); the machine owns
/// all timing.
pub trait Service {
    /// Per-thread operation state machine.
    type Op;
    /// Create the next operation for a thread.
    fn next_op(&mut self, tid: usize, rng: &mut Rng) -> Self::Op;
    /// Advance the operation; called repeatedly until `Step::Done`.
    fn step(&mut self, tid: usize, op: &mut Self::Op, rng: &mut Rng) -> Step;
    /// Notification that the op's outstanding IO completed (deliver data).
    fn io_done(&mut self, _tid: usize, _op: &mut Self::Op) {}
    /// Notification that the op's outstanding IO failed permanently — all
    /// retries exhausted, or the device is dead with no replica route. The
    /// service should surface a per-op error and finish the op rather than
    /// wedge (see `kvs::common::KvStats::failed_ops`).
    fn io_failed(&mut self, _tid: usize, _op: &mut Self::Op) {}
    /// Which tenant owns thread `tid`'s in-flight op, if the service is
    /// multi-tenant (see `workload::tenants`). Queried by the machine at
    /// `Step::Done` so `Metrics` can account the op to a per-tenant lane;
    /// `None` (the default, and background workers' answer) records the op
    /// globally only.
    fn op_tenant(&self, _tid: usize) -> Option<u32> {
        None
    }
}

/// IO retry policy: on a transient device error the machine resubmits the
/// IO after a capped exponential backoff, charging the whole ladder as
/// elapsed IO wait (the thread stays parked; latency and p99 pay for the
/// robustness). `RetryPolicy::none()` is the no-retry control arm: the
/// first error is final and `Service::io_failed` fires.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Max resubmissions after the first failed attempt.
    pub max_retries: u32,
    /// Backoff before retry k is `backoff_base << k`, capped below.
    pub backoff_base: Dur,
    pub backoff_cap: Dur,
}

impl RetryPolicy {
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            backoff_base: Dur::ZERO,
            backoff_cap: Dur::ZERO,
        }
    }

    /// Backoff before the (attempt+1)-th resubmission (attempt is 0-based).
    pub fn backoff(&self, attempt: u32) -> Dur {
        let mult = 1u64 << attempt.min(20);
        Dur(self.backoff_base.0.saturating_mul(mult).min(self.backoff_cap.0))
    }

    /// Total wait budget across a full retry ladder (for sizing fault
    /// windows and p99 bounds in experiments).
    pub fn total_backoff(&self) -> Dur {
        let mut sum = Dur::ZERO;
        for k in 0..self.max_retries {
            sum += self.backoff(k);
        }
        sum
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        // 6 retries at 20us doubling, capped at 640us: ~1.26ms of total
        // backoff — enough to ride out a sub-millisecond error window.
        RetryPolicy {
            max_retries: 6,
            backoff_base: Dur::us(20.0),
            backoff_cap: Dur::us(640.0),
        }
    }
}

/// Machine configuration (the Table 2/Table 3 knobs).
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub cores: usize,
    pub threads_per_core: usize,
    /// Prefetch queue depth P per core (paper measures P=12 on the Xeon).
    pub prefetch_depth: usize,
    /// Context switch time of the user-level threads.
    pub t_sw: Dur,
    /// Inline DRAM access latency.
    pub dram_latency: Dur,
    /// CPU cache capacity in lines for prefetched-data survival. A line is
    /// prematurely evicted if at least this many later line-fills happened
    /// before it is consumed (LRU approximation; see DESIGN.md §6).
    pub cache_lines: u64,
    /// Secondary memory device.
    pub mem: MemConfig,
    /// SSD (array).
    pub ssd: SsdConfig,
    /// Number of simulated locks available to the service.
    pub n_locks: usize,
    /// Per-extra-core inflation of Compute durations, modeling cross-core
    /// cache/coherence contention (κ; Fig 14's sublinear scaling).
    pub contention_factor: f64,
    /// Charge `T_sw` when a thread resumes from IO wait (the model's `2 T_sw`
    /// per IO in Eq 6). Default true.
    pub charge_resume_switch: bool,
    /// Transient-IO-error retry policy (only exercised when an SSD
    /// `FaultPlan` is configured; the fault-free path never consults it).
    pub retry: RetryPolicy,
    /// RNG seed.
    pub seed: u64,
}

impl Default for MachineConfig {
    fn default() -> Self {
        MachineConfig {
            cores: 1,
            threads_per_core: 48,
            prefetch_depth: 12,
            t_sw: Dur::ns(50.0),
            dram_latency: Dur::ns(90.0),
            cache_lines: 1_000_000, // ~60 MB L3 / 64 B
            mem: MemConfig::fpga(Dur::us(5.0)),
            ssd: SsdConfig::optane_array(),
            n_locks: 0,
            contention_factor: 0.0,
            charge_resume_switch: true,
            retry: RetryPolicy::default(),
            seed: 0x5eed,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ThreadState {
    Ready,
    WaitIo,
    WaitLock,
}

/// A pending prefetched line to be consumed at the thread's next slice.
#[derive(Debug, Clone, Copy)]
struct PendingLine {
    ready_at: Time,
    /// Core line-fill sequence number at issue (for the eviction check).
    seq: u64,
}

struct ThreadSlot<Op> {
    core: usize,
    state: ThreadState,
    op: Option<Op>,
    pending: Option<PendingLine>,
    /// Charge post-IO CPU time at next slice start.
    resume_post_io: Option<Dur>,
    /// Bytes of the outstanding IO (its DMA pollutes the CPU cache on
    /// completion, DDIO-style — counted as line fills for the ε model).
    pending_io_bytes: u32,
    // Per-op measurement state.
    op_start: Time,
    op_mem_accesses: u32,
    op_ios: u32,
    op_compute: Dur,
}

struct Core {
    time: Time,
    ready: VecDeque<usize>,
    /// Completion times of in-flight prefetches (FIFO, ≤ P entries).
    pf_ring: VecDeque<Time>,
    /// Line-fill sequence counter (prefetch issues).
    fetch_seq: u64,
    breakdown: CoreBreakdown,
}

#[derive(Debug, Default)]
struct SimLock {
    holder: Option<usize>,
    waiters: VecDeque<usize>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum EventKind {
    /// IO resolution for a thread; the flag is success (false = the IO
    /// failed permanently and `Service::io_failed` fires on delivery).
    IoDone(usize, bool),
    LockGrant(usize),
}

/// The simulated machine, generic over the service (workload/KV store).
pub struct Machine<S: Service> {
    pub cfg: MachineConfig,
    pub service: S,
    pub mem: MemDevice,
    pub ssd: SsdArray,
    pub metrics: Metrics,
    threads: Vec<ThreadSlot<S::Op>>,
    cores: Vec<Core>,
    locks: Vec<SimLock>,
    events: BinaryHeap<Reverse<(Time, u64, EventKind)>>,
    event_seq: u64,
    rng: Rng,
    /// Compute-duration multiplier from cross-core contention (fixed-point /1024).
    contention_mul_1024: u64,
}

impl<S: Service> Machine<S> {
    pub fn new(cfg: MachineConfig, service: S) -> Machine<S> {
        let mut rng = Rng::new(cfg.seed);
        let n_threads = cfg.cores * cfg.threads_per_core;
        let mut threads = Vec::with_capacity(n_threads);
        let mut cores = Vec::with_capacity(cfg.cores);
        for c in 0..cfg.cores {
            let mut ready = VecDeque::with_capacity(cfg.threads_per_core);
            for i in 0..cfg.threads_per_core {
                ready.push_back(c * cfg.threads_per_core + i);
            }
            cores.push(Core {
                // Stagger core start times slightly to avoid artificial lockstep.
                time: Time::ZERO + Dur(rng.below(1000) * 100),
                ready,
                pf_ring: VecDeque::with_capacity(cfg.prefetch_depth),
                fetch_seq: 0,
                breakdown: CoreBreakdown::default(),
            });
        }
        for c in 0..cfg.cores {
            for _ in 0..cfg.threads_per_core {
                threads.push(ThreadSlot {
                    core: c,
                    state: ThreadState::Ready,
                    op: None,
                    pending: None,
                    resume_post_io: None,
                    pending_io_bytes: 0,
                    op_start: Time::ZERO,
                    op_mem_accesses: 0,
                    op_ios: 0,
                    op_compute: Dur::ZERO,
                });
            }
        }
        let contention_mul_1024 =
            (1024.0 * (1.0 + cfg.contention_factor * (cfg.cores as f64 - 1.0))) as u64;
        let locks = (0..cfg.n_locks).map(|_| SimLock::default()).collect();
        Machine {
            mem: MemDevice::new(cfg.mem.clone()),
            ssd: SsdArray::new(cfg.ssd.clone()),
            metrics: Metrics::new(cfg.cores),
            threads,
            cores,
            locks,
            events: BinaryHeap::new(),
            event_seq: 0,
            rng,
            contention_mul_1024,
            cfg,
            service,
        }
    }

    /// Simulated time = max over cores (for reporting).
    pub fn now(&self) -> Time {
        // Fast path: the dominant single-core sweeps skip the iterator.
        if self.cores.len() == 1 {
            return self.cores[0].time;
        }
        self.cores.iter().map(|c| c.time).max().unwrap_or(Time::ZERO)
    }

    #[inline]
    fn push_event(&mut self, t: Time, k: EventKind) {
        self.event_seq += 1;
        self.events.push(Reverse((t, self.event_seq, k)));
    }

    /// Run a measurement: `warmup` of untimed execution, then reset counters
    /// and run `window`; metrics then describe the window only.
    pub fn run(&mut self, warmup: Dur, window: Dur) -> RunStats {
        let t0 = self.now();
        self.run_until(t0 + warmup);
        self.start_window(window);
        let w_end = self.metrics.window_end;
        self.run_until(w_end);
        self.window_stats(window)
    }

    /// Open a measurement window at the current simulated time: reset every
    /// counter and mark the window bounds. Drive it with `run_until(t)` —
    /// one call or several slices (the adaptive replan loop runs
    /// epoch-sized slices and inspects the service between them); slicing
    /// is observationally identical to one long `run_until`, so a sliced
    /// window with no intervening mutation reproduces `run` bit-for-bit.
    pub fn start_window(&mut self, window: Dur) {
        self.metrics.reset();
        self.mem.reset_stats();
        self.ssd.reset_stats();
        let w_start = self.now();
        self.metrics.window_start = w_start;
        self.metrics.window_end = w_start + window;
    }

    /// Summarize the window opened by [`Machine::start_window`] — exactly
    /// the [`RunStats`] that [`Machine::run`] would have returned.
    pub fn window_stats(&self, window: Dur) -> RunStats {
        RunStats::from_metrics(&self.metrics, window, &self.mem, &self.ssd)
    }

    /// Charge a replan's migration traffic as simulated work (see
    /// `kvs::placement`, "Online replanning"): every re-tiered 64-byte line
    /// costs a read from its old tier plus a write to its new tier —
    /// `secondary_lines` of them touch the secondary device, `dram_lines`
    /// are inline DRAM touches — and cache contents whose flip moved them
    /// across the SSD shard route cost `refill_reads` value reads of
    /// `io_bytes` each.
    ///
    /// Cost model: the secondary-line copy streams through the device as a
    /// pipelined loop — successive transfers issue one per
    /// `max(T_sw + L_dram, L_mem/P)` (the CPU side of the copy vs. the
    /// prefetch-depth wall), and the copy completes when the last line
    /// lands. This prices the copy even on a device with an unthrottled
    /// bandwidth server, where back-to-back same-instant transfers would
    /// otherwise all complete after one latency. The migration is
    /// stop-the-world: every core's clock advances to the copy's end,
    /// attributed to the stall breakdown — so a thrashing planner pays for
    /// every flip inside its measurement window. Returns the stall.
    pub fn charge_migration(
        &mut self,
        dram_lines: u32,
        secondary_lines: u32,
        refill_reads: u32,
        io_bytes: u32,
    ) -> Dur {
        let t0 = self.now();
        let mut done = t0;
        let cpu = self.cfg.t_sw + self.cfg.dram_latency;
        let wall = Dur(self.mem.cfg.mean_latency().0 / self.cfg.prefetch_depth.max(1) as u64);
        let gap = if cpu >= wall { cpu } else { wall };
        for i in 0..secondary_lines as u64 {
            let d = self.mem.transfer(t0 + Dur(gap.0 * i), &mut self.rng);
            done = done.max(d);
        }
        done = done.max(t0 + Dur(self.cfg.dram_latency.0 * dram_lines as u64));
        for i in 0..refill_reads as u64 {
            let d = self.ssd.submit(
                t0,
                i,
                IoKind::Read,
                TrafficClass::Foreground,
                io_bytes,
                &mut self.rng,
            );
            done = done.max(d);
        }
        self.metrics.dram_accesses += dram_lines as u64;
        self.metrics.secondary_accesses += secondary_lines as u64;
        self.metrics.ios += refill_reads as u64;
        for c in self.cores.iter_mut() {
            if c.time < done {
                c.breakdown.stall += done - c.time;
                c.time = done;
            }
        }
        done - t0
    }

    /// Advance the simulation until every core's local clock reaches `t_end`.
    ///
    /// Scheduling rule (unchanged from the seed implementation): run the
    /// runnable core with the smallest local clock (lowest index wins ties),
    /// delivering any pending event that is strictly earlier first.
    ///
    /// Perf: the seed rescanned all cores before *every* slice. A slice only
    /// mutates its own core's clock/ready queue — other cores change solely
    /// through event delivery — so once a core is chosen it remains the
    /// scheduler's pick until its clock crosses the cached next-best bound
    /// or an event comes due. The inner loop below keeps running that core
    /// with O(1) checks (one event peek) per slice and only falls back to
    /// the O(cores) rescan when the cached choice is invalidated.
    pub fn run_until(&mut self, t_end: Time) {
        loop {
            // Rescan: earliest runnable core (lowest index wins ties).
            let mut best_core: Option<(Time, usize)> = None;
            for (i, c) in self.cores.iter().enumerate() {
                if !c.ready.is_empty() {
                    match best_core {
                        Some((t, _)) if t <= c.time => {}
                        _ => best_core = Some((c.time, i)),
                    }
                }
            }
            let ev_time = self.events.peek().map(|Reverse((t, _, _))| *t);
            let ci = match (best_core, ev_time) {
                (None, None) => break, // fully quiescent
                (None, Some(et)) => {
                    if et >= t_end {
                        break;
                    }
                    self.deliver_event();
                    continue;
                }
                (Some((ct, ci)), et_opt) => {
                    if let Some(et) = et_opt {
                        if et < ct {
                            if et >= t_end {
                                break;
                            }
                            self.deliver_event();
                            continue;
                        }
                    }
                    if ct >= t_end {
                        break;
                    }
                    ci
                }
            };
            // Bounds under which `ci` stays the pick without rescanning:
            // strictly below every lower-index runnable core (they win
            // ties), at-or-below every higher-index one (we win ties).
            // Slices on `ci` cannot change other cores' clocks or wake
            // their threads (only events do), so the bounds stay valid for
            // the whole inner loop.
            let mut bound_lo = Time(u64::MAX);
            let mut bound_hi = Time(u64::MAX);
            for (j, c) in self.cores.iter().enumerate() {
                if j == ci || c.ready.is_empty() {
                    continue;
                }
                if j < ci {
                    bound_lo = bound_lo.min(c.time);
                } else {
                    bound_hi = bound_hi.min(c.time);
                }
            }
            self.run_slice(ci);
            loop {
                let c = &self.cores[ci];
                if c.ready.is_empty() {
                    break;
                }
                let ct = c.time;
                if ct >= t_end || ct >= bound_lo || ct > bound_hi {
                    break;
                }
                if let Some(Reverse((et, _, _))) = self.events.peek() {
                    if *et < ct {
                        break;
                    }
                }
                self.run_slice(ci);
            }
        }
    }

    fn deliver_event(&mut self) {
        let Reverse((t, _, kind)) = self.events.pop().unwrap();
        match kind {
            EventKind::IoDone(tid, ok) => {
                let op = self.threads[tid].op.as_mut().unwrap();
                if ok {
                    self.service.io_done(tid, op);
                    // IO DMA lands in the LLC (DDIO): its lines push
                    // prefetched data toward eviction.
                    let lines = (self.threads[tid].pending_io_bytes / 64) as u64;
                    let core_id = self.threads[tid].core;
                    self.cores[core_id].fetch_seq += lines;
                } else {
                    // No data arrived; no DDIO fill. The service surfaces
                    // the error and finishes the op.
                    self.service.io_failed(tid, op);
                }
                self.make_ready(tid, t);
            }
            EventKind::LockGrant(tid) => {
                self.make_ready(tid, t);
            }
        }
    }

    fn make_ready(&mut self, tid: usize, t: Time) {
        let core_id = self.threads[tid].core;
        let core = &mut self.cores[core_id];
        self.threads[tid].state = ThreadState::Ready;
        if core.ready.is_empty() && core.time < t {
            core.breakdown.idle += t - core.time;
            core.time = t;
        }
        core.ready.push_back(tid);
    }

    #[inline]
    fn scaled(&self, d: Dur) -> Dur {
        if self.contention_mul_1024 == 1024 {
            d
        } else {
            Dur(d.0 * self.contention_mul_1024 / 1024)
        }
    }

    /// Run one thread until it yields.
    fn run_slice(&mut self, core_id: usize) {
        let tid = self.cores[core_id].ready.pop_front().unwrap();
        debug_assert_eq!(self.threads[tid].state, ThreadState::Ready);

        // 1. Consume a pending prefetched line, if any.
        if let Some(p) = self.threads[tid].pending.take() {
            let core = &mut self.cores[core_id];
            let evicted = core.fetch_seq - p.seq >= self.cfg.cache_lines;
            if evicted {
                // ε path: the prefetched line is gone; synchronous demand fetch.
                let done = self.mem.transfer(core.time, &mut self.rng);
                let wait = done - core.time;
                core.breakdown.stall += wait;
                core.time = done;
                self.metrics.load_wait.record(wait);
                self.metrics.evictions += 1;
                self.metrics.loads += 1;
            } else if p.ready_at > core.time {
                // Late prefetch (queue-depth limited): stall until arrival.
                let wait = p.ready_at - core.time;
                core.breakdown.stall += wait;
                core.time = p.ready_at;
                self.metrics.load_wait.record(wait);
                self.metrics.loads += 1;
            } else {
                // Cache hit — the common case the whole scheme exists for.
                self.metrics.load_wait.record(Dur::ZERO);
                self.metrics.loads += 1;
            }
        }

        // 2. Charge post-IO CPU time if resuming from IO.
        if let Some(post) = self.threads[tid].resume_post_io.take() {
            let mut d = self.scaled(post);
            let core = &mut self.cores[core_id];
            if self.cfg.charge_resume_switch {
                d += self.cfg.t_sw;
            }
            core.time += d;
            core.breakdown.busy += d;
            self.threads[tid].op_compute += post;
        }

        // 3. Run steps until the thread yields.
        loop {
            if self.threads[tid].op.is_none() {
                let op = self.service.next_op(tid, &mut self.rng);
                let th = &mut self.threads[tid];
                th.op = Some(op);
                th.op_start = self.cores[core_id].time;
                th.op_mem_accesses = 0;
                th.op_ios = 0;
                th.op_compute = Dur::ZERO;
            }
            let step = {
                let th = &mut self.threads[tid];
                self.service.step(tid, th.op.as_mut().unwrap(), &mut self.rng)
            };
            match step {
                Step::Compute(d) => {
                    let dd = self.scaled(d);
                    let core = &mut self.cores[core_id];
                    core.time += dd;
                    core.breakdown.busy += dd;
                    self.threads[tid].op_compute += d;
                }
                Step::MemAccess(Tier::Dram) => {
                    let core = &mut self.cores[core_id];
                    core.time += self.cfg.dram_latency;
                    core.breakdown.busy += self.cfg.dram_latency;
                    self.metrics.dram_accesses += 1;
                    // Inline access: no yield; continue the slice.
                }
                Step::MemAccess(Tier::Secondary) => {
                    let core = &mut self.cores[core_id];
                    // Prefetch queue depth P: if full, the new prefetch starts
                    // only when the oldest in-flight one completes.
                    let start = if core.pf_ring.len() >= self.cfg.prefetch_depth {
                        let oldest = core.pf_ring.pop_front().unwrap();
                        oldest.max(core.time)
                    } else {
                        core.time
                    };
                    let completion = self.mem.transfer(start, &mut self.rng);
                    core.pf_ring.push_back(completion);
                    core.fetch_seq += 1;
                    let seq = core.fetch_seq;
                    // Yield: charge T_sw, go to the back of the ready queue.
                    core.time += self.cfg.t_sw;
                    core.breakdown.busy += self.cfg.t_sw;
                    core.ready.push_back(tid);
                    let th = &mut self.threads[tid];
                    th.pending = Some(PendingLine {
                        ready_at: completion,
                        seq,
                    });
                    th.op_mem_accesses += 1;
                    self.metrics.secondary_accesses += 1;
                    return;
                }
                Step::Io {
                    kind,
                    bytes,
                    extra_pre,
                    extra_post,
                    shard,
                    class,
                } => {
                    let t_pre = self.scaled(self.cfg.ssd.t_pre + extra_pre);
                    let core = &mut self.cores[core_id];
                    core.time += t_pre;
                    core.breakdown.busy += t_pre;
                    let submit = core.time;
                    let mut comp =
                        self.ssd
                            .submit_checked(submit, shard, kind, class, bytes, &mut self.rng);
                    // Transient errors: resubmit after capped exponential
                    // backoff. The whole ladder resolves synchronously at
                    // submit time (the device model is a time function) but
                    // is charged as elapsed IO wait — the thread stays
                    // parked until the final attempt's resolution, so
                    // retries inflate io_latency/p99 exactly like a real
                    // driver's requeue path. A fault-free array never
                    // returns an error, leaving this path cold.
                    if comp.error.is_some() {
                        let pol = self.cfg.retry;
                        let mut attempt = 0u32;
                        while comp.error == Some(IoError::Transient) && attempt < pol.max_retries {
                            let resubmit = comp.at + pol.backoff(attempt);
                            attempt += 1;
                            self.metrics.io_retries += 1;
                            comp = self.ssd.submit_checked(
                                resubmit, shard, kind, class, bytes, &mut self.rng,
                            );
                        }
                        if comp.error.is_some() {
                            self.metrics.io_errors += 1;
                        }
                    }
                    let completion = comp.at;
                    // Yield: T_sw, block until completion.
                    let core = &mut self.cores[core_id];
                    core.time += self.cfg.t_sw;
                    core.breakdown.busy += self.cfg.t_sw;
                    let th = &mut self.threads[tid];
                    th.state = ThreadState::WaitIo;
                    th.resume_post_io = Some(self.cfg.ssd.t_post + extra_post);
                    th.pending_io_bytes = bytes;
                    th.op_ios += 1;
                    th.op_compute += self.cfg.ssd.t_pre + extra_pre;
                    self.metrics.ios += 1;
                    self.metrics.io_latency.record(completion - submit);
                    self.metrics.class_io_latency[class.lane()].record(completion - submit);
                    self.push_event(completion, EventKind::IoDone(tid, comp.error.is_none()));
                    return;
                }
                Step::Lock(id) => {
                    let lock = &mut self.locks[id as usize];
                    match lock.holder {
                        None => {
                            lock.holder = Some(tid);
                            self.metrics.lock_acquires += 1;
                        }
                        Some(h) => {
                            debug_assert_ne!(h, tid, "recursive lock");
                            lock.waiters.push_back(tid);
                            let core = &mut self.cores[core_id];
                            core.time += self.cfg.t_sw;
                            core.breakdown.busy += self.cfg.t_sw;
                            self.threads[tid].state = ThreadState::WaitLock;
                            self.metrics.lock_contended += 1;
                            return;
                        }
                    }
                }
                Step::Unlock(id) => {
                    let now = self.cores[core_id].time;
                    let lock = &mut self.locks[id as usize];
                    debug_assert_eq!(lock.holder, Some(tid), "unlock by non-holder");
                    if let Some(next) = lock.waiters.pop_front() {
                        lock.holder = Some(next);
                        self.metrics.lock_acquires += 1;
                        self.push_event(now, EventKind::LockGrant(next));
                    } else {
                        lock.holder = None;
                    }
                }
                Step::Yield => {
                    let core = &mut self.cores[core_id];
                    core.time += self.cfg.t_sw;
                    core.breakdown.busy += self.cfg.t_sw;
                    core.ready.push_back(tid);
                    return;
                }
                Step::Done => {
                    let now = self.cores[core_id].time;
                    let tenant = self.service.op_tenant(tid);
                    let th = &mut self.threads[tid];
                    self.metrics.record_op(
                        now,
                        now - th.op_start,
                        th.op_mem_accesses,
                        th.op_ios,
                        th.op_compute,
                        tenant,
                    );
                    th.op = None;
                    // Continue in the same slice: the next op's first memory
                    // access or IO will yield naturally.
                }
            }
        }
    }

    /// Per-core busy/stall/idle breakdown (for reports and perf analysis).
    pub fn breakdowns(&self) -> Vec<CoreBreakdown> {
        self.cores.iter().map(|c| c.breakdown.clone()).collect()
    }

    /// The current window's raw counters (read-only; tests use this to
    /// check the per-tenant accounting invariant against the globals).
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

/// Summary of one measurement window.
#[derive(Debug, Clone)]
pub struct RunStats {
    /// Operations completed per second of simulated time.
    pub ops_per_sec: f64,
    pub ops: u64,
    /// Mean KV-op latency and quantiles (p999 is meaningful because the
    /// histogram interpolates within buckets and reports the observed max
    /// for the clamped top bucket — see `sim::hist`).
    pub op_latency_mean: Dur,
    pub op_latency_p50: Dur,
    pub op_latency_p99: Dur,
    pub op_latency_p999: Dur,
    /// Mean secondary-memory accesses per op (the measured M_sec).
    pub mean_m: f64,
    /// Mean inline DRAM accesses per op (the measured M_dram of the
    /// tier-placement split; window-wide `dram_accesses / ops`, so
    /// background threads' accesses are included).
    pub mean_m_dram: f64,
    /// Mean IOs per op (the measured S).
    pub mean_s: f64,
    /// Mean compute time per op (→ T_mem estimation).
    pub mean_compute: Dur,
    /// Premature-eviction ratio ε (evictions / secondary loads).
    pub eviction_ratio: f64,
    /// Load-wait distribution (Fig 10).
    pub load_wait_mean: Dur,
    pub load_wait_p99: Dur,
    /// IO statistics.
    pub io_reads: u64,
    pub io_writes: u64,
    pub io_bytes: u64,
    /// Fault-injection statistics: transient-error resubmissions and IOs
    /// that failed permanently (both zero on a fault-free array).
    pub io_retries: u64,
    pub io_errors: u64,
    /// Lock contention ratio.
    pub lock_contention: f64,
    /// Per-tenant lanes, indexed by tenant id (empty on the single-tenant
    /// path — names live in the tenant set, not the machine).
    pub tenants: Vec<TenantStats>,
    /// Per-traffic-class IO lanes in `TrafficClass::lane()` order (fg,
    /// compaction, flush, defrag, wal). Device-side counters are
    /// authoritative (they count every retry attempt); `io_p99` comes from
    /// the machine's per-class latency lanes (one record per `Step::Io`,
    /// including the whole retry ladder).
    pub io_classes: Vec<IoClassStats>,
}

/// One traffic class's slice of a measurement window's IO activity.
#[derive(Debug, Clone)]
pub struct IoClassStats {
    /// Lane name (`TrafficClass::lane_name`): fg / compaction / flush /
    /// defrag / wal.
    pub name: &'static str,
    /// Device-side IOs served for this class (retry attempts included).
    pub ios: u64,
    /// Device-side bytes transferred for this class.
    pub bytes: u64,
    /// Mean pre-service wait (queue depth + rate servers) per IO.
    pub queue_wait_mean: Dur,
    /// p99 of submit→completion latency as seen by the issuing thread.
    pub io_p99: Dur,
}

/// One tenant's slice of a measurement window (see `workload::tenants`).
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub ops: u64,
    pub ops_per_sec: f64,
    pub mean: Dur,
    pub p50: Dur,
    pub p99: Dur,
    pub p999: Dur,
}

impl RunStats {
    fn from_metrics(m: &Metrics, window: Dur, _mem: &MemDevice, ssd: &SsdArray) -> RunStats {
        let ops = m.ops;
        let secs = window.as_secs();
        // Every IO a store issues must be tagged: per-class device lanes sum
        // exactly to the device totals, or an untagged call site slipped in.
        ssd.check_flow_conservation();
        let class_ios = ssd.class_ios();
        let class_bytes = ssd.class_bytes();
        let class_wait = ssd.class_wait();
        let io_classes = (0..N_TRAFFIC_LANES)
            .map(|lane| IoClassStats {
                name: TrafficClass::lane_name(lane),
                ios: class_ios[lane],
                bytes: class_bytes[lane],
                queue_wait_mean: if class_ios[lane] > 0 {
                    Dur(class_wait[lane].0 / class_ios[lane])
                } else {
                    Dur::ZERO
                },
                io_p99: m.class_io_latency[lane].quantile(0.99),
            })
            .collect();
        RunStats {
            ops_per_sec: ops as f64 / secs,
            ops,
            op_latency_mean: m.op_latency.mean(),
            op_latency_p50: m.op_latency.quantile(0.5),
            op_latency_p99: m.op_latency.quantile(0.99),
            op_latency_p999: m.op_latency.quantile(0.999),
            mean_m: if ops > 0 {
                m.sum_mem_accesses as f64 / ops as f64
            } else {
                0.0
            },
            mean_m_dram: if ops > 0 {
                m.dram_accesses as f64 / ops as f64
            } else {
                0.0
            },
            mean_s: if ops > 0 {
                m.sum_ios as f64 / ops as f64
            } else {
                0.0
            },
            mean_compute: if ops > 0 {
                Dur(m.sum_compute.0 / ops)
            } else {
                Dur::ZERO
            },
            eviction_ratio: if m.loads > 0 {
                m.evictions as f64 / m.loads as f64
            } else {
                0.0
            },
            load_wait_mean: m.load_wait.mean(),
            load_wait_p99: m.load_wait.quantile(0.99),
            io_reads: ssd.reads(),
            io_writes: ssd.writes(),
            io_bytes: ssd.bytes(),
            io_retries: m.io_retries,
            io_errors: m.io_errors,
            lock_contention: if m.lock_acquires > 0 {
                m.lock_contended as f64 / m.lock_acquires as f64
            } else {
                0.0
            },
            tenants: m
                .tenant_ops
                .iter()
                .zip(&m.tenant_latency)
                .map(|(&ops, h)| TenantStats {
                    ops,
                    ops_per_sec: ops as f64 / secs,
                    mean: h.mean(),
                    p50: h.quantile(0.5),
                    p99: h.quantile(0.99),
                    p999: h.quantile(0.999),
                })
                .collect(),
            io_classes,
        }
    }
}

// `mem` is used for symmetry in from_metrics signatures today; keep the
// parameter so device-level stats can be surfaced without changing callers.
#[allow(dead_code)]
fn _use(_m: &MemDevice) {}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial service: fixed M memory accesses + one IO per op.
    struct FixedOps {
        m: u32,
        t_mem: Dur,
        tier: Tier,
    }
    #[derive(Debug)]
    struct FixedOp {
        left: u32,
        io_done: bool,
        compute_next: bool,
    }
    impl Service for FixedOps {
        type Op = FixedOp;
        fn next_op(&mut self, _tid: usize, _rng: &mut Rng) -> FixedOp {
            FixedOp {
                left: self.m,
                io_done: false,
                compute_next: true,
            }
        }
        fn step(&mut self, _tid: usize, op: &mut FixedOp, _rng: &mut Rng) -> Step {
            if op.left > 0 {
                if op.compute_next {
                    op.compute_next = false;
                    return Step::Compute(self.t_mem);
                }
                op.left -= 1;
                op.compute_next = true;
                return Step::MemAccess(self.tier);
            }
            if !op.io_done {
                op.io_done = true;
                return Step::Io {
                    kind: IoKind::Read,
                    bytes: 1536,
                    extra_pre: Dur::ZERO,
                    extra_post: Dur::ZERO,
                    shard: 0,
                    class: TrafficClass::Foreground,
                };
            }
            Step::Done
        }
    }

    fn base_cfg() -> MachineConfig {
        MachineConfig {
            threads_per_core: 48,
            mem: MemConfig::fpga(Dur::us(1.0)),
            ssd: SsdConfig {
                jitter_frac: 0.0, // exact timings for the arithmetic tests
                ..SsdConfig::optane_array()
            },
            ..MachineConfig::default()
        }
    }

    #[test]
    fn single_thread_single_op_timing() {
        // One thread, M=2, DRAM-tier accesses are inline: op time is
        // deterministic: 2*(T_mem + L_dram) + T_pre + L_IO + T_sw(yield)
        // + T_sw(resume) + T_post.
        let cfg = MachineConfig {
            threads_per_core: 1,
            ..base_cfg()
        };
        let mut m = Machine::new(
            cfg,
            FixedOps {
                m: 2,
                t_mem: Dur::ns(100.0),
                tier: Tier::Dram,
            },
        );
        let stats = m.run(Dur::ms(1.0), Dur::ms(10.0));
        // The submit-side T_sw overlaps the IO latency (the switch happens
        // while the IO is in flight), so op latency is:
        // 2(T_mem+L_dram) + T_pre + L_IO + T_sw(resume) + T_post.
        let expect = 2.0 * (0.1 + 0.09) + 1.5 + 10.0 + 0.05 + 0.2; // us
        let got = stats.op_latency_mean.as_us();
        assert!(
            (got - expect).abs() < 0.02,
            "op latency {got} vs expected {expect}"
        );
        assert!((stats.mean_m - 0.0).abs() < 1e-9); // DRAM accesses aren't "M"
        assert!((stats.mean_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn multithreading_hides_io_latency() {
        // Single-threaded: each op takes >11.6us (IO latency dominates).
        // 48 threads: IO latency is hidden; throughput approaches
        // 1/(M(T_mem+T_sw) + E) per core.
        let svc = || FixedOps {
            m: 10,
            t_mem: Dur::ns(100.0),
            tier: Tier::Secondary,
        };
        let mut single = Machine::new(
            MachineConfig {
                threads_per_core: 1,
                mem: MemConfig::fpga(Dur::ns(100.0)),
                ..base_cfg()
            },
            svc(),
        );
        let s1 = single.run(Dur::ms(1.0), Dur::ms(20.0));
        let mut multi = Machine::new(
            MachineConfig {
                threads_per_core: 64,
                mem: MemConfig::fpga(Dur::ns(100.0)),
                ..base_cfg()
            },
            svc(),
        );
        let sn = multi.run(Dur::ms(1.0), Dur::ms(20.0));
        assert!(
            sn.ops_per_sec > 4.0 * s1.ops_per_sec,
            "single={} multi={}",
            s1.ops_per_sec,
            sn.ops_per_sec
        );
        // Reciprocal throughput should be near M(T_mem+T_sw)+E
        // = 10*0.15 + (1.5+0.2+2*0.05) = 3.3 us
        // (plus small prefetch waits at 100ns latency: none).
        let recip_us = 1e6 / sn.ops_per_sec;
        assert!(
            (recip_us - 3.3).abs() < 0.3,
            "recip_us={recip_us} expected ~3.3"
        );
    }

    #[test]
    fn prefetch_depth_wall_appears_without_io() {
        // Memory-only service: no IO. At L=10us with P=12,
        // reciprocal >= L/P = 0.833us per access.
        struct MemOnly;
        impl Service for MemOnly {
            type Op = (u32, bool);
            fn next_op(&mut self, _t: usize, _r: &mut Rng) -> (u32, bool) {
                (1, true)
            }
            fn step(&mut self, _t: usize, op: &mut (u32, bool), _r: &mut Rng) -> Step {
                if op.1 {
                    op.1 = false;
                    return Step::Compute(Dur::ns(100.0));
                }
                if op.0 > 0 {
                    op.0 -= 1;
                    return Step::MemAccess(Tier::Secondary);
                }
                Step::Done
            }
        }
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 64,
                mem: MemConfig::fpga(Dur::us(10.0)),
                ..base_cfg()
            },
            MemOnly,
        );
        let st = m.run(Dur::ms(1.0), Dur::ms(20.0));
        let recip_us = 1e6 / st.ops_per_sec;
        // L/P = 10/12 = 0.833us; with T_mem+T_sw=0.15 the wall dominates.
        assert!(
            (recip_us - 10.0 / 12.0).abs() < 0.05,
            "recip_us={recip_us} expected ~0.833"
        );
        // And the load-wait histogram must show real stalls.
        assert!(st.load_wait_mean > Dur::ns(100.0));
    }

    #[test]
    fn eviction_ratio_rises_with_tiny_cache() {
        let svc = FixedOps {
            m: 10,
            t_mem: Dur::ns(100.0),
            tier: Tier::Secondary,
        };
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 64,
                cache_lines: 40, // smaller than the thread count
                mem: MemConfig::fpga(Dur::us(5.0)),
                ..base_cfg()
            },
            svc,
        );
        let st = m.run(Dur::ms(1.0), Dur::ms(10.0));
        assert!(
            st.eviction_ratio > 0.01,
            "eviction_ratio={}",
            st.eviction_ratio
        );
    }

    #[test]
    fn no_evictions_with_large_cache() {
        let svc = FixedOps {
            m: 10,
            t_mem: Dur::ns(100.0),
            tier: Tier::Secondary,
        };
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 64,
                mem: MemConfig::fpga(Dur::us(5.0)),
                ..base_cfg()
            },
            svc,
        );
        let st = m.run(Dur::ms(1.0), Dur::ms(10.0));
        assert_eq!(st.eviction_ratio, 0.0);
    }

    #[test]
    fn multicore_scales() {
        let svc = || FixedOps {
            m: 10,
            t_mem: Dur::ns(100.0),
            tier: Tier::Secondary,
        };
        let run = |cores: usize| {
            let mut m = Machine::new(
                MachineConfig {
                    cores,
                    threads_per_core: 48,
                    contention_factor: 0.025,
                    mem: MemConfig::fpga(Dur::us(5.0)),
                    ..base_cfg()
                },
                svc(),
            );
            m.run(Dur::ms(1.0), Dur::ms(10.0)).ops_per_sec
        };
        let t1 = run(1);
        let t4 = run(4);
        assert!(t4 > 3.0 * t1, "t1={t1} t4={t4}");
        assert!(t4 < 4.0 * t1, "contention should make scaling sublinear");
    }

    #[test]
    fn locks_serialize() {
        // Every op takes the same lock around its memory access; with many
        // threads, throughput should be far below the lock-free case.
        struct Locked;
        impl Service for Locked {
            type Op = u32; // 0=lock,1=mem,2=unlock,3=done
            fn next_op(&mut self, _t: usize, _r: &mut Rng) -> u32 {
                0
            }
            fn step(&mut self, _t: usize, op: &mut u32, _r: &mut Rng) -> Step {
                let s = *op;
                *op += 1;
                match s {
                    0 => Step::Lock(0),
                    1 => Step::MemAccess(Tier::Secondary),
                    2 => Step::Unlock(0),
                    _ => Step::Done,
                }
            }
        }
        let mut m = Machine::new(
            MachineConfig {
                threads_per_core: 32,
                n_locks: 1,
                mem: MemConfig::fpga(Dur::us(5.0)),
                ..base_cfg()
            },
            Locked,
        );
        let st = m.run(Dur::ms(1.0), Dur::ms(10.0));
        // Lock held across the 5us access: throughput ~1/5us = 200k ops/s.
        let recip_us = 1e6 / st.ops_per_sec;
        assert!(recip_us > 4.0, "recip_us={recip_us}: lock did not serialize");
        assert!(st.lock_contention > 0.5);
    }

    #[test]
    fn sliced_window_reproduces_run() {
        // start_window + repeated run_until + window_stats must be
        // bit-identical to one run() call: the adaptive loop's epoch
        // slicing (with no intervening mutation) is pure observation.
        let svc = || FixedOps {
            m: 5,
            t_mem: Dur::ns(120.0),
            tier: Tier::Secondary,
        };
        let mut a = Machine::new(base_cfg(), svc());
        let sa = a.run(Dur::ms(1.0), Dur::ms(6.0));
        let mut b = Machine::new(base_cfg(), svc());
        let t0 = b.now();
        b.run_until(t0 + Dur::ms(1.0));
        b.start_window(Dur::ms(6.0));
        let end = b.metrics.window_end;
        let mut t = b.now();
        while t < end {
            t = (t + Dur::ms(1.0)).min(end);
            b.run_until(t);
        }
        let sb = b.window_stats(Dur::ms(6.0));
        assert_eq!(sa.ops, sb.ops);
        assert_eq!(sa.op_latency_mean, sb.op_latency_mean);
        assert_eq!(sa.io_reads, sb.io_reads);
    }

    #[test]
    fn charge_migration_costs_time_and_counts() {
        let svc = FixedOps {
            m: 2,
            t_mem: Dur::ns(100.0),
            tier: Tier::Secondary,
        };
        let mut m = Machine::new(base_cfg(), svc);
        m.run(Dur::ms(1.0), Dur::ms(2.0));
        let before = m.now();
        // Nothing to migrate: free, clocks untouched.
        assert_eq!(m.charge_migration(0, 0, 0, 0), Dur::ZERO);
        assert_eq!(m.now(), before);
        // 1000 secondary lines at L_mem=1us, P=12: the pipelined copy is
        // gapped at max(T_sw + L_dram, L_mem/P) = max(140ns, 83ns) = 140ns,
        // so the copy takes ~ 999*140ns + 1us ≈ 141us of stop-the-world.
        let (s0, d0, i0) = (
            m.metrics.secondary_accesses,
            m.metrics.dram_accesses,
            m.metrics.ios,
        );
        let d = m.charge_migration(1000, 1000, 0, 0);
        assert!(
            d > Dur::us(100.0) && d < Dur::us(200.0),
            "migration stall {d}"
        );
        assert_eq!(m.now(), before + d, "stop-the-world advances the clocks");
        assert_eq!(m.metrics.secondary_accesses, s0 + 1000);
        assert_eq!(m.metrics.dram_accesses, d0 + 1000);
        // Refill reads land on the SSD stats (the window's io accounting).
        let r0 = m.ssd.reads();
        let d = m.charge_migration(0, 0, 8, 1536);
        assert!(d >= Dur::us(10.0), "an SSD read costs its latency: {d}");
        assert_eq!(m.ssd.reads(), r0 + 8);
        assert_eq!(m.metrics.ios, i0 + 8);
    }

    #[test]
    fn transient_fault_window_retries_and_recovers() {
        // A 300us full-fail window early in the window: with the default
        // retry policy every IO eventually succeeds (goodput > 0, no
        // permanent errors), and the retries show up in the metrics.
        use super::super::ssd::{ErrorWindow, FaultPlan};
        let plan = FaultPlan {
            error_windows: vec![ErrorWindow {
                from: Time::ZERO + Dur::ms(2.0),
                until: Time::ZERO + Dur::ms(2.3),
                prob: 1.0,
            }],
            ..FaultPlan::default()
        };
        let cfg = MachineConfig {
            threads_per_core: 8,
            ssd: SsdConfig {
                jitter_frac: 0.0,
                ..SsdConfig::optane_array()
            }
            .with_fault(0, plan),
            ..MachineConfig::default()
        };
        let mut m = Machine::new(
            cfg,
            FixedOps {
                m: 2,
                t_mem: Dur::ns(100.0),
                tier: Tier::Dram,
            },
        );
        let st = m.run(Dur::ms(1.0), Dur::ms(10.0));
        assert!(st.ops > 0, "goodput must survive the fault window");
        assert!(st.io_retries > 0, "the window must actually trigger retries");
        assert_eq!(st.io_errors, 0, "backoff outlasts the window: no failures");
    }

    #[test]
    fn no_retry_control_surfaces_errors() {
        // Same fault window, RetryPolicy::none(): the first error is final,
        // Service::io_failed fires, and ops finish with surfaced errors
        // instead of wedging.
        use super::super::ssd::{ErrorWindow, FaultPlan};
        struct Failing {
            failed: u64,
        }
        impl Service for Failing {
            type Op = FixedOp;
            fn next_op(&mut self, _tid: usize, _rng: &mut Rng) -> FixedOp {
                FixedOp {
                    left: 0,
                    io_done: false,
                    compute_next: false,
                }
            }
            fn step(&mut self, _tid: usize, op: &mut FixedOp, _rng: &mut Rng) -> Step {
                if !op.io_done {
                    op.io_done = true;
                    return Step::Io {
                        kind: IoKind::Read,
                        bytes: 1536,
                        extra_pre: Dur::ZERO,
                        extra_post: Dur::ZERO,
                        shard: 0,
                        class: TrafficClass::Foreground,
                    };
                }
                Step::Done
            }
            fn io_failed(&mut self, _tid: usize, _op: &mut FixedOp) {
                self.failed += 1;
            }
        }
        let plan = FaultPlan {
            error_windows: vec![ErrorWindow {
                from: Time::ZERO,
                until: Time::ZERO + Dur::secs(1.0),
                prob: 1.0,
            }],
            ..FaultPlan::default()
        };
        let cfg = MachineConfig {
            threads_per_core: 4,
            retry: RetryPolicy::none(),
            ssd: SsdConfig {
                jitter_frac: 0.0,
                ..SsdConfig::optane_array()
            }
            .with_fault(0, plan),
            ..MachineConfig::default()
        };
        let mut m = Machine::new(cfg, Failing { failed: 0 });
        let st = m.run(Dur::ms(1.0), Dur::ms(5.0));
        assert!(st.ops > 0, "ops must still complete (with surfaced errors)");
        assert_eq!(st.io_retries, 0);
        assert!(st.io_errors > 0, "every IO fails under prob=1.0 / no retry");
        assert!(m.service.failed > 0, "io_failed must be delivered");
    }

    #[test]
    fn deterministic_given_seed() {
        let svc = || FixedOps {
            m: 5,
            t_mem: Dur::ns(120.0),
            tier: Tier::Secondary,
        };
        let mut a = Machine::new(base_cfg(), svc());
        let mut b = Machine::new(base_cfg(), svc());
        let sa = a.run(Dur::ms(1.0), Dur::ms(5.0));
        let sb = b.run(Dur::ms(1.0), Dur::ms(5.0));
        assert_eq!(sa.ops, sb.ops);
        assert_eq!(sa.op_latency_mean, sb.op_latency_mean);
    }
}
