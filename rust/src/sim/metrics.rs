//! Measurement-window counters and histograms collected by the machine.

use super::hist::LatencyHist;
use super::ssd::N_TRAFFIC_LANES;
use super::time::{Dur, Time};

/// Per-core time breakdown (busy = useful CPU work incl. context switches,
/// stall = waiting for late prefetches / evicted lines, idle = no runnable
/// thread).
#[derive(Debug, Clone, Default)]
pub struct CoreBreakdown {
    pub busy: Dur,
    pub stall: Dur,
    pub idle: Dur,
}

/// Counters for one measurement window.
#[derive(Debug)]
pub struct Metrics {
    pub window_start: Time,
    pub window_end: Time,
    /// Completed operations.
    pub ops: u64,
    /// Secondary-memory accesses (prefetch+yield path) issued.
    pub secondary_accesses: u64,
    /// Inline DRAM accesses.
    pub dram_accesses: u64,
    /// Loads of prefetched lines (consumption events).
    pub loads: u64,
    /// Premature cache evictions observed at load time.
    pub evictions: u64,
    /// IOs issued.
    pub ios: u64,
    /// Transient-error resubmissions (fault injection; see `sim::ssd`).
    pub io_retries: u64,
    /// IOs that failed permanently (retries exhausted / device dead).
    pub io_errors: u64,
    /// Lock statistics.
    pub lock_acquires: u64,
    pub lock_contended: u64,
    /// Sum over completed ops (for measured model parameters).
    pub sum_mem_accesses: u64,
    pub sum_ios: u64,
    pub sum_compute: Dur,
    /// Distribution of load waits (Fig 10) — 0 means the prefetch fully hid
    /// the latency.
    pub load_wait: LatencyHist,
    /// Distribution of whole-operation latency (Fig 17).
    pub op_latency: LatencyHist,
    /// Distribution of device-side IO latency.
    pub io_latency: LatencyHist,
    /// Per-traffic-class IO-latency lanes (`TrafficClass::lane()` order:
    /// fg, compaction, flush, defrag, wal), same bucket layout as
    /// `io_latency` so lanes merge with it cleanly. Under `BgShare::None`
    /// the lanes are pure accounting; under `Cap`/`Weighted` they expose
    /// the per-class service-time split.
    pub class_io_latency: Vec<LatencyHist>,
    /// Per-tenant completed ops (indexed by tenant id; grown on demand —
    /// empty on the single-tenant path, where `record_op` sees no tenant).
    pub tenant_ops: Vec<u64>,
    /// Per-tenant op-latency histograms, same range as `op_latency` so
    /// they merge with it (and with each other) cleanly.
    pub tenant_latency: Vec<LatencyHist>,
    #[allow(dead_code)]
    cores: usize,
}

impl Metrics {
    pub fn new(cores: usize) -> Metrics {
        Metrics {
            window_start: Time::ZERO,
            window_end: Time::ZERO,
            ops: 0,
            secondary_accesses: 0,
            dram_accesses: 0,
            loads: 0,
            evictions: 0,
            ios: 0,
            io_retries: 0,
            io_errors: 0,
            lock_acquires: 0,
            lock_contended: 0,
            sum_mem_accesses: 0,
            sum_ios: 0,
            sum_compute: Dur::ZERO,
            load_wait: LatencyHist::new(),
            op_latency: Metrics::op_latency_hist(),
            io_latency: Metrics::io_latency_hist(),
            class_io_latency: (0..N_TRAFFIC_LANES)
                .map(|_| Metrics::io_latency_hist())
                .collect(),
            tenant_ops: Vec::new(),
            tenant_latency: Vec::new(),
            cores,
        }
    }

    /// The op-latency bucket layout (shared by the global and per-tenant
    /// histograms so `LatencyHist::merge`'s range check always passes).
    pub fn op_latency_hist() -> LatencyHist {
        LatencyHist::with_range(Dur::ns(10.0), Dur::ms(10.0), 160)
    }

    /// The IO-latency bucket layout (shared by the global histogram and the
    /// per-traffic-class lanes so `LatencyHist::merge` always accepts them).
    pub fn io_latency_hist() -> LatencyHist {
        LatencyHist::with_range(Dur::ns(100.0), Dur::ms(10.0), 120)
    }

    pub fn reset(&mut self) {
        let cores = self.cores;
        *self = Metrics::new(cores);
    }

    #[inline]
    pub fn record_op(
        &mut self,
        _now: Time,
        latency: Dur,
        mem_accesses: u32,
        ios: u32,
        compute: Dur,
        tenant: Option<u32>,
    ) {
        self.ops += 1;
        self.sum_mem_accesses += mem_accesses as u64;
        self.sum_ios += ios as u64;
        self.sum_compute += compute;
        self.op_latency.record(latency);
        if let Some(t) = tenant {
            let t = t as usize;
            if t >= self.tenant_ops.len() {
                self.tenant_ops.resize(t + 1, 0);
                self.tenant_latency.resize_with(t + 1, Metrics::op_latency_hist);
            }
            self.tenant_ops[t] += 1;
            self.tenant_latency[t].record(latency);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_reset() {
        let mut m = Metrics::new(2);
        m.record_op(Time::ZERO, Dur::us(3.0), 10, 1, Dur::us(1.0), None);
        m.record_op(Time::ZERO, Dur::us(5.0), 12, 2, Dur::us(1.2), None);
        assert_eq!(m.ops, 2);
        assert_eq!(m.sum_mem_accesses, 22);
        assert_eq!(m.sum_ios, 3);
        assert!(m.tenant_ops.is_empty());
        m.reset();
        assert_eq!(m.ops, 0);
        assert_eq!(m.op_latency.total(), 0);
    }

    #[test]
    fn class_io_lanes_merge_with_global() {
        let mut m = Metrics::new(1);
        assert_eq!(m.class_io_latency.len(), N_TRAFFIC_LANES);
        m.io_latency.record(Dur::us(12.0));
        m.class_io_latency[0].record(Dur::us(12.0));
        m.io_latency.record(Dur::us(40.0));
        m.class_io_latency[1].record(Dur::us(40.0));
        let mut merged = Metrics::io_latency_hist();
        for h in &m.class_io_latency {
            merged.merge(h); // same layout: must never panic
        }
        assert_eq!(merged.total(), m.io_latency.total());
        assert_eq!(merged.max(), m.io_latency.max());
        m.reset();
        assert!(m.class_io_latency.iter().all(|h| h.total() == 0));
    }

    #[test]
    fn per_tenant_lanes_sum_to_global() {
        let mut m = Metrics::new(1);
        m.record_op(Time::ZERO, Dur::us(3.0), 1, 0, Dur::ZERO, Some(1));
        m.record_op(Time::ZERO, Dur::us(5.0), 1, 0, Dur::ZERO, Some(0));
        m.record_op(Time::ZERO, Dur::us(7.0), 1, 0, Dur::ZERO, Some(1));
        // Untenanted (background) ops count globally but in no lane.
        m.record_op(Time::ZERO, Dur::us(9.0), 1, 0, Dur::ZERO, None);
        assert_eq!(m.tenant_ops, vec![1, 2]);
        assert_eq!(m.tenant_ops.iter().sum::<u64>() + 1, m.ops);
        let mut merged = Metrics::op_latency_hist();
        for h in &m.tenant_latency {
            merged.merge(h);
        }
        assert_eq!(merged.total(), 3);
        assert_eq!(merged.max(), Dur::us(7.0));
    }
}
