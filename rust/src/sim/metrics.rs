//! Measurement-window counters and histograms collected by the machine.

use super::hist::LatencyHist;
use super::time::{Dur, Time};

/// Per-core time breakdown (busy = useful CPU work incl. context switches,
/// stall = waiting for late prefetches / evicted lines, idle = no runnable
/// thread).
#[derive(Debug, Clone, Default)]
pub struct CoreBreakdown {
    pub busy: Dur,
    pub stall: Dur,
    pub idle: Dur,
}

/// Counters for one measurement window.
#[derive(Debug)]
pub struct Metrics {
    pub window_start: Time,
    pub window_end: Time,
    /// Completed operations.
    pub ops: u64,
    /// Secondary-memory accesses (prefetch+yield path) issued.
    pub secondary_accesses: u64,
    /// Inline DRAM accesses.
    pub dram_accesses: u64,
    /// Loads of prefetched lines (consumption events).
    pub loads: u64,
    /// Premature cache evictions observed at load time.
    pub evictions: u64,
    /// IOs issued.
    pub ios: u64,
    /// Transient-error resubmissions (fault injection; see `sim::ssd`).
    pub io_retries: u64,
    /// IOs that failed permanently (retries exhausted / device dead).
    pub io_errors: u64,
    /// Lock statistics.
    pub lock_acquires: u64,
    pub lock_contended: u64,
    /// Sum over completed ops (for measured model parameters).
    pub sum_mem_accesses: u64,
    pub sum_ios: u64,
    pub sum_compute: Dur,
    /// Distribution of load waits (Fig 10) — 0 means the prefetch fully hid
    /// the latency.
    pub load_wait: LatencyHist,
    /// Distribution of whole-operation latency (Fig 17).
    pub op_latency: LatencyHist,
    /// Distribution of device-side IO latency.
    pub io_latency: LatencyHist,
    #[allow(dead_code)]
    cores: usize,
}

impl Metrics {
    pub fn new(cores: usize) -> Metrics {
        Metrics {
            window_start: Time::ZERO,
            window_end: Time::ZERO,
            ops: 0,
            secondary_accesses: 0,
            dram_accesses: 0,
            loads: 0,
            evictions: 0,
            ios: 0,
            io_retries: 0,
            io_errors: 0,
            lock_acquires: 0,
            lock_contended: 0,
            sum_mem_accesses: 0,
            sum_ios: 0,
            sum_compute: Dur::ZERO,
            load_wait: LatencyHist::new(),
            op_latency: LatencyHist::with_range(Dur::ns(10.0), Dur::ms(10.0), 160),
            io_latency: LatencyHist::with_range(Dur::ns(100.0), Dur::ms(10.0), 120),
            cores,
        }
    }

    pub fn reset(&mut self) {
        let cores = self.cores;
        *self = Metrics::new(cores);
    }

    #[inline]
    pub fn record_op(&mut self, _now: Time, latency: Dur, mem_accesses: u32, ios: u32, compute: Dur) {
        self.ops += 1;
        self.sum_mem_accesses += mem_accesses as u64;
        self.sum_ios += ios as u64;
        self.sum_compute += compute;
        self.op_latency.record(latency);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_reset() {
        let mut m = Metrics::new(2);
        m.record_op(Time::ZERO, Dur::us(3.0), 10, 1, Dur::us(1.0));
        m.record_op(Time::ZERO, Dur::us(5.0), 12, 2, Dur::us(1.2));
        assert_eq!(m.ops, 2);
        assert_eq!(m.sum_mem_accesses, 22);
        assert_eq!(m.sum_ios, 3);
        m.reset();
        assert_eq!(m.ops, 0);
        assert_eq!(m.op_latency.total(), 0);
    }
}
