//! Log-bucketed latency histograms (the simulator's replacement for the
//! paper's PEBS `perf mem` load-latency sampling, Fig 10, and for the KV
//! operation latency percentiles, Fig 17).

use super::time::Dur;

/// A histogram over durations with logarithmic buckets.
///
/// Bucket `i` covers `[lo * g^i, lo * g^(i+1))` where `g` is chosen so that
/// `n_buckets` buckets span `[lo, hi)`. Values below `lo` land in bucket 0
/// (that bucket therefore means "effectively zero wait" — cache hits);
/// values at or above `hi` land in the last bucket.
#[derive(Debug, Clone)]
pub struct LatencyHist {
    counts: Vec<u64>,
    lo_ps: f64,
    log_g: f64,
    total: u64,
    sum_ps: u128,
    max_ps: u64,
}

impl LatencyHist {
    /// Default: 1 ns .. 100 µs over 120 buckets (≈10 buckets per decade).
    pub fn new() -> LatencyHist {
        LatencyHist::with_range(Dur::ns(1.0), Dur::us(100.0), 120)
    }

    pub fn with_range(lo: Dur, hi: Dur, n_buckets: usize) -> LatencyHist {
        assert!(n_buckets >= 2 && hi > lo && lo.0 > 0);
        let lo_ps = lo.0 as f64;
        let hi_ps = hi.0 as f64;
        let log_g = (hi_ps / lo_ps).ln() / n_buckets as f64;
        LatencyHist {
            counts: vec![0; n_buckets],
            lo_ps,
            log_g,
            total: 0,
            sum_ps: 0,
            max_ps: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, d: Dur) {
        // Perf fast path: the overwhelmingly common case on the simulator's
        // hot path is a zero/near-zero wait (prefetch hit) — skip only the
        // ln(), not the sum/max bookkeeping, so the zero path stays
        // symmetric with the slow path (both updates are identities at 0).
        let idx = if d.0 == 0 || (d.0 as f64) < self.lo_ps {
            0
        } else {
            let i = ((d.0 as f64 / self.lo_ps).ln() / self.log_g) as usize;
            i.min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_ps += d.0 as u128;
        self.max_ps = self.max_ps.max(d.0);
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Dur {
        if self.total == 0 {
            Dur::ZERO
        } else {
            Dur((self.sum_ps / self.total as u128) as u64)
        }
    }

    pub fn max(&self) -> Dur {
        Dur(self.max_ps)
    }

    /// Quantile (0.0..=1.0) estimated as the upper edge of the containing
    /// bucket. Bucket 0 means "effectively zero wait" (below `lo`, i.e.
    /// prefetch/cache hits), so it reports `Dur::ZERO` rather than its
    /// ~`lo * g` upper edge — an all-hit histogram has an honest zero p50.
    pub fn quantile(&self, q: f64) -> Dur {
        if self.total == 0 {
            return Dur::ZERO;
        }
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                if i == 0 {
                    return Dur::ZERO;
                }
                let edge = self.lo_ps * ((i as f64 + 1.0) * self.log_g).exp();
                return Dur(edge as u64);
            }
        }
        Dur(self.max_ps)
    }

    /// Fraction of samples at or above a threshold (used to estimate the
    /// premature-eviction ratio ε from the load-wait distribution).
    pub fn frac_at_least(&self, d: Dur) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            let upper = self.lo_ps * ((i as f64 + 1.0) * self.log_g).exp();
            if upper as u64 > d.0 {
                acc += c;
            }
        }
        acc as f64 / self.total as f64
    }

    /// (bucket_upper_edge, count) pairs for non-empty buckets — the Fig 10 series.
    pub fn buckets(&self) -> Vec<(Dur, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let edge = self.lo_ps * ((i as f64 + 1.0) * self.log_g).exp();
                (Dur(edge as u64), c)
            })
            .collect()
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ps += other.sum_ps;
        self.max_ps = self.max_ps.max(other.max_ps);
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts() {
        let mut h = LatencyHist::new();
        for _ in 0..10 {
            h.record(Dur::us(1.0));
        }
        assert_eq!(h.total(), 10);
        assert_eq!(h.mean(), Dur::us(1.0));
        assert_eq!(h.max(), Dur::us(1.0));
    }

    #[test]
    fn quantiles_are_ordered_and_bracket() {
        let mut h = LatencyHist::new();
        for i in 1..=1000u64 {
            h.record(Dur::ns(i as f64 * 10.0)); // 10ns .. 10us uniform
        }
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!(p50 < p99);
        // ~5us median, bucket resolution ~12%
        assert!(p50.as_us() > 3.5 && p50.as_us() < 7.0, "p50={p50}");
        assert!(p99.as_us() > 8.0, "p99={p99}");
    }

    #[test]
    fn frac_at_least_splits() {
        let mut h = LatencyHist::new();
        for _ in 0..90 {
            h.record(Dur::ns(5.0));
        }
        for _ in 0..10 {
            h.record(Dur::us(9.0));
        }
        let f = h.frac_at_least(Dur::us(1.0));
        assert!((f - 0.10).abs() < 0.01, "f={f}");
    }

    #[test]
    fn zero_and_overflow_clamp() {
        let mut h = LatencyHist::new();
        h.record(Dur::ZERO);
        h.record(Dur::secs(1.0)); // way past hi
        assert_eq!(h.total(), 2);
        assert_eq!(h.buckets().len(), 2);
    }

    #[test]
    fn zero_wait_bucket_reports_zero_quantile() {
        // Regression: the pre-fix quantile reported bucket 0's upper edge
        // (~1.2 ns) for zero-wait samples, so an all-prefetch-hit histogram
        // showed a nonzero p50. Bucket 0 must read as `Dur::ZERO`.
        let mut h = LatencyHist::new();
        for _ in 0..100 {
            h.record(Dur::ZERO);
        }
        assert_eq!(h.quantile(0.50), Dur::ZERO);
        assert_eq!(h.quantile(0.99), Dur::ZERO);
        assert_eq!(h.mean(), Dur::ZERO);
        assert_eq!(h.max(), Dur::ZERO);
        // A mostly-hit histogram: zero p50, honest nonzero tail.
        for _ in 0..5 {
            h.record(Dur::us(9.0));
        }
        assert_eq!(h.quantile(0.50), Dur::ZERO);
        assert!(h.quantile(0.99) >= Dur::us(8.0));
        assert_eq!(h.max(), Dur::us(9.0));
        // Sub-`lo` (but nonzero) samples land in bucket 0 and keep the
        // sum/max bookkeeping symmetric with the zero fast path.
        let mut s = LatencyHist::new();
        s.record(Dur(1));
        assert_eq!(s.quantile(0.5), Dur::ZERO);
        assert_eq!(s.max(), Dur(1));
        assert_eq!(s.mean(), Dur(1));
    }

    #[test]
    fn merge_sums() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        a.record(Dur::us(1.0));
        b.record(Dur::us(2.0));
        a.merge(&b);
        assert_eq!(a.total(), 2);
        assert!(a.mean() > Dur::us(1.0));
    }
}
