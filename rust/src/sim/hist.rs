//! Log-bucketed latency histograms (the simulator's replacement for the
//! paper's PEBS `perf mem` load-latency sampling, Fig 10, and for the KV
//! operation latency percentiles, Fig 17).

use super::time::Dur;

/// A histogram over durations with logarithmic buckets.
///
/// Bucket `i` covers `[lo * g^i, lo * g^(i+1))` where `g` is chosen so that
/// `n_buckets` buckets span `[lo, hi)`. Values below `lo` land in bucket 0
/// (that bucket therefore means "effectively zero wait" — cache hits);
/// values at or above `hi` land in the last bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHist {
    counts: Vec<u64>,
    lo_ps: f64,
    log_g: f64,
    total: u64,
    sum_ps: u128,
    max_ps: u64,
}

impl LatencyHist {
    /// Default: 1 ns .. 100 µs over 120 buckets (≈10 buckets per decade).
    pub fn new() -> LatencyHist {
        LatencyHist::with_range(Dur::ns(1.0), Dur::us(100.0), 120)
    }

    pub fn with_range(lo: Dur, hi: Dur, n_buckets: usize) -> LatencyHist {
        assert!(n_buckets >= 2 && hi > lo && lo.0 > 0);
        let lo_ps = lo.0 as f64;
        let hi_ps = hi.0 as f64;
        let log_g = (hi_ps / lo_ps).ln() / n_buckets as f64;
        LatencyHist {
            counts: vec![0; n_buckets],
            lo_ps,
            log_g,
            total: 0,
            sum_ps: 0,
            max_ps: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, d: Dur) {
        // Perf fast path: the overwhelmingly common case on the simulator's
        // hot path is a zero/near-zero wait (prefetch hit) — skip only the
        // ln(), not the sum/max bookkeeping, so the zero path stays
        // symmetric with the slow path (both updates are identities at 0).
        let idx = if d.0 == 0 || (d.0 as f64) < self.lo_ps {
            0
        } else {
            let i = ((d.0 as f64 / self.lo_ps).ln() / self.log_g) as usize;
            i.min(self.counts.len() - 1)
        };
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_ps += d.0 as u128;
        self.max_ps = self.max_ps.max(d.0);
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> Dur {
        if self.total == 0 {
            Dur::ZERO
        } else {
            Dur((self.sum_ps / self.total as u128) as u64)
        }
    }

    pub fn max(&self) -> Dur {
        Dur(self.max_ps)
    }

    /// Quantile (0.0..=1.0) with intra-bucket linear interpolation.
    ///
    /// Bucket 0 means "effectively zero wait" (below `lo`, i.e.
    /// prefetch/cache hits), so it reports `Dur::ZERO` rather than its
    /// ~`lo * g` upper edge — an all-hit histogram has an honest zero p50.
    /// A quantile landing in the *last* bucket reports the observed
    /// `max()` instead of the bucket edge: samples at or above `hi` clamp
    /// into that bucket, so its edge can understate the true tail
    /// arbitrarily (a 1 s sample in a 100 µs histogram read as ~100 µs).
    /// Everywhere else the rank fraction within the containing bucket
    /// interpolates between the bucket edges — at 120-bucket (~12%)
    /// resolution the raw upper edge would quantize p999 onto p99.
    pub fn quantile(&self, q: f64) -> Dur {
        if self.total == 0 {
            return Dur::ZERO;
        }
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                if i == 0 {
                    return Dur::ZERO;
                }
                if i == self.counts.len() - 1 {
                    return Dur(self.max_ps);
                }
                let lower = self.lo_ps * (i as f64 * self.log_g).exp();
                let upper = self.lo_ps * ((i as f64 + 1.0) * self.log_g).exp();
                let f = (target - (acc - c)) as f64 / c as f64;
                let v = lower + f * (upper - lower);
                return Dur((v as u64).min(self.max_ps));
            }
        }
        Dur(self.max_ps)
    }

    /// Fraction of samples at or above a threshold (used to estimate the
    /// premature-eviction ratio ε from the load-wait distribution).
    ///
    /// Bucket 0 holds zero/sub-`lo` samples ("effectively zero wait" —
    /// prefetch hits), but its upper edge is `lo·g` ≈ 1.2 ns, so the
    /// generic edge test would count every hit as "at least d" for any
    /// threshold below that edge and an all-hit histogram would report
    /// 1.0. Mirror the quantile's bucket-0 handling: hits only count
    /// against a zero threshold.
    pub fn frac_at_least(&self, d: Dur) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if i == 0 {
                if d.0 == 0 {
                    acc += c;
                }
                continue;
            }
            let upper = self.lo_ps * ((i as f64 + 1.0) * self.log_g).exp();
            if upper as u64 > d.0 {
                acc += c;
            }
        }
        acc as f64 / self.total as f64
    }

    /// (bucket_upper_edge, count) pairs for non-empty buckets — the Fig 10 series.
    pub fn buckets(&self) -> Vec<(Dur, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let edge = self.lo_ps * ((i as f64 + 1.0) * self.log_g).exp();
                (Dur(edge as u64), c)
            })
            .collect()
    }

    pub fn merge(&mut self, other: &LatencyHist) {
        // Equal bucket *count* is not enough: `load_wait` (1 ns–100 µs,
        // 120) and `io_latency` (100 ns–10 ms, 120) would pass a
        // count-only assert yet merge into garbage. Ranges are built from
        // the same constants when they match, so bit-compare.
        assert!(
            self.counts.len() == other.counts.len()
                && self.lo_ps.to_bits() == other.lo_ps.to_bits()
                && self.log_g.to_bits() == other.log_g.to_bits(),
            "LatencyHist::merge requires identical bucket ranges"
        );
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_ps += other.sum_ps;
        self.max_ps = self.max_ps.max(other.max_ps);
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts() {
        let mut h = LatencyHist::new();
        for _ in 0..10 {
            h.record(Dur::us(1.0));
        }
        assert_eq!(h.total(), 10);
        assert_eq!(h.mean(), Dur::us(1.0));
        assert_eq!(h.max(), Dur::us(1.0));
    }

    #[test]
    fn quantiles_are_ordered_and_bracket() {
        let mut h = LatencyHist::new();
        for i in 1..=1000u64 {
            h.record(Dur::ns(i as f64 * 10.0)); // 10ns .. 10us uniform
        }
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        assert!(p50 < p99);
        // ~5us median, bucket resolution ~12%
        assert!(p50.as_us() > 3.5 && p50.as_us() < 7.0, "p50={p50}");
        assert!(p99.as_us() > 8.0, "p99={p99}");
    }

    #[test]
    fn frac_at_least_splits() {
        let mut h = LatencyHist::new();
        for _ in 0..90 {
            h.record(Dur::ns(5.0));
        }
        for _ in 0..10 {
            h.record(Dur::us(9.0));
        }
        let f = h.frac_at_least(Dur::us(1.0));
        assert!((f - 0.10).abs() < 0.01, "f={f}");
    }

    #[test]
    fn zero_and_overflow_clamp() {
        let mut h = LatencyHist::new();
        h.record(Dur::ZERO);
        h.record(Dur::secs(1.0)); // way past hi
        assert_eq!(h.total(), 2);
        assert_eq!(h.buckets().len(), 2);
    }

    #[test]
    fn zero_wait_bucket_reports_zero_quantile() {
        // Regression: the pre-fix quantile reported bucket 0's upper edge
        // (~1.2 ns) for zero-wait samples, so an all-prefetch-hit histogram
        // showed a nonzero p50. Bucket 0 must read as `Dur::ZERO`.
        let mut h = LatencyHist::new();
        for _ in 0..100 {
            h.record(Dur::ZERO);
        }
        assert_eq!(h.quantile(0.50), Dur::ZERO);
        assert_eq!(h.quantile(0.99), Dur::ZERO);
        assert_eq!(h.mean(), Dur::ZERO);
        assert_eq!(h.max(), Dur::ZERO);
        // A mostly-hit histogram: zero p50, honest nonzero tail.
        for _ in 0..5 {
            h.record(Dur::us(9.0));
        }
        assert_eq!(h.quantile(0.50), Dur::ZERO);
        assert!(h.quantile(0.99) >= Dur::us(8.0));
        assert_eq!(h.max(), Dur::us(9.0));
        // Sub-`lo` (but nonzero) samples land in bucket 0 and keep the
        // sum/max bookkeeping symmetric with the zero fast path.
        let mut s = LatencyHist::new();
        s.record(Dur(1));
        assert_eq!(s.quantile(0.5), Dur::ZERO);
        assert_eq!(s.max(), Dur(1));
        assert_eq!(s.mean(), Dur(1));
    }

    #[test]
    fn frac_at_least_ignores_zero_bucket() {
        // Regression: bucket 0's upper edge is ~1.2 ns, so the pre-fix
        // frac_at_least counted every zero-wait (prefetch hit) sample as
        // "at least d" for thresholds below that edge — an all-hit
        // histogram reported fraction 1.0 and inflated the ε estimate.
        let mut h = LatencyHist::new();
        for _ in 0..100 {
            h.record(Dur::ZERO);
        }
        assert_eq!(h.frac_at_least(Dur::ns(1.0)), 0.0);
        assert_eq!(h.frac_at_least(Dur(1)), 0.0);
        // A zero threshold is satisfied by every sample, hits included.
        assert_eq!(h.frac_at_least(Dur::ZERO), 1.0);
        // Mixed: 100 hits + 25 slow loads → 20% at or above 1 µs.
        for _ in 0..25 {
            h.record(Dur::us(9.0));
        }
        let f = h.frac_at_least(Dur::us(1.0));
        assert!((f - 0.20).abs() < 1e-9, "f={f}");
    }

    #[test]
    fn top_bucket_quantile_reports_observed_max() {
        // Regression: samples at or above `hi` clamp into the last
        // bucket, and the pre-fix quantile reported that bucket's edge
        // (~100 µs) even when every sample was 1 s — a 10⁴× tail
        // understatement.
        let mut h = LatencyHist::new();
        for _ in 0..10 {
            h.record(Dur::secs(1.0));
        }
        assert_eq!(h.quantile(0.50), Dur::secs(1.0));
        assert_eq!(h.quantile(0.999), Dur::secs(1.0));
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // 100 identical samples land in one bucket; pre-fix every
        // quantile reported the same upper edge. The rank fraction now
        // spreads across the bucket (capped at the observed max).
        let mut h = LatencyHist::new();
        for _ in 0..100 {
            h.record(Dur::us(5.0));
        }
        let p10 = h.quantile(0.10);
        let p90 = h.quantile(0.90);
        assert!(p10 < p90, "p10={p10} p90={p90}");
        assert!(p10 > Dur::us(4.0) && p90 <= h.max());
        // A distinguishable tail: p999 resolves past p99 instead of
        // quantizing onto the same bucket edge.
        let mut t = LatencyHist::new();
        for _ in 0..900 {
            t.record(Dur::us(1.0));
        }
        for _ in 0..90 {
            t.record(Dur::us(5.0));
        }
        for _ in 0..10 {
            t.record(Dur::us(50.0));
        }
        let p99 = t.quantile(0.99);
        let p999 = t.quantile(0.999);
        assert!(p99 < p999, "p99={p99} p999={p999}");
        assert!(p999 > Dur::us(20.0) && p999 <= t.max());
    }

    #[test]
    #[should_panic(expected = "identical bucket ranges")]
    fn merge_rejects_mismatched_ranges() {
        // Regression: `load_wait` (1 ns–100 µs, 120) and `io_latency`
        // (100 ns–10 ms, 120) have equal bucket counts, so the pre-fix
        // count-only assert let them merge into garbage.
        let mut a = LatencyHist::new();
        let b = LatencyHist::with_range(Dur::ns(100.0), Dur::ms(10.0), 120);
        a.merge(&b);
    }

    #[test]
    fn merge_sums() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        a.record(Dur::us(1.0));
        b.record(Dur::us(2.0));
        a.merge(&b);
        assert_eq!(a.total(), 2);
        assert!(a.mean() > Dur::us(1.0));
    }
}
