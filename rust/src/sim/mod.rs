//! Discrete-event testbed simulator.
//!
//! Substitutes for the paper's hardware testbed (Table 3): Xeon cores with a
//! depth-P prefetch queue, FPGA-based CXL memory with adjustable microsecond
//! latency, Optane SSDs, and Argobots-style user-level threads. See
//! DESIGN.md §2 (substitution table) and §6 (execution semantics).

pub mod hist;
pub mod machine;
pub mod mem;
pub mod metrics;
pub mod rng;
pub mod ssd;
pub mod time;

pub use hist::LatencyHist;
pub use machine::{
    IoClassStats, Machine, MachineConfig, RetryPolicy, RunStats, Service, Step, TenantStats, Tier,
};
pub use mem::{MemConfig, MemDevice, TailProfile};
pub use metrics::{CoreBreakdown, Metrics};
pub use rng::Rng;
pub use ssd::{
    BgKind, BgShare, DeviceStats, ErrorWindow, FaultPlan, IoCompletion, IoError, IoKind,
    LatencySpike, SsdArray, SsdConfig, SsdDevice, TrafficClass, N_TRAFFIC_LANES,
};
pub use time::{Dur, Time};
