//! Memory device models.
//!
//! This module stands in for the paper's testbed memory parts (Table 3):
//! host DRAM, a commercial CXL memory expander (~300 ns), and the FPGA-based
//! CXL memory whose latency is **user-configurable in the microsecond range**
//! and which can (a) throttle bandwidth and (b) inject a tail-latency profile
//! (§5.1: 14 µs at 9.9% and 48 µs at 0.1% on top of a 5 µs base, fitted to a
//! low-latency SSD's latency distribution).
//!
//! The device is modeled as a latency draw plus a completion-rate server:
//! consecutive line transfers cannot complete closer together than
//! `A_mem / B_mem` (Eq 15's second term).

use super::rng::Rng;
use super::time::{Dur, Time};

/// Probabilistic extra-latency profile (longer latencies with probabilities).
#[derive(Debug, Clone, Default)]
pub struct TailProfile {
    /// (latency, probability) entries; probabilities must sum to < 1.
    /// The remaining mass uses the base latency.
    pub entries: Vec<(Dur, f64)>,
}

impl TailProfile {
    /// The §5.1 profile: 14 µs at 9.9%, 48 µs at 0.1%.
    pub fn paper_flash() -> TailProfile {
        TailProfile {
            entries: vec![(Dur::us(14.0), 0.099), (Dur::us(48.0), 0.001)],
        }
    }

    /// Expected latency given a base latency.
    pub fn mean_latency(&self, base: Dur) -> Dur {
        let tail_p: f64 = self.entries.iter().map(|&(_, p)| p).sum();
        let mut mean = base.0 as f64 * (1.0 - tail_p);
        for &(d, p) in &self.entries {
            mean += d.0 as f64 * p;
        }
        Dur(mean as u64)
    }
}

/// §5.2.4 extension: an on-device cache in front of the slow medium.
/// Commercial µs-latency devices (e.g. CXL flash with a DRAM buffer) serve
/// a fraction of loads at near-DRAM latency.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCache {
    /// Fraction of transfers served by the on-device cache.
    pub hit_ratio: f64,
    /// Latency of a device-cache hit.
    pub hit_latency: Dur,
}

/// Configuration of one memory device (a NUMA node in the paper's setup).
#[derive(Debug, Clone)]
pub struct MemConfig {
    /// Base load-to-use latency of the device.
    pub latency: Dur,
    /// Cacheline transfer size A_mem (bytes).
    pub line_bytes: u32,
    /// Max bandwidth B_mem in bytes/sec; `f64::INFINITY` disables the server.
    pub bandwidth_bps: f64,
    /// Optional tail-latency profile.
    pub tail: Option<TailProfile>,
    /// Optional on-device cache (§5.2.4 extension).
    pub device_cache: Option<DeviceCache>,
}

impl MemConfig {
    /// Host DRAM: ~90 ns, effectively unlimited bandwidth at our scale.
    pub fn dram() -> MemConfig {
        MemConfig {
            latency: Dur::ns(90.0),
            line_bytes: 64,
            bandwidth_bps: f64::INFINITY,
            tail: None,
            device_cache: None,
        }
    }

    /// Commercial CXL memory expander (~300 ns measured in the paper).
    pub fn cxl_expander() -> MemConfig {
        MemConfig {
            latency: Dur::ns(300.0),
            line_bytes: 64,
            bandwidth_bps: f64::INFINITY,
            tail: None,
            device_cache: None,
        }
    }

    /// FPGA-based adjustable microsecond-latency memory. The paper's device
    /// bottoms out at 0.5 µs; we accept any latency (DRAM-placement runs use
    /// the same code path with a ~0.1 µs setting).
    pub fn fpga(latency: Dur) -> MemConfig {
        MemConfig {
            latency,
            line_bytes: 64,
            bandwidth_bps: f64::INFINITY,
            tail: None,
            device_cache: None,
        }
    }

    pub fn with_bandwidth(mut self, bps: f64) -> MemConfig {
        self.bandwidth_bps = bps;
        self
    }

    pub fn with_tail(mut self, tail: TailProfile) -> MemConfig {
        self.tail = Some(tail);
        self
    }

    /// §5.2.4 extension: add an on-device cache.
    pub fn with_device_cache(mut self, hit_ratio: f64, hit_latency: Dur) -> MemConfig {
        self.device_cache = Some(DeviceCache {
            hit_ratio,
            hit_latency,
        });
        self
    }

    /// Mean latency including the tail profile.
    pub fn mean_latency(&self) -> Dur {
        match &self.tail {
            Some(t) => t.mean_latency(self.latency),
            None => self.latency,
        }
    }
}

/// Runtime state of a memory device.
#[derive(Debug, Clone)]
pub struct MemDevice {
    pub cfg: MemConfig,
    /// Completion-rate server: earliest time the next transfer may complete.
    next_completion_floor: Time,
    /// Minimum spacing between completions (A_mem / B_mem), 0 if unlimited.
    spacing: Dur,
    /// Stats.
    pub transfers: u64,
    pub tail_hits: u64,
}

impl MemDevice {
    pub fn new(cfg: MemConfig) -> MemDevice {
        let spacing = if cfg.bandwidth_bps.is_finite() && cfg.bandwidth_bps > 0.0 {
            Dur::secs(cfg.line_bytes as f64 / cfg.bandwidth_bps)
        } else {
            Dur::ZERO
        };
        MemDevice {
            cfg,
            next_completion_floor: Time::ZERO,
            spacing,
            transfers: 0,
            tail_hits: 0,
        }
    }

    /// Draw the latency for one transfer.
    #[inline]
    pub fn draw_latency(&mut self, rng: &mut Rng) -> Dur {
        // On-device cache hits short-circuit both the slow medium and the
        // tail profile (the tail models the medium, not the buffer).
        if let Some(dc) = &self.cfg.device_cache {
            if rng.f64() < dc.hit_ratio {
                return dc.hit_latency;
            }
        }
        if let Some(tail) = &self.cfg.tail {
            let x = rng.f64();
            let mut acc = 0.0;
            for &(d, p) in &tail.entries {
                acc += p;
                if x < acc {
                    self.tail_hits += 1;
                    return d;
                }
            }
        }
        self.cfg.latency
    }

    /// Issue a line transfer starting at `start`; returns its completion time,
    /// honoring both the latency draw and the bandwidth server.
    #[inline]
    pub fn transfer(&mut self, start: Time, rng: &mut Rng) -> Time {
        let lat = self.draw_latency(rng);
        let mut done = start + lat;
        if !self.spacing.is_zero() {
            if done < self.next_completion_floor {
                done = self.next_completion_floor;
            }
            self.next_completion_floor = done + self.spacing;
        }
        self.transfers += 1;
        done
    }

    /// Reset server state & stats (between measurement windows).
    pub fn reset_stats(&mut self) {
        self.transfers = 0;
        self.tail_hits = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_latency_no_bandwidth_limit() {
        let mut dev = MemDevice::new(MemConfig::fpga(Dur::us(5.0)));
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO + Dur::us(1.0);
        assert_eq!(dev.transfer(t0, &mut rng), t0 + Dur::us(5.0));
        // Unlimited bandwidth: back-to-back transfers complete at the same time.
        assert_eq!(dev.transfer(t0, &mut rng), t0 + Dur::us(5.0));
    }

    #[test]
    fn bandwidth_server_spaces_completions() {
        // 64B lines at 64 GB/s -> 1 ns spacing.
        let cfg = MemConfig::fpga(Dur::us(1.0)).with_bandwidth(64e9);
        let mut dev = MemDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = dev.transfer(t0, &mut rng);
        let c2 = dev.transfer(t0, &mut rng);
        let c3 = dev.transfer(t0, &mut rng);
        assert_eq!(c1, t0 + Dur::us(1.0));
        assert_eq!(c2, c1 + Dur::ns(1.0));
        assert_eq!(c3, c2 + Dur::ns(1.0));
    }

    #[test]
    fn tail_profile_frequencies() {
        let cfg = MemConfig::fpga(Dur::us(5.0)).with_tail(TailProfile::paper_flash());
        let mut dev = MemDevice::new(cfg);
        let mut rng = Rng::new(99);
        let n = 200_000;
        let mut long = 0;
        let mut very_long = 0;
        for _ in 0..n {
            let l = dev.draw_latency(&mut rng);
            if l == Dur::us(14.0) {
                long += 1;
            } else if l == Dur::us(48.0) {
                very_long += 1;
            } else {
                assert_eq!(l, Dur::us(5.0));
            }
        }
        let p_long = long as f64 / n as f64;
        let p_very = very_long as f64 / n as f64;
        assert!((p_long - 0.099).abs() < 0.005, "p_long={p_long}");
        assert!((p_very - 0.001).abs() < 0.0005, "p_very={p_very}");
    }

    #[test]
    fn tail_mean_latency() {
        let t = TailProfile::paper_flash();
        let mean = t.mean_latency(Dur::us(5.0)).as_us();
        // 0.9*5 + 0.099*14 + 0.001*48 = 4.5 + 1.386 + 0.048 = 5.934
        assert!((mean - 5.934).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn device_cache_mixes_latencies() {
        let cfg = MemConfig::fpga(Dur::us(5.0)).with_device_cache(0.3, Dur::ns(400.0));
        let mut dev = MemDevice::new(cfg);
        let mut rng = Rng::new(21);
        let n = 100_000;
        let mut hits = 0;
        for _ in 0..n {
            let l = dev.draw_latency(&mut rng);
            if l == Dur::ns(400.0) {
                hits += 1;
            } else {
                assert_eq!(l, Dur::us(5.0));
            }
        }
        let hr = hits as f64 / n as f64;
        assert!((hr - 0.3).abs() < 0.01, "hit ratio {hr}");
    }

    #[test]
    fn device_cache_beats_tail_profile() {
        // Cache hits bypass the tail draws.
        let cfg = MemConfig::fpga(Dur::us(5.0))
            .with_tail(TailProfile::paper_flash())
            .with_device_cache(1.0, Dur::ns(400.0));
        let mut dev = MemDevice::new(cfg);
        let mut rng = Rng::new(22);
        for _ in 0..1000 {
            assert_eq!(dev.draw_latency(&mut rng), Dur::ns(400.0));
        }
    }

    #[test]
    fn presets_are_sane() {
        assert!(MemConfig::dram().latency < MemConfig::cxl_expander().latency);
        assert!(MemConfig::cxl_expander().latency < MemConfig::fpga(Dur::us(1.0)).latency);
    }
}
