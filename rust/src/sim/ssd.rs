//! SSD device model.
//!
//! Stands in for the paper's Optane 900P NVMe drives accessed as block
//! devices through io_uring. One IO is (1) a pre-IO CPU suboperation
//! `T_IO_pre` (address computation + non-blocking submission), (2) device
//! latency `L_IO`, (3) a post-IO CPU suboperation `T_IO_post` (completion
//! check + buffer copy). The CPU suboperation times are charged by the core
//! (see `machine.rs`); this module models the device side: latency plus
//! three servers enforcing the Table 2 limits — queue depth, bandwidth
//! `B_IO` (bytes/sec), and random-access rate `R_IO` (IOPS).

use super::rng::Rng;
use super::time::{Dur, Time};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Read,
    Write,
}

#[derive(Debug, Clone)]
pub struct SsdConfig {
    /// Device read latency (submission to completion, uncontended).
    pub read_latency: Dur,
    /// Device write latency (writes land in the device buffer; Optane-class).
    pub write_latency: Dur,
    /// Max sustained bandwidth in bytes/sec (aggregate over the array).
    pub bandwidth_bps: f64,
    /// Max random-access rate in IO/sec (aggregate).
    pub iops: f64,
    /// Device queue depth (in-flight IOs beyond this wait in the submission queue).
    pub queue_depth: u32,
    /// Default CPU-side suboperation times (can be overridden per workload).
    pub t_pre: Dur,
    pub t_post: Dur,
    /// Relative latency jitter (uniform in ±jitter_frac·latency). Real
    /// devices are not clock-exact; this jitter is also what naturally
    /// misaligns thread phases (§3.2.2's "timing will be mostly random") —
    /// a perfectly deterministic device can lock threads into the Fig 7(a)
    /// aligned pattern.
    pub jitter_frac: f64,
}

impl SsdConfig {
    /// The paper's array: 4× Optane 900P. Combined ~2.2 MIOPS random reads,
    /// ~10 GB/s, ~10 µs read latency; deep queues.
    pub fn optane_array() -> SsdConfig {
        SsdConfig {
            read_latency: Dur::us(10.0),
            write_latency: Dur::us(10.0),
            bandwidth_bps: 10e9,
            iops: 2.2e6,
            queue_depth: 1024,
            t_pre: Dur::us(1.5),
            t_post: Dur::us(0.2),
            jitter_frac: 0.15,
        }
    }

    /// A single Optane 900P (Fig 12(a): B_IO-limited scenario).
    pub fn optane_single() -> SsdConfig {
        SsdConfig {
            bandwidth_bps: 2.5e9,
            iops: 550e3,
            ..SsdConfig::optane_array()
        }
    }

    /// A slow SATA SSD (Fig 12(b): R_IO-limited scenario).
    pub fn sata_slow() -> SsdConfig {
        SsdConfig {
            read_latency: Dur::us(80.0),
            write_latency: Dur::us(80.0),
            bandwidth_bps: 0.5e9,
            iops: 75e3,
            queue_depth: 32,
            t_pre: Dur::us(1.5),
            t_post: Dur::us(0.2),
            jitter_frac: 0.3,
        }
    }

    pub fn with_latency(mut self, d: Dur) -> SsdConfig {
        self.read_latency = d;
        self.write_latency = d;
        self
    }
}

/// Runtime state of the SSD (array): latency + rate servers.
#[derive(Debug, Clone)]
pub struct SsdDevice {
    pub cfg: SsdConfig,
    /// Bandwidth server: time the device's data channel frees up.
    bw_free: Time,
    /// IOPS server: time the command processor frees up.
    iops_free: Time,
    /// Completion times of in-flight IOs (bounded by queue_depth). Kept as a
    /// sorted-ish ring: completions are monotone given monotone submissions.
    inflight: std::collections::VecDeque<Time>,
    pub reads: u64,
    pub writes: u64,
    pub bytes: u64,
}

impl SsdDevice {
    pub fn new(cfg: SsdConfig) -> SsdDevice {
        SsdDevice {
            cfg,
            bw_free: Time::ZERO,
            iops_free: Time::ZERO,
            inflight: std::collections::VecDeque::new(),
            reads: 0,
            writes: 0,
            bytes: 0,
        }
    }

    /// Submit one IO at time `submit`; returns its completion time.
    pub fn submit(&mut self, submit: Time, kind: IoKind, bytes: u32, rng: &mut Rng) -> Time {
        // Queue-depth server: if the device queue is full, the IO effectively
        // starts when the oldest in-flight IO completes.
        while let Some(&front) = self.inflight.front() {
            if front <= submit || self.inflight.len() < self.cfg.queue_depth as usize {
                if front <= submit {
                    self.inflight.pop_front();
                    continue;
                }
            }
            break;
        }
        let mut start = submit;
        if self.inflight.len() >= self.cfg.queue_depth as usize {
            // wait for a slot
            start = self.inflight.pop_front().unwrap().max(start);
        }

        // IOPS server.
        if self.cfg.iops.is_finite() && self.cfg.iops > 0.0 {
            let gap = Dur::secs(1.0 / self.cfg.iops);
            if start < self.iops_free {
                start = self.iops_free;
            }
            self.iops_free = start + gap;
        }

        // Bandwidth server: transfer occupies bytes/B_IO of channel time.
        let base = match kind {
            IoKind::Read => self.cfg.read_latency,
            IoKind::Write => self.cfg.write_latency,
        };
        let lat = if self.cfg.jitter_frac > 0.0 {
            let f = 1.0 + self.cfg.jitter_frac * (2.0 * rng.f64() - 1.0);
            Dur((base.0 as f64 * f) as u64)
        } else {
            base
        };
        let mut done = start + lat;
        if self.cfg.bandwidth_bps.is_finite() && self.cfg.bandwidth_bps > 0.0 {
            let xfer = Dur::secs(bytes as f64 / self.cfg.bandwidth_bps);
            let chan_start = self.bw_free.max(start);
            let chan_done = chan_start + xfer;
            self.bw_free = chan_done;
            done = done.max(chan_done);
        }

        self.inflight.push_back(done);
        match kind {
            IoKind::Read => self.reads += 1,
            IoKind::Write => self.writes += 1,
        }
        self.bytes += bytes as u64;
        done
    }

    pub fn reset_stats(&mut self) {
        self.reads = 0;
        self.writes = 0;
        self.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_symmetric_and_bounded() {
        let mut d = SsdDevice::new(SsdConfig {
            iops: f64::INFINITY,
            bandwidth_bps: f64::INFINITY,
            ..SsdConfig::optane_array() // keeps the 15% jitter
        });
        let mut rng = Rng::new(5);
        let mut sum = 0.0;
        let n = 20_000;
        for i in 0..n {
            // Space submissions so the queue-depth server stays idle.
            let t = Time::ZERO + Dur::us(20.0) * i;
            let done = d.submit(t, IoKind::Read, 512, &mut rng);
            let lat = (done - t).as_us();
            assert!((8.5..=11.5).contains(&lat), "lat {lat}");
            sum += lat;
        }
        let mean = sum / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn uncontended_read_latency() {
        let mut d = SsdDevice::new(SsdConfig {
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        });
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO + Dur::us(100.0);
        let done = d.submit(t0, IoKind::Read, 4096, &mut rng);
        assert_eq!(done, t0 + Dur::us(10.0));
        assert_eq!(d.reads, 1);
    }

    #[test]
    fn iops_cap_enforced() {
        // 1 MIOPS -> 1 us between command starts.
        let cfg = SsdConfig {
            iops: 1e6,
            bandwidth_bps: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, 512, &mut rng);
        let c2 = d.submit(t0, IoKind::Read, 512, &mut rng);
        let c3 = d.submit(t0, IoKind::Read, 512, &mut rng);
        assert_eq!(c2 - c1, Dur::us(1.0));
        assert_eq!(c3 - c2, Dur::us(1.0));
    }

    #[test]
    fn bandwidth_cap_enforced() {
        // 1 GB/s, 1 MB IOs -> 1 ms per transfer dominates latency.
        let cfg = SsdConfig {
            bandwidth_bps: 1e9,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, 1_000_000, &mut rng);
        let c2 = d.submit(t0, IoKind::Read, 1_000_000, &mut rng);
        assert_eq!(c1, t0 + Dur::ms(1.0));
        assert_eq!(c2, t0 + Dur::ms(2.0));
    }

    #[test]
    fn queue_depth_backpressure() {
        let cfg = SsdConfig {
            queue_depth: 2,
            bandwidth_bps: f64::INFINITY,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, 512, &mut rng);
        let _c2 = d.submit(t0, IoKind::Read, 512, &mut rng);
        // Third IO at t0 with QD=2 waits for c1 to finish.
        let c3 = d.submit(t0, IoKind::Read, 512, &mut rng);
        assert_eq!(c3, c1 + Dur::us(10.0));
    }

    #[test]
    fn write_counts() {
        let mut d = SsdDevice::new(SsdConfig::optane_array());
        let mut rng = Rng::new(1);
        d.submit(Time::ZERO, IoKind::Write, 2048, &mut rng);
        assert_eq!(d.writes, 1);
        assert_eq!(d.bytes, 2048);
    }
}
