//! SSD device model.
//!
//! Stands in for the paper's Optane 900P NVMe drives accessed as block
//! devices through io_uring. One IO is (1) a pre-IO CPU suboperation
//! `T_IO_pre` (address computation + non-blocking submission), (2) device
//! latency `L_IO`, (3) a post-IO CPU suboperation `T_IO_post` (completion
//! check + buffer copy). The CPU suboperation times are charged by the core
//! (see `machine.rs`); this module models the device side: latency plus
//! three servers enforcing the Table 2 limits — queue depth, bandwidth
//! `B_IO` (bytes/sec), and random-access rate `R_IO` (IOPS).
//!
//! ## Multi-SSD sharding
//!
//! [`SsdArray`] composes `n_ssd` independent [`SsdDevice`]s, each with its
//! own queue-depth/IOPS/bandwidth servers (per-device submission queue).
//! Every `Step::Io` carries a **shard route** — a stable placement key the
//! store derives from what it is reading/writing (treekv: value-log block,
//! lsmkv: SSTable block id, cachekv: SOC slab hash) — and the array maps it
//! to a device with `shard % n_ssd`. The aggregate ceilings therefore scale
//! as `Θ_ssd = n_ssd · R_IO` and `n_ssd · B_IO` (the Eq 14 floors composed
//! with the array term), while a skewed route distribution exposes
//! per-device hotspots exactly like a real array. With `n_ssd = 1` every
//! route maps to device 0 and the array is bit-identical to the former
//! single-device path (same servers, same jitter RNG draw order).
//!
//! ## Fault injection
//!
//! Each device can carry a [`FaultPlan`]: scheduled latency-spike windows
//! (a grey / thermally-throttling device), transient-error windows
//! (submissions inside the window fail with a configured probability,
//! drawn from the machine's seeded RNG so runs stay deterministic), and a
//! permanent death time. [`SsdDevice::submit_checked`] reports the outcome
//! as an [`IoCompletion`]; the plain [`SsdDevice::submit`] path is a
//! success-assuming wrapper kept for fault-free callers. A failed transient
//! attempt still occupies the device servers — a failed read costs its
//! latency, exactly like a real drive returning an error after the flash
//! access — while a dead device short-circuits (host-side timeout path)
//! without touching the servers or the RNG, so fault-free devices in the
//! same array are unaffected. With an empty plan every check is a pure
//! comparison and zero extra RNG draws: the fault layer is bit-invisible
//! unless configured.
//!
//! ## Traffic classes and foreground/background sharing
//!
//! Every IO carries a [`TrafficClass`]: `Foreground` for work on a client
//! op's critical path, `Background(BgKind)` for the store's own maintenance
//! traffic (LSM compaction, memtable flush, value-log defrag, WAL group
//! flushes). The device keeps per-class IO / byte / queue-wait counters —
//! the lane sums are pinned to the untyped totals
//! ([`SsdDevice::check_flow_conservation`]), so an untagged call site
//! cannot silently leak traffic out of the accounting.
//!
//! [`BgShare`] selects how the two classes share the device's rate servers:
//!
//! - [`BgShare::None`] (default): both classes run through the same
//!   IOPS/bandwidth servers at full rate — today's behavior, pinned
//!   bit-identical (the class tag is pure accounting).
//! - [`BgShare::Cap { frac }`](BgShare::Cap): a **static capacity
//!   partition**. Background runs on a dedicated server pair at
//!   `frac · R_IO` / `frac · B_IO`; foreground keeps `(1-frac)` of each.
//!   Deterministic and trivially monotone — shrinking `frac` can only speed
//!   foreground up — at the cost of work conservation (an idle background
//!   partition is not lent to foreground). This is deliberate: pacing
//!   background into the *shared* FIFO call-order servers is provably
//!   non-monotone (a delayed background start pushes the shared server's
//!   free-time later, which can delay subsequent foreground IOs).
//! - [`BgShare::Weighted { fg_w, bg_w }`](BgShare::Weighted): shared
//!   full-rate servers plus a command/byte **pacer** holding background to
//!   its weighted share `bg_w/(fg_w+bg_w)` (a RocksDB-rate-limiter-style
//!   throttle). Foreground is never paced, so it is work-conserving for
//!   foreground; background is throttled to its share even when foreground
//!   is idle.

use super::rng::Rng;
use super::time::{Dur, Time};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Read,
    Write,
}

/// Which background subsystem issued an IO (see [`TrafficClass`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BgKind {
    /// LSM compaction bulk IO (merge reads + output writes).
    Compaction,
    /// Memtable / dirty-slab flush writes (lsmkv flush, cachekv SOC slab
    /// refill writes).
    Flush,
    /// Value-log defragmentation (treekv garbage collection).
    Defrag,
    /// WAL group-commit flushes (`kvs::wal`).
    WalFlush,
}

/// Number of accounting lanes: foreground plus one per [`BgKind`].
pub const N_TRAFFIC_LANES: usize = 5;

/// Who an IO belongs to: client-op critical path, or the store's own
/// background maintenance. Pure accounting under [`BgShare::None`]; under
/// the other policies it also selects the rate servers the IO runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    Foreground,
    Background(BgKind),
}

impl TrafficClass {
    /// Stable accounting-lane index: [Foreground, Compaction, Flush,
    /// Defrag, WalFlush].
    #[inline]
    pub fn lane(self) -> usize {
        match self {
            TrafficClass::Foreground => 0,
            TrafficClass::Background(BgKind::Compaction) => 1,
            TrafficClass::Background(BgKind::Flush) => 2,
            TrafficClass::Background(BgKind::Defrag) => 3,
            TrafficClass::Background(BgKind::WalFlush) => 4,
        }
    }

    #[inline]
    pub fn is_background(self) -> bool {
        !matches!(self, TrafficClass::Foreground)
    }

    /// Human-readable lane name for reports (index = [`TrafficClass::lane`]).
    pub fn lane_name(lane: usize) -> &'static str {
        ["fg", "compaction", "flush", "defrag", "wal"][lane]
    }
}

/// Foreground/background bandwidth-sharing policy (module docs, "Traffic
/// classes and foreground/background sharing").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BgShare {
    /// Shared servers at full rate for both classes (the historical
    /// behavior; the class tag is pure accounting). Default.
    None,
    /// Static partition: background gets a dedicated server pair at
    /// `frac` of each rate, foreground keeps `1 - frac`. `frac` is
    /// clamped to `[1/64, 63/64]` so neither partition degenerates.
    Cap { frac: f64 },
    /// Shared full-rate servers plus a background pacer at share
    /// `bg_w / (fg_w + bg_w)` of each rate.
    Weighted { fg_w: u32, bg_w: u32 },
}

impl Default for BgShare {
    fn default() -> BgShare {
        BgShare::None
    }
}

impl BgShare {
    /// Resolve this policy for one IO: (rate multiplier on the servers the
    /// IO runs through, run on the dedicated background server pair?,
    /// pacer share — 0.0 = unpaced).
    #[inline]
    fn route(self, background: bool) -> (f64, bool, f64) {
        match self {
            BgShare::None => (1.0, false, 0.0),
            BgShare::Cap { frac } => {
                let f = frac.clamp(1.0 / 64.0, 63.0 / 64.0);
                if background {
                    (f, true, 0.0)
                } else {
                    (1.0 - f, false, 0.0)
                }
            }
            BgShare::Weighted { fg_w, bg_w } => {
                if background {
                    let share = (bg_w.max(1) as f64) / ((fg_w + bg_w).max(1) as f64);
                    (1.0, false, share.clamp(1.0 / 64.0, 1.0))
                } else {
                    (1.0, false, 0.0)
                }
            }
        }
    }

    /// The background fraction this policy reserves/paces (`0.0` for
    /// `None`) — the `bg_share` knob the extended model consumes.
    pub fn bg_frac(self) -> f64 {
        match self {
            BgShare::None => 0.0,
            BgShare::Cap { frac } => frac.clamp(1.0 / 64.0, 63.0 / 64.0),
            BgShare::Weighted { fg_w, bg_w } => {
                ((bg_w.max(1) as f64) / ((fg_w + bg_w).max(1) as f64)).clamp(1.0 / 64.0, 1.0)
            }
        }
    }
}

/// Why a submitted IO failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoError {
    /// Transient failure (media retry / link CRC class): the same IO
    /// resubmitted after a backoff may succeed.
    Transient,
    /// The device is permanently dead (its `FaultPlan::dead_from` passed).
    DeviceDead,
}

/// Outcome of one submitted IO: when the attempt resolves, and whether it
/// succeeded. On error `at` is when the failure is reported to the
/// submitter — for a transient error that is the full service time of the
/// failed attempt; for a dead device it is the host's timeout detection
/// (one uncontended read latency after submit).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoCompletion {
    pub at: Time,
    pub error: Option<IoError>,
}

impl IoCompletion {
    #[inline]
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

/// A scheduled latency brown-out: submissions landing in `[from, until)`
/// see the device's base latency multiplied by `factor` (jitter still
/// applies on top, so the RNG draw count is unchanged).
#[derive(Debug, Clone, Copy)]
pub struct LatencySpike {
    pub from: Time,
    pub until: Time,
    pub factor: f64,
}

/// A transient-error window: submissions landing in `[from, until)` fail
/// with probability `prob`. The draw comes from the caller's seeded RNG,
/// so identical seeds reproduce identical fault sequences; `prob >= 1.0`
/// fails unconditionally without a draw.
#[derive(Debug, Clone, Copy)]
pub struct ErrorWindow {
    pub from: Time,
    pub until: Time,
    pub prob: f64,
}

/// Per-device fault schedule. `Default` is the empty plan (no faults); an
/// empty plan adds zero RNG draws and zero behavior change.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub latency_spikes: Vec<LatencySpike>,
    pub error_windows: Vec<ErrorWindow>,
    /// Device is permanently dead from this time on.
    pub dead_from: Option<Time>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.latency_spikes.is_empty() && self.error_windows.is_empty() && self.dead_from.is_none()
    }
}

#[derive(Debug, Clone)]
pub struct SsdConfig {
    /// Device read latency (submission to completion, uncontended).
    pub read_latency: Dur,
    /// Device write latency (writes land in the device buffer; Optane-class).
    pub write_latency: Dur,
    /// Max sustained bandwidth in bytes/sec, per device.
    pub bandwidth_bps: f64,
    /// Max random-access rate in IO/sec, per device.
    pub iops: f64,
    /// Device queue depth (in-flight IOs beyond this wait in the submission queue).
    pub queue_depth: u32,
    /// Default CPU-side suboperation times (can be overridden per workload).
    pub t_pre: Dur,
    pub t_post: Dur,
    /// Relative latency jitter (uniform in ±jitter_frac·latency). Real
    /// devices are not clock-exact; this jitter is also what naturally
    /// misaligns thread phases (§3.2.2's "timing will be mostly random") —
    /// a perfectly deterministic device can lock threads into the Fig 7(a)
    /// aligned pattern.
    pub jitter_frac: f64,
    /// Number of independent devices in the array. The latency / bandwidth /
    /// IOPS / queue-depth fields above are **per device**; [`SsdArray`]
    /// instantiates `n_ssd` of them and routes each IO by its shard key.
    pub n_ssd: u32,
    /// Per-device fault schedules: device `i` runs `faults[i]` (missing
    /// entries mean fault-free). Empty by default.
    pub faults: Vec<FaultPlan>,
    /// Foreground/background bandwidth-sharing policy. `BgShare::None`
    /// (default) is bit-identical to the pre-traffic-class device.
    pub bg_share: BgShare,
}

impl SsdConfig {
    /// The paper's array: 4× Optane 900P. Combined ~2.2 MIOPS random reads,
    /// ~10 GB/s, ~10 µs read latency; deep queues.
    pub fn optane_array() -> SsdConfig {
        SsdConfig {
            read_latency: Dur::us(10.0),
            write_latency: Dur::us(10.0),
            bandwidth_bps: 10e9,
            iops: 2.2e6,
            queue_depth: 1024,
            t_pre: Dur::us(1.5),
            t_post: Dur::us(0.2),
            jitter_frac: 0.15,
            n_ssd: 1,
            faults: Vec::new(),
            bg_share: BgShare::None,
        }
    }

    /// A single Optane 900P (Fig 12(a): B_IO-limited scenario).
    pub fn optane_single() -> SsdConfig {
        SsdConfig {
            bandwidth_bps: 2.5e9,
            iops: 550e3,
            ..SsdConfig::optane_array()
        }
    }

    /// A slow SATA SSD (Fig 12(b): R_IO-limited scenario).
    pub fn sata_slow() -> SsdConfig {
        SsdConfig {
            read_latency: Dur::us(80.0),
            write_latency: Dur::us(80.0),
            bandwidth_bps: 0.5e9,
            iops: 75e3,
            queue_depth: 32,
            t_pre: Dur::us(1.5),
            t_post: Dur::us(0.2),
            jitter_frac: 0.3,
            n_ssd: 1,
            faults: Vec::new(),
            bg_share: BgShare::None,
        }
    }

    pub fn with_latency(mut self, d: Dur) -> SsdConfig {
        self.read_latency = d;
        self.write_latency = d;
        self
    }

    /// Set the array size (per-device limits stay as configured).
    pub fn with_n_ssd(mut self, n: u32) -> SsdConfig {
        self.n_ssd = n.max(1);
        self
    }

    /// Attach a fault plan to device `device` (list grows as needed).
    pub fn with_fault(mut self, device: usize, plan: FaultPlan) -> SsdConfig {
        if self.faults.len() <= device {
            self.faults.resize(device + 1, FaultPlan::default());
        }
        self.faults[device] = plan;
        self
    }

    /// Set the foreground/background sharing policy.
    pub fn with_bg_share(mut self, share: BgShare) -> SsdConfig {
        self.bg_share = share;
        self
    }
}

/// Per-device observability snapshot (skew / brown-out analysis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeviceStats {
    pub ios: u64,
    pub bytes: u64,
    pub errors: u64,
    /// Mean submit→resolve latency over the attempted IOs (queue waits
    /// included), so a grey device's spike windows show up directly.
    pub mean_latency: Dur,
}

/// Runtime state of the SSD (array): latency + rate servers.
#[derive(Debug, Clone)]
pub struct SsdDevice {
    pub cfg: SsdConfig,
    /// This device's fault schedule (empty plan = fault-free).
    fault: FaultPlan,
    /// Bandwidth server: time the device's data channel frees up.
    bw_free: Time,
    /// IOPS server: time the command processor frees up.
    iops_free: Time,
    /// Background bandwidth server: the dedicated partition channel under
    /// [`BgShare::Cap`], the byte pacer under [`BgShare::Weighted`]; idle
    /// under [`BgShare::None`].
    bg_bw_free: Time,
    /// Background IOPS server (partition / pacer counterpart of the above).
    bg_iops_free: Time,
    /// Completion times of in-flight IOs (bounded by queue_depth), kept
    /// sorted ascending. Submissions arrive at per-core clocks that are not
    /// globally monotone, so completions are inserted in sorted position —
    /// a full queue then waits on the *earliest* completion, not on
    /// whichever IO happened to be submitted first.
    inflight: std::collections::VecDeque<Time>,
    pub reads: u64,
    pub writes: u64,
    pub bytes: u64,
    /// Failed attempts (transient + dead-device).
    pub errors: u64,
    /// Every submit_checked call, including dead-device short-circuits.
    attempts: u64,
    /// Sum of submit→resolve latencies (for `DeviceStats::mean_latency`).
    lat_sum: Dur,
    /// Per-traffic-class served IOs (lane order: [`TrafficClass::lane`]).
    /// Lane sums are pinned to `reads + writes` / `bytes` — see
    /// [`SsdDevice::check_flow_conservation`].
    pub class_ios: [u64; N_TRAFFIC_LANES],
    pub class_bytes: [u64; N_TRAFFIC_LANES],
    /// Per-class summed pre-service wait (queue-depth + rate-server +
    /// pacer delays before the command starts service).
    pub class_wait: [Dur; N_TRAFFIC_LANES],
}

impl SsdDevice {
    pub fn new(cfg: SsdConfig) -> SsdDevice {
        SsdDevice::for_index(cfg, 0)
    }

    /// Construct the device at array position `idx`, picking up its fault
    /// plan from `cfg.faults[idx]` (fault-free when absent).
    pub fn for_index(cfg: SsdConfig, idx: usize) -> SsdDevice {
        let fault = cfg.faults.get(idx).cloned().unwrap_or_default();
        SsdDevice {
            cfg,
            fault,
            bw_free: Time::ZERO,
            iops_free: Time::ZERO,
            bg_bw_free: Time::ZERO,
            bg_iops_free: Time::ZERO,
            inflight: std::collections::VecDeque::new(),
            reads: 0,
            writes: 0,
            bytes: 0,
            errors: 0,
            attempts: 0,
            lat_sum: Dur::ZERO,
            class_ios: [0; N_TRAFFIC_LANES],
            class_bytes: [0; N_TRAFFIC_LANES],
            class_wait: [Dur::ZERO; N_TRAFFIC_LANES],
        }
    }

    /// Is the device permanently dead at `t`?
    #[inline]
    pub fn is_dead_at(&self, t: Time) -> bool {
        matches!(self.fault.dead_from, Some(d) if t >= d)
    }

    /// Submit one IO at time `submit`; returns its completion time. Assumes
    /// success — fault-aware callers use [`SsdDevice::submit_checked`].
    pub fn submit(
        &mut self,
        submit: Time,
        kind: IoKind,
        class: TrafficClass,
        bytes: u32,
        rng: &mut Rng,
    ) -> Time {
        self.submit_checked(submit, kind, class, bytes, rng).at
    }

    /// Submit one IO at time `submit`; returns its resolution time and
    /// error status (see [`IoCompletion`]). With an empty fault plan and
    /// `BgShare::None` this is exactly the historical `submit` path: same
    /// servers, same single jitter draw, never an error — whatever the
    /// traffic class (the tag is then pure accounting).
    pub fn submit_checked(
        &mut self,
        submit: Time,
        kind: IoKind,
        class: TrafficClass,
        bytes: u32,
        rng: &mut Rng,
    ) -> IoCompletion {
        // Permanent death: the host's timeout path. Short-circuits before
        // the servers and the jitter draw so sibling devices (and any
        // fault-free rerun of the same seed) are unaffected.
        if self.is_dead_at(submit) {
            self.errors += 1;
            self.attempts += 1;
            let at = submit + self.cfg.read_latency;
            self.lat_sum += at - submit;
            return IoCompletion {
                at,
                error: Some(IoError::DeviceDead),
            };
        }

        // Queue-depth server: drain completed IOs, then — if the device
        // queue is still full — the new IO starts when the earliest
        // in-flight completion frees a slot.
        while let Some(&front) = self.inflight.front() {
            if front <= submit {
                self.inflight.pop_front();
            } else {
                break;
            }
        }
        let mut start = submit;
        if self.inflight.len() >= self.cfg.queue_depth as usize {
            // wait for a slot
            start = self.inflight.pop_front().unwrap().max(start);
        }

        // Sharing policy: rate multiplier, server-pair selection, and the
        // Weighted pacer share for this IO's class. Under `BgShare::None`
        // this resolves to (1.0, primary servers, unpaced) — multiplying a
        // rate by exactly 1.0 keeps the arithmetic bit-identical to the
        // pre-traffic-class device.
        let (rate_mult, bg_servers, pace_share) =
            self.cfg.bg_share.route(class.is_background());

        // Weighted command pacer: holds background to its share of R_IO
        // before it reaches the shared command processor.
        if pace_share > 0.0 && self.cfg.iops.is_finite() && self.cfg.iops > 0.0 {
            let gap = Dur::secs(1.0 / (self.cfg.iops * pace_share));
            if start < self.bg_iops_free {
                start = self.bg_iops_free;
            }
            self.bg_iops_free = start + gap;
        }

        // IOPS server (the partitioned background pair under `Cap`).
        if self.cfg.iops.is_finite() && self.cfg.iops > 0.0 {
            let gap = Dur::secs(1.0 / (self.cfg.iops * rate_mult));
            let free = if bg_servers {
                &mut self.bg_iops_free
            } else {
                &mut self.iops_free
            };
            if start < *free {
                start = *free;
            }
            *free = start + gap;
        }

        // Device latency: base, times any scheduled spike window, times
        // jitter (the jitter draw happens regardless of spikes, keeping
        // the RNG draw order identical across fault plans).
        let mut base = match kind {
            IoKind::Read => self.cfg.read_latency,
            IoKind::Write => self.cfg.write_latency,
        };
        for s in &self.fault.latency_spikes {
            if submit >= s.from && submit < s.until {
                base = Dur((base.0 as f64 * s.factor) as u64);
                break;
            }
        }
        let lat = if self.cfg.jitter_frac > 0.0 {
            let f = 1.0 + self.cfg.jitter_frac * (2.0 * rng.f64() - 1.0);
            Dur((base.0 as f64 * f) as u64)
        } else {
            base
        };

        // Bandwidth server: transfer occupies bytes/B_IO of channel time
        // (the partitioned background channel under `Cap`).
        let mut done = start + lat;
        if self.cfg.bandwidth_bps.is_finite() && self.cfg.bandwidth_bps > 0.0 {
            let xfer = Dur::secs(bytes as f64 / (self.cfg.bandwidth_bps * rate_mult));
            let chan = if bg_servers {
                &mut self.bg_bw_free
            } else {
                &mut self.bw_free
            };
            let chan_start = (*chan).max(start);
            let chan_done = chan_start + xfer;
            *chan = chan_done;
            done = done.max(chan_done);
            // Weighted byte pacer: the transfer also claims pacer-channel
            // time at the background share of B_IO.
            if pace_share > 0.0 {
                let xfer_pace = Dur::secs(bytes as f64 / (self.cfg.bandwidth_bps * pace_share));
                let p_start = self.bg_bw_free.max(start);
                let p_done = p_start + xfer_pace;
                self.bg_bw_free = p_done;
                done = done.max(p_done);
            }
        }

        // Sorted insert (equivalent to push_back when completions happen to
        // be monotone, which keeps single-core runs bit-identical).
        let pos = self.inflight.partition_point(|&t| t <= done);
        self.inflight.insert(pos, done);
        match kind {
            IoKind::Read => self.reads += 1,
            IoKind::Write => self.writes += 1,
        }
        self.bytes += bytes as u64;
        self.attempts += 1;
        self.lat_sum += done - submit;
        let lane = class.lane();
        self.class_ios[lane] += 1;
        self.class_bytes[lane] += bytes as u64;
        self.class_wait[lane] += start - submit;

        // Transient-error window: the attempt occupied the servers above
        // (a failed read costs its latency); the draw happens only for
        // submissions inside a window, so fault-free time regions consume
        // no extra randomness.
        let mut error = None;
        for w in &self.fault.error_windows {
            if submit >= w.from && submit < w.until {
                if w.prob >= 1.0 || rng.f64() < w.prob {
                    self.errors += 1;
                    error = Some(IoError::Transient);
                }
                break;
            }
        }
        IoCompletion { at: done, error }
    }

    pub fn stats(&self) -> DeviceStats {
        DeviceStats {
            ios: self.reads + self.writes,
            bytes: self.bytes,
            errors: self.errors,
            mean_latency: Dur(self.lat_sum.0 / self.attempts.max(1)),
        }
    }

    pub fn reset_stats(&mut self) {
        self.reads = 0;
        self.writes = 0;
        self.bytes = 0;
        self.errors = 0;
        self.attempts = 0;
        self.lat_sum = Dur::ZERO;
        self.class_ios = [0; N_TRAFFIC_LANES];
        self.class_bytes = [0; N_TRAFFIC_LANES];
        self.class_wait = [Dur::ZERO; N_TRAFFIC_LANES];
    }

    /// Flow-conservation invariant: the per-class lanes must sum exactly to
    /// the untyped served totals. Every served IO increments exactly one
    /// lane, so a violation means a counting path bypassed the class
    /// accounting — panic loudly rather than report skewed lanes.
    pub fn check_flow_conservation(&self) {
        let lane_ios: u64 = self.class_ios.iter().sum();
        let lane_bytes: u64 = self.class_bytes.iter().sum();
        assert_eq!(
            lane_ios,
            self.reads + self.writes,
            "traffic-class IO lanes out of sync with device totals"
        );
        assert_eq!(
            lane_bytes, self.bytes,
            "traffic-class byte lanes out of sync with device totals"
        );
    }
}

/// A sharded array of `n_ssd` independent devices (see the module docs).
///
/// Each device keeps its own latency/queue-depth/IOPS/bandwidth servers and
/// its own submission queue; the array only routes. Stats are aggregated on
/// demand so `RunStats` stays device-count agnostic, while
/// [`SsdArray::per_device_ios`] / [`SsdArray::per_device_stats`] expose the
/// balance for skew and brown-out analysis.
#[derive(Debug, Clone)]
pub struct SsdArray {
    pub cfg: SsdConfig,
    devices: Vec<SsdDevice>,
}

impl SsdArray {
    pub fn new(cfg: SsdConfig) -> SsdArray {
        let n = cfg.n_ssd.max(1) as usize;
        let devices = (0..n)
            .map(|i| SsdDevice::for_index(cfg.clone(), i))
            .collect();
        SsdArray { cfg, devices }
    }

    #[inline]
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Device owning a shard route (stable: pure function of the route).
    #[inline]
    pub fn device_of(&self, shard: u64) -> usize {
        (shard % self.devices.len() as u64) as usize
    }

    /// Submit one IO routed by `shard`; returns its completion time.
    /// Assumes success — fault-aware callers use
    /// [`SsdArray::submit_checked`].
    #[inline]
    pub fn submit(
        &mut self,
        submit: Time,
        shard: u64,
        kind: IoKind,
        class: TrafficClass,
        bytes: u32,
        rng: &mut Rng,
    ) -> Time {
        self.submit_checked(submit, shard, kind, class, bytes, rng).at
    }

    /// Submit one IO routed by `shard`, with fault reporting. When the
    /// routed device is permanently dead and the array has a live sibling,
    /// the IO is re-routed to the next live device (the replica / refill
    /// path: a mirrored array serves the read elsewhere) — the brown-out
    /// then shows up as load skew on the survivors rather than hard errors.
    /// A single-device array (or fully dead array) reports `DeviceDead`.
    #[inline]
    pub fn submit_checked(
        &mut self,
        submit: Time,
        shard: u64,
        kind: IoKind,
        class: TrafficClass,
        bytes: u32,
        rng: &mut Rng,
    ) -> IoCompletion {
        let n = self.devices.len();
        let mut d = self.device_of(shard);
        if n > 1 && self.devices[d].is_dead_at(submit) {
            for step in 1..n {
                let alt = (d + step) % n;
                if !self.devices[alt].is_dead_at(submit) {
                    d = alt;
                    break;
                }
            }
        }
        self.devices[d].submit_checked(submit, kind, class, bytes, rng)
    }

    pub fn reads(&self) -> u64 {
        self.devices.iter().map(|d| d.reads).sum()
    }

    pub fn writes(&self) -> u64 {
        self.devices.iter().map(|d| d.writes).sum()
    }

    pub fn bytes(&self) -> u64 {
        self.devices.iter().map(|d| d.bytes).sum()
    }

    pub fn errors(&self) -> u64 {
        self.devices.iter().map(|d| d.errors).sum()
    }

    /// Array-wide per-traffic-class served IOs (lane order:
    /// [`TrafficClass::lane`]).
    pub fn class_ios(&self) -> [u64; N_TRAFFIC_LANES] {
        let mut out = [0u64; N_TRAFFIC_LANES];
        for d in &self.devices {
            for (o, v) in out.iter_mut().zip(d.class_ios.iter()) {
                *o += v;
            }
        }
        out
    }

    /// Array-wide per-traffic-class bytes.
    pub fn class_bytes(&self) -> [u64; N_TRAFFIC_LANES] {
        let mut out = [0u64; N_TRAFFIC_LANES];
        for d in &self.devices {
            for (o, v) in out.iter_mut().zip(d.class_bytes.iter()) {
                *o += v;
            }
        }
        out
    }

    /// Array-wide per-traffic-class summed pre-service wait.
    pub fn class_wait(&self) -> [Dur; N_TRAFFIC_LANES] {
        let mut out = [Dur::ZERO; N_TRAFFIC_LANES];
        for d in &self.devices {
            for (o, v) in out.iter_mut().zip(d.class_wait.iter()) {
                *o += *v;
            }
        }
        out
    }

    /// Total background-lane IOs (every lane except foreground).
    pub fn bg_ios(&self) -> u64 {
        self.class_ios()[1..].iter().sum()
    }

    /// Total background-lane bytes.
    pub fn bg_bytes(&self) -> u64 {
        self.class_bytes()[1..].iter().sum()
    }

    /// Assert the per-class lanes sum to the untyped totals on every
    /// device (see [`SsdDevice::check_flow_conservation`]).
    pub fn check_flow_conservation(&self) {
        for d in &self.devices {
            d.check_flow_conservation();
        }
    }

    /// Per-device total IO counts (reads + writes), for balance reporting.
    pub fn per_device_ios(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.reads + d.writes).collect()
    }

    /// Per-device byte / error / latency stats (skew and brown-outs).
    pub fn per_device_stats(&self) -> Vec<DeviceStats> {
        self.devices.iter().map(|d| d.stats()).collect()
    }

    pub fn reset_stats(&mut self) {
        for d in &mut self.devices {
            d.reset_stats();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FG: TrafficClass = TrafficClass::Foreground;

    #[test]
    fn jitter_symmetric_and_bounded() {
        let mut d = SsdDevice::new(SsdConfig {
            iops: f64::INFINITY,
            bandwidth_bps: f64::INFINITY,
            ..SsdConfig::optane_array() // keeps the 15% jitter
        });
        let mut rng = Rng::new(5);
        let mut sum = 0.0;
        let n = 20_000;
        for i in 0..n {
            // Space submissions so the queue-depth server stays idle.
            let t = Time::ZERO + Dur::us(20.0) * i;
            let done = d.submit(t, IoKind::Read, FG, 512, &mut rng);
            let lat = (done - t).as_us();
            assert!((8.5..=11.5).contains(&lat), "lat {lat}");
            sum += lat;
        }
        let mean = sum / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn uncontended_read_latency() {
        let mut d = SsdDevice::new(SsdConfig {
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        });
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO + Dur::us(100.0);
        let done = d.submit(t0, IoKind::Read, FG, 4096, &mut rng);
        assert_eq!(done, t0 + Dur::us(10.0));
        assert_eq!(d.reads, 1);
    }

    #[test]
    fn iops_cap_enforced() {
        // 1 MIOPS -> 1 us between command starts.
        let cfg = SsdConfig {
            iops: 1e6,
            bandwidth_bps: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        let c2 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        let c3 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        assert_eq!(c2 - c1, Dur::us(1.0));
        assert_eq!(c3 - c2, Dur::us(1.0));
    }

    #[test]
    fn bandwidth_cap_enforced() {
        // 1 GB/s, 1 MB IOs -> 1 ms per transfer dominates latency.
        let cfg = SsdConfig {
            bandwidth_bps: 1e9,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, FG, 1_000_000, &mut rng);
        let c2 = d.submit(t0, IoKind::Read, FG, 1_000_000, &mut rng);
        assert_eq!(c1, t0 + Dur::ms(1.0));
        assert_eq!(c2, t0 + Dur::ms(2.0));
    }

    #[test]
    fn queue_depth_backpressure() {
        let cfg = SsdConfig {
            queue_depth: 2,
            bandwidth_bps: f64::INFINITY,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        let _c2 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        // Third IO at t0 with QD=2 waits for c1 to finish.
        let c3 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        assert_eq!(c3, c1 + Dur::us(10.0));
    }

    #[test]
    fn out_of_order_submissions_wait_on_earliest_completion() {
        // Regression test for the in-flight queue invariant: multi-core
        // stores submit at per-core clocks that are not globally monotone,
        // so completion order can invert submission order. A full queue
        // must wait on the *earliest* completion, not the oldest entry.
        let cfg = SsdConfig {
            queue_depth: 2,
            bandwidth_bps: f64::INFINITY,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            write_latency: Dur::us(100.0),
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let w = d.submit(Time::ZERO, IoKind::Write, FG, 512, &mut rng);
        assert_eq!(w, Time::ZERO + Dur::us(100.0));
        // A read submitted 1us later (by another core) completes at 11us —
        // long before the write.
        let r1 = d.submit(Time::ZERO + Dur::us(1.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(r1, Time::ZERO + Dur::us(11.0));
        // Queue full: the third IO waits for the read slot at 11us and
        // completes at 21us. The old pop_front-of-submission-order queue
        // waited on the 100us write instead (completion at 110us).
        let r2 = d.submit(Time::ZERO + Dur::us(2.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(r2, Time::ZERO + Dur::us(21.0));
    }

    #[test]
    fn out_of_order_submissions_qd1() {
        // queue_depth 1: strictly serial device. Interleaved out-of-order
        // submissions serialize on whatever is in flight.
        let cfg = SsdConfig {
            queue_depth: 1,
            bandwidth_bps: f64::INFINITY,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            write_latency: Dur::us(100.0),
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let w = d.submit(Time::ZERO + Dur::us(5.0), IoKind::Write, FG, 512, &mut rng);
        assert_eq!(w, Time::ZERO + Dur::us(105.0));
        // Earlier-clock core submits at 1us: slot frees at 105us.
        let r1 = d.submit(Time::ZERO + Dur::us(1.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(r1, Time::ZERO + Dur::us(115.0));
        let r2 = d.submit(Time::ZERO + Dur::us(2.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(r2, Time::ZERO + Dur::us(125.0));
    }

    #[test]
    fn write_counts() {
        let mut d = SsdDevice::new(SsdConfig::optane_array());
        let mut rng = Rng::new(1);
        d.submit(Time::ZERO, IoKind::Write, FG, 2048, &mut rng);
        assert_eq!(d.writes, 1);
        assert_eq!(d.bytes, 2048);
    }

    #[test]
    fn array_n1_is_bit_identical_to_single_device() {
        // Determinism guard: with n_ssd = 1 the array must reproduce the
        // bare device's completion times exactly, whatever the shard route.
        let cfg = SsdConfig::optane_array(); // jittered: exercises the RNG path
        let mut dev = SsdDevice::new(cfg.clone());
        let mut arr = SsdArray::new(cfg);
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for i in 0..5_000u64 {
            let t = Time::ZERO + Dur::ns(730.0) * i;
            let kind = if i % 3 == 0 { IoKind::Write } else { IoKind::Read };
            let a = dev.submit(t, kind, FG, 1536, &mut r1);
            let b = arr.submit(t, i.wrapping_mul(0x9e37), kind, FG, 1536, &mut r2);
            assert_eq!(a, b, "io {i}");
        }
        assert_eq!(dev.reads, arr.reads());
        assert_eq!(dev.writes, arr.writes());
        assert_eq!(dev.bytes, arr.bytes());
    }

    #[test]
    fn empty_fault_plan_is_bit_identical() {
        // A configured-but-empty FaultPlan must not perturb completions or
        // RNG draw order relative to no plan at all.
        let base = SsdConfig::optane_array();
        let with_plan = base.clone().with_fault(0, FaultPlan::default());
        let mut d1 = SsdDevice::new(base);
        let mut d2 = SsdDevice::new(with_plan);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for i in 0..2_000u64 {
            let t = Time::ZERO + Dur::ns(900.0) * i;
            let a = d1.submit_checked(t, IoKind::Read, FG, 1024, &mut r1);
            let b = d2.submit_checked(t, IoKind::Read, FG, 1024, &mut r2);
            assert_eq!(a, b, "io {i}");
            assert!(a.is_ok());
        }
        assert_eq!(r1.next_u64(), r2.next_u64(), "RNG streams must stay in sync");
    }

    #[test]
    fn transient_error_window_is_deterministic_and_scoped() {
        let plan = FaultPlan {
            error_windows: vec![ErrorWindow {
                from: Time::ZERO + Dur::us(100.0),
                until: Time::ZERO + Dur::us(200.0),
                prob: 1.0,
            }],
            ..FaultPlan::default()
        };
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        }
        .with_fault(0, plan);
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(11);
        // Before the window: success.
        let ok = d.submit_checked(Time::ZERO + Dur::us(50.0), IoKind::Read, FG, 512, &mut rng);
        assert!(ok.is_ok());
        // Inside: Transient, and the failed attempt still costs its latency.
        let bad = d.submit_checked(Time::ZERO + Dur::us(150.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(bad.error, Some(IoError::Transient));
        assert_eq!(bad.at, Time::ZERO + Dur::us(160.0));
        // After: success again.
        let ok2 = d.submit_checked(Time::ZERO + Dur::us(250.0), IoKind::Read, FG, 512, &mut rng);
        assert!(ok2.is_ok());
        assert_eq!(d.errors, 1);
        assert_eq!(d.reads, 3, "failed attempts still occupy the device");
    }

    #[test]
    fn latency_spike_window_multiplies_latency() {
        let plan = FaultPlan {
            latency_spikes: vec![LatencySpike {
                from: Time::ZERO + Dur::ms(1.0),
                until: Time::ZERO + Dur::ms(2.0),
                factor: 10.0,
            }],
            ..FaultPlan::default()
        };
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        }
        .with_fault(0, plan);
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(2);
        let fast = d.submit_checked(Time::ZERO, IoKind::Read, FG, 512, &mut rng);
        assert_eq!(fast.at, Time::ZERO + Dur::us(10.0));
        let slow = d.submit_checked(Time::ZERO + Dur::ms(1.5), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(slow.at, Time::ZERO + Dur::ms(1.5) + Dur::us(100.0));
        let after = d.submit_checked(Time::ZERO + Dur::ms(3.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(after.at, Time::ZERO + Dur::ms(3.0) + Dur::us(10.0));
    }

    #[test]
    fn dead_device_short_circuits_without_rng_draws() {
        let plan = FaultPlan {
            dead_from: Some(Time::ZERO),
            ..FaultPlan::default()
        };
        // Jittered config: a served IO would draw from the RNG.
        let cfg = SsdConfig::optane_array().with_fault(0, plan);
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(13);
        let mut shadow = Rng::new(13);
        let c = d.submit_checked(Time::ZERO + Dur::us(5.0), IoKind::Read, FG, 512, &mut rng);
        assert_eq!(c.error, Some(IoError::DeviceDead));
        assert_eq!(c.at, Time::ZERO + Dur::us(15.0), "timeout = one read latency");
        assert_eq!(d.errors, 1);
        assert_eq!(d.reads, 0, "a dead device serves nothing");
        assert_eq!(rng.next_u64(), shadow.next_u64(), "no RNG draw on the dead path");
    }

    #[test]
    fn array_routes_around_dead_device() {
        let plan = FaultPlan {
            dead_from: Some(Time::ZERO),
            ..FaultPlan::default()
        };
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            n_ssd: 2,
            ..SsdConfig::optane_array()
        }
        .with_fault(0, plan);
        let mut arr = SsdArray::new(cfg);
        let mut rng = Rng::new(3);
        // Shard 0 routes to the dead device 0; the array re-routes to 1.
        let c = arr.submit_checked(Time::ZERO, 0, IoKind::Read, FG, 512, &mut rng);
        assert!(c.is_ok());
        let per = arr.per_device_ios();
        assert_eq!(per, vec![0, 1], "survivor absorbed the re-routed IO");
        assert_eq!(arr.errors(), 0);

        // A single-device array has no replica path: hard error surfaces.
        let cfg1 = SsdConfig {
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        }
        .with_fault(
            0,
            FaultPlan {
                dead_from: Some(Time::ZERO),
                ..FaultPlan::default()
            },
        );
        let mut lone = SsdArray::new(cfg1);
        let c = lone.submit_checked(Time::ZERO, 0, IoKind::Read, FG, 512, &mut rng);
        assert_eq!(c.error, Some(IoError::DeviceDead));
    }

    #[test]
    fn per_device_stats_expose_bytes_and_errors() {
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            n_ssd: 2,
            ..SsdConfig::optane_array()
        };
        let mut arr = SsdArray::new(cfg);
        let mut rng = Rng::new(8);
        for i in 0..10u64 {
            arr.submit(Time::ZERO + Dur::us(20.0) * i, i % 2, IoKind::Read, FG, 4096, &mut rng);
        }
        let stats = arr.per_device_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].ios, 5);
        assert_eq!(stats[1].ios, 5);
        assert_eq!(stats[0].bytes, 5 * 4096);
        assert_eq!(stats[0].errors, 0);
        assert_eq!(stats[0].mean_latency, Dur::us(10.0));
    }

    #[test]
    fn array_aggregate_iops_scales_with_n_ssd() {
        // IO-only service at the device level: per-device 1 MIOPS command
        // rate means n devices drain n× as fast when routes are balanced.
        let run = |n_ssd: u32| {
            let cfg = SsdConfig {
                iops: 1e6,
                bandwidth_bps: f64::INFINITY,
                jitter_frac: 0.0,
                queue_depth: u32::MAX,
                n_ssd,
                ..SsdConfig::optane_array()
            };
            let mut arr = SsdArray::new(cfg);
            let mut rng = Rng::new(3);
            let mut last = Time::ZERO;
            for i in 0..80_000u64 {
                last = last.max(arr.submit(Time::ZERO, i, IoKind::Read, FG, 512, &mut rng));
            }
            last.as_secs()
        };
        let t1 = run(1);
        let t4 = run(4);
        let t8 = run(8);
        assert!(
            (t1 / t4 - 4.0).abs() < 0.05,
            "4-device drain speedup {} != ~4",
            t1 / t4
        );
        assert!(
            (t1 / t8 - 8.0).abs() < 0.1,
            "8-device drain speedup {} != ~8",
            t1 / t8
        );
    }

    #[test]
    fn array_routing_is_stable_and_spreads() {
        let arr = SsdArray::new(SsdConfig::optane_array().with_n_ssd(4));
        assert_eq!(arr.n_devices(), 4);
        let mut seen = [false; 4];
        for shard in 0..64u64 {
            let d = arr.device_of(shard);
            assert_eq!(d, arr.device_of(shard), "route must be stable");
            seen[d] = true;
        }
        assert!(seen.iter().all(|&s| s), "all devices reachable");
    }

    #[test]
    fn array_skewed_routes_pile_onto_one_device() {
        // All shards equal: one device serves everything — the array models
        // placement skew rather than silently load-balancing.
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            n_ssd: 4,
            ..SsdConfig::optane_array()
        };
        let mut arr = SsdArray::new(cfg);
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            arr.submit(Time::ZERO, 42, IoKind::Read, FG, 512, &mut rng);
        }
        let per = arr.per_device_ios();
        assert_eq!(per.iter().sum::<u64>(), 100);
        assert_eq!(per[2], 100, "shard 42 % 4 = 2 owns every IO");
    }

    #[test]
    fn bg_class_under_none_is_bit_identical() {
        // Under BgShare::None the traffic class is pure accounting: a
        // mixed fg/bg stream must produce the same completions and RNG
        // draw order as the same stream tagged all-foreground.
        let cfg = SsdConfig::optane_array(); // jittered: exercises the RNG path
        let mut d1 = SsdDevice::new(cfg.clone());
        let mut d2 = SsdDevice::new(cfg);
        let mut r1 = Rng::new(21);
        let mut r2 = Rng::new(21);
        let classes = [
            TrafficClass::Foreground,
            TrafficClass::Background(BgKind::Compaction),
            TrafficClass::Background(BgKind::Flush),
            TrafficClass::Background(BgKind::Defrag),
            TrafficClass::Background(BgKind::WalFlush),
        ];
        for i in 0..5_000u64 {
            let t = Time::ZERO + Dur::ns(640.0) * i;
            let kind = if i % 4 == 0 { IoKind::Write } else { IoKind::Read };
            let a = d1.submit(t, kind, classes[(i % 5) as usize], 2048, &mut r1);
            let b = d2.submit(t, kind, FG, 2048, &mut r2);
            assert_eq!(a, b, "io {i}");
        }
        assert_eq!(r1.next_u64(), r2.next_u64(), "RNG streams must stay in sync");
        // ... while the lanes differ: d1 spread its IOs, d2 put all in fg.
        assert_eq!(d1.class_ios.iter().sum::<u64>(), 5_000);
        assert_eq!(d1.class_ios[0], 1_000);
        assert_eq!(d2.class_ios[0], 5_000);
        d1.check_flow_conservation();
        d2.check_flow_conservation();
    }

    #[test]
    fn lane_counters_conserve_flow() {
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            n_ssd: 2,
            ..SsdConfig::optane_array()
        };
        let mut arr = SsdArray::new(cfg);
        let mut rng = Rng::new(6);
        for i in 0..30u64 {
            let class = match i % 3 {
                0 => FG,
                1 => TrafficClass::Background(BgKind::Compaction),
                _ => TrafficClass::Background(BgKind::WalFlush),
            };
            arr.submit(Time::ZERO + Dur::us(30.0) * i, i, IoKind::Write, class, 4096, &mut rng);
        }
        let ios = arr.class_ios();
        let bytes = arr.class_bytes();
        assert_eq!(ios, [10, 10, 0, 0, 10]);
        assert_eq!(ios.iter().sum::<u64>(), arr.reads() + arr.writes());
        assert_eq!(bytes.iter().sum::<u64>(), arr.bytes());
        assert_eq!(arr.bg_ios(), 20);
        assert_eq!(arr.bg_bytes(), 20 * 4096);
        arr.check_flow_conservation();
        // Uncontended stream: no pre-service waits accumulate.
        assert_eq!(arr.class_wait()[0], Dur::ZERO);
    }

    #[test]
    fn cap_partitions_rate_servers() {
        // Cap{0.5} at 1 MIOPS: each class gets its own 0.5 MIOPS command
        // server (2 us gaps), and background never queues foreground.
        let cfg = SsdConfig {
            iops: 1e6,
            bandwidth_bps: f64::INFINITY,
            jitter_frac: 0.0,
            bg_share: BgShare::Cap { frac: 0.5 },
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let bg = TrafficClass::Background(BgKind::Compaction);
        // Load the background partition first...
        let b1 = d.submit(t0, IoKind::Write, bg, 512, &mut rng);
        let b2 = d.submit(t0, IoKind::Write, bg, 512, &mut rng);
        assert_eq!(b2 - b1, Dur::us(2.0), "bg partition at frac*R_IO");
        // ...then foreground: served immediately on its own pair.
        let f1 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        let f2 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        assert_eq!(f1, t0 + Dur::us(10.0), "fg start unaffected by bg load");
        assert_eq!(f2 - f1, Dur::us(2.0), "fg partition at (1-frac)*R_IO");
    }

    #[test]
    fn cap_fg_makespan_monotone_in_frac() {
        // Shrinking the background cap can only speed foreground up: the
        // foreground makespan of a fixed interleaved stream must be
        // non-increasing as frac shrinks.
        let run = |frac: f64| {
            let cfg = SsdConfig {
                bandwidth_bps: 1e9,
                iops: f64::INFINITY,
                jitter_frac: 0.0,
                queue_depth: u32::MAX,
                bg_share: BgShare::Cap { frac },
                ..SsdConfig::optane_array()
            };
            let mut d = SsdDevice::new(cfg);
            let mut rng = Rng::new(5);
            let bg = TrafficClass::Background(BgKind::Compaction);
            let mut last_fg = Time::ZERO;
            for i in 0..200u64 {
                let t = Time::ZERO + Dur::us(1.0) * i;
                d.submit(t, IoKind::Write, bg, 32 * 1024, &mut rng);
                last_fg = last_fg.max(d.submit(t, IoKind::Read, FG, 4096, &mut rng));
            }
            last_fg
        };
        let m25 = run(0.25);
        let m50 = run(0.5);
        let m75 = run(0.75);
        assert!(m25 <= m50, "frac 0.25 fg makespan {m25:?} > 0.5's {m50:?}");
        assert!(m50 <= m75, "frac 0.5 fg makespan {m50:?} > 0.75's {m75:?}");
        assert!(m25 < m75, "caps must actually change fg service");
    }

    #[test]
    fn weighted_paces_background_only() {
        // Weighted{3,1} at 1 MIOPS: background commands are paced to a
        // 0.25 MIOPS share (4 us apart); foreground is never paced.
        let cfg = SsdConfig {
            iops: 1e6,
            bandwidth_bps: f64::INFINITY,
            jitter_frac: 0.0,
            bg_share: BgShare::Weighted { fg_w: 3, bg_w: 1 },
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(7);
        let t0 = Time::ZERO;
        let f1 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        let f2 = d.submit(t0, IoKind::Read, FG, 512, &mut rng);
        assert_eq!(f1, t0 + Dur::us(10.0));
        assert_eq!(f2 - f1, Dur::us(1.0), "fg at the full shared R_IO");
        let bg = TrafficClass::Background(BgKind::Defrag);
        let b1 = d.submit(t0 + Dur::us(2.0), IoKind::Write, bg, 512, &mut rng);
        let b2 = d.submit(t0 + Dur::us(2.0), IoKind::Write, bg, 512, &mut rng);
        assert_eq!(b2 - b1, Dur::us(4.0), "bg paced to bg_w/(fg_w+bg_w)");
    }
}
