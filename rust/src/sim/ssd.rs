//! SSD device model.
//!
//! Stands in for the paper's Optane 900P NVMe drives accessed as block
//! devices through io_uring. One IO is (1) a pre-IO CPU suboperation
//! `T_IO_pre` (address computation + non-blocking submission), (2) device
//! latency `L_IO`, (3) a post-IO CPU suboperation `T_IO_post` (completion
//! check + buffer copy). The CPU suboperation times are charged by the core
//! (see `machine.rs`); this module models the device side: latency plus
//! three servers enforcing the Table 2 limits — queue depth, bandwidth
//! `B_IO` (bytes/sec), and random-access rate `R_IO` (IOPS).
//!
//! ## Multi-SSD sharding
//!
//! [`SsdArray`] composes `n_ssd` independent [`SsdDevice`]s, each with its
//! own queue-depth/IOPS/bandwidth servers (per-device submission queue).
//! Every `Step::Io` carries a **shard route** — a stable placement key the
//! store derives from what it is reading/writing (treekv: value-log block,
//! lsmkv: SSTable block id, cachekv: SOC slab hash) — and the array maps it
//! to a device with `shard % n_ssd`. The aggregate ceilings therefore scale
//! as `Θ_ssd = n_ssd · R_IO` and `n_ssd · B_IO` (the Eq 14 floors composed
//! with the array term), while a skewed route distribution exposes
//! per-device hotspots exactly like a real array. With `n_ssd = 1` every
//! route maps to device 0 and the array is bit-identical to the former
//! single-device path (same servers, same jitter RNG draw order).

use super::rng::Rng;
use super::time::{Dur, Time};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoKind {
    Read,
    Write,
}

#[derive(Debug, Clone)]
pub struct SsdConfig {
    /// Device read latency (submission to completion, uncontended).
    pub read_latency: Dur,
    /// Device write latency (writes land in the device buffer; Optane-class).
    pub write_latency: Dur,
    /// Max sustained bandwidth in bytes/sec, per device.
    pub bandwidth_bps: f64,
    /// Max random-access rate in IO/sec, per device.
    pub iops: f64,
    /// Device queue depth (in-flight IOs beyond this wait in the submission queue).
    pub queue_depth: u32,
    /// Default CPU-side suboperation times (can be overridden per workload).
    pub t_pre: Dur,
    pub t_post: Dur,
    /// Relative latency jitter (uniform in ±jitter_frac·latency). Real
    /// devices are not clock-exact; this jitter is also what naturally
    /// misaligns thread phases (§3.2.2's "timing will be mostly random") —
    /// a perfectly deterministic device can lock threads into the Fig 7(a)
    /// aligned pattern.
    pub jitter_frac: f64,
    /// Number of independent devices in the array. The latency / bandwidth /
    /// IOPS / queue-depth fields above are **per device**; [`SsdArray`]
    /// instantiates `n_ssd` of them and routes each IO by its shard key.
    pub n_ssd: u32,
}

impl SsdConfig {
    /// The paper's array: 4× Optane 900P. Combined ~2.2 MIOPS random reads,
    /// ~10 GB/s, ~10 µs read latency; deep queues.
    pub fn optane_array() -> SsdConfig {
        SsdConfig {
            read_latency: Dur::us(10.0),
            write_latency: Dur::us(10.0),
            bandwidth_bps: 10e9,
            iops: 2.2e6,
            queue_depth: 1024,
            t_pre: Dur::us(1.5),
            t_post: Dur::us(0.2),
            jitter_frac: 0.15,
            n_ssd: 1,
        }
    }

    /// A single Optane 900P (Fig 12(a): B_IO-limited scenario).
    pub fn optane_single() -> SsdConfig {
        SsdConfig {
            bandwidth_bps: 2.5e9,
            iops: 550e3,
            ..SsdConfig::optane_array()
        }
    }

    /// A slow SATA SSD (Fig 12(b): R_IO-limited scenario).
    pub fn sata_slow() -> SsdConfig {
        SsdConfig {
            read_latency: Dur::us(80.0),
            write_latency: Dur::us(80.0),
            bandwidth_bps: 0.5e9,
            iops: 75e3,
            queue_depth: 32,
            t_pre: Dur::us(1.5),
            t_post: Dur::us(0.2),
            jitter_frac: 0.3,
            n_ssd: 1,
        }
    }

    pub fn with_latency(mut self, d: Dur) -> SsdConfig {
        self.read_latency = d;
        self.write_latency = d;
        self
    }

    /// Set the array size (per-device limits stay as configured).
    pub fn with_n_ssd(mut self, n: u32) -> SsdConfig {
        self.n_ssd = n.max(1);
        self
    }
}

/// Runtime state of the SSD (array): latency + rate servers.
#[derive(Debug, Clone)]
pub struct SsdDevice {
    pub cfg: SsdConfig,
    /// Bandwidth server: time the device's data channel frees up.
    bw_free: Time,
    /// IOPS server: time the command processor frees up.
    iops_free: Time,
    /// Completion times of in-flight IOs (bounded by queue_depth). Kept as a
    /// sorted-ish ring: completions are monotone given monotone submissions.
    inflight: std::collections::VecDeque<Time>,
    pub reads: u64,
    pub writes: u64,
    pub bytes: u64,
}

impl SsdDevice {
    pub fn new(cfg: SsdConfig) -> SsdDevice {
        SsdDevice {
            cfg,
            bw_free: Time::ZERO,
            iops_free: Time::ZERO,
            inflight: std::collections::VecDeque::new(),
            reads: 0,
            writes: 0,
            bytes: 0,
        }
    }

    /// Submit one IO at time `submit`; returns its completion time.
    pub fn submit(&mut self, submit: Time, kind: IoKind, bytes: u32, rng: &mut Rng) -> Time {
        // Queue-depth server: if the device queue is full, the IO effectively
        // starts when the oldest in-flight IO completes.
        while let Some(&front) = self.inflight.front() {
            if front <= submit || self.inflight.len() < self.cfg.queue_depth as usize {
                if front <= submit {
                    self.inflight.pop_front();
                    continue;
                }
            }
            break;
        }
        let mut start = submit;
        if self.inflight.len() >= self.cfg.queue_depth as usize {
            // wait for a slot
            start = self.inflight.pop_front().unwrap().max(start);
        }

        // IOPS server.
        if self.cfg.iops.is_finite() && self.cfg.iops > 0.0 {
            let gap = Dur::secs(1.0 / self.cfg.iops);
            if start < self.iops_free {
                start = self.iops_free;
            }
            self.iops_free = start + gap;
        }

        // Bandwidth server: transfer occupies bytes/B_IO of channel time.
        let base = match kind {
            IoKind::Read => self.cfg.read_latency,
            IoKind::Write => self.cfg.write_latency,
        };
        let lat = if self.cfg.jitter_frac > 0.0 {
            let f = 1.0 + self.cfg.jitter_frac * (2.0 * rng.f64() - 1.0);
            Dur((base.0 as f64 * f) as u64)
        } else {
            base
        };
        let mut done = start + lat;
        if self.cfg.bandwidth_bps.is_finite() && self.cfg.bandwidth_bps > 0.0 {
            let xfer = Dur::secs(bytes as f64 / self.cfg.bandwidth_bps);
            let chan_start = self.bw_free.max(start);
            let chan_done = chan_start + xfer;
            self.bw_free = chan_done;
            done = done.max(chan_done);
        }

        self.inflight.push_back(done);
        match kind {
            IoKind::Read => self.reads += 1,
            IoKind::Write => self.writes += 1,
        }
        self.bytes += bytes as u64;
        done
    }

    pub fn reset_stats(&mut self) {
        self.reads = 0;
        self.writes = 0;
        self.bytes = 0;
    }
}

/// A sharded array of `n_ssd` independent devices (see the module docs).
///
/// Each device keeps its own latency/queue-depth/IOPS/bandwidth servers and
/// its own submission queue; the array only routes. Stats are aggregated on
/// demand so `RunStats` stays device-count agnostic, while
/// [`SsdArray::per_device_ios`] exposes the balance for skew analysis.
#[derive(Debug, Clone)]
pub struct SsdArray {
    pub cfg: SsdConfig,
    devices: Vec<SsdDevice>,
}

impl SsdArray {
    pub fn new(cfg: SsdConfig) -> SsdArray {
        let n = cfg.n_ssd.max(1) as usize;
        let devices = (0..n).map(|_| SsdDevice::new(cfg.clone())).collect();
        SsdArray { cfg, devices }
    }

    #[inline]
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Device owning a shard route (stable: pure function of the route).
    #[inline]
    pub fn device_of(&self, shard: u64) -> usize {
        (shard % self.devices.len() as u64) as usize
    }

    /// Submit one IO routed by `shard`; returns its completion time.
    #[inline]
    pub fn submit(
        &mut self,
        submit: Time,
        shard: u64,
        kind: IoKind,
        bytes: u32,
        rng: &mut Rng,
    ) -> Time {
        let d = self.device_of(shard);
        self.devices[d].submit(submit, kind, bytes, rng)
    }

    pub fn reads(&self) -> u64 {
        self.devices.iter().map(|d| d.reads).sum()
    }

    pub fn writes(&self) -> u64 {
        self.devices.iter().map(|d| d.writes).sum()
    }

    pub fn bytes(&self) -> u64 {
        self.devices.iter().map(|d| d.bytes).sum()
    }

    /// Per-device total IO counts (reads + writes), for balance reporting.
    pub fn per_device_ios(&self) -> Vec<u64> {
        self.devices.iter().map(|d| d.reads + d.writes).collect()
    }

    pub fn reset_stats(&mut self) {
        for d in &mut self.devices {
            d.reset_stats();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jitter_symmetric_and_bounded() {
        let mut d = SsdDevice::new(SsdConfig {
            iops: f64::INFINITY,
            bandwidth_bps: f64::INFINITY,
            ..SsdConfig::optane_array() // keeps the 15% jitter
        });
        let mut rng = Rng::new(5);
        let mut sum = 0.0;
        let n = 20_000;
        for i in 0..n {
            // Space submissions so the queue-depth server stays idle.
            let t = Time::ZERO + Dur::us(20.0) * i;
            let done = d.submit(t, IoKind::Read, 512, &mut rng);
            let lat = (done - t).as_us();
            assert!((8.5..=11.5).contains(&lat), "lat {lat}");
            sum += lat;
        }
        let mean = sum / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn uncontended_read_latency() {
        let mut d = SsdDevice::new(SsdConfig {
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        });
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO + Dur::us(100.0);
        let done = d.submit(t0, IoKind::Read, 4096, &mut rng);
        assert_eq!(done, t0 + Dur::us(10.0));
        assert_eq!(d.reads, 1);
    }

    #[test]
    fn iops_cap_enforced() {
        // 1 MIOPS -> 1 us between command starts.
        let cfg = SsdConfig {
            iops: 1e6,
            bandwidth_bps: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, 512, &mut rng);
        let c2 = d.submit(t0, IoKind::Read, 512, &mut rng);
        let c3 = d.submit(t0, IoKind::Read, 512, &mut rng);
        assert_eq!(c2 - c1, Dur::us(1.0));
        assert_eq!(c3 - c2, Dur::us(1.0));
    }

    #[test]
    fn bandwidth_cap_enforced() {
        // 1 GB/s, 1 MB IOs -> 1 ms per transfer dominates latency.
        let cfg = SsdConfig {
            bandwidth_bps: 1e9,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, 1_000_000, &mut rng);
        let c2 = d.submit(t0, IoKind::Read, 1_000_000, &mut rng);
        assert_eq!(c1, t0 + Dur::ms(1.0));
        assert_eq!(c2, t0 + Dur::ms(2.0));
    }

    #[test]
    fn queue_depth_backpressure() {
        let cfg = SsdConfig {
            queue_depth: 2,
            bandwidth_bps: f64::INFINITY,
            iops: f64::INFINITY,
            jitter_frac: 0.0,
            ..SsdConfig::optane_array()
        };
        let mut d = SsdDevice::new(cfg);
        let mut rng = Rng::new(1);
        let t0 = Time::ZERO;
        let c1 = d.submit(t0, IoKind::Read, 512, &mut rng);
        let _c2 = d.submit(t0, IoKind::Read, 512, &mut rng);
        // Third IO at t0 with QD=2 waits for c1 to finish.
        let c3 = d.submit(t0, IoKind::Read, 512, &mut rng);
        assert_eq!(c3, c1 + Dur::us(10.0));
    }

    #[test]
    fn write_counts() {
        let mut d = SsdDevice::new(SsdConfig::optane_array());
        let mut rng = Rng::new(1);
        d.submit(Time::ZERO, IoKind::Write, 2048, &mut rng);
        assert_eq!(d.writes, 1);
        assert_eq!(d.bytes, 2048);
    }

    #[test]
    fn array_n1_is_bit_identical_to_single_device() {
        // Determinism guard: with n_ssd = 1 the array must reproduce the
        // bare device's completion times exactly, whatever the shard route.
        let cfg = SsdConfig::optane_array(); // jittered: exercises the RNG path
        let mut dev = SsdDevice::new(cfg.clone());
        let mut arr = SsdArray::new(cfg);
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        for i in 0..5_000u64 {
            let t = Time::ZERO + Dur::ns(730.0) * i;
            let kind = if i % 3 == 0 { IoKind::Write } else { IoKind::Read };
            let a = dev.submit(t, kind, 1536, &mut r1);
            let b = arr.submit(t, i.wrapping_mul(0x9e37), kind, 1536, &mut r2);
            assert_eq!(a, b, "io {i}");
        }
        assert_eq!(dev.reads, arr.reads());
        assert_eq!(dev.writes, arr.writes());
        assert_eq!(dev.bytes, arr.bytes());
    }

    #[test]
    fn array_aggregate_iops_scales_with_n_ssd() {
        // IO-only service at the device level: per-device 1 MIOPS command
        // rate means n devices drain n× as fast when routes are balanced.
        let run = |n_ssd: u32| {
            let cfg = SsdConfig {
                iops: 1e6,
                bandwidth_bps: f64::INFINITY,
                jitter_frac: 0.0,
                queue_depth: u32::MAX,
                n_ssd,
                ..SsdConfig::optane_array()
            };
            let mut arr = SsdArray::new(cfg);
            let mut rng = Rng::new(3);
            let mut last = Time::ZERO;
            for i in 0..80_000u64 {
                last = last.max(arr.submit(Time::ZERO, i, IoKind::Read, 512, &mut rng));
            }
            last.as_secs()
        };
        let t1 = run(1);
        let t4 = run(4);
        let t8 = run(8);
        assert!(
            (t1 / t4 - 4.0).abs() < 0.05,
            "4-device drain speedup {} != ~4",
            t1 / t4
        );
        assert!(
            (t1 / t8 - 8.0).abs() < 0.1,
            "8-device drain speedup {} != ~8",
            t1 / t8
        );
    }

    #[test]
    fn array_routing_is_stable_and_spreads() {
        let arr = SsdArray::new(SsdConfig::optane_array().with_n_ssd(4));
        assert_eq!(arr.n_devices(), 4);
        let mut seen = [false; 4];
        for shard in 0..64u64 {
            let d = arr.device_of(shard);
            assert_eq!(d, arr.device_of(shard), "route must be stable");
            seen[d] = true;
        }
        assert!(seen.iter().all(|&s| s), "all devices reachable");
    }

    #[test]
    fn array_skewed_routes_pile_onto_one_device() {
        // All shards equal: one device serves everything — the array models
        // placement skew rather than silently load-balancing.
        let cfg = SsdConfig {
            jitter_frac: 0.0,
            n_ssd: 4,
            ..SsdConfig::optane_array()
        };
        let mut arr = SsdArray::new(cfg);
        let mut rng = Rng::new(4);
        for _ in 0..100 {
            arr.submit(Time::ZERO, 42, IoKind::Read, 512, &mut rng);
        }
        let per = arr.per_device_ios();
        assert_eq!(per.iter().sum::<u64>(), 100);
        assert_eq!(per[2], 100, "shard 42 % 4 = 2 owns every IO");
    }
}
