//! Phased (drifting) workload schedules: piecewise-stationary sequences of
//! (operation weights, key distribution) composed over `opgen`/`keygen`.
//!
//! The stationary YCSB presets validate the paper's claim at equilibrium;
//! these schedules supply the scenario where an *online* placement planner
//! earns its keep — the measured density ranking that was right for phase
//! `k` is wrong for phase `k+1`:
//!
//! - **diurnal** read↔write swing (C-like days, write-heavy nights): under
//!   the write-heavy phase cachekv's LRU lists out-access its hash chains
//!   (every insert walks eviction candidates), the reverse under reads.
//! - **scan swing** (B-like point reads ↔ E-like scans): scans never touch
//!   lsmkv's block restart arrays (they walk chains and block bytes), so
//!   the restarts' placement density collapses mid-run.
//! - **Zipf-exponent drift** sweeping `s` *through* 1.0 — the schedule that
//!   made the `keygen` θ-pole guard a prerequisite.
//! - **hotspot shift**: the hashed hot set changes membership mid-run, so
//!   hit ratios (and with them the access mix) turn.
//!
//! Each phase runs for a simulated-time `window`; the adaptive runner
//! prepends a settle slack before measuring (see
//! `coordinator::runner::run_store_ycsb_adaptive`). Phases after the first
//! are the "post-turn" phases the `cxlkvs run adaptive` gate scores.

use super::keygen::KeyDist;
use super::opgen::OpWeights;
use super::ycsb::YcsbWorkload;
use crate::sim::Dur;

/// One stationary phase of a drifting schedule.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub ops: OpWeights,
    pub key_dist: KeyDist,
    /// Measured window of this phase (settle slack not included).
    pub window: Dur,
}

/// A named piecewise-stationary schedule over one store configuration.
#[derive(Debug, Clone)]
pub struct PhasedWorkload {
    pub name: &'static str,
    /// Short tag for CSV/report keys.
    pub tag: &'static str,
    /// YCSB preset supplying the store sizing context and scan lengths
    /// (phases override only op weights and key distribution).
    pub base: YcsbWorkload,
    pub phases: Vec<Phase>,
}

impl PhasedWorkload {
    /// Total measured time across all phases.
    pub fn total_window(&self) -> Dur {
        Dur(self.phases.iter().map(|p| p.window.0).sum())
    }

    /// Number of workload turns (phase boundaries).
    pub fn turns(&self) -> usize {
        self.phases.len().saturating_sub(1)
    }

    /// Diurnal read↔write swing: C-like (read-only) → write-heavy (20/80)
    /// → back. Flips cachekv's chains-vs-LRU density ordering at each turn.
    pub fn diurnal(window: Dur) -> PhasedWorkload {
        let zipf = KeyDist::Zipf {
            s: 0.99,
            scrambled: true,
        };
        PhasedWorkload {
            name: "diurnal(read<->write)",
            tag: "diurnal",
            base: YcsbWorkload::A,
            phases: vec![
                Phase {
                    name: "day-read",
                    ops: OpWeights::READ_ONLY,
                    key_dist: zipf,
                    window,
                },
                Phase {
                    name: "night-write",
                    ops: OpWeights::new(0.2, 0.8, 0.0, 0.0, 0.0),
                    key_dist: zipf,
                    window,
                },
                Phase {
                    name: "day-read-2",
                    ops: OpWeights::READ_ONLY,
                    key_dist: zipf,
                    window,
                },
            ],
        }
    }

    /// Point-read ↔ scan swing: B-like → E-like. Collapses the placement
    /// density of lsmkv's restart arrays mid-run (scans never touch them).
    pub fn scan_swing(window: Dur) -> PhasedWorkload {
        let zipf = KeyDist::Zipf {
            s: 0.99,
            scrambled: true,
        };
        PhasedWorkload {
            name: "scan-swing(B<->E)",
            tag: "scan",
            base: YcsbWorkload::E,
            phases: vec![
                Phase {
                    name: "point-reads",
                    ops: OpWeights::new(0.95, 0.05, 0.0, 0.0, 0.0),
                    key_dist: zipf,
                    window,
                },
                Phase {
                    name: "scans",
                    ops: OpWeights::new(0.0, 0.05, 0.0, 0.95, 0.0),
                    key_dist: zipf,
                    window,
                },
            ],
        }
    }

    /// Zipfian-exponent drift sweeping `s` through the θ = 1 pole — the
    /// schedule the `keygen` guard exists for.
    pub fn zipf_drift(window: Dur) -> PhasedWorkload {
        let phase = |name, s| Phase {
            name,
            ops: OpWeights::new(0.95, 0.05, 0.0, 0.0, 0.0),
            key_dist: KeyDist::Zipf { s, scrambled: true },
            window,
        };
        PhasedWorkload {
            name: "zipf-drift(s:0.7->1.0->1.3)",
            tag: "zipf",
            base: YcsbWorkload::B,
            phases: vec![
                phase("s0.7", 0.7),
                phase("s1.0", 1.0),
                phase("s1.3", 1.3),
            ],
        }
    }

    /// Hotspot shift: the hashed hot set widens mid-run (5% of the keyspace
    /// absorbing 95% of accesses → 40%), turning hit ratios and the access
    /// mix they drive.
    pub fn hotspot_shift(window: Dur) -> PhasedWorkload {
        let phase = |name, hot_frac| Phase {
            name,
            ops: OpWeights::new(0.5, 0.5, 0.0, 0.0, 0.0),
            key_dist: KeyDist::HotSet {
                hot_frac,
                hot_weight: 0.95,
            },
            window,
        };
        PhasedWorkload {
            name: "hotspot-shift(5%->40%)",
            tag: "hotspot",
            base: YcsbWorkload::A,
            phases: vec![phase("narrow-hot", 0.05), phase("wide-hot", 0.40)],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_turn_at_least_once() {
        let w = Dur::ms(2.0);
        for s in [
            PhasedWorkload::diurnal(w),
            PhasedWorkload::scan_swing(w),
            PhasedWorkload::zipf_drift(w),
            PhasedWorkload::hotspot_shift(w),
        ] {
            assert!(s.turns() >= 1, "{}: no workload turn", s.name);
            assert_eq!(s.total_window().0, w.0 * s.phases.len() as u64);
            for p in &s.phases {
                assert!(p.window > Dur::ZERO);
            }
            // Each turn changes the workload: neighboring phases differ in
            // weights or key distribution.
            for pair in s.phases.windows(2) {
                let differs = pair[0].ops != pair[1].ops || pair[0].key_dist != pair[1].key_dist;
                assert!(differs, "{}: a turn that changes nothing", s.name);
            }
        }
    }

    #[test]
    fn zipf_drift_crosses_the_pole() {
        let s = PhasedWorkload::zipf_drift(Dur::ms(1.0));
        assert!(
            s.phases
                .iter()
                .any(|p| matches!(p.key_dist, KeyDist::Zipf { s, .. } if s == 1.0)),
            "the drift schedule must sweep through the guarded exponent"
        );
    }

    #[test]
    fn diurnal_swings_reads_to_writes() {
        let s = PhasedWorkload::diurnal(Dur::ms(1.0));
        assert!(!s.phases[0].ops.has_writes());
        assert!(s.phases[1].ops.has_writes());
        assert!(!s.phases[2].ops.has_writes());
    }
}
