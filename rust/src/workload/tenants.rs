//! Multi-tenant workload multiplexing: N tenants, each with its own
//! keyspace slice, key distribution, and YCSB mix, scheduled over one
//! shared store by a *deterministic* weighted round-robin.
//!
//! Determinism discipline: tenant selection must consume **zero** RNG
//! draws, so a single tenant spanning the full keyspace with the store's
//! own mix produces a bit-identical RNG stream to the legacy single-tenant
//! path (`tests/tenants.rs` pins this). The scheduler is smooth weighted
//! round-robin (nginx's `swrr`): each pick adds every tenant's weight to
//! its credit, takes the max-credit tenant (lowest index on ties), and
//! subtracts the total weight from the winner — exact `w_i / Σw` issuance
//! shares over any window of `Σw` picks, with maximal interleaving.

use super::keygen::{KeyDist, KeyGen};
use super::opgen::{OpWeights, ScanLen};
use super::ycsb::YcsbWorkload;
use crate::sim::Rng;

/// One tenant: a named workload over a slice of the shared keyspace.
///
/// `lo_frac..hi_frac` is the tenant's half-open keyspace slice as fractions
/// of the store's `n_items`; slices may overlap (shared data) or partition
/// the space (isolated tenants).
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: &'static str,
    /// Scheduling weight (issuance share is `weight / Σ weights`).
    pub weight: u32,
    pub ops: OpWeights,
    pub key_dist: KeyDist,
    pub scan_len: ScanLen,
    pub lo_frac: f64,
    pub hi_frac: f64,
}

impl TenantSpec {
    /// A tenant running a YCSB preset over `[lo_frac, hi_frac)` of the
    /// keyspace.
    pub fn ycsb(
        name: &'static str,
        wl: YcsbWorkload,
        weight: u32,
        lo_frac: f64,
        hi_frac: f64,
    ) -> TenantSpec {
        TenantSpec {
            name,
            weight,
            ops: wl.weights(),
            key_dist: wl.key_dist(),
            scan_len: wl.scan_len(),
            lo_frac,
            hi_frac,
        }
    }
}

/// A validated set of tenants (the store-config handle).
#[derive(Debug, Clone)]
pub struct TenantSet {
    specs: Vec<TenantSpec>,
}

impl TenantSet {
    pub fn new(specs: Vec<TenantSpec>) -> TenantSet {
        assert!(!specs.is_empty(), "tenant set must be non-empty");
        for s in &specs {
            assert!(s.weight > 0, "tenant {} has zero weight", s.name);
            assert!(
                s.lo_frac >= 0.0 && s.lo_frac < s.hi_frac && s.hi_frac <= 1.0,
                "tenant {} slice [{}, {}) out of range",
                s.name,
                s.lo_frac,
                s.hi_frac
            );
        }
        TenantSet { specs }
    }

    /// A one-tenant set (full-slice solo baseline arms).
    pub fn solo(spec: TenantSpec) -> TenantSet {
        TenantSet::new(vec![spec])
    }

    pub fn specs(&self) -> &[TenantSpec] {
        &self.specs
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// True when any tenant's mix has mutating mass (drives the stores'
    /// background workers the same way `OpWeights::has_writes` does).
    pub fn any_writes(&self) -> bool {
        self.specs.iter().any(|s| s.ops.has_writes())
    }
}

/// The runtime router a store builds from a [`TenantSet`]: per-tenant key
/// generators bound to keyspace slices, plus the SWRR scheduler state.
#[derive(Debug, Clone)]
pub struct TenantRouter {
    specs: Vec<TenantSpec>,
    gens: Vec<KeyGen>,
    starts: Vec<u64>,
    credit: Vec<i64>,
    total_weight: i64,
}

impl TenantRouter {
    pub fn new(set: &TenantSet, n_keys: u64) -> TenantRouter {
        assert!(n_keys > 0);
        let mut gens = Vec::with_capacity(set.len());
        let mut starts = Vec::with_capacity(set.len());
        for s in set.specs() {
            let start = (s.lo_frac * n_keys as f64) as u64;
            let end = ((s.hi_frac * n_keys as f64) as u64).clamp(start + 1, n_keys);
            let start = start.min(end - 1);
            gens.push(KeyGen::new(end - start, s.key_dist));
            starts.push(start);
        }
        let total_weight = set.specs().iter().map(|s| s.weight as i64).sum();
        TenantRouter {
            specs: set.specs().to_vec(),
            gens,
            starts,
            credit: vec![0; set.len()],
            total_weight,
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.specs.len()
    }

    pub fn spec(&self, t: usize) -> &TenantSpec {
        &self.specs[t]
    }

    pub fn any_writes(&self) -> bool {
        self.specs.iter().any(|s| s.ops.has_writes())
    }

    /// Pick the next tenant to issue an op — smooth weighted round-robin,
    /// RNG-free and deterministic.
    pub fn pick(&mut self) -> usize {
        for (c, s) in self.credit.iter_mut().zip(&self.specs) {
            *c += s.weight as i64;
        }
        let mut best = 0usize;
        for (i, &c) in self.credit.iter().enumerate() {
            // Strict `>` gives the lowest index on ties.
            if c > self.credit[best] {
                best = i;
            }
        }
        self.credit[best] -= self.total_weight;
        best
    }

    /// Draw a key for tenant `t` from its slice (one draw of the tenant's
    /// own distribution, offset into the shared keyspace).
    #[inline]
    pub fn sample_key(&self, t: usize, rng: &mut Rng) -> u64 {
        self.starts[t] + self.gens[t].sample(rng)
    }

    /// `[start, end)` key range of tenant `t` in the shared keyspace.
    pub fn slice(&self, t: usize) -> (u64, u64) {
        (self.starts[t], self.starts[t] + self.gens[t].n)
    }
}

/// Per-thread "which tenant owns the in-flight op" map, so a store can
/// answer [`crate::sim::Service::op_tenant`] when the op completes many
/// simulated microseconds after `next_op` chose the tenant.
#[derive(Debug, Clone, Default)]
pub struct TenantTracker {
    by_tid: Vec<u32>,
}

const NO_TENANT: u32 = u32::MAX;

impl TenantTracker {
    pub fn note(&mut self, tid: usize, tenant: Option<usize>) {
        if tid >= self.by_tid.len() {
            self.by_tid.resize(tid + 1, NO_TENANT);
        }
        self.by_tid[tid] = tenant.map(|t| t as u32).unwrap_or(NO_TENANT);
    }

    pub fn current(&self, tid: usize) -> Option<u32> {
        match self.by_tid.get(tid) {
            Some(&t) if t != NO_TENANT => Some(t),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_tenants() -> TenantSet {
        TenantSet::new(vec![
            TenantSpec::ycsb("pt", YcsbWorkload::B, 3, 0.0, 0.5),
            TenantSpec::ycsb("nn", YcsbWorkload::E, 1, 0.5, 1.0),
        ])
    }

    #[test]
    fn swrr_issuance_shares_are_exact() {
        let set = two_tenants();
        let mut r = TenantRouter::new(&set, 1000);
        let mut counts = [0u64; 2];
        for _ in 0..40 {
            counts[r.pick()] += 1;
        }
        // 3:1 weights → exactly 30/10 over any 40-pick window.
        assert_eq!(counts, [30, 10]);
    }

    #[test]
    fn swrr_is_deterministic_and_interleaved() {
        let set = two_tenants();
        let mut a = TenantRouter::new(&set, 1000);
        let mut b = TenantRouter::new(&set, 1000);
        let seq_a: Vec<usize> = (0..16).map(|_| a.pick()).collect();
        let seq_b: Vec<usize> = (0..16).map(|_| b.pick()).collect();
        assert_eq!(seq_a, seq_b);
        // Smooth WRR interleaves rather than bursting: the weight-1 tenant
        // appears within every weight-total window.
        for w in seq_a.chunks(4) {
            assert!(w.contains(&1), "window {w:?} starves tenant 1");
        }
    }

    #[test]
    fn single_tenant_always_picked() {
        let set = TenantSet::solo(TenantSpec::ycsb("solo", YcsbWorkload::B, 1, 0.0, 1.0));
        let mut r = TenantRouter::new(&set, 500);
        for _ in 0..10 {
            assert_eq!(r.pick(), 0);
        }
        assert_eq!(r.slice(0), (0, 500));
    }

    #[test]
    fn keys_stay_inside_the_tenant_slice() {
        let set = two_tenants();
        let r = TenantRouter::new(&set, 1000);
        let mut rng = Rng::new(7);
        for t in 0..r.n_tenants() {
            let (lo, hi) = r.slice(t);
            for _ in 0..5000 {
                let k = r.sample_key(t, &mut rng);
                assert!(k >= lo && k < hi, "tenant {t} key {k} outside [{lo},{hi})");
            }
        }
        assert_eq!(r.slice(0), (0, 500));
        assert_eq!(r.slice(1), (500, 1000));
    }

    #[test]
    fn tracker_maps_threads_to_tenants() {
        let mut tr = TenantTracker::default();
        assert_eq!(tr.current(3), None);
        tr.note(3, Some(1));
        tr.note(0, None);
        assert_eq!(tr.current(3), Some(1));
        assert_eq!(tr.current(0), None);
        tr.note(3, None);
        assert_eq!(tr.current(3), None);
    }

    #[test]
    #[should_panic(expected = "zero weight")]
    fn zero_weight_rejected() {
        TenantSet::new(vec![TenantSpec::ycsb("z", YcsbWorkload::C, 0, 0.0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bad_slice_rejected() {
        TenantSet::new(vec![TenantSpec::ycsb("s", YcsbWorkload::C, 1, 0.6, 0.4)]);
    }
}
