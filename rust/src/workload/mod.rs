//! Workload generation: key distributions, operation mixes/weights, scan
//! lengths, and value sizes (the paper's Table 5 settings plus the YCSB
//! core-workload presets A–F).

pub mod keygen;
pub mod opgen;
pub mod phased;
pub mod tenants;
pub mod ycsb;

pub use keygen::{KeyDist, KeyGen};
pub use opgen::{OpKind, OpMix, OpWeights, ScanLen, ValueSize};
pub use phased::{Phase, PhasedWorkload};
pub use tenants::{TenantRouter, TenantSet, TenantSpec, TenantTracker};
pub use ycsb::{churn_weights, YcsbWorkload};
