//! Workload generation: key distributions, operation mixes, and value sizes
//! (the paper's Table 5 settings).

pub mod keygen;
pub mod opgen;

pub use keygen::{KeyDist, KeyGen};
pub use opgen::{OpKind, OpMix, ValueSize};
