//! Key distributions: uniform, Zipfian (YCSB's generator with exact zeta),
//! Gaussian, and a hotset distribution standing in for CacheBench's
//! "graph cache leader" key-popularity profile.

use crate::sim::Rng;

/// Which key distribution to draw from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KeyDist {
    /// Uniform over [0, n).
    Uniform,
    /// Zipfian with exponent `s` over ranks [0, n), scrambled so popular keys
    /// are spread across the keyspace (YCSB's scrambled-zipfian behaviour is
    /// optional; the paper's db_bench patch uses plain rank order).
    Zipf { s: f64, scrambled: bool },
    /// Gaussian centered at n/2 with standard deviation `sigma_frac * n`
    /// (CacheBench's default key profile).
    Gaussian { sigma_frac: f64 },
    /// `hot_weight` of accesses go to the first `hot_frac` of the (hashed)
    /// keyspace — a two-mode profile approximating the "graph cache leader"
    /// trace's key-popularity skew.
    HotSet { hot_frac: f64, hot_weight: f64 },
}

/// Zipf exponents within this distance of 1.0 are nudged to `1.0 - guard`:
/// the YCSB closed form divides by `1 - θ`, so θ = 1 exactly is a pole.
/// Wide enough that fp drift through a phased-sweep schedule cannot land on
/// the pole, narrow enough that no preset (0.99, 1.1) is touched.
pub const ZIPF_THETA_GUARD: f64 = 1e-4;

/// A sampler bound to a keyspace size.
#[derive(Debug, Clone)]
pub struct KeyGen {
    pub n: u64,
    pub dist: KeyDist,
    /// Zipf state (YCSB ZipfianGenerator constants).
    zipf: Option<ZipfState>,
}

#[derive(Debug, Clone)]
struct ZipfState {
    theta: f64,
    zetan: f64,
    alpha: f64,
    eta: f64,
}

fn zeta(n: u64, theta: f64) -> f64 {
    // Exact for small n; Euler-Maclaurin style continuous approximation for
    // large n keeps construction O(1)-ish while staying within ~1e-4.
    if n <= 10_000_000 {
        let mut sum = 0.0;
        for i in 1..=n {
            sum += 1.0 / (i as f64).powf(theta);
        }
        sum
    } else {
        let n0 = 10_000_000u64;
        let mut sum = zeta(n0, theta);
        // ∫_{n0}^{n} x^-theta dx
        if (theta - 1.0).abs() < 1e-12 {
            sum += (n as f64 / n0 as f64).ln();
        } else {
            sum += ((n as f64).powf(1.0 - theta) - (n0 as f64).powf(1.0 - theta)) / (1.0 - theta);
        }
        sum
    }
}

#[inline]
fn fnv1a(x: u64) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl KeyGen {
    pub fn new(n: u64, dist: KeyDist) -> KeyGen {
        assert!(n > 0);
        let zipf = match dist {
            KeyDist::Zipf { s, .. } => {
                // The YCSB generator's `alpha = 1/(1-θ)` blows up at θ = 1
                // (alpha → ±∞ makes every deep draw collapse to rank n-1),
                // so an exponent within ZIPF_THETA_GUARD of 1 is nudged just
                // below it. The pmf shift is O(guard·ln n) — invisible next
                // to the generator's own deep-rank approximation — and
                // exponents outside the guard band are untouched.
                let theta = if (s - 1.0).abs() < ZIPF_THETA_GUARD {
                    1.0 - ZIPF_THETA_GUARD
                } else {
                    s
                };
                let zetan = zeta(n, theta);
                let zeta2 = zeta(2, theta);
                let alpha = 1.0 / (1.0 - theta);
                let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
                Some(ZipfState {
                    theta,
                    zetan,
                    alpha,
                    eta,
                })
            }
            _ => None,
        };
        KeyGen { n, dist, zipf }
    }

    /// Draw a key in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match self.dist {
            KeyDist::Uniform => rng.below(self.n),
            KeyDist::Zipf { scrambled, .. } => {
                let z = self.zipf.as_ref().unwrap();
                let u = rng.f64();
                let uz = u * z.zetan;
                let rank = if uz < 1.0 {
                    0
                } else if uz < 1.0 + 0.5f64.powf(z.theta) {
                    1
                } else {
                    ((self.n as f64) * (z.eta * u - z.eta + 1.0).powf(z.alpha)) as u64
                };
                let rank = rank.min(self.n - 1);
                if scrambled {
                    fnv1a(rank) % self.n
                } else {
                    rank
                }
            }
            KeyDist::Gaussian { sigma_frac } => {
                let sigma = sigma_frac * self.n as f64;
                loop {
                    let x = rng.normal() * sigma + self.n as f64 / 2.0;
                    if x >= 0.0 && x < self.n as f64 {
                        return x as u64;
                    }
                }
            }
            KeyDist::HotSet {
                hot_frac,
                hot_weight,
            } => {
                // Clamp to the keyspace, and short-circuit the cold branch
                // when the hot set *is* the keyspace (`hot_frac` ≥ 1 made
                // the pre-fix code reach `rng.below(0)`): with no cold keys
                // every draw is hot, so the weight coin is never tossed.
                let hot_n = ((self.n as f64 * hot_frac) as u64).clamp(1, self.n);
                let raw = if hot_n == self.n || rng.chance(hot_weight) {
                    rng.below(hot_n)
                } else {
                    hot_n + rng.below(self.n - hot_n)
                };
                // Hash so "hot" keys are spread over the keyspace.
                fnv1a(raw) % self.n
            }
        }
    }

    /// Zeta-based exact popularity of a rank (tests only).
    #[cfg(test)]
    fn zipf_pmf(&self, rank: u64) -> f64 {
        let z = self.zipf.as_ref().unwrap();
        1.0 / ((rank + 1) as f64).powf(z.theta) / z.zetan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_covers_keyspace() {
        let g = KeyGen::new(100, KeyDist::Uniform);
        let mut rng = Rng::new(1);
        let mut seen = vec![false; 100];
        for _ in 0..10_000 {
            seen[g.sample(&mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_head_frequencies_match_pmf() {
        let g = KeyGen::new(
            100_000,
            KeyDist::Zipf {
                s: 0.99,
                scrambled: false,
            },
        );
        let mut rng = Rng::new(2);
        let trials = 400_000;
        let mut counts = vec![0u64; 4];
        for _ in 0..trials {
            let k = g.sample(&mut rng);
            if (k as usize) < counts.len() {
                counts[k as usize] += 1;
            }
        }
        // Ranks 0 and 1 are produced exactly by the YCSB generator; deeper
        // ranks use the continuous approximation (looser tolerance).
        for r in 0..2u64 {
            let emp = counts[r as usize] as f64 / trials as f64;
            let pmf = g.zipf_pmf(r);
            assert!(
                (emp - pmf).abs() / pmf < 0.08,
                "rank {r}: emp {emp:.5} vs pmf {pmf:.5}"
            );
        }
        for r in 2..4u64 {
            let emp = counts[r as usize] as f64 / trials as f64;
            let pmf = g.zipf_pmf(r);
            assert!(
                (emp - pmf).abs() / pmf < 0.35,
                "rank {r}: emp {emp:.5} vs pmf {pmf:.5}"
            );
        }
    }

    #[test]
    fn zipf_skew_increases_with_s() {
        let mut rng = Rng::new(3);
        let mut top_share = |s: f64| {
            let g = KeyGen::new(
                100_000,
                KeyDist::Zipf {
                    s,
                    scrambled: false,
                },
            );
            let mut hot = 0;
            let trials = 100_000;
            for _ in 0..trials {
                if g.sample(&mut rng) < 1000 {
                    hot += 1;
                }
            }
            hot as f64 / trials as f64
        };
        let s08 = top_share(0.8);
        let s11 = top_share(1.1);
        assert!(s11 > s08 + 0.1, "s=1.1 share {s11} vs s=0.8 share {s08}");
    }

    #[test]
    fn gaussian_centered() {
        let g = KeyGen::new(10_000, KeyDist::Gaussian { sigma_frac: 0.1 });
        let mut rng = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| g.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - 5_000.0).abs() < 100.0, "mean={mean}");
    }

    #[test]
    fn hotset_weight_respected() {
        let g = KeyGen::new(
            100_000,
            KeyDist::HotSet {
                hot_frac: 0.1,
                hot_weight: 0.9,
            },
        );
        let mut rng = Rng::new(5);
        // The hot keys are hashed; measure by re-deriving: draw many samples,
        // count distinct keys covering 90% of mass — should be ~10% of space.
        let mut counts = std::collections::HashMap::new();
        let trials = 200_000;
        for _ in 0..trials {
            *counts.entry(g.sample(&mut rng)).or_insert(0u64) += 1;
        }
        let mut freqs: Vec<u64> = counts.values().cloned().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let mut acc = 0u64;
        let mut distinct = 0usize;
        for f in freqs {
            acc += f;
            distinct += 1;
            if acc as f64 >= 0.9 * trials as f64 {
                break;
            }
        }
        let frac = distinct as f64 / 100_000.0;
        assert!(frac < 0.15, "90% of mass in {frac} of keyspace");
    }

    #[test]
    fn degenerate_hotset_full_keyspace_is_safe() {
        // Regression: `hot_frac: 1.0` made the cold branch call
        // `rng.below(0)`. A hot set spanning the keyspace must behave as a
        // hashed-uniform draw over [0, n).
        for hot_frac in [1.0, 1.5] {
            let g = KeyGen::new(
                1000,
                KeyDist::HotSet {
                    hot_frac,
                    hot_weight: 0.9,
                },
            );
            let mut rng = Rng::new(6);
            let mut seen = vec![false; 1000];
            for _ in 0..50_000 {
                let k = g.sample(&mut rng);
                assert!(k < 1000);
                seen[k as usize] = true;
            }
            let covered = seen.iter().filter(|&&s| s).count();
            assert!(covered > 900, "full-keyspace hot set covered {covered}/1000");
        }
    }

    #[test]
    fn degenerate_zipf_exponent_one_is_guarded() {
        // Regression: `s: 1.0` made `alpha = 1/(1-s)` infinite and collapsed
        // every deep draw onto rank n-1. The guarded exponent must keep the
        // head Zipf-shaped: rank 0 strictly most popular, deep ranks still
        // reachable, skew between s=0.9 and s=1.1.
        let g = KeyGen::new(
            100_000,
            KeyDist::Zipf {
                s: 1.0,
                scrambled: false,
            },
        );
        let mut rng = Rng::new(7);
        let trials = 200_000;
        let (mut rank0, mut top1000, mut tail) = (0u64, 0u64, 0u64);
        for _ in 0..trials {
            let k = g.sample(&mut rng);
            assert!(k < 100_000);
            if k == 0 {
                rank0 += 1;
            }
            if k < 1000 {
                top1000 += 1;
            }
            if k >= 50_000 {
                tail += 1;
            }
        }
        let head = top1000 as f64 / trials as f64;
        assert!(rank0 > 0, "rank 0 never drawn at s=1.0");
        assert!(tail > 0, "deep ranks unreachable at s=1.0 (collapsed head)");
        assert!(
            tail < trials / 4,
            "tail share {tail} looks collapsed onto the last rank"
        );
        // Between the neighbouring exponents' head shares (~0.4 at s=0.9,
        // ~0.75 at s=1.1 for n=1e5), as a guarded θ→1⁻ should be; pre-fix
        // the head held only the two exactly-generated ranks (~12%).
        assert!((0.40..0.78).contains(&head), "head share {head}");
    }

    #[test]
    fn zeta_large_n_approximation() {
        let exact = zeta(10_000_000, 0.99);
        assert!(exact > 0.0);
        let approx = zeta(20_000_000, 0.99);
        assert!(approx > exact);
    }
}
