//! YCSB-style workload presets (the six standard core workloads A–F),
//! composed from the existing key-distribution and the full-surface
//! operation weights.
//!
//! | preset | mix                     | key distribution        |
//! |--------|-------------------------|-------------------------|
//! | A      | 50% read / 50% update   | Zipf 0.99, scrambled    |
//! | B      | 95% read / 5% update    | Zipf 0.99, scrambled    |
//! | C      | 100% read               | Zipf 0.99, scrambled    |
//! | D      | 95% read / 5% update    | Zipf 0.99, rank-ordered |
//! | E      | 95% scan / 5% update    | Zipf 0.99, scrambled    |
//! | F      | 50% read / 50% RMW      | Zipf 0.99, scrambled    |
//!
//! Approximations versus stock YCSB, documented here once:
//!
//! - The simulated stores run over a fixed pre-populated keyspace, so
//!   YCSB's "insert" (D and E's 5%) maps to an upsert ([`OpKind::Write`] of
//!   a possibly-absent key) — the write paths of all three stores handle
//!   insert-of-absent.
//! - D's "latest" distribution (reads skewed toward recent inserts) is
//!   approximated by an **unscrambled** Zipfian: rank order stands in for
//!   recency order, giving the same popularity profile over a stable head.
//! - E's scan lengths are uniform on [1, 24] (stock YCSB uses [1, 100]);
//!   scaled with the item counts so a single scan cannot dominate a
//!   measurement window. Override via the store configs' `scan_len`.
//!
//! Deletes are not part of the six standard mixes; [`churn_weights`]
//! provides a delete-heavy CRUD mix used by the property suite and
//! available to custom sweeps.

use super::keygen::KeyDist;
use super::opgen::{OpWeights, ScanLen};

/// One of the six standard YCSB core workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum YcsbWorkload {
    A,
    B,
    C,
    D,
    E,
    F,
}

impl YcsbWorkload {
    pub const ALL: [YcsbWorkload; 6] = [
        YcsbWorkload::A,
        YcsbWorkload::B,
        YcsbWorkload::C,
        YcsbWorkload::D,
        YcsbWorkload::E,
        YcsbWorkload::F,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            YcsbWorkload::A => "A(50r/50u)",
            YcsbWorkload::B => "B(95r/5u)",
            YcsbWorkload::C => "C(read-only)",
            YcsbWorkload::D => "D(latest-read)",
            YcsbWorkload::E => "E(scan-heavy)",
            YcsbWorkload::F => "F(rmw)",
        }
    }

    /// Short tag for CSV/report keys.
    pub fn tag(&self) -> &'static str {
        match self {
            YcsbWorkload::A => "A",
            YcsbWorkload::B => "B",
            YcsbWorkload::C => "C",
            YcsbWorkload::D => "D",
            YcsbWorkload::E => "E",
            YcsbWorkload::F => "F",
        }
    }

    /// The preset operation weights.
    pub fn weights(&self) -> OpWeights {
        match self {
            YcsbWorkload::A => OpWeights::new(0.5, 0.5, 0.0, 0.0, 0.0),
            YcsbWorkload::B => OpWeights::new(0.95, 0.05, 0.0, 0.0, 0.0),
            YcsbWorkload::C => OpWeights::READ_ONLY,
            YcsbWorkload::D => OpWeights::new(0.95, 0.05, 0.0, 0.0, 0.0),
            YcsbWorkload::E => OpWeights::new(0.0, 0.05, 0.0, 0.95, 0.0),
            YcsbWorkload::F => OpWeights::new(0.5, 0.0, 0.0, 0.0, 0.5),
        }
    }

    /// The preset key distribution (see the module docs for the D
    /// approximation).
    pub fn key_dist(&self) -> KeyDist {
        match self {
            YcsbWorkload::D => KeyDist::Zipf {
                s: 0.99,
                scrambled: false,
            },
            _ => KeyDist::Zipf {
                s: 0.99,
                scrambled: true,
            },
        }
    }

    /// The preset scan-length distribution (only E draws scans).
    pub fn scan_len(&self) -> ScanLen {
        ScanLen::default()
    }
}

/// A delete-heavy CRUD mix (not a standard YCSB core workload): exercises
/// the tombstone/invalidation paths under churn.
pub fn churn_weights() -> OpWeights {
    OpWeights::new(0.40, 0.25, 0.25, 0.05, 0.05)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Rng;
    use crate::workload::OpKind;

    #[test]
    fn presets_have_expected_masses() {
        use YcsbWorkload as W;
        assert!((W::A.weights().fraction(OpKind::Read) - 0.5).abs() < 1e-12);
        assert!((W::B.weights().fraction(OpKind::Write) - 0.05).abs() < 1e-12);
        assert!((W::C.weights().fraction(OpKind::Read) - 1.0).abs() < 1e-12);
        assert!((W::E.weights().fraction(OpKind::Scan) - 0.95).abs() < 1e-12);
        assert!((W::F.weights().fraction(OpKind::Rmw) - 0.5).abs() < 1e-12);
        assert!(!W::C.weights().has_writes());
        assert!(W::A.weights().has_writes());
    }

    #[test]
    fn d_uses_rank_ordered_zipf() {
        assert_eq!(
            YcsbWorkload::D.key_dist(),
            KeyDist::Zipf {
                s: 0.99,
                scrambled: false
            }
        );
        assert_eq!(
            YcsbWorkload::A.key_dist(),
            KeyDist::Zipf {
                s: 0.99,
                scrambled: true
            }
        );
    }

    #[test]
    fn sampling_each_preset_yields_only_its_kinds() {
        let mut rng = Rng::new(17);
        for wl in YcsbWorkload::ALL {
            let w = wl.weights();
            for _ in 0..2000 {
                let k = w.sample(&mut rng);
                assert!(
                    w.fraction(k) > 0.0,
                    "{}: sampled {k:?} with zero weight",
                    wl.name()
                );
            }
        }
    }

    #[test]
    fn churn_mix_has_deletes_and_scans() {
        let w = churn_weights();
        assert!(w.fraction(OpKind::Delete) > 0.2);
        assert!(w.fraction(OpKind::Scan) > 0.0);
        assert!(w.has_writes());
    }
}
