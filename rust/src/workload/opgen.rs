//! Operation mixes and value sizes (Table 5 rows "Read:write", "Value size").

use crate::sim::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Read,
    Write,
}

/// A read:write mix (paper notation "1:0", "2:1", "1:1").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpMix {
    pub read_ratio: f64,
}

impl OpMix {
    pub const READ_ONLY: OpMix = OpMix { read_ratio: 1.0 };

    /// "r:w" ratios, e.g. `OpMix::ratio(2, 1)` for 2:1.
    pub fn ratio(r: u32, w: u32) -> OpMix {
        OpMix {
            read_ratio: r as f64 / (r + w) as f64,
        }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> OpKind {
        if rng.chance(self.read_ratio) {
            OpKind::Read
        } else {
            OpKind::Write
        }
    }
}

/// Value-size distributions (fixed or uniform range, as in Table 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueSize {
    Fixed(u32),
    Range(u32, u32),
}

impl ValueSize {
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match *self {
            ValueSize::Fixed(b) => b,
            ValueSize::Range(lo, hi) => rng.range(lo as u64, hi as u64) as u32,
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            ValueSize::Fixed(b) => b as f64,
            ValueSize::Range(lo, hi) => (lo + hi) as f64 / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_math() {
        assert_eq!(OpMix::ratio(1, 0).read_ratio, 1.0);
        assert_eq!(OpMix::ratio(1, 1).read_ratio, 0.5);
        assert!((OpMix::ratio(2, 1).read_ratio - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mix_sampling_matches_ratio() {
        let mix = OpMix::ratio(2, 1);
        let mut rng = Rng::new(1);
        let n = 100_000;
        let reads = (0..n)
            .filter(|_| mix.sample(&mut rng) == OpKind::Read)
            .count();
        let frac = reads as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn value_sizes_in_range() {
        let vs = ValueSize::Range(200, 300);
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let v = vs.sample(&mut rng);
            assert!((200..=300).contains(&v));
        }
        assert!((vs.mean() - 250.0).abs() < 1e-12);
        assert_eq!(ValueSize::Fixed(1536).sample(&mut rng), 1536);
    }
}
