//! Operation mixes, scan lengths, and value sizes (Table 5 rows
//! "Read:write", "Value size", plus the YCSB-style full operation surface).

use crate::sim::Rng;

/// One KV operation kind. The seed reproduction served only point
/// reads/writes; the full surface adds deletes, ordered range scans, and
/// read-modify-writes (YCSB's operation vocabulary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Read,
    /// Blind write / update (YCSB "update"; also covers insert-of-absent).
    Write,
    /// Remove the key (tombstone in LSM designs, index-entry removal in
    /// tree designs, invalidation in caches).
    Delete,
    /// Ordered range scan of `scan_len` entries from a start key.
    Scan,
    /// Read-modify-write: a full read path followed by a full write path on
    /// the same key (YCSB workload F).
    Rmw,
}

impl OpKind {
    /// Every operation kind, in [`OpWeights`] field order.
    pub const ALL: [OpKind; 5] = [
        OpKind::Read,
        OpKind::Write,
        OpKind::Delete,
        OpKind::Scan,
        OpKind::Rmw,
    ];
}

/// A read:write mix (paper notation "1:0", "2:1", "1:1"). Retained for the
/// paper-figure experiments; the full-surface workloads use [`OpWeights`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpMix {
    pub read_ratio: f64,
}

impl OpMix {
    pub const READ_ONLY: OpMix = OpMix { read_ratio: 1.0 };

    /// "r:w" ratios, e.g. `OpMix::ratio(2, 1)` for 2:1.
    pub fn ratio(r: u32, w: u32) -> OpMix {
        OpMix {
            read_ratio: r as f64 / (r + w) as f64,
        }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> OpKind {
        if rng.chance(self.read_ratio) {
            OpKind::Read
        } else {
            OpKind::Write
        }
    }
}

/// Weights over the full operation surface. Weights need not sum to 1; they
/// are normalized at sampling time. `OpWeights::from(mix)` reproduces the
/// two-kind behaviour of [`OpMix`] exactly, so stores can honor either.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpWeights {
    pub read: f64,
    pub update: f64,
    pub delete: f64,
    pub scan: f64,
    pub rmw: f64,
}

impl OpWeights {
    pub const READ_ONLY: OpWeights = OpWeights {
        read: 1.0,
        update: 0.0,
        delete: 0.0,
        scan: 0.0,
        rmw: 0.0,
    };

    pub fn new(read: f64, update: f64, delete: f64, scan: f64, rmw: f64) -> OpWeights {
        let w = OpWeights {
            read,
            update,
            delete,
            scan,
            rmw,
        };
        assert!(
            read >= 0.0 && update >= 0.0 && delete >= 0.0 && scan >= 0.0 && rmw >= 0.0,
            "op weights must be non-negative"
        );
        assert!(w.total() > 0.0, "op weights must have positive mass");
        w
    }

    #[inline]
    fn total(&self) -> f64 {
        self.read + self.update + self.delete + self.scan + self.rmw
    }

    /// True when any mutating kind has mass (drives background workers:
    /// defrag in treekv, flush/compaction in lsmkv).
    #[inline]
    pub fn has_writes(&self) -> bool {
        self.update + self.delete + self.rmw > 0.0
    }

    /// Fraction of an operation kind after normalization.
    pub fn fraction(&self, kind: OpKind) -> f64 {
        let w = match kind {
            OpKind::Read => self.read,
            OpKind::Write => self.update,
            OpKind::Delete => self.delete,
            OpKind::Scan => self.scan,
            OpKind::Rmw => self.rmw,
        };
        w / self.total()
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> OpKind {
        // `x` can reach `total()` despite `f64() < 1`: the product rounds up
        // when the draw is within an ulp of 1 (and saturating weight sums
        // make `x >= acc` for every bucket). Skipping zero-weight kinds and
        // clamping the fallthrough to the last *positive*-weight kind keeps
        // that fp-epsilon path from fabricating an operation the mix
        // excludes; for in-range draws the branch points are unchanged
        // (adding 0.0 is exact), so well-scaled sequences are bit-identical.
        let x = rng.f64() * self.total();
        let mut acc = 0.0;
        let mut last = OpKind::Read;
        for (kind, w) in [
            (OpKind::Read, self.read),
            (OpKind::Write, self.update),
            (OpKind::Delete, self.delete),
            (OpKind::Scan, self.scan),
            (OpKind::Rmw, self.rmw),
        ] {
            if w > 0.0 {
                acc += w;
                last = kind;
                if x < acc {
                    return kind;
                }
            }
        }
        last
    }
}

impl From<OpMix> for OpWeights {
    fn from(mix: OpMix) -> OpWeights {
        OpWeights {
            read: mix.read_ratio,
            update: 1.0 - mix.read_ratio,
            delete: 0.0,
            scan: 0.0,
            rmw: 0.0,
        }
    }
}

/// Scan-length distributions (YCSB E draws uniform lengths).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScanLen {
    Fixed(u32),
    /// Uniform over the **inclusive** range `[lo, hi]`.
    Uniform(u32, u32),
}

impl ScanLen {
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match *self {
            ScanLen::Fixed(n) => n.max(1),
            ScanLen::Uniform(lo, hi) => rng.range(lo.max(1) as u64, hi.max(1) as u64) as u32,
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            ScanLen::Fixed(n) => n.max(1) as f64,
            ScanLen::Uniform(lo, hi) => (lo.max(1) + hi.max(1)) as f64 / 2.0,
        }
    }

    /// Second raw moment `E[len²]` (same `max(1)` clamps as `sample`).
    ///
    /// The scan model's batched IO count `E[⌈len/batch⌉]` is convex in
    /// `len`, so the mean alone understates it for spread-out mixes; the
    /// first two moments pin the uniform support exactly and
    /// `model::KindCost::scan_dist` reconstructs it — see the Θ_scan notes
    /// in `model/extended.rs`.
    pub fn second_moment(&self) -> f64 {
        match *self {
            ScanLen::Fixed(n) => {
                let n = n.max(1) as f64;
                n * n
            }
            ScanLen::Uniform(lo, hi) => {
                // E[l²] over the integers lo..=hi via Σ l² = n(n+1)(2n+1)/6,
                // in f64 — the u64 product overflows for multi-million
                // endpoints even though the u32 fields admit them.
                let (a, b) = (lo.max(1) as f64, hi.max(1) as f64);
                let sq = |n: f64| n * (n + 1.0) * (2.0 * n + 1.0) / 6.0;
                (sq(b) - sq(a - 1.0)) / (b - a + 1.0)
            }
        }
    }
}

impl Default for ScanLen {
    fn default() -> ScanLen {
        // YCSB E uses uniform 1..100; scaled down to keep simulated scans
        // from dwarfing the measurement window at our item counts.
        ScanLen::Uniform(1, 24)
    }
}

/// Value-size distributions, as in Table 5: fixed, or uniform over the
/// **inclusive** range `[lo, hi]` — `sample` draws via `Rng::range(lo, hi)`,
/// which includes both endpoints, and `mean` is `(lo + hi) / 2` accordingly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueSize {
    Fixed(u32),
    /// Uniform over `lo..=hi` (both endpoints attainable).
    Range(u32, u32),
}

impl ValueSize {
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        match *self {
            ValueSize::Fixed(b) => b,
            ValueSize::Range(lo, hi) => rng.range(lo as u64, hi as u64) as u32,
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            ValueSize::Fixed(b) => b as f64,
            ValueSize::Range(lo, hi) => (lo + hi) as f64 / 2.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_math() {
        assert_eq!(OpMix::ratio(1, 0).read_ratio, 1.0);
        assert_eq!(OpMix::ratio(1, 1).read_ratio, 0.5);
        assert!((OpMix::ratio(2, 1).read_ratio - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mix_sampling_matches_ratio() {
        let mix = OpMix::ratio(2, 1);
        let mut rng = Rng::new(1);
        let n = 100_000;
        let reads = (0..n)
            .filter(|_| mix.sample(&mut rng) == OpKind::Read)
            .count();
        let frac = reads as f64 / n as f64;
        assert!((frac - 2.0 / 3.0).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn weights_sampling_matches_fractions() {
        let w = OpWeights::new(0.5, 0.2, 0.1, 0.1, 0.1);
        let mut rng = Rng::new(2);
        let n = 200_000;
        let mut counts = [0u64; 5];
        for _ in 0..n {
            let i = match w.sample(&mut rng) {
                OpKind::Read => 0,
                OpKind::Write => 1,
                OpKind::Delete => 2,
                OpKind::Scan => 3,
                OpKind::Rmw => 4,
            };
            counts[i] += 1;
        }
        let fr = |i: usize| counts[i] as f64 / n as f64;
        assert!((fr(0) - 0.5).abs() < 0.01, "read {}", fr(0));
        assert!((fr(1) - 0.2).abs() < 0.01, "update {}", fr(1));
        assert!((fr(2) - 0.1).abs() < 0.01, "delete {}", fr(2));
        assert!((fr(3) - 0.1).abs() < 0.01, "scan {}", fr(3));
        assert!((fr(4) - 0.1).abs() < 0.01, "rmw {}", fr(4));
    }

    #[test]
    fn zero_weight_kinds_are_never_sampled() {
        // Regression: the pre-fix fallthrough returned `Rmw` whenever
        // `x = f64() * total` reached the accumulated mass, even with
        // `rmw == 0`. Millions of draws across mixes with structural zeros
        // must never produce a zero-weight kind.
        let mixes = [
            OpWeights::new(0.95, 0.05, 0.0, 0.0, 0.0), // YCSB B/D shape
            OpWeights::new(0.0, 0.05, 0.0, 0.95, 0.0), // YCSB E shape
            OpWeights::new(0.5, 0.0, 0.0, 0.0, 0.5),   // YCSB F shape
            OpWeights::new(0.1, 0.2, 0.3, 0.4, 0.0),   // non-dyadic sums
        ];
        let mut rng = Rng::new(0xa11);
        for w in mixes {
            for _ in 0..1_000_000u32 {
                let k = w.sample(&mut rng);
                assert!(w.fraction(k) > 0.0, "sampled zero-weight {k:?} from {w:?}");
            }
        }
    }

    #[test]
    fn saturating_weight_sums_clamp_to_last_positive_kind() {
        // The deterministic instance of the fallthrough bug: weights whose
        // sum saturates to infinity make `x = f64() * inf` either `inf`
        // (draw > 0) or NaN (draw == 0), so every `x < acc` test fails and
        // the pre-fix code returned `Rmw` for a read/update-only mix.
        let w = OpWeights::new(f64::MAX, f64::MAX, 0.0, 0.0, 0.0);
        let mut rng = Rng::new(0xa12);
        for _ in 0..1000 {
            let k = w.sample(&mut rng);
            assert!(
                matches!(k, OpKind::Read | OpKind::Write),
                "saturating sum leaked a zero-weight kind: {k:?}"
            );
        }
    }

    #[test]
    fn weights_from_mix_round_trip() {
        let w = OpWeights::from(OpMix::ratio(2, 1));
        assert!((w.fraction(OpKind::Read) - 2.0 / 3.0).abs() < 1e-12);
        assert!((w.fraction(OpKind::Write) - 1.0 / 3.0).abs() < 1e-12);
        assert!(!OpWeights::READ_ONLY.has_writes());
        assert!(w.has_writes());
    }

    #[test]
    fn scan_len_bounds_inclusive() {
        let s = ScanLen::Uniform(2, 5);
        let mut rng = Rng::new(3);
        let mut seen = [false; 6];
        for _ in 0..2000 {
            let v = s.sample(&mut rng);
            assert!((2..=5).contains(&v));
            seen[v as usize] = true;
        }
        assert!(seen[2] && seen[5], "inclusive endpoints must be attainable");
        assert!((s.mean() - 3.5).abs() < 1e-12);
        assert_eq!(ScanLen::Fixed(0).sample(&mut rng), 1, "scan length >= 1");
    }

    #[test]
    fn scan_len_second_moment_matches_brute_force() {
        for (lo, hi) in [(1u32, 24u32), (2, 5), (7, 7), (1, 100)] {
            let s = ScanLen::Uniform(lo, hi);
            let n = (hi - lo + 1) as f64;
            let brute = (lo..=hi).map(|l| (l as f64) * (l as f64)).sum::<f64>() / n;
            assert!(
                (s.second_moment() - brute).abs() < 1e-9,
                "[{lo},{hi}]: {} vs {brute}",
                s.second_moment()
            );
            // Var ≥ 0 and consistent with the mean.
            assert!(s.second_moment() >= s.mean() * s.mean() - 1e-9);
        }
        let f = ScanLen::Fixed(20);
        assert_eq!(f.second_moment(), 400.0);
        // The max(1) clamp mirrors sample()/mean().
        assert_eq!(ScanLen::Fixed(0).second_moment(), 1.0);
    }

    #[test]
    fn value_sizes_in_range() {
        let vs = ValueSize::Range(200, 300);
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let v = vs.sample(&mut rng);
            assert!((200..=300).contains(&v));
        }
        assert!((vs.mean() - 250.0).abs() < 1e-12);
        assert_eq!(ValueSize::Fixed(1536).sample(&mut rng), 1536);
    }

    #[test]
    fn value_size_range_is_inclusive_of_both_endpoints() {
        // Pins the off-by-one contract: `Range(lo, hi)` samples `lo..=hi`
        // via `Rng::range`, so both boundary values must actually occur.
        let vs = ValueSize::Range(10, 12);
        let mut rng = Rng::new(9);
        let mut seen = [false; 13];
        for _ in 0..5000 {
            seen[vs.sample(&mut rng) as usize] = true;
        }
        assert!(seen[10], "lower bound never sampled");
        assert!(seen[11], "midpoint never sampled");
        assert!(seen[12], "upper bound never sampled (range must be inclusive)");
        assert!(!seen[9] && !seen[0]);
    }
}
