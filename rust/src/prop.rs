//! Minimal property-based testing support.
//!
//! The offline image has no `proptest`/`quickcheck`, so this module provides
//! the subset the test suite needs: seeded generators, a runner that reports
//! the failing case, and shrinking for integer tuples (halving toward the
//! minimum). Deliberately tiny — tests pass explicit generator closures.

use crate::sim::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropCfg {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropCfg {
    fn default() -> Self {
        PropCfg {
            cases: 64,
            seed: 0x9e3779b97f4a7c15,
        }
    }
}

/// Run `check` on `cases` values from `gen`. On failure, try to shrink via
/// `shrink` (which yields "smaller" candidates) and panic with the smallest
/// failing input.
pub fn forall<T, G, S, C>(cfg: PropCfg, mut gen: G, shrink: S, mut check: C)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    S: Fn(&T) -> Vec<T>,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            // Greedy shrink: repeatedly take the first shrunk candidate that
            // still fails.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 64 {
                improved = false;
                rounds += 1;
                for cand in shrink(&best) {
                    if let Err(m) = check(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {best:?}\n  error: {best_msg}",
                cfg.seed
            );
        }
    }
}

/// No shrinking (for types where it isn't worth it).
pub fn no_shrink<T: Clone>(_: &T) -> Vec<T> {
    Vec::new()
}

/// Shrink a `u64` toward `lo` by halving the distance.
pub fn shrink_u64(lo: u64) -> impl Fn(&u64) -> Vec<u64> {
    move |&x| {
        if x <= lo {
            Vec::new()
        } else {
            let mid = lo + (x - lo) / 2;
            if mid == x {
                vec![lo]
            } else {
                vec![mid, x - 1]
            }
        }
    }
}

/// Shrink an `f64` toward a reference point.
pub fn shrink_f64(lo: f64) -> impl Fn(&f64) -> Vec<f64> {
    move |&x| {
        if (x - lo).abs() < 1e-9 {
            Vec::new()
        } else {
            vec![lo + (x - lo) / 2.0]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            PropCfg::default(),
            |rng| rng.below(1000),
            no_shrink,
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            forall(
                PropCfg {
                    cases: 200,
                    seed: 3,
                },
                |rng| rng.below(10_000),
                shrink_u64(0),
                |&x| {
                    if x < 500 {
                        Ok(())
                    } else {
                        Err("too big".into())
                    }
                },
            );
        });
        assert!(result.is_err(), "property should have failed");
    }

    #[test]
    fn shrink_u64_halves() {
        let s = shrink_u64(0);
        assert_eq!(s(&8), vec![4, 7]);
        assert!(s(&0).is_empty());
    }

    #[test]
    fn shrink_f64_midpoint() {
        let s = shrink_f64(0.0);
        assert_eq!(s(&8.0), vec![4.0]);
        assert!(s(&0.0).is_empty());
    }
}
