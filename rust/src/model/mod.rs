//! The paper's analytic throughput models (§3), implemented natively in Rust.
//!
//! The same equations are implemented in JAX+Pallas (python/compile/) and
//! AOT-compiled to an HLO artifact executed through [`crate::runtime`]; this
//! native version exists to cross-validate the artifact and to serve
//! latency-insensitive call sites (single evaluations in tests).
//!
//! All times are in **microseconds** (the paper's Table 1 units); throughputs
//! are in operations per microsecond (reciprocals are µs/op).

pub mod analytic;
pub mod cpr;
pub mod extended;

pub use analytic::{
    l_star_io, l_star_memonly, theta_best_recip, theta_mask_recip, theta_mem_recip,
    theta_multi_recip, theta_prob_recip, theta_single_recip, wait_subop, OpParams, SysParams,
};
pub use cpr::{cpr, CprScenario};
pub use extended::{
    theta_extended_recip, theta_kind_recip, theta_mix_recip, theta_rev_recip, theta_scan_recip,
    ExtParams, KindCost,
};
