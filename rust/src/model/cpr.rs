//! Equation 16: cost-performance ratio of replacing part of the host DRAM by
//! secondary memory (§5.1, Table 6).

/// A §5.1 cost-performance scenario.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CprScenario {
    /// Cost share of the replaced DRAM relative to the whole server (c < 1).
    pub c: f64,
    /// Relative bit cost of the secondary memory vs DRAM (b < 1).
    pub b: f64,
    /// Throughput degradation caused by the secondary memory (d < 1).
    pub d: f64,
}

/// Eq 16 — r = (1-d) / (c·b + (1-c)). r > 1 means the replacement improves
/// cost-performance.
pub fn cpr(s: &CprScenario) -> f64 {
    (1.0 - s.d) / (s.c * s.b + (1.0 - s.c))
}

impl CprScenario {
    /// The paper's hypothetical: DRAM is half the server cost, 80% of it is
    /// replaced → c = 0.4.
    pub fn paper_c() -> f64 {
        0.5 * 0.8
    }

    /// Table 6 rows: compressed DRAM (b 1/3–1/2, d 0–2%).
    pub fn compressed_dram() -> [CprScenario; 2] {
        let c = Self::paper_c();
        [
            CprScenario { c, b: 1.0 / 3.0, d: 0.0 },
            CprScenario { c, b: 0.5, d: 0.02 },
        ]
    }

    /// Table 6 rows: low-latency SLC flash (b 0.15–0.2, d 2–19%).
    pub fn low_latency_flash() -> [CprScenario; 2] {
        let c = Self::paper_c();
        [
            CprScenario { c, b: 0.15, d: 0.02 },
            CprScenario { c, b: 0.2, d: 0.19 },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_case() {
        // No degradation, same bit cost: r = 1.
        assert!((cpr(&CprScenario { c: 0.4, b: 1.0, d: 0.0 }) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn table6_compressed_dram_range() {
        // Paper: CPR 1.23–1.36 for compressed DRAM.
        let rs: Vec<f64> = CprScenario::compressed_dram().iter().map(cpr).collect();
        let lo = rs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rs.iter().cloned().fold(0.0, f64::max);
        assert!((lo - 1.23).abs() < 0.02, "lo={lo}");
        assert!((hi - 1.36).abs() < 0.02, "hi={hi}");
    }

    #[test]
    fn table6_flash_range() {
        // Paper: CPR 1.19–1.50 for low-latency flash.
        let rs: Vec<f64> = CprScenario::low_latency_flash().iter().map(cpr).collect();
        let lo = rs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = rs.iter().cloned().fold(0.0, f64::max);
        assert!((lo - 1.19).abs() < 0.02, "lo={lo}");
        assert!((hi - 1.50).abs() < 0.02, "hi={hi}");
    }

    #[test]
    fn worse_with_more_degradation() {
        let base = CprScenario { c: 0.4, b: 0.2, d: 0.05 };
        let worse = CprScenario { d: 0.5, ..base };
        assert!(cpr(&worse) < cpr(&base));
    }

    #[test]
    fn breakeven_degradation() {
        // r = 1 at d* = 1 - (cb + 1 - c); cheaper memory tolerates more
        // degradation.
        let s = CprScenario { c: 0.4, b: 0.15, d: 0.0 };
        let d_star = 1.0 - (s.c * s.b + (1.0 - s.c));
        let at_break = CprScenario { d: d_star, ..s };
        assert!((cpr(&at_break) - 1.0).abs() < 1e-12);
        assert!((d_star - 0.34).abs() < 1e-9);
    }
}
