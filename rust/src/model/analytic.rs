//! Equations 1–13: memory-only, masking-only, best-case, and the paper's
//! probabilistic memory-and-IO throughput model.

/// Per-operation parameters (Table 1). One "operation" here is the Sec 3.2.3
/// split unit: `m` memory accesses followed by one IO. Times in µs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpParams {
    /// Average number of memory accesses per IO (M).
    pub m: f64,
    /// Memory suboperation time T_mem (compute before requesting next line).
    pub t_mem: f64,
    /// Pre-IO suboperation time T_IO^pre.
    pub t_pre: f64,
    /// Post-IO suboperation time T_IO^post.
    pub t_post: f64,
}

impl OpParams {
    /// Table 1's example values.
    pub fn table1_example() -> OpParams {
        OpParams {
            m: 10.0,
            t_mem: 0.1,
            t_pre: 4.0,
            t_post: 3.0,
        }
    }

    /// The IO CPU-time offset E = T_pre + T_post + 2 T_sw (Eq 6).
    #[inline]
    pub fn e(&self, t_sw: f64) -> f64 {
        self.t_pre + self.t_post + 2.0 * t_sw
    }
}

/// System parameters (Table 1). Times in µs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SysParams {
    /// Context switch time T_sw of the user-level threads.
    pub t_sw: f64,
    /// Prefetch queue depth P per core.
    pub p: usize,
    /// Number of user-level threads N per core.
    pub n: usize,
}

impl SysParams {
    /// Table 1's example values (P=10, T_sw=0.05), with "enough" threads.
    pub fn table1_example() -> SysParams {
        SysParams {
            t_sw: 0.05,
            p: 10,
            n: 1_000_000,
        }
    }

    /// The paper's measured testbed values (§4.1.3: T_sw=50ns, P=12).
    pub fn measured_testbed(n: usize) -> SysParams {
        SysParams {
            t_sw: 0.05,
            p: 12,
            n,
        }
    }
}

/// Eq 1 — single-threaded memory-only reciprocal throughput.
#[inline]
pub fn theta_single_recip(t_mem: f64, l_mem: f64) -> f64 {
    t_mem + l_mem
}

/// Eq 2 — multi-threaded memory-only reciprocal throughput (no prefetch limit).
#[inline]
pub fn theta_multi_recip(t_mem: f64, l_mem: f64, sys: &SysParams) -> f64 {
    (t_mem + sys.t_sw).max((t_mem + l_mem) / sys.n as f64)
}

/// Eq 3 — multi-threaded memory-only reciprocal throughput with the
/// prefetch-queue-depth limit.
#[inline]
pub fn theta_mem_recip(t_mem: f64, l_mem: f64, sys: &SysParams) -> f64 {
    theta_multi_recip(t_mem, l_mem, sys).max(l_mem / sys.p as f64)
}

/// Eq 4 — the latency beyond which the memory-only throughput degrades.
#[inline]
pub fn l_star_memonly(t_mem: f64, sys: &SysParams) -> f64 {
    sys.p as f64 * (t_mem + sys.t_sw)
}

/// Eq 5 — masking-only model: IO time merely added to M memory-only units.
#[inline]
pub fn theta_mask_recip(op: &OpParams, l_mem: f64, sys: &SysParams) -> f64 {
    op.m * theta_mem_recip(op.t_mem, l_mem, sys) + op.e(sys.t_sw)
}

/// Eq 7 — best-case (perfectly misaligned) memory-and-IO model.
#[inline]
pub fn theta_best_recip(op: &OpParams, l_mem: f64, sys: &SysParams) -> f64 {
    (op.m * (op.t_mem + sys.t_sw) + op.e(sys.t_sw)).max(op.m * l_mem / sys.p as f64)
}

/// Eq 8 — the latency beyond which the best-case throughput degrades.
#[inline]
pub fn l_star_io(op: &OpParams, sys: &SysParams) -> f64 {
    sys.p as f64 * (op.t_mem + sys.t_sw) + sys.p as f64 * op.e(sys.t_sw) / op.m
}

/// Eq 9 — prefetch wait time for a window of P suboperations in which `j`
/// memory suboperations were replaced by pre-IOs and `k` post-IOs were
/// inserted.
#[inline]
pub fn t_wait(j: usize, k: usize, op: &OpParams, l_mem: f64, sys: &SysParams) -> f64 {
    let w = l_mem
        - sys.p as f64 * (op.t_mem + sys.t_sw)
        - j as f64 * (op.t_pre - op.t_mem)
        - k as f64 * (op.t_post + sys.t_sw);
    w.max(0.0)
}

/// Natural log of n! (exact iterative; used for the test oracle).
#[cfg(test)]
fn ln_factorial(n: usize) -> f64 {
    let mut acc = 0.0;
    for i in 2..=n {
        acc += (i as f64).ln();
    }
    acc
}

/// Upper k-summation bound: p(j,k) vanishes fast; 512 is far past underflow
/// for the paper's parameter ranges.
const K_MAX: usize = 512;

/// Cumulative log-factorial table 0..=n (perf: building it per index via
/// `ln_factorial` made `wait_subop` O(K²); a single cumulative pass is O(K)).
fn ln_fact_table(n: usize) -> Vec<f64> {
    let mut t = vec![0.0f64; n + 1];
    for i in 2..=n {
        t[i] = t[i - 1] + (i as f64).ln();
    }
    t
}

/// Eq 10–12 — expected prefetch wait time per suboperation.
///
/// The probability of the (j,k) window is
/// `p(j,k) = (P+k)! / ((P-j)! j! k!) * (M/(M+2))^(P-j) * (1/(M+2))^(j+k)`
/// and the expectation is `Σ p·T_wait / Σ p·(P+k)`.
pub fn wait_subop(op: &OpParams, l_mem: f64, sys: &SysParams) -> f64 {
    let p = sys.p;
    let m = op.m;
    let ln_q_mem = (m / (m + 2.0)).ln();
    let ln_q_io = (1.0 / (m + 2.0)).ln();
    let ln_fact = ln_fact_table(K_MAX.max(p) + 1);
    let ln_fact_p_minus: Vec<f64> = (0..=p).map(|j| ln_fact[p - j]).collect();

    let mut num = 0.0;
    let mut den = 0.0;
    for j in 0..=p {
        // T_wait decreases linearly in k; once zero it stays zero, but p(j,k)
        // still contributes to the denominator, so sum k fully (to underflow).
        let mut tail_negligible = 0;
        for k in 0..=K_MAX {
            let ln_p = ln_fact[p + k] - ln_fact_p_minus[j] - ln_fact[j] - ln_fact[k]
                + (p - j) as f64 * ln_q_mem
                + (j + k) as f64 * ln_q_io;
            let pr = ln_p.exp();
            if pr < 1e-18 {
                tail_negligible += 1;
                if tail_negligible > 4 && k > p {
                    break;
                }
                continue;
            }
            tail_negligible = 0;
            num += pr * t_wait(j, k, op, l_mem, sys);
            den += pr * (p + k) as f64;
        }
    }
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Eq 13 — the paper's probabilistic memory-and-IO reciprocal throughput.
pub fn theta_prob_recip(op: &OpParams, l_mem: f64, sys: &SysParams) -> f64 {
    op.m * (op.t_mem + sys.t_sw) + op.e(sys.t_sw) + (op.m + 2.0) * wait_subop(op, l_mem, sys)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sys() -> SysParams {
        SysParams::table1_example()
    }
    fn op() -> OpParams {
        OpParams::table1_example()
    }

    #[test]
    fn eq1_eq2_eq3_limits() {
        // Single thread: throughput degrades linearly.
        assert_eq!(theta_single_recip(0.1, 5.0), 5.1);
        // Many threads, small latency: bounded by T_mem + T_sw.
        let s = sys();
        assert!((theta_multi_recip(0.1, 0.1, &s) - 0.15).abs() < 1e-12);
        // Depth wall: at L=5 with P=10, L/P = 0.5 dominates.
        assert!((theta_mem_recip(0.1, 5.0, &s) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn eq4_example_value() {
        // Paper: L* = 10 × (0.1 + 0.05) = 1.5 µs.
        assert!((l_star_memonly(0.1, &sys()) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn eq6_e_example() {
        // E = 4 + 3 + 2(0.05) = 7.1 µs.
        assert!((op().e(0.05) - 7.1).abs() < 1e-12);
    }

    #[test]
    fn eq8_example_value() {
        // Paper: L* = 1.5 + PE/M = 1.5 + 7.1 = 8.6 µs.
        assert!((l_star_io(&op(), &sys()) - 8.6).abs() < 1e-12);
    }

    #[test]
    fn masking_paper_example_29pct_at_5us() {
        // Paper (§3.2.1): masking-only predicts ~29% degradation at 5 µs with
        // Table 1 values.
        let s = sys();
        let o = op();
        let at_dram = theta_mask_recip(&o, 0.1, &s);
        let at_5us = theta_mask_recip(&o, 5.0, &s);
        let degradation = 1.0 - at_dram / at_5us;
        assert!(
            (degradation - 0.29).abs() < 0.02,
            "degradation={degradation}"
        );
    }

    #[test]
    fn prob_paper_example_7pct_at_5us() {
        // Paper (§3.2.2): the probabilistic model predicts ~7% degradation at
        // 5 µs with Table 1 values.
        let s = sys();
        let o = op();
        let at_dram = theta_prob_recip(&o, 0.1, &s);
        let at_5us = theta_prob_recip(&o, 5.0, &s);
        let degradation = 1.0 - at_dram / at_5us;
        assert!(
            (degradation - 0.07).abs() < 0.02,
            "degradation={degradation}"
        );
    }

    #[test]
    fn prob_at_short_latency_has_no_wait() {
        // At DRAM-ish latency the wait term vanishes and Eq 13 reduces to
        // M(T_mem+T_sw) + E.
        let s = sys();
        let o = op();
        let recip = theta_prob_recip(&o, 0.1, &s);
        let floor = o.m * (o.t_mem + s.t_sw) + o.e(s.t_sw);
        assert!((recip - floor).abs() < 1e-9, "recip={recip} floor={floor}");
    }

    #[test]
    fn prob_bounded_by_masking_and_best() {
        // Θ_best⁻¹ ≤ Θ_prob⁻¹ ≤ Θ_mask⁻¹ across latencies: the probabilistic
        // model sits between the perfectly-misaligned and aligned extremes.
        let s = sys();
        let o = op();
        for l in [0.1, 0.5, 1.0, 2.0, 3.0, 5.0, 8.0, 10.0] {
            let prob = theta_prob_recip(&o, l, &s);
            let mask = theta_mask_recip(&o, l, &s);
            let best = theta_best_recip(&o, l, &s);
            assert!(
                prob <= mask + 1e-9,
                "L={l}: prob={prob} > mask={mask}"
            );
            assert!(
                best <= prob + 1e-9,
                "L={l}: best={best} > prob={prob}"
            );
        }
    }

    #[test]
    fn prob_monotone_in_latency() {
        let s = sys();
        let o = op();
        let mut prev = 0.0;
        for i in 1..=100 {
            let l = i as f64 * 0.1;
            let r = theta_prob_recip(&o, l, &s);
            assert!(r >= prev - 1e-12, "not monotone at L={l}");
            prev = r;
        }
    }

    #[test]
    fn wait_subop_zero_when_latency_tiny() {
        assert_eq!(wait_subop(&op(), 0.01, &sys()), 0.0);
    }

    #[test]
    fn ln_factorial_values() {
        assert_eq!(ln_factorial(0), 0.0);
        assert_eq!(ln_factorial(1), 0.0);
        assert!((ln_factorial(5) - 120f64.ln()).abs() < 1e-12);
        assert!((ln_factorial(10) - 3628800f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn ln_fact_table_matches_iterative() {
        let t = ln_fact_table(64);
        for n in [0usize, 1, 2, 5, 10, 32, 64] {
            assert!((t[n] - ln_factorial(n)).abs() < 1e-9, "n={n}");
        }
    }
}
