//! Equations 14–15: the extended model with SSD bandwidth/IOPS caps, memory
//! bandwidth, DRAM/secondary tiering (ρ), and premature cache eviction (ε).
//!
//! §3.2.3's extension replaces the latency in Eq 9 by
//! `L ← max(ρ·L_mem + (1-ρ)·L_DRAM, (P-j)·A_mem/B_mem)` and splits the memory
//! suboperation into pre-/post-eviction cases; a post-eviction load behaves
//! like a post-IO suboperation whose time is the (tiered) memory latency.

use super::analytic::{OpParams, SysParams};

/// Extended system parameters (Table 2). Times µs, sizes bytes, rates per µs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExtParams {
    /// Offloading ratio ρ of indices/caches to secondary memory (by access).
    pub rho: f64,
    /// DRAM latency (µs).
    pub l_dram: f64,
    /// Premature CPU-cache eviction ratio ε.
    pub eps: f64,
    /// Memory access size A_mem (bytes).
    pub a_mem: f64,
    /// Max memory bandwidth B_mem (bytes per µs; e.g. 10 GB/s = 10_000 B/µs).
    pub b_mem: f64,
    /// Average IO size A_IO (bytes).
    pub a_io: f64,
    /// Max SSD bandwidth B_IO (bytes per µs), **per device**.
    pub b_io: f64,
    /// Max SSD random-access rate R_IO (IOs per µs; 2.2 MIOPS = 2.2 IO/µs),
    /// **per device**.
    pub r_io: f64,
    /// Average IOs per (whole) KV operation, S (§3.2.3 splits ops per IO).
    pub s: f64,
    /// Number of devices in the SSD array: the Eq 14 floors compose with the
    /// aggregate ceilings `Θ_ssd = n_ssd·R_IO` and `n_ssd·B_IO` (balanced
    /// shard routing assumed; skew lowers the effective n_ssd).
    pub n_ssd: f64,
}

impl ExtParams {
    /// Table 2's example values: full offload, no eviction, testbed devices.
    pub fn table2_example() -> ExtParams {
        ExtParams {
            rho: 1.0,
            l_dram: 0.09,
            eps: 0.0,
            a_mem: 64.0,
            b_mem: 10_000.0, // 10 GB/s
            a_io: 1536.0,
            b_io: 10_000.0,  // 10 GB/s
            r_io: 2.2,       // 2.2 MIOPS
            s: 1.0,
            n_ssd: 1.0,
        }
    }
}

/// Tiered average latency: ρ·L + (1-ρ)·L_DRAM (Eq 15 first term).
#[inline]
fn tiered_latency(l_mem: f64, ext: &ExtParams) -> f64 {
    ext.rho * l_mem + (1.0 - ext.rho) * ext.l_dram
}

/// Effective Eq-9 latency for a window with `j` pre-IO replacements (Eq 15).
#[inline]
fn l_eff(j: usize, l_mem: f64, ext: &ExtParams, sys: &SysParams) -> f64 {
    let bw_floor = (sys.p - j) as f64 * ext.a_mem / ext.b_mem;
    tiered_latency(l_mem, ext).max(bw_floor)
}

const K_MAX: usize = 256;

/// Θ_rev⁻¹: the probabilistic model revised for tiering, memory bandwidth,
/// and eviction. Falls back to the base model's behaviour when
/// ρ=1, ε=0, and B_mem is large.
///
/// Suboperation categories (per §3.2.3):
/// - pre-eviction memory: probability (1-ε)·M/(M+2) — behaves like `mem`,
/// - post-eviction memory: probability ε·M/(M+2) — behaves like post-IO with
///   time = tiered memory latency,
/// - pre-IO: 1/(M+2), post-IO: 1/(M+2).
///
/// A window holds P "slot" suboperations of which j are pre-IO, plus k1
/// post-IO and k2 post-eviction insertions.
pub fn theta_rev_recip(op: &OpParams, l_mem: f64, ext: &ExtParams, sys: &SysParams) -> f64 {
    let p = sys.p;
    let m = op.m;
    let l_tier = tiered_latency(l_mem, ext);

    let q_mem = (1.0 - ext.eps) * m / (m + 2.0);
    let q_pre = 1.0 / (m + 2.0);
    let q_post = 1.0 / (m + 2.0);
    let q_ev = ext.eps * m / (m + 2.0);

    let ln_q_mem = q_mem.ln();
    let ln_q_pre = q_pre.ln();
    let ln_q_post = q_post.ln();
    let ln_q_ev = if q_ev > 0.0 { q_ev.ln() } else { f64::NEG_INFINITY };

    let max_n = p + 2 * K_MAX + 2;
    let mut ln_fact = vec![0.0f64; max_n + 1];
    for i in 2..=max_n {
        ln_fact[i] = ln_fact[i - 1] + (i as f64).ln();
    }

    let k2_max = if ext.eps > 0.0 { K_MAX } else { 0 };
    let mut num = 0.0;
    let mut den = 0.0;
    for j in 0..=p {
        let le = l_eff(j, l_mem, ext, sys);
        let base =
            le - p as f64 * (op.t_mem + sys.t_sw) - j as f64 * (op.t_pre - op.t_mem);
        for k1 in 0..=K_MAX {
            let after_k1 = base - k1 as f64 * (op.t_post + sys.t_sw);
            let ln_p1 = ln_fact[p + k1] - ln_fact[p - j] - ln_fact[j] - ln_fact[k1]
                + (p - j) as f64 * ln_q_mem
                + j as f64 * ln_q_pre
                + k1 as f64 * ln_q_post;
            if ln_p1 < -60.0 && k1 > p {
                break;
            }
            for k2 in 0..=k2_max {
                let ln_pr = if k2 == 0 {
                    ln_p1
                } else {
                    // extend the multinomial with k2 post-eviction insertions
                    ln_fact[p + k1 + k2] - ln_fact[p - j] - ln_fact[j] - ln_fact[k1]
                        - ln_fact[k2]
                        + (p - j) as f64 * ln_q_mem
                        + j as f64 * ln_q_pre
                        + k1 as f64 * ln_q_post
                        + k2 as f64 * ln_q_ev
                };
                if ln_pr < -60.0 {
                    if k2 > 0 {
                        break;
                    }
                    continue;
                }
                let pr = ln_pr.exp();
                let w = (after_k1 - k2 as f64 * (l_tier + sys.t_sw)).max(0.0);
                num += pr * w;
                den += pr * (p + k1 + k2) as f64;
            }
        }
    }
    let t_wait_subop = if den > 0.0 { num / den } else { 0.0 };

    // Eq 13 assembly plus the expected synchronous-refetch cost of evicted
    // loads (ε·M loads pay the tiered latency again).
    op.m * (op.t_mem + sys.t_sw)
        + op.e(sys.t_sw)
        + (op.m + 2.0) * t_wait_subop
        + ext.eps * op.m * l_tier
}

/// Eq 14 — the full extended reciprocal throughput of a *whole* KV operation
/// with S IOs: S split-operations plus the SSD bandwidth/IOPS floors. The
/// floors use the array aggregates `Θ_ssd = n_ssd·R_IO` / `n_ssd·B_IO`:
/// SSD-bound throughput scales linearly with the array size while the
/// CPU/memory term (`S · Θ_rev⁻¹`) is unchanged — exactly the measured
/// behaviour of the sharded `sim::SsdArray`.
pub fn theta_extended_recip(op: &OpParams, l_mem: f64, ext: &ExtParams, sys: &SysParams) -> f64 {
    let per_io = theta_rev_recip(op, l_mem, ext, sys);
    let n_ssd = ext.n_ssd.max(1.0);
    let whole = ext.s * per_io;
    let bw_floor = ext.s * ext.a_io / (ext.b_io * n_ssd);
    let iops_floor = ext.s / (ext.r_io * n_ssd);
    whole.max(bw_floor).max(iops_floor)
}

#[cfg(test)]
mod tests {
    use super::super::analytic::{theta_prob_recip, OpParams, SysParams};
    use super::*;

    fn op() -> OpParams {
        OpParams::table1_example()
    }
    fn sys() -> SysParams {
        SysParams::table1_example()
    }

    #[test]
    fn reduces_to_base_model() {
        // ρ=1, ε=0, huge B_mem → Θ_rev == Θ_prob.
        let ext = ExtParams {
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        for l in [0.1, 1.0, 3.0, 5.0, 10.0] {
            let a = theta_rev_recip(&op(), l, &ext, &sys());
            let b = theta_prob_recip(&op(), l, &sys());
            assert!((a - b).abs() < 1e-6, "L={l}: rev={a} prob={b}");
        }
    }

    #[test]
    fn tiering_interpolates() {
        let sys = sys();
        let mk = |rho| ExtParams {
            rho,
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        let full = theta_rev_recip(&op(), 10.0, &mk(1.0), &sys);
        let half = theta_rev_recip(&op(), 10.0, &mk(0.5), &sys);
        let none = theta_rev_recip(&op(), 10.0, &mk(0.0), &sys);
        assert!(none < half && half < full, "none={none} half={half} full={full}");
        // ρ=0 equals running at DRAM latency.
        let dram = theta_prob_recip(&op(), 0.09, &sys);
        assert!((none - dram).abs() < 1e-9);
    }

    #[test]
    fn eviction_hurts() {
        let sys = sys();
        let clean = ExtParams {
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        let dirty = ExtParams { eps: 0.05, ..clean };
        let a = theta_rev_recip(&op(), 5.0, &clean, &sys);
        let b = theta_rev_recip(&op(), 5.0, &dirty, &sys);
        assert!(b > a, "eviction should slow things down: {a} vs {b}");
        // ε=5% of M=10 loads paying 5 µs ≈ +2.5 µs on ~8.7 µs: substantial.
        assert!(b - a > 1.5, "expected sizable penalty, got {}", b - a);
    }

    #[test]
    fn io_bandwidth_floor_caps_throughput() {
        let sys = sys();
        // Huge IOs on a slow device: A_IO/B_IO dominates at short latency.
        let ext = ExtParams {
            a_io: 128.0 * 1024.0,
            b_io: 2_500.0, // 2.5 GB/s
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        let recip_dram = theta_extended_recip(&op(), 0.1, &ext, &sys);
        let floor = ext.a_io / ext.b_io;
        assert!((recip_dram - floor).abs() < 1e-9);
        // The cap makes short-latency throughput flat: 0.1 and 2 µs agree.
        let recip_2us = theta_extended_recip(&op(), 2.0, &ext, &sys);
        assert_eq!(recip_dram, recip_2us);
    }

    #[test]
    fn iops_floor_caps_throughput() {
        let sys = sys();
        let ext = ExtParams {
            r_io: 0.075, // 75 KIOPS SATA
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        let recip = theta_extended_recip(&op(), 0.1, &ext, &sys);
        assert!((recip - 1.0 / 0.075).abs() < 1e-9);
    }

    #[test]
    fn mem_bandwidth_floor_raises_wait() {
        let sys = sys();
        // Throttle memory bandwidth hard: 64B per (P·64/B) window forces
        // waits even at DRAM-like latency.
        let slow = ExtParams {
            b_mem: 50.0, // 50 MB/s
            ..ExtParams::table2_example()
        };
        let fast = ExtParams {
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        let a = theta_rev_recip(&op(), 0.5, &slow, &sys);
        let b = theta_rev_recip(&op(), 0.5, &fast, &sys);
        assert!(a > b * 1.2, "bandwidth floor should bite: {a} vs {b}");
    }

    #[test]
    fn n_ssd_lifts_only_the_device_floors() {
        let sys = sys();
        // IOPS-bound point: 75 KIOPS per device dominates at DRAM latency.
        let mk = |n_ssd| ExtParams {
            r_io: 0.075,
            b_mem: 1e12,
            n_ssd,
            ..ExtParams::table2_example()
        };
        let r1 = theta_extended_recip(&op(), 0.1, &mk(1.0), &sys);
        let r4 = theta_extended_recip(&op(), 0.1, &mk(4.0), &sys);
        assert!((r1 - 1.0 / 0.075).abs() < 1e-9, "1-device IOPS floor");
        // 4 devices: the floor drops 4× (13.3 → 3.3 µs); the 8.6 µs CPU
        // term takes over, so throughput improves but by less than 4×.
        assert!(r4 < r1, "r1={r1} r4={r4}");
        let cpu = theta_rev_recip(&op(), 0.1, &mk(4.0), &sys);
        assert!((r4 - cpu.max(1.0 / (4.0 * 0.075))).abs() < 1e-9);
        // Away from the floors, n_ssd changes nothing (latency-bound point).
        let base1 = theta_extended_recip(&op(), 10.0, &mk(1.0), &sys);
        let base4 = theta_extended_recip(&op(), 10.0, &mk(4.0), &sys);
        let unbound = ExtParams {
            b_mem: 1e12,
            n_ssd: 8.0,
            ..ExtParams::table2_example()
        };
        let fast_dev = theta_extended_recip(&op(), 10.0, &unbound, &sys);
        assert!(base1 >= base4, "floors can only drop");
        assert_eq!(
            theta_extended_recip(&op(), 10.0, &ExtParams { n_ssd: 1.0, ..unbound }, &sys),
            fast_dev,
            "unsaturated devices: array size is invisible"
        );
    }

    #[test]
    fn s_scales_whole_op() {
        let sys = sys();
        let mk = |s| ExtParams {
            s,
            b_mem: 1e12,
            ..ExtParams::table2_example()
        };
        let one = theta_extended_recip(&op(), 1.0, &mk(1.0), &sys);
        let two = theta_extended_recip(&op(), 1.0, &mk(2.0), &sys);
        assert!((two - 2.0 * one).abs() < 1e-9);
    }
}
